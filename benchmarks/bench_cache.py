"""CN-side hot-row embedding cache: Zipf alpha x cache size sweep.

Production embedding access streams are heavily skewed (Gupta et al.),
and FlexEMR-style compute-side caching of the hot set slashes
disaggregated gather traffic without giving up memory-pool capacity
scaling.  This bench serves the same Zipf-skewed request stream through
``ClusterEngine`` uncached and with a per-CN ``RowCache``, sweeping the
skew exponent and the cache budget, and reports per point:

- cache hit rate,
- gather-byte reduction vs the uncached baseline (with the exact
  accounting identity ``bytes_saved == uncached - cached`` checked),
- modeled p99 latency reduction (hits come off the G_S NIC path).

The module asserts bitwise score parity between every cached run and
its uncached baseline — the cache moves bytes and time, never values.
``tests/test_cache_golden.py`` pins the smoke point (alpha=1.05,
cache_mb=64): >30% gather-byte reduction is the headline claim.

  PYTHONPATH=src python -m benchmarks.bench_cache [--smoke]
"""
from __future__ import annotations

import argparse
import sys

from repro.configs import rm1
from repro.configs.base import DLRMConfig
from repro.models.dlrm import DLRMModel
from repro.serving.scenario import (ScenarioSpec, Workload, plan_workload,
                                    run_scenario, smoke_topology)

from benchmarks.common import row

# 8 x 65536 x 64 fp32 rows = 128 MB of tables (256 B rows): the 64 MB
# smoke cache holds half the pool, so skew — not capacity — decides the
# hit rate, while the 8 MB point exercises eviction pressure.
CFG = rm1.CONFIG.replace(
    name="rm1-cache-bench",
    dlrm=DLRMConfig(num_tables=8, rows_per_table=65536, embed_dim=64,
                    avg_pooling=10, num_dense_features=16,
                    bottom_mlp=(32, 64), top_mlp=(64, 32, 1),
                    interaction_proj=8),
)
SMOKE_ALPHAS = (0.0, 1.05)
FULL_ALPHAS = (0.0, 0.8, 1.05, 1.2)
SMOKE_SIZES = (64.0,)
FULL_SIZES = (8.0, 64.0)
SEED = 7


def _spec(n: int, alpha: float, cache_mb: float,
          policy: str = "lru") -> ScenarioSpec:
    # batch-filling queries (sizes clip to batch_size) so batches form on
    # arrival and modeled latency is stage-dominated — the p99 delta then
    # reads the G_S reduction instead of the ingress flush deadline.
    # use_kernel=False: jnp reference pooling — the interpret-mode Pallas
    # bag costs time proportional to the resident shard size, which this
    # bench makes deliberately large (128 MB of tables) so the 64 MB
    # budget binds.  The cache layer is kernel-agnostic — byte/hit
    # accounting is identical on both paths, and kernel-vs-ref bitwise
    # parity is pinned separately by the cache test suite.
    return ScenarioSpec(
        name=f"cache-a{alpha:g}-mb{cache_mb:g}",
        topology=smoke_topology(use_kernel=False, cache_mb=cache_mb,
                                cache_policy=policy),
        workload=Workload(requests=n, mean_size=128.0, sigma=0.25,
                          max_size=32, alpha=alpha, gap_s=0.0005,
                          seed=SEED))


def _serve(model, params, n, alpha, cache_mb: float, stream=None,
           policy: str = "lru"):
    return run_scenario(_spec(n, alpha, cache_mb, policy),
                        model=model, params=params, stream=stream)


def run(smoke: bool = False) -> dict:
    model = DLRMModel(CFG)
    params = model.init(SEED)
    n_req = 40 if smoke else 64
    alphas = SMOKE_ALPHAS if smoke else FULL_ALPHAS
    sizes = SMOKE_SIZES if smoke else FULL_SIZES
    out = {}
    for alpha in alphas:
        # one seeded stream per alpha, shared by the uncached baseline
        # and every cache size (the specs differ only in topology)
        stream = plan_workload(_spec(n_req, alpha, 0.0), CFG)
        rep_u = _serve(model, params, n_req, alpha, cache_mb=0.0,
                       stream=stream)
        st_u = rep_u.stats
        gat_u = sum(st_u.mn_gather_bytes)
        for mb in sizes:
            rep_c = _serve(model, params, n_req, alpha, cache_mb=mb,
                           stream=stream)
            st_c = rep_c.stats
            bitwise = rep_c.bitwise_equal(rep_u)
            if not bitwise:
                raise AssertionError(
                    f"cache broke score parity (alpha={alpha}, {mb}MB)")
            gat_c = sum(st_c.mn_gather_bytes)
            probes = st_c.cache_hits + st_c.cache_misses
            hit_rate = st_c.cache_hits / max(probes, 1)
            reduction = 1 - gat_c / gat_u
            if st_c.cache_bytes_saved != gat_u - gat_c:
                raise AssertionError("bytes_saved accounting identity broke")
            p99_drop = 1 - st_c.p99 / st_u.p99
            key = (alpha, mb)
            out[key] = {"hit_rate": hit_rate, "reduction": reduction,
                        "p99_drop": p99_drop, "bitwise": bitwise,
                        "evictions": st_c.cache_evictions}
            row(f"cache_a{alpha}_mb{mb:g}_hit_rate_pct", 100 * hit_rate,
                f"gather -{100 * reduction:.1f}% "
                f"({gat_u / 1e6:.1f}->{gat_c / 1e6:.1f}MB), "
                f"p99 -{100 * p99_drop:.1f}% "
                f"({st_u.p99 * 1e6:.0f}->{st_c.p99 * 1e6:.0f}us), "
                f"evictions={st_c.cache_evictions}")
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small sweep (CI): alpha x {64MB} vs uncached")
    args = p.parse_args(argv)
    out = run(smoke=args.smoke)
    hot = out.get((1.05, 64.0))
    if hot and hot["reduction"] <= 0.30:
        raise AssertionError(
            f"headline gather reduction {hot['reduction']:.2%} <= 30% "
            f"at Zipf alpha=1.05 with a 64MB cache")
    return 0


if __name__ == "__main__":
    sys.exit(main())
