"""Traffic realism & SLA feedback: arrivals, queueing, hedging, control.

Three experiments over the virtual-clock serving stack:

1. **Arrival-process sweep** — the same mean rate served as ``linear``
   (evenly spaced), ``poisson``, and ``bursty`` (Markov-modulated
   Poisson) arrivals.  Stochastic arrivals pile queueing delay
   (arrival -> batch admission, ``ClusterStats.queue_wait_*``) into the
   tail that the historical evenly-spaced stream structurally could not
   produce — the Gupta et al. observation that production recommendation
   traffic is bursty, not fluid.

2. **Flash crowd, SLA controller on/off** — the ``flash_crowd`` preset
   (Poisson traffic spiking ~5x past the pool's capacity) served with
   and without ``sla_p99_s``.  With the controller, measured p99 feeds
   ``serving.autoscaler.SLAController``, which emits live ``Resize``
   events; the bench asserts the controlled run's p99 beats the
   uncontrolled one and that the pool returns to its floor.

3. **MN straggler, hedged re-issue on/off** — a mid-stream ``DegradeMN``
   slows one MN's bus 8x; with ``hedge_multiplier`` set, scans
   straggling past the multiplier re-issue on replica buses (FlexEMR's
   optimistic get) and the batch proceeds at the first finisher.  The
   bench asserts hedging reduces p99 AND that scores stay
   bitwise-identical — hedging moves time, never values.

4. **Router x controller grid** — the flash crowd served under every
   ``cn_router`` policy x {coupled, decoupled} SLA scaling.  The crowd
   is compute-bound, so the coupled controller's lockstep steps buy MNs
   that never help; the decoupled controller attributes the breach to
   the CN pool and leaves the MN pool at its floor.  The bench asserts
   decoupled holds p99 at least as well as coupled in every router with
   strictly fewer MN node-seconds, and that ``pipeline_free`` beats the
   legacy ``cpu_free`` tail in both modes.  ``--json PATH`` dumps the
   grid for CI artifacts.

  PYTHONPATH=src python -m benchmarks.bench_sla [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.serving.cluster import CN_ROUTERS
from repro.serving.scenario import (DegradeMN, ScenarioSpec, Workload,
                                    preset, run_scenario, smoke_topology)

from benchmarks.common import row

SEED = 7
GAP_S = 1e-6          # shared mean inter-arrival for the sweep
ARRIVALS = ("linear", "poisson", "bursty")
SLA_MODES = ("coupled", "decoupled")


def _arrival_spec(kind: str, n: int) -> ScenarioSpec:
    return ScenarioSpec(
        name=f"arrivals-{kind}",
        topology=smoke_topology(inflight_depth=4, max_wait_s=2e-5),
        workload=Workload(requests=n, gap_s=GAP_S, arrival=kind,
                          seed=SEED))


def sweep_arrivals(n: int) -> dict:
    out = {}
    for kind in ARRIVALS:
        st = run_scenario(_arrival_spec(kind, n)).stats
        out[kind] = st
        row(f"sla_arrival_{kind}_p99_us", st.p99 * 1e6,
            f"queue_wait mean {st.queue_wait_mean * 1e6:.2f}us "
            f"p99 {st.queue_wait_p99 * 1e6:.2f}us "
            f"(same mean rate, {n} reqs)")
    return out


def flash_crowd(n: int) -> dict:
    spec = preset("flash_crowd")
    spec = dataclasses.replace(
        spec, workload=dataclasses.replace(spec.workload, requests=n))
    rep_on = run_scenario(spec)
    rep_off = run_scenario(dataclasses.replace(spec, sla_p99_s=None))
    on, off = rep_on.stats, rep_off.stats
    row("sla_flash_crowd_p99_on_us", on.p99 * 1e6,
        f"controller held the crowd: {on.sla_actions} resize actions, "
        f"final pool {{{rep_on.final_n_cn} CN, {rep_on.final_m_mn} MN}}")
    row("sla_flash_crowd_p99_off_us", off.p99 * 1e6,
        f"uncontrolled baseline ({off.p99 / on.p99:.2f}x the "
        f"controlled tail)")
    if not on.sla_actions:
        raise AssertionError("SLA controller never acted on the crowd")
    if not on.sla_window_filled:
        raise AssertionError(
            "p99 window never filled — the crowd is too short for the "
            "controller to see")
    if not on.p99 < off.p99:
        raise AssertionError(
            f"controller failed to hold p99: on={on.p99:g} "
            f"off={off.p99:g}")
    if (rep_on.final_n_cn, rep_on.final_m_mn) != (spec.topology.n_cn,
                                                  spec.topology.m_mn):
        raise AssertionError(
            f"pool did not return to its floor: "
            f"{{{rep_on.final_n_cn}, {rep_on.final_m_mn}}}")
    return {"on": on, "off": off}


def _mn_node_seconds(spec: ScenarioSpec, rep) -> float:
    """MN capacity actually provisioned over the run: integrate the MN
    pool size across the audit trail (each ``EventRecord`` carries the
    pool it left behind) from t=0 to the makespan.  This is the TCO
    denominator the decoupled controller exists to shrink."""
    st = rep.stats
    m, t, total = spec.topology.m_mn, 0.0, 0.0
    for r in st.events:
        tt = min(max(r.time_s, t), st.makespan_s)
        total += m * (tt - t)
        t, m = tt, r.m_mn
    return total + m * max(0.0, st.makespan_s - t)


def router_controller_grid(n: int) -> dict:
    spec = preset("flash_crowd")
    spec = dataclasses.replace(
        spec, workload=dataclasses.replace(spec.workload, requests=n))
    grid: dict = {}
    for router in CN_ROUTERS:
        for mode in SLA_MODES:
            s = dataclasses.replace(
                spec, sla_mode=mode,
                topology=dataclasses.replace(spec.topology,
                                             cn_router=router))
            rep = run_scenario(s)
            st = rep.stats
            cell = {
                "router": router, "mode": mode,
                "p99_us": st.p99 * 1e6,
                "sla_actions": st.sla_actions,
                "sla_actions_cn": st.sla_actions_cn,
                "sla_actions_mn": st.sla_actions_mn,
                "mn_node_seconds": _mn_node_seconds(s, rep),
                "window_filled": st.sla_window_filled,
            }
            grid[(router, mode)] = cell
            row(f"sla_grid_{router}_{mode}_p99_us", cell["p99_us"],
                f"{st.sla_actions} actions ({st.sla_actions_cn} CN-dim, "
                f"{st.sla_actions_mn} MN-dim), "
                f"{cell['mn_node_seconds'] * 1e3:.3f} MN node-ms")
            if not st.sla_actions:
                raise AssertionError(
                    f"{router}/{mode}: controller never acted on the "
                    f"crowd")
            if not st.sla_window_filled:
                raise AssertionError(
                    f"{router}/{mode}: p99 window never filled")
    for router in CN_ROUTERS:
        coup, dec = grid[(router, "coupled")], grid[(router, "decoupled")]
        # the crowd is compute-bound: decoupling must hold the tail at
        # least as well while provisioning strictly less MN capacity
        if dec["p99_us"] > coup["p99_us"]:
            raise AssertionError(
                f"{router}: decoupled p99 {dec['p99_us']:.1f}us worse "
                f"than coupled {coup['p99_us']:.1f}us")
        if not dec["mn_node_seconds"] < coup["mn_node_seconds"]:
            raise AssertionError(
                f"{router}: decoupled bought as much MN capacity as "
                f"coupled ({dec['mn_node_seconds']:g} vs "
                f"{coup['mn_node_seconds']:g} node-s)")
        if dec["sla_actions_mn"] >= coup["sla_actions_mn"]:
            raise AssertionError(
                f"{router}: decoupled emitted {dec['sla_actions_mn']} "
                f"MN-dim actions, coupled {coup['sla_actions_mn']}")
    for mode in SLA_MODES:
        if (grid[("pipeline_free", mode)]["p99_us"]
                >= grid[("cpu_free", mode)]["p99_us"]):
            raise AssertionError(
                f"{mode}: pipeline_free did not beat cpu_free p99")
    return grid


def straggler_hedge(n: int, factor: float = 8.0) -> dict:
    base = ScenarioSpec(
        name="straggler",
        topology=smoke_topology(inflight_depth=4, max_wait_s=2e-5),
        workload=Workload(requests=n, gap_s=GAP_S, seed=SEED),
        events=(DegradeMN(5e-5, mn=1, factor=factor),))
    rep_off = run_scenario(base)
    rep_on = run_scenario(dataclasses.replace(
        base, topology=dataclasses.replace(base.topology,
                                           hedge_multiplier=2.0)))
    on, off = rep_on.stats, rep_off.stats
    row("sla_hedge_p99_off_us", off.p99 * 1e6,
        f"one MN bus degraded {factor:g}x mid-stream, no hedging")
    row("sla_hedge_p99_on_us", on.p99 * 1e6,
        f"{on.hedges} hedged scans, {on.hedge_wins} won "
        f"(-{100 * (1 - on.p99 / off.p99):.1f}% p99)")
    if not on.hedges:
        raise AssertionError("no hedges issued against the straggler")
    if not on.p99 < off.p99:
        raise AssertionError(
            f"hedging failed to cut p99: on={on.p99:g} off={off.p99:g}")
    if not rep_on.bitwise_equal(rep_off):
        raise AssertionError("hedging broke bitwise score parity")
    return {"on": on, "off": off}


def run(smoke: bool = False) -> dict:
    n_sweep = 256 if smoke else 512
    n_flash = 960          # the preset's full arc (up AND back down)
    n_strag = 256 if smoke else 512
    return {
        "arrivals": sweep_arrivals(n_sweep),
        "flash_crowd": flash_crowd(n_flash),
        "straggler": straggler_hedge(n_strag),
        "grid": router_controller_grid(n_flash),
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized runs (same assertions)")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="dump the router x controller grid as a JSON "
                        "artifact")
    args = p.parse_args(argv)
    out = run(smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"sla_grid": list(out["grid"].values())}, f,
                      indent=2)
        print(f"[bench_sla] grid written to {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
