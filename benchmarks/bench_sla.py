"""Traffic realism & SLA feedback: arrivals, queueing, hedging, control.

Three experiments over the virtual-clock serving stack:

1. **Arrival-process sweep** — the same mean rate served as ``linear``
   (evenly spaced), ``poisson``, and ``bursty`` (Markov-modulated
   Poisson) arrivals.  Stochastic arrivals pile queueing delay
   (arrival -> batch admission, ``ClusterStats.queue_wait_*``) into the
   tail that the historical evenly-spaced stream structurally could not
   produce — the Gupta et al. observation that production recommendation
   traffic is bursty, not fluid.

2. **Flash crowd, SLA controller on/off** — the ``flash_crowd`` preset
   (Poisson traffic spiking ~5x past the pool's capacity) served with
   and without ``sla_p99_s``.  With the controller, measured p99 feeds
   ``serving.autoscaler.SLAController``, which emits live ``Resize``
   events; the bench asserts the controlled run's p99 beats the
   uncontrolled one and that the pool returns to its floor.

3. **MN straggler, hedged re-issue on/off** — a mid-stream ``DegradeMN``
   slows one MN's bus 8x; with ``hedge_multiplier`` set, scans
   straggling past the multiplier re-issue on replica buses (FlexEMR's
   optimistic get) and the batch proceeds at the first finisher.  The
   bench asserts hedging reduces p99 AND that scores stay
   bitwise-identical — hedging moves time, never values.

  PYTHONPATH=src python -m benchmarks.bench_sla [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

from repro.serving.scenario import (DegradeMN, ScenarioSpec, Workload,
                                    preset, run_scenario, smoke_topology)

from benchmarks.common import row

SEED = 7
GAP_S = 1e-6          # shared mean inter-arrival for the sweep
ARRIVALS = ("linear", "poisson", "bursty")


def _arrival_spec(kind: str, n: int) -> ScenarioSpec:
    return ScenarioSpec(
        name=f"arrivals-{kind}",
        topology=smoke_topology(inflight_depth=4, max_wait_s=2e-5),
        workload=Workload(requests=n, gap_s=GAP_S, arrival=kind,
                          seed=SEED))


def sweep_arrivals(n: int) -> dict:
    out = {}
    for kind in ARRIVALS:
        st = run_scenario(_arrival_spec(kind, n)).stats
        out[kind] = st
        row(f"sla_arrival_{kind}_p99_us", st.p99 * 1e6,
            f"queue_wait mean {st.queue_wait_mean * 1e6:.2f}us "
            f"p99 {st.queue_wait_p99 * 1e6:.2f}us "
            f"(same mean rate, {n} reqs)")
    return out


def flash_crowd(n: int) -> dict:
    spec = preset("flash_crowd")
    spec = dataclasses.replace(
        spec, workload=dataclasses.replace(spec.workload, requests=n))
    rep_on = run_scenario(spec)
    rep_off = run_scenario(dataclasses.replace(spec, sla_p99_s=None))
    on, off = rep_on.stats, rep_off.stats
    row("sla_flash_crowd_p99_on_us", on.p99 * 1e6,
        f"controller held the crowd: {on.sla_actions} resize actions, "
        f"final pool {{{rep_on.final_n_cn} CN, {rep_on.final_m_mn} MN}}")
    row("sla_flash_crowd_p99_off_us", off.p99 * 1e6,
        f"uncontrolled baseline ({off.p99 / on.p99:.2f}x the "
        f"controlled tail)")
    if not on.sla_actions:
        raise AssertionError("SLA controller never acted on the crowd")
    if not on.p99 < off.p99:
        raise AssertionError(
            f"controller failed to hold p99: on={on.p99:g} "
            f"off={off.p99:g}")
    if (rep_on.final_n_cn, rep_on.final_m_mn) != (spec.topology.n_cn,
                                                  spec.topology.m_mn):
        raise AssertionError(
            f"pool did not return to its floor: "
            f"{{{rep_on.final_n_cn}, {rep_on.final_m_mn}}}")
    return {"on": on, "off": off}


def straggler_hedge(n: int, factor: float = 8.0) -> dict:
    base = ScenarioSpec(
        name="straggler",
        topology=smoke_topology(inflight_depth=4, max_wait_s=2e-5),
        workload=Workload(requests=n, gap_s=GAP_S, seed=SEED),
        events=(DegradeMN(5e-5, mn=1, factor=factor),))
    rep_off = run_scenario(base)
    rep_on = run_scenario(dataclasses.replace(
        base, topology=dataclasses.replace(base.topology,
                                           hedge_multiplier=2.0)))
    on, off = rep_on.stats, rep_off.stats
    row("sla_hedge_p99_off_us", off.p99 * 1e6,
        f"one MN bus degraded {factor:g}x mid-stream, no hedging")
    row("sla_hedge_p99_on_us", on.p99 * 1e6,
        f"{on.hedges} hedged scans, {on.hedge_wins} won "
        f"(-{100 * (1 - on.p99 / off.p99):.1f}% p99)")
    if not on.hedges:
        raise AssertionError("no hedges issued against the straggler")
    if not on.p99 < off.p99:
        raise AssertionError(
            f"hedging failed to cut p99: on={on.p99:g} off={off.p99:g}")
    if not rep_on.bitwise_equal(rep_off):
        raise AssertionError("hedging broke bitwise score parity")
    return {"on": on, "off": off}


def run(smoke: bool = False) -> dict:
    n_sweep = 256 if smoke else 512
    n_flash = 960          # the preset's full arc (up AND back down)
    n_strag = 256 if smoke else 512
    return {
        "arrivals": sweep_arrivals(n_sweep),
        "flash_crowd": flash_crowd(n_flash),
        "straggler": straggler_hedge(n_strag),
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized runs (same assertions)")
    args = p.parse_args(argv)
    run(smoke=args.smoke)
    return 0


if __name__ == "__main__":
    sys.exit(main())
