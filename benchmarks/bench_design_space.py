"""Paper Fig. 12: {n CN, m MN} design-space grid for RM1.V0 — throughput,
power, allocated nodes, normalized TCO; diagonal = monolithic scale-out."""
from __future__ import annotations

from repro.configs import rm1
from repro.core import allocator
from repro.core.serving_unit import ServingUnitModel, UnitSpec

from benchmarks.common import row

PEAK_LOAD = 2e5  # samples/s fleet load


def run() -> dict:
    m = rm1.generation(0)
    out = {"grid": {}}

    # diagonal: monolithic SO-1S scale-out (2, 4, 8 servers)
    base_tco = None
    for n in (2, 4, 8):
        u = UnitSpec(n, "so1s_1g", scheme="distributed")
        sm = ServingUnitModel(m, u)
        if not sm.fits():
            continue
        plan = allocator.allocate_from_model(m, u, PEAK_LOAD)
        if base_tco is None:
            base_tco = plan.tco
        out["grid"][f"mono_{n}"] = (plan.qps_per_unit, plan.tco)
        row(f"fig12_mono_so1s_x{n}_qps", plan.qps_per_unit,
            f"tco_norm={plan.tco / base_tco:.2f}")

    # 2D disaggregated grid
    best = None
    for n in (1, 2, 3, 4, 6, 8):
        for mm in (2, 4, 8, 12, 16):
            u = UnitSpec(n, "cn_1g", mm, "ddr_mn")
            sm = ServingUnitModel(m, u)
            if not sm.fits():
                continue
            try:
                plan = allocator.allocate_from_model(m, u, PEAK_LOAD)
            except ValueError:
                continue
            out["grid"][f"disagg_{n}_{mm}"] = (plan.qps_per_unit, plan.tco)
            if best is None or plan.tco < best[2]:
                best = (n, mm, plan.tco, plan.qps_per_unit)
    n, mm, tco_, qps = best
    row("fig12_best_disagg", qps,
        f"{{{n}CN,{mm}MN}} tco_norm={tco_ / base_tco:.2f} (paper: {{3,8}} -2% QPS)")
    mono8 = out["grid"].get("mono_8")
    if mono8:
        row("fig12_disagg_vs_mono8_qps_pct",
            100 * (qps / mono8[0] - 1), "paper: -2%")
    out["best"] = best
    out["base_tco"] = base_tco
    return out
