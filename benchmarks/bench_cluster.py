"""ClusterEngine end-to-end: multi-unit routed serving (paper §IV/§V).

Serves a reduced-RM1 query stream through the real-JAX ClusterEngine at
{2 CN, 4 MN} with 2x replication, once clean and once with an MN killed
mid-stream, and reports the routed-access imbalance plus the latency
cross-check against the analytic serving-unit model.
"""
from __future__ import annotations

from repro import configs
from repro.data.queries import QueryDist, dlrm_request_stream
from repro.models.dlrm import DLRMModel
from repro.serving.cluster import ClusterConfig, ClusterEngine
from repro.serving.engine import Request

from benchmarks.common import row, time_call


def _requests(cfg, n, seed=0):
    return [Request(*t) for t in dlrm_request_stream(
        cfg, n, seed=seed, dist=QueryDist(mean_size=8.0, max_size=64))]


def run() -> dict:
    cfg = configs.get_reduced("rm1")
    model = DLRMModel(cfg)
    params = model.init(0)
    reqs = _requests(cfg, 32, seed=0)
    out = {}

    cc = ClusterConfig(n_cn=2, m_mn=4, batch_size=32, n_replicas=2)
    us = time_call(
        lambda: ClusterEngine(model, params, cc).serve(reqs),
        reps=1, warmup=1)
    eng = ClusterEngine(model, params, cc)
    _, st = eng.serve(reqs)
    v = eng.validate_latency_model()
    row("cluster_serve_32q_us", us,
        f"p95_ms={st.p95 * 1e3:.3f},imbalance={st.imbalance:.3f},"
        f"lat_model_ratio={v['ratio']:.2f}")
    out["clean"] = st

    us_f = time_call(
        lambda: ClusterEngine(model, params, cc).serve(
            reqs, failures=[(0.03, 1)]),
        reps=1, warmup=1)
    engf = ClusterEngine(model, params, cc)
    _, stf = engf.serve(reqs, failures=[(0.03, 1)])
    row("cluster_serve_mn_fail_us", us_f,
        f"completed={stf.completed}/32,reroutes={stf.reroutes},"
        f"reinits={stf.reinits}")
    out["failure"] = stf

    # heterogeneous pool: NMP MNs pool on-node, ship only Fsum vectors
    cch = ClusterConfig(n_cn=2, m_mn=4, batch_size=32, n_replicas=2,
                        mn_types=["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"])
    us_h = time_call(
        lambda: ClusterEngine(model, params, cch).serve(reqs),
        reps=1, warmup=1)
    engh = ClusterEngine(model, params, cch)
    _, sth = engh.serve(reqs)
    gat_ddr = sum(st.mn_gather_bytes)
    gat_het = sum(sth.mn_gather_bytes)
    row("cluster_serve_hetero_us", us_h,
        f"gather_bytes={gat_het:.0f} (ddr pool {gat_ddr:.0f}, "
        f"{100 * (1 - gat_het / gat_ddr):.1f}% saved),"
        f"lat_model_ratio={engh.validate_latency_model()['ratio']:.2f}")
    out["hetero"] = sth
    return out
