"""ClusterEngine end-to-end: multi-unit routed serving (paper §IV/§V).

Serves a reduced-RM1 query stream through the scenario front door
(``serving.scenario.run_scenario``) at {2 CN, 4 MN} with 2x replication
— once clean, once with an MN killed mid-stream (a ``FailMN`` event),
and once on the heterogeneous DDR+NMP pool — and reports the
routed-access imbalance plus the latency cross-check against the
analytic serving-unit model.

The ``bench_pipeline`` slice sweeps ``inflight_depth`` 1 -> 8 over a
backlogged burst and reports modeled throughput per depth: it should
rise with depth and saturate once the bottleneck resource (the gather
NIC on the all-DDR smoke pool) hits full utilization — scores stay
bitwise-identical to depth 1 at every depth (paper §IV pipelining).

  PYTHONPATH=src python -m benchmarks.bench_cluster [--smoke]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro import configs
from repro.models.dlrm import DLRMModel
from repro.serving.scenario import (FailMN, ScenarioSpec, Workload,
                                    run_scenario, smoke_topology)

from benchmarks.common import row, time_call

DEPTHS = (1, 2, 4, 8)


def _specs(n_req: int):
    clean = ScenarioSpec(
        name="cluster-clean",
        topology=smoke_topology(),
        workload=Workload(requests=n_req, seed=0))
    failure = ScenarioSpec(
        name="cluster-mn-fail",
        topology=smoke_topology(),
        workload=Workload(requests=n_req, seed=0),
        events=(FailMN(0.03, mn=1),))
    hetero = ScenarioSpec(
        name="cluster-hetero",
        topology=smoke_topology(
            mn_types=("ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn")),
        workload=Workload(requests=n_req, seed=0))
    return clean, failure, hetero


def run(smoke: bool = False) -> dict:
    cfg = configs.get_reduced("rm1")
    model = DLRMModel(cfg)
    params = model.init(0)
    n_req = 16 if smoke else 32
    clean, failure, hetero = _specs(n_req)
    out = {}

    us = time_call(
        lambda: run_scenario(clean, model=model, params=params),
        reps=1, warmup=1)
    rep = run_scenario(clean, model=model, params=params)
    st = rep.stats
    v = rep.latency_model
    row(f"cluster_serve_{n_req}q_us", us,
        f"p95_ms={st.p95 * 1e3:.3f},imbalance={st.imbalance:.3f},"
        f"lat_model_ratio={v['ratio']:.2f}")
    out["clean"] = st

    us_f = time_call(
        lambda: run_scenario(failure, model=model, params=params),
        reps=1, warmup=1)
    repf = run_scenario(failure, model=model, params=params)
    stf = repf.stats
    row("cluster_serve_mn_fail_us", us_f,
        f"completed={stf.completed}/{n_req},reroutes={stf.reroutes},"
        f"reinits={stf.reinits}")
    out["failure"] = stf

    # heterogeneous pool: NMP MNs pool on-node, ship only Fsum vectors
    us_h = time_call(
        lambda: run_scenario(hetero, model=model, params=params),
        reps=1, warmup=1)
    reph = run_scenario(hetero, model=model, params=params)
    sth = reph.stats
    gat_ddr = sum(st.mn_gather_bytes)
    gat_het = sum(sth.mn_gather_bytes)
    row("cluster_serve_hetero_us", us_h,
        f"gather_bytes={gat_het:.0f} (ddr pool {gat_ddr:.0f}, "
        f"{100 * (1 - gat_het / gat_ddr):.1f}% saved),"
        f"lat_model_ratio={reph.latency_model['ratio']:.2f}")
    out["hetero"] = sth

    # pipelined overlap: backlogged burst, depth sweep 1 -> 8.  The
    # tail batch's flush wait is clamped so makespan measures the
    # pipeline, not the batcher deadline.
    n_burst = 32 if smoke else 64
    base = None
    sweep = {}
    for d in DEPTHS:
        spec = ScenarioSpec(
            name=f"cluster-pipeline-d{d}",
            topology=smoke_topology(inflight_depth=d, max_wait_s=2e-5),
            workload=Workload(requests=n_burst, gap_s=0.0, seed=5))
        repp = run_scenario(spec, model=model, params=params)
        stp = repp.stats
        if base is None:
            base = repp
        else:
            assert all(
                np.array_equal(a.outputs, b.outputs)
                for a, b in zip(base.results, repp.results)), \
                f"depth={d} perturbed scores vs depth=1"
        bottleneck = max(stp.resource_util, key=stp.resource_util.get)
        row(f"cluster_pipeline_d{d}_qps", stp.throughput_qps,
            f"speedup={stp.throughput_qps / base.stats.throughput_qps:.2f}x,"
            f"bottleneck={bottleneck}"
            f"@{stp.resource_util[bottleneck]:.2f}")
        sweep[d] = stp
    out["pipeline"] = sweep
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small request stream (CI)")
    args = p.parse_args(argv)
    run(smoke=args.smoke)
    return 0


if __name__ == "__main__":
    sys.exit(main())
