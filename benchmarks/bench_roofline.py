"""§Roofline: aggregate the dry-run JSON records into the roofline table
(compute / memory / collective terms per arch x shape x mesh)."""
from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.configs.base import SHAPES

from benchmarks.common import row
from benchmarks.roofline import model_flops, roofline_terms


def load_records(dryrun_dir: str = "results/dryrun"):
    recs = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(fn) as f:
            d = json.load(f)
        c = d.get("collectives")
        if c and not c.get("ar_weighted"):
            c["total"] += c.get("all-reduce", 0.0)   # ring AR = 2x payload
            c["all-reduce"] = 2 * c.get("all-reduce", 0.0)
            c["ar_weighted"] = True
        recs.append(d)
    return recs


def summarize(rec: dict) -> dict:
    from benchmarks.roofline import analytic_hbm_bytes

    arch, shape_name = rec["arch"], rec["shape"]
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    chips = rec.get("devices", 256)
    flops = rec.get("hlo_scaled", {}).get("flops", 0.0) * chips
    # fusion-realistic analytic lower bound (see EXPERIMENTS.md §Roofline)
    hbm = analytic_hbm_bytes(cfg, shape, chips) * chips
    coll = rec.get("collectives", {}).get("total", 0.0) * chips
    terms = roofline_terms(flops, hbm, coll, chips)
    mf = model_flops(cfg, shape)
    terms["model_flops"] = mf
    terms["hlo_flops"] = flops
    terms["useful_ratio"] = mf / flops if flops else 0.0
    terms["mem_gib"] = rec.get("memory", {}).get(
        "total_per_device_bytes", 0) / 2 ** 30
    return terms


def run() -> dict:
    out = {}
    for rec in load_records():
        if rec.get("status") != "ok":
            continue
        key = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
        t = summarize(rec)
        out[key] = t
        row(f"roofline_{key}",
            max(t['compute_s'], t['memory_s'], t['collective_s']) * 1e6,
            f"bound={t['bottleneck']} c={t['compute_s']:.3f}s "
            f"m={t['memory_s']:.3f}s n={t['collective_s']:.3f}s "
            f"useful={t['useful_ratio']:.2f} mem={t['mem_gib']:.1f}GiB")
    return out
