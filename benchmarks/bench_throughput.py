"""Paper Fig. 5: throughput-latency tradeoff + batch-size sweep for
RM1.V0 on two SO-1S servers (latency-bounded throughput peaks at an
intermediate batch; SLA violated at batch 2048)."""
from __future__ import annotations

from repro.configs import rm1
from repro.core.serving_unit import ServingUnitModel, UnitSpec

from benchmarks.common import row


def run() -> dict:
    m = rm1.generation(0)
    sm = ServingUnitModel(m, UnitSpec(2, "so1s_1g", scheme="distributed"))
    best_qps, best_b = sm.latency_bounded_qps(sla=0.1)
    out = {"batch_sweep": {}}
    for b in (32, 64, 128, 256, 512, 1024, 2048):
        total = sm.stage_times(b).total()
        # rate search at this batch only
        lo, hi = 0.0, sm.peak_qps(b)
        for _ in range(30):
            mid = 0.5 * (lo + hi)
            if sm.p95_latency(b, mid) <= 0.1:
                lo = mid
            else:
                hi = mid
        out["batch_sweep"][b] = (lo, total)
        row(f"fig5_qps_batch_{b}", lo,
            f"pipeline={total * 1e3:.1f}ms" + (" SLA-infeasible" if total > 0.1 else ""))
    out["best"] = (best_qps, best_b)
    row("fig5_best_qps", best_qps, f"best batch={best_b} (paper: 128)")
    return out
