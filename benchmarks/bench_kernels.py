"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU perf —
these rows exist to regression-track kernel call overheads + validate
numerics at bench scale; roofline numbers come from the dry-run)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from benchmarks.common import block, row, time_call


def run() -> dict:
    rng = np.random.RandomState(0)
    out = {}

    tables = jnp.asarray(rng.randn(8, 512, 64), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 512, (16, 8, 20)), jnp.int32)
    us = time_call(lambda: block(ops.embedding_bag(tables, idx)))
    err = float(jnp.max(jnp.abs(
        ops.embedding_bag(tables, idx) - ref.embedding_bag_ref(tables, idx))))
    row("kernel_embedding_bag_us", us, f"maxerr={err:.2e}")
    out["embedding_bag"] = (us, err)

    # fused multi-table: one pallas_call for the whole table stack vs the
    # vmapped per-table kernel above (one launch per table)
    us_f = time_call(lambda: block(ops.embedding_bag_fused(tables, idx)))
    err_f = float(jnp.max(jnp.abs(
        ops.embedding_bag_fused(tables, idx)
        - ref.embedding_bag_ref(tables, idx))))
    row("kernel_embedding_bag_fused_us", us_f,
        f"maxerr={err_f:.2e},vs_vmapped={us / max(us_f, 1e-9):.2f}x")
    out["embedding_bag_fused"] = (us_f, err_f)

    q = jnp.asarray(rng.randn(1, 4, 256, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 256, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 256, 32), jnp.float32)
    us = time_call(lambda: block(ops.flash_attention(q, k, v)))
    err = float(jnp.max(jnp.abs(
        ops.flash_attention(q, k, v)
        - ref.flash_attention_ref(q, k, v, causal=True))))
    row("kernel_flash_attention_us", us, f"maxerr={err:.2e}")
    out["flash_attention"] = (us, err)

    q1 = jnp.asarray(rng.randn(2, 8, 32), jnp.float32)
    kc = jnp.asarray(rng.randn(2, 256, 4, 32), jnp.float32)
    vc = jnp.asarray(rng.randn(2, 256, 4, 32), jnp.float32)
    pos = jnp.asarray(200, jnp.int32)
    us = time_call(lambda: block(ops.flash_decode_partial(q1, kc, vc, pos)[0]))
    o1, l1, m1 = ops.flash_decode_partial(q1, kc, vc, pos)
    o2, l2, m2 = ref.flash_decode_ref(q1, kc, vc, pos)
    err = float(jnp.max(jnp.abs(o1 - o2)))
    row("kernel_flash_decode_us", us, f"maxerr={err:.2e}")
    out["flash_decode"] = (us, err)
    return out
