"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable


def time_call(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def block(x):
    import jax
    return jax.block_until_ready(x)


def row(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line)
    return line
