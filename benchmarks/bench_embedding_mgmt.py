"""Paper Fig. 7(d): greedy vs random embedding allocation + routing
(thousands of tables on 8 MNs)."""
from __future__ import annotations

import numpy as np

from repro.core import embedding_manager as em

from benchmarks.common import row


def run() -> dict:
    rng = np.random.RandomState(0)
    tables = [em.TableInfo(i, int(rng.lognormal(14, 1.2)) + 1, 128,
                           float(rng.lognormal(4, 1.0)) + 1)
              for i in range(4000)]
    caps = [int(2.2 * sum(t.size_bytes for t in tables) / 8)] * 8

    g = em.allocate_greedy(tables, caps)
    r = em.allocate_random(tables, caps)
    rg = em.route_greedy(tables, g, 4, 8)
    rr = em.route_random(tables, r, 4, 8)

    out = {
        "alloc_imbalance_greedy": em.imbalance(g.mn_used),
        "alloc_imbalance_random": em.imbalance(r.mn_used),
        "route_imbalance_greedy": em.imbalance(rg.mn_access),
        "route_imbalance_random": em.imbalance(rr.mn_access),
        "n_replicas": g.n_replicas,
    }
    row("fig7d_alloc_imbalance_greedy", out["alloc_imbalance_greedy"],
        "max/mean capacity, 8 MNs")
    row("fig7d_alloc_imbalance_random", out["alloc_imbalance_random"], "")
    row("fig7d_route_imbalance_greedy", out["route_imbalance_greedy"],
        "max/mean accesses")
    row("fig7d_route_imbalance_random", out["route_imbalance_random"], "")
    return out
