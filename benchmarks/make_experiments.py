"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
results/dryrun JSON records.

  PYTHONPATH=src:. python -m benchmarks.make_experiments > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro import configs
from repro.configs.base import SHAPES

from benchmarks.roofline import (HBM_BW, analytic_hbm_bytes, model_flops,
                                 roofline_terms)

ARCH_ORDER = list(configs.ASSIGNED_ARCHS)
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load(dryrun_dir="results/dryrun"):
    recs = {}
    for fn in glob.glob(os.path.join(dryrun_dir, "*.json")):
        d = json.load(open(fn))
        c = d.get("collectives")
        if c and not c.get("ar_weighted"):
            # legacy parse: weight ring all-reduce at 2x payload
            c["total"] = c["total"] + c.get("all-reduce", 0.0)
            c["all-reduce"] = 2 * c.get("all-reduce", 0.0)
            c["ar_weighted"] = True
        recs[(d["arch"], d["shape"], d["mesh"])] = d
    return recs


def dryrun_table(recs, mesh):
    print(f"\n### Dry-run — {mesh} pod "
          f"({'512' if mesh == 'multi' else '256'} chips)\n")
    print("| arch | shape | status | mem/chip (GiB) | HLO GFLOPs/chip | "
          "collective GB/chip | AR/AG/RS/A2A/CP |")
    print("|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                print(f"| {arch} | {shape} | MISSING | | | | |")
                continue
            if r["status"] == "skip":
                print(f"| {arch} | {shape} | skip (full-attn) | — | — | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {arch} | {shape} | ERROR | | | | |")
                continue
            mem = fmt_bytes(r["memory"]["total_per_device_bytes"])
            fl = r.get("hlo_scaled", {}).get("flops", 0) / 1e9
            c = r.get("collectives", {})
            cnt = c.get("counts", {})
            ops = "/".join(str(cnt.get(k, 0)) for k in
                           ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute"))
            print(f"| {arch} | {shape} | ok | {mem} | {fl:.1f} | "
                  f"{c.get('total', 0)/1e9:.2f} | {ops} |")


def roofline_table(recs):
    print("\n### Roofline — single pod (v5e: 197 TF/s bf16, 819 GB/s HBM, "
          "50 GB/s/link)\n")
    print("Memory is dual-reported: `mem-hi` counts every HLO intermediate "
          "(non-fusing CPU backend = upper bound); `mem-lo` is the "
          "fusion-realistic analytic traffic (params+opt+boundary "
          "activations+caches). The bound column uses mem-lo.\n")
    print("| arch | shape | compute | mem-lo | mem-hi | collective | bound | "
          "MODEL/HLO flops | fit GiB | next lever |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    levers = {
        "compute_s": "skip fully-masked causal blocks / trim padded heads",
        "memory_s": "Pallas-fused attention + opt-state in bf16",
        "collective_s": "overlap grad-AR with bwd dots / int8 compression",
    }
    for arch in ARCH_ORDER:
        cfg = configs.get_config(arch)
        for shape_name in SHAPE_ORDER:
            r = recs.get((arch, shape_name, "single"))
            if r is None or r["status"] != "ok":
                continue
            shape = SHAPES[shape_name]
            chips = r.get("devices", 256)
            flops = r.get("hlo_scaled", {}).get("flops", 0.0) * chips
            hbm_hi = r.get("hlo_scaled", {}).get("bytes", 0.0) * chips
            hbm_lo = analytic_hbm_bytes(cfg, shape, chips) * chips
            coll = r.get("collectives", {}).get("total", 0.0) * chips
            t = roofline_terms(flops, hbm_lo, coll, chips)
            hi_s = hbm_hi / (chips * HBM_BW)
            mf = model_flops(cfg, shape)
            ratio = mf / flops if flops else 0.0
            mem = r["memory"]["total_per_device_bytes"] / 2**30
            print(f"| {arch} | {shape_name} | {fmt_s(t['compute_s'])} | "
                  f"{fmt_s(t['memory_s'])} | {fmt_s(hi_s)} | "
                  f"{fmt_s(t['collective_s'])} | "
                  f"{t['bottleneck'].replace('_s','')} | {ratio:.2f} | "
                  f"{mem:.1f} | {levers[t['bottleneck']]} |")


def main():
    recs = load()
    dryrun_table(recs, "single")
    dryrun_table(recs, "multi")
    roofline_table(recs)


if __name__ == "__main__":
    main()
