"""Paper Fig. 13 + Fig. 10/11: disaggregated vs monolithic TCO across
RM1/RM2 generations V0..V5; idleness breakdown."""
from __future__ import annotations

from repro.configs import rm1, rm2
from repro.core import allocator, tco
from repro.core.serving_unit import ServingUnitModel, UnitSpec

from benchmarks.common import row

PEAK_LOAD = 2e5


def run() -> dict:
    out = {}
    for fam, mod in (("rm1", rm1), ("rm2", rm2)):
        best_saving = 0.0
        savings = []
        for v in range(6):
            m = mod.generation(v)
            try:
                bm, _ = allocator.best_unit(m, tco.monolithic_candidates(),
                                            PEAK_LOAD)
                bd, _ = allocator.best_unit(m, tco.disagg_candidates(),
                                            PEAK_LOAD)
            except ValueError:
                continue
            s = 1 - bd.tco / bm.tco
            savings.append(s)
            best_saving = max(best_saving, s)
            row(f"fig13_{fam}_v{v}_saving_pct", 100 * s,
                f"mono=${bm.tco/1e6:.2f}M disagg=${bd.tco/1e6:.2f}M "
                f"unit={{{bd.unit.n}x{bd.unit.cn_type},{bd.unit.m}MN}}")
        out[fam] = savings
        row(f"fig13_{fam}_max_saving_pct", 100 * best_saving,
            "paper RM1: up to 49.3%; RM2: 4.3-9.3%")

    # Fig. 11: wasted-TCO breakdown on monolithic
    idl = tco.idleness_breakdown(
        rm1.generation(0), UnitSpec(8, "so1s_1g", scheme="distributed"),
        PEAK_LOAD)
    row("fig11_pipeline_idle_tco_pct", 100 * idl["pipeline_idle_tco_frac"],
        "paper RM1: 15.6-23.1%")
    row("fig11_overprovision_tco_pct", 100 * idl["overprovision_tco_frac"],
        "paper: 6.8%")
    out["idleness"] = idl
    return out
