"""Paper Fig. 4 + Fig. 12(a): scale-up NUMA effects and scale-out scaling.

- naive SU-2S vs NUMA-aware SU-2S vs distributed 2x SO-1S (Fig. 4)
- serving-unit throughput scaling with 2/4/8 SO-1S servers (Fig. 12a)
"""
from __future__ import annotations

from repro.configs import rm1
from repro.core.serving_unit import ServingUnitModel, UnitSpec

from benchmarks.common import row


def run() -> dict:
    m = rm1.generation(0)
    out = {}

    naive = ServingUnitModel(m, UnitSpec(1, "su2s", scheme="su_naive"))
    aware = ServingUnitModel(m, UnitSpec(1, "su2s", scheme="su_numa"))
    dist2 = ServingUnitModel(m, UnitSpec(2, "so1s_1g", scheme="distributed"))

    s_naive = naive.stage_times(128)
    s_aware = aware.stage_times(128)
    s_dist = dist2.stage_times(128)
    red = 1 - s_aware.t_sparse / s_naive.t_sparse
    row("fig4_sparse_reduction_numa_pct", 100 * red, "paper: >60%")
    comm_frac = (s_aware.t_comm_in + s_aware.t_comm_out) / s_aware.total()
    row("fig4_numa_comm_overhead_pct", 100 * comm_frac, "paper: <8%")
    deg = s_dist.total() / s_aware.total() - 1
    row("fig4_distributed_vs_numa_latency_pct", 100 * deg, "paper: <5%")
    out["fig4"] = {"numa_reduction": red, "comm_frac": comm_frac,
                   "dist_degradation": deg}

    # Fig. 12(a): scaling out improves latency-bounded fraction of peak
    qs = {}
    for n in (2, 4, 8):
        sm = ServingUnitModel(m, UnitSpec(n, "so1s_1g", scheme="distributed"))
        q, _ = sm.latency_bounded_qps(sla=0.1)
        qs[n] = q
        row(f"fig12a_so1s_x{n}_qps", q,
            f"frac_of_peak={q / sm.peak_qps():.2f} (paper: 65/76/90.6%)")
    row("fig12a_superlinear_2to8", qs[8] / qs[2],
        "paper: 5.6x with 4x servers")
    out["fig12a"] = qs
    return out
