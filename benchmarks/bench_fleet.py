"""Fleet consolidation: two models on one shared pool vs isolated pools.

Two experiments over the fleet serving subsystem (``serving.fleet``):

1. **Consolidation** — RM1 and RM2 each served alone on an isolated
   {1 CN, 2 MN} pool (3 nodes each, 6 total), then together as a fleet
   on one shared {2 CN, 3 MN} pool (5 nodes) at the same per-model
   arrival rate.  Each model's per-model SLA target is set to 1.25x its
   isolated p99; the bench asserts the shared pool holds BOTH models'
   targets while provisioning fewer node-seconds than the isolated
   pools combined — the DisaggRec consolidation argument: disaggregated
   resources pool across models, so the fleet rides one shared
   provisioning margin instead of two private ones.

2. **Single-model parity** — the same scenario expressed through the
   legacy singular ``model`` field and as a one-entry ``models`` fleet.
   ``ScenarioSpec.__post_init__`` normalizes both to the same value, so
   the runs must be bitwise-identical: scores AND the full report
   (every ClusterStats field, per-model breakdown included).

  PYTHONPATH=src python -m benchmarks.bench_fleet [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.serving.scenario import (ModelRef, ScenarioSpec, Topology,
                                    Workload, run_scenario)

from benchmarks.common import row

SEED = 11
GAP_S = 2e-3              # per-model mean inter-arrival
SLA_MARGIN = 1.25         # per-model target = margin x isolated p99
ISO_TOPO = dict(n_cn=1, m_mn=2, batch_size=32, max_wait_s=2e-4,
                n_replicas=2, cache_mb=0.05)
SHARED_TOPO = dict(n_cn=2, m_mn=3, batch_size=32, max_wait_s=2e-4,
                   n_replicas=2, cache_mb=0.05)


def _nodes(topo: dict) -> int:
    return topo["n_cn"] + topo["m_mn"]


def _node_seconds(spec: ScenarioSpec, rep) -> float:
    """Total node capacity provisioned over the run (CN + MN),
    integrated across the audit trail — resizes the SLA controllers
    emit count against the pool that emitted them."""
    st = rep.stats
    n, m = spec.topology.n_cn, spec.topology.m_mn
    t, total = 0.0, 0.0
    for r in st.events:
        tt = min(max(r.time_s, t), st.makespan_s)
        total += (n + m) * (tt - t)
        t, n, m = tt, r.n_cn, r.m_mn
    return total + (n + m) * max(0.0, st.makespan_s - t)


def _iso_spec(arch: str, n: int) -> ScenarioSpec:
    return ScenarioSpec(
        name=f"fleet-iso-{arch}",
        model=ModelRef(arch=arch),
        topology=Topology(**ISO_TOPO),
        workload=Workload(requests=n, gap_s=GAP_S, seed=SEED))


def consolidation(n: int) -> dict:
    iso = {}
    for arch in ("rm1", "rm2"):
        rep = run_scenario(_iso_spec(arch, n))
        if rep.completed != rep.total:
            raise AssertionError(
                f"isolated {arch} dropped queries: "
                f"{rep.completed}/{rep.total}")
        iso[arch] = rep
        row(f"fleet_iso_{arch}_p99_us", rep.stats.p99 * 1e6,
            f"{arch} alone on {{{ISO_TOPO['n_cn']} CN, "
            f"{ISO_TOPO['m_mn']} MN}} ({n} reqs)")

    slas = {a: SLA_MARGIN * iso[a].stats.p99 for a in iso}
    shared = ScenarioSpec(
        name="fleet-shared",
        models=tuple(ModelRef(arch=a, rate_share=0.5,
                              sla_p99_s=slas[a])
                     for a in ("rm1", "rm2")),
        topology=Topology(**SHARED_TOPO),
        # half the aggregate gap = each model at its isolated rate
        workload=Workload(requests=2 * n, gap_s=GAP_S / 2, seed=SEED))
    rep = run_scenario(shared)
    if rep.completed != rep.total:
        raise AssertionError(
            f"shared pool dropped queries: {rep.completed}/{rep.total}")
    for a in ("rm1", "rm2"):
        ms = rep.stats.per_model[a]
        row(f"fleet_shared_{a}_p99_us", ms.p99 * 1e6,
            f"{a} on the shared pool: {ms.completed}/{ms.queries} "
            f"completed, SLA {slas[a] * 1e6:.1f}us, "
            f"{ms.cache_hits} cache hits")
        if not ms.p99 <= slas[a]:
            raise AssertionError(
                f"shared pool missed {a}'s SLA: p99 {ms.p99:g} > "
                f"target {slas[a]:g}")

    nodes_iso = 2 * _nodes(ISO_TOPO)
    nodes_shared = _nodes(SHARED_TOPO)
    row("fleet_nodes_shared", nodes_shared,
        f"shared pool vs {nodes_iso} across isolated pools")
    if not nodes_shared <= nodes_iso:
        raise AssertionError(
            f"shared pool uses {nodes_shared} nodes, isolated pools "
            f"{nodes_iso}")
    ns_iso = sum(_node_seconds(_iso_spec(a, n), iso[a]) for a in iso)
    ns_shared = _node_seconds(shared, rep)
    row("fleet_node_seconds_shared", ns_shared,
        f"vs {ns_iso:.4f} node-s across isolated pools "
        f"(-{100 * (1 - ns_shared / ns_iso):.1f}%)")
    if not ns_shared < ns_iso:
        raise AssertionError(
            f"consolidation bought no capacity: shared {ns_shared:g} "
            f"node-s vs isolated {ns_iso:g}")
    return {
        "iso": {a: {"p99_us": iso[a].stats.p99 * 1e6} for a in iso},
        "shared": {a: {"p99_us": rep.stats.per_model[a].p99 * 1e6,
                       "sla_us": slas[a] * 1e6,
                       "queries": rep.stats.per_model[a].queries}
                   for a in ("rm1", "rm2")},
        "nodes": {"iso": nodes_iso, "shared": nodes_shared},
        "node_seconds": {"iso": ns_iso, "shared": ns_shared},
    }


def single_model_parity(n: int) -> dict:
    """A one-entry fleet spec and the legacy singular-model spec are the
    same value after ``__post_init__`` normalization — their runs must
    match bitwise on scores and on the full report."""
    legacy = ScenarioSpec(
        name="fleet-parity",
        model=ModelRef(arch="rm1"),
        topology=Topology(**ISO_TOPO),
        workload=Workload(requests=n, gap_s=GAP_S, seed=SEED))
    as_fleet = ScenarioSpec(
        name="fleet-parity",
        models=(ModelRef(arch="rm1"),),
        topology=Topology(**ISO_TOPO),
        workload=Workload(requests=n, gap_s=GAP_S, seed=SEED))
    if legacy != as_fleet:
        raise AssertionError(
            "one-model fleet spec did not normalize to the legacy spec")
    rep_a, rep_b = run_scenario(legacy), run_scenario(as_fleet)
    if not rep_a.bitwise_equal(rep_b):
        raise AssertionError("one-model fleet broke score parity")
    da = json.dumps(rep_a.to_dict(), sort_keys=True)
    db = json.dumps(rep_b.to_dict(), sort_keys=True)
    if da != db:
        raise AssertionError(
            "one-model fleet report differs from the legacy run")
    row("fleet_parity_p99_us", rep_a.stats.p99 * 1e6,
        f"one-model fleet bitwise-identical to the legacy path "
        f"({n} reqs, full report compared)")
    return {"p99_us": rep_a.stats.p99 * 1e6, "bitwise": True}


def run(smoke: bool = False) -> dict:
    n = 48 if smoke else 160
    return {
        "consolidation": consolidation(n),
        "parity": single_model_parity(n),
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized runs (same assertions)")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="dump the consolidation results as a JSON "
                        "artifact")
    args = p.parse_args(argv)
    out = run(smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[bench_fleet] results written to {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
