"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (for perf rows the middle
column is the relevant scalar; derived carries the paper-claim context).

  PYTHONPATH=src python -m benchmarks.run [--only fig8,tco,...]
"""
import argparse
import sys
import traceback

MODULES = [
    ("fig4_scaleout", "benchmarks.bench_scaleout"),
    ("fig5_throughput", "benchmarks.bench_throughput"),
    ("fig7d_embedding_mgmt", "benchmarks.bench_embedding_mgmt"),
    ("fig8_scheduler", "benchmarks.bench_scheduler"),
    ("fig12_design_space", "benchmarks.bench_design_space"),
    ("fig13_tco", "benchmarks.bench_tco"),
    ("fig14_nmp", "benchmarks.bench_nmp"),
    ("fig11_elastic", "benchmarks.bench_elastic"),
    ("hot_row_cache", "benchmarks.bench_cache"),
    ("cluster_engine", "benchmarks.bench_cluster"),
    ("sla_traffic", "benchmarks.bench_sla"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None)
    args = p.parse_args(argv)
    import importlib
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        print(f"# === {name} ===")
        try:
            importlib.import_module(mod).run()
        except Exception:
            failures += 1
            print(f"{name},nan,ERROR")
            traceback.print_exc()
    return failures


if __name__ == "__main__":
    sys.exit(main())
