"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh):
  compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective = collective_bytes / (chips * 50e9 B/s ICI link)

HLO_FLOPs / bytes come from compiled.cost_analysis(). Collective bytes
are NOT in cost_analysis: we parse compiled.as_text() (post-SPMD HLO),
summing operand sizes of all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute. Ops inside while-loop bodies (the
scan-over-layers) are scaled by the loop trip count, read from XLA's
known_trip_count annotation when present.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

# peak numbers (TPU v5e targets; see core/hardware.py)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    """Group size from replica_groups: iota form [g,k]<=[N] or explicit
    {{0,1,..},{..}}."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _moved_bytes(kind: str, shape_region: str, line: str) -> int:
    """Per-device link bytes of one collective (ring algorithm), derived
    from the RESULT shape (operand shapes are not printed in post-opt
    HLO): all-gather/reduce-scatter move ~payload bytes; all-reduce is
    RS+AG = 2x payload; all-to-all/permute move ~payload.
    """
    total = sum(_shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(shape_region)
                if dt in _DTYPE_BYTES)
    g = _group_size(line)
    if kind == "all-gather" and g:
        total //= g          # operand (per-device payload) = result/gsize
    elif kind == "reduce-scatter" and g:
        total *= g           # operand = result * gsize
    elif kind == "all-reduce":
        total *= 2           # ring AR = reduce-scatter + all-gather
    return total


def collective_bytes_from_hlo(hlo: str,
                              default_trip: int = 1) -> Dict[str, float]:
    """Parse post-optimization HLO: per-op-kind collective bytes, ops in
    while bodies scaled by XLA's known_trip_count annotation."""
    # 1. split into computations
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            m2 = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", s)
            cur = m2.group(1) if m2 else None
            comps[cur] = []
            continue
        if cur is not None and s and not s.startswith("}"):
            comps[cur].append(s)

    # 2. while ops: body/condition computation -> trip count + parent
    body_trip: Dict[str, int] = {}
    call_sites: Dict[str, str] = {}
    for cname, lines in comps.items():
        for ln in lines:
            if " while(" in ln and "body=" in ln:
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                if not bm:
                    continue
                body = bm.group(1)
                tm = re.search(
                    r'known_trip_count"?\s*[:=]\s*\{+\s*"?n"?\s*[:=]\s*"?(\d+)',
                    ln)
                trip = int(tm.group(1)) if tm else default_trip
                body_trip[body] = trip
                call_sites[body] = cname
                cm = re.search(r"condition=%?([\w.\-]+)", ln)
                if cm:
                    call_sites[cm.group(1)] = cname
                    body_trip.setdefault(cm.group(1), trip)

    def multiplier(cname: str, depth=0) -> int:
        if depth > 8 or cname is None:
            return 1
        if cname in body_trip:
            parent = call_sites.get(cname)
            outer = multiplier(parent, depth + 1) if parent else 1
            return body_trip[cname] * outer
        return 1

    # 3. sum collective bytes, scaled by loop trip counts
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    out["total"] = 0.0
    counts = {k: 0 for k in _COLLECTIVES}
    op_re = re.compile(
        r"=\s*[^=]*?\b(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
    for cname, lines in comps.items():
        mult = multiplier(cname)
        for ln in lines:
            m = op_re.search(ln)
            if not m:
                continue
            kind = m.group(1)
            b = _moved_bytes(kind, ln[m.start():m.end()], ln) * mult
            out[kind] += b
            out["total"] += b
            counts[kind] += 1
    out["counts"] = counts
    out["while_trips"] = {k: v for k, v in body_trip.items() if v != 1}
    out["ar_weighted"] = True   # all-reduce already counted at 2x payload
    return out


def hlo_cost_scaled(hlo: str, default_trip: int = 1) -> Dict[str, float]:
    """Loop-aware per-device cost from post-opt HLO text.

    compiled.cost_analysis() counts while bodies ONCE (verified on this
    backend), so we re-derive: FLOPs from every `dot` (2*M*N*K via a
    per-computation symbol table for operand shapes) and HBM bytes as
    result+operand bytes of materializing instructions — each scaled by
    its computation's loop trip count (XLA known_trip_count). Fusion-body
    internals are skipped (counted at their call sites) for bytes but
    traversed for FLOPs.
    """
    # split computations, keep raw lines
    comps: Dict[str, List[str]] = {}
    fusion_bodies = set(re.findall(r"calls=%?([\w.\-]+)", hlo))
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            m2 = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", s)
            cur = m2.group(1) if m2 else None
            comps[cur] = [s]
            continue
        if cur is not None and s and not s.startswith("}"):
            comps[cur].append(s)

    # while body/cond -> trip, parent
    body_trip: Dict[str, int] = {}
    call_sites: Dict[str, str] = {}
    fusion_sites: Dict[str, str] = {}
    for cname, lines in comps.items():
        for ln in lines:
            if " while(" in ln and "body=" in ln:
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                if bm:
                    tm = re.search(
                        r'known_trip_count"?\s*[:=]\s*\{+\s*"?n"?\s*[:=]\s*"?(\d+)',
                        ln)
                    trip = int(tm.group(1)) if tm else default_trip
                    body_trip[bm.group(1)] = trip
                    call_sites[bm.group(1)] = cname
                cm = re.search(r"condition=%?([\w.\-]+)", ln)
                if cm:
                    call_sites[cm.group(1)] = cname
                    body_trip.setdefault(cm.group(1), 1)
            for fb in re.findall(r"calls=%?([\w.\-]+)", ln):
                fusion_sites[fb] = cname

    def multiplier(cname, depth=0) -> int:
        if cname is None or depth > 10:
            return 1
        if cname in body_trip:
            return body_trip[cname] * multiplier(call_sites.get(cname),
                                                 depth + 1)
        if cname in fusion_sites:
            return multiplier(fusion_sites[cname], depth + 1)
        return 1

    # per-computation symbol tables: %name -> (dtype, [dims])
    def symtab(lines):
        tab = {}
        for ln in lines:
            m = re.match(r"%?([\w.\-]+)\s*=\s*(.+)", ln)
            if not m:
                # computation signature params: %p.1: f32[...]
                for pm in re.finditer(r"%?([\w.\-]+):\s*(\w+)\[([\d,]*)\]",
                                      ln):
                    tab[pm.group(1)] = (pm.group(2), pm.group(3))
                continue
            name, rest = m.group(1), m.group(2)
            sm = _SHAPE_RE.search(rest)
            if sm and sm.group(1) in _DTYPE_BYTES:
                tab[name] = (sm.group(1), sm.group(2))
        return tab

    flops = 0.0
    bytes_ = 0.0
    transcend = 0.0
    for cname, lines in comps.items():
        mult = multiplier(cname)
        tab = symtab(lines)
        in_fusion = cname in fusion_bodies
        for ln in lines:
            m = re.match(r"%?([\w.\-]+)\s*=\s*(.*)", ln)
            if not m:
                continue
            rest = m.group(2)
            # FLOPs: dots (counted everywhere incl. fusion bodies)
            dm = re.search(r"\bdot\(%?([\w.\-]+),\s*%?([\w.\-]+)\)", rest)
            if dm:
                out_elems = 1
                sm = _SHAPE_RE.search(rest)
                if sm:
                    dims = sm.group(2)
                    for d in dims.split(","):
                        if d:
                            out_elems *= int(d)
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
                k = 1
                lhs = tab.get(dm.group(1))
                if lhs and cdims and cdims.group(1):
                    ldims = [int(x) for x in lhs[1].split(",") if x]
                    for ci in cdims.group(1).split(","):
                        ci = int(ci)
                        if ci < len(ldims):
                            k *= ldims[ci]
                flops += 2.0 * out_elems * k * mult
                continue
            if in_fusion:
                continue
            # bytes: result + operands for real ops
            op = re.match(r"(?:\([^)]*\)|\S+)\s+([\w\-]+)\(", rest)
            kindname = op.group(1) if op else ""
            if kindname in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast", "while", "conditional",
                            "after-all", ""):
                continue
            b = 0
            sm = _SHAPE_RE.search(rest.split("(")[0])
            for dt, dims in _SHAPE_RE.findall(rest.split("(")[0]):
                if dt in _DTYPE_BYTES:
                    b += _shape_bytes(dt, dims)
            for on in re.findall(r"[(,]\s*%([\w.\-]+)", rest):
                if on in tab:
                    b += _shape_bytes(*tab[on])
            bytes_ += b * mult
            if kindname in ("exponential", "log", "tanh", "rsqrt", "power"):
                transcend += b / 4 * mult
    return {"flops": flops, "bytes": bytes_, "transcendentals": transcend}


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int) -> Dict[str, float]:
    compute = flops / (chips * PEAK_FLOPS)
    memory = hbm_bytes / (chips * HBM_BW)
    collective = coll_bytes / (chips * ICI_BW)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom
    total = max(compute, memory, collective)
    terms["roofline_frac_compute"] = compute / total if total else 0.0
    return terms


def analytic_hbm_bytes(cfg, shape, chips: int) -> float:
    """Fusion-realistic per-device HBM traffic per step (lower bound).

    The HLO-text byte count on this CPU backend treats every intermediate
    as an HBM round-trip (no fusion) — an upper bound. Real TPU executors
    fuse elementwise chains; the dominant residual traffic is parameters
    (+optimizer state), activations at block boundaries, and caches.
    """
    P_loc = cfg.param_count() / (16 if chips >= 256 else 1)  # model axis
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = max(cfg.num_layers, 1)
    dp = chips / 16 if chips >= 256 else 1
    tokens_loc = B * S / dp if shape.kind != "decode" else B / dp
    if shape.kind == "train":
        # params: fwd read + bwd read (remat) + grad write (bf16)
        #       + opt m/v read+write + master read/write (f32)
        traffic = P_loc * (3 * 2 + 4 * 4)
        # activations: residual stream per layer, write+2reads, bf16, SP/16
        traffic += L * (tokens_loc / 16) * d * 2 * 3 * 16 / 16
        traffic += L * tokens_loc * d * 2 * 3       # block-internal acts
        # logits chunks fp32
        traffic += tokens_loc * (cfg.vocab_size / 16) * 4 * 2
    elif shape.kind == "prefill":
        traffic = P_loc * 2
        traffic += L * tokens_loc * d * 2 * 2
        # emitted KV cache write
        traffic += L * tokens_loc * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * 2
    else:
        traffic = P_loc * 2                         # stream all weights
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        if cfg.family not in ("ssm",):
            L_attn = L
            if cfg.family == "hybrid" and cfg.ssm and cfg.ssm.attn_every:
                L_attn = L // cfg.ssm.attn_every
            # read the local KV-cache slice once
            traffic += L_attn * (B / dp if B >= dp else 1) * S / 16 * kv * hd * 2 * 2
    return traffic


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train (2*N*D forward), using active
    params for MoE, + attention sequence terms."""
    import math
    N = cfg.active_param_count() if cfg.family == "moe" else cfg.param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        f = 6.0 * N * B * S
    elif shape.kind == "prefill":
        f = 2.0 * N * B * S
    else:
        f = 2.0 * N * B  # one token
    # attention score/value FLOPs (causal ~ S^2/2 per head pair)
    hd = cfg.resolved_head_dim if cfg.num_heads else 0
    H = cfg.num_heads
    if H and cfg.family not in ("ssm",):
        L_attn = cfg.num_layers
        if cfg.family == "hybrid" and cfg.ssm and cfg.ssm.attn_every:
            L_attn = cfg.num_layers // cfg.ssm.attn_every
        if shape.kind in ("train", "prefill"):
            per = 2 * 2 * H * hd * (S * S / 2) * B * L_attn
            f += per * (3 if shape.kind == "train" else 1)
        else:
            f += 2 * 2 * H * hd * S * B * L_attn
    return f
