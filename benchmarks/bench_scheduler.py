"""Paper Fig. 8: interleaved vs sequential query processing."""
from __future__ import annotations

from repro.configs import rm1
from repro.core.scheduler import INTERLEAVED, SEQUENTIAL
from repro.core.serving_unit import ServingUnitModel, UnitSpec
from repro.serving.simulator import ClusterSim, SimConfig

from benchmarks.common import row


def run() -> dict:
    m = rm1.generation(0)
    um = ServingUnitModel(m, UnitSpec(2, "cn_1g", 2, "ddr_mn"))
    out = {}
    for policy in (SEQUENTIAL, INTERLEAVED):
        sim = ClusterSim(um, SimConfig(policy=policy, batch_size=128,
                                       duration_s=10.0, warmup_s=2.0,
                                       seed=1))
        out[policy] = sim.latency_bounded_qps(sla=0.25, iters=10)
        peak = ClusterSim(um, SimConfig(policy=policy, batch_size=128,
                                        duration_s=10.0, warmup_s=2.0,
                                        seed=1)).latency_bounded_qps(
            sla=5.0, iters=8)
        out[policy + "_peak"] = peak
    gain = out[SEQUENTIAL] / max(out[INTERLEAVED], 1e-9) - 1
    row("fig8_sequential_qps", out[SEQUENTIAL], "latency-bounded@250ms")
    row("fig8_interleaved_qps", out[INTERLEAVED], "latency-bounded@250ms")
    row("fig8_sequential_gain_pct", 100 * gain, "paper: ~28%")
    peak_gap = abs(out[SEQUENTIAL + "_peak"] / max(out[INTERLEAVED + "_peak"], 1e-9) - 1)
    row("fig8_peak_gap_pct", 100 * peak_gap, "paper: similar peak")
    return {"gain": gain, "peak_gap": peak_gap}
