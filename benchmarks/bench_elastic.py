"""Fig. 2b/11: elastic provisioning — fixed-peak vs elastic disagg vs
elastic monolithic over the 24h diurnal trace.

Fixed-proportion provisioning pins the peak-hour pool all day; the
diurnal trough (~40% of peak) turns up to 30% of TCO into idle units
(paper Fig. 11).  The elastic disaggregated cluster follows the curve
with both pools independently — compute tracks load, memory shrinks only
to its capacity floor — while the elastic *monolithic* fleet cannot drop
below the servers needed to hold the model and pays full-server power
for every unit it does keep.

Three views:
  1. node-level day: idle node-hours + energy recovered vs fixed-peak,
     for the elastic disagg pools and the elastic monolithic fleet;
  2. cross-check vs the failure-aware allocator: a fixed-peak plan's
     idle unit-hours must equal ``AllocationPlan.idle_units`` x 24h;
  3. executable slice: a diurnal resize schedule mapped onto a real
     request stream through ``ClusterEngine`` — every resize step must
     score bitwise-identically to the fixed-peak pool, with migration
     bytes charged on the virtual clock.

  PYTHONPATH=src python -m benchmarks.bench_elastic [--smoke]
"""
from __future__ import annotations

import argparse
import sys

from repro import configs
from repro.configs import rm1
from repro.core import allocator, hardware as hw
from repro.core.serving_unit import UnitSpec
from repro.models.dlrm import DLRMModel
from repro.serving.autoscaler import (Autoscaler, AutoscalerConfig,
                                      energy_joules, idle_node_hours)
from repro.serving.scenario import (Resize, ScenarioSpec, Workload,
                                    run_scenario, smoke_topology)

from benchmarks.common import row

PEAK_LOAD = 2e5
STEPS = 96
LIFETIME_DAYS = 365.0 * hw.LIFETIME_YEARS


def run(smoke: bool = False) -> dict:
    out = {}
    m = rm1.generation(0)

    # ---- 1. node-level diurnal day: elastic vs fixed-peak ------------
    auto = Autoscaler.for_model(m)
    series = auto.series(PEAK_LOAD, STEPS)
    n_pk = max(n for n, _ in series)
    m_pk = max(mm for _, mm in series)
    idle_cn_h, idle_mn_h = idle_node_hours(series)
    e_fixed = energy_joules([(n_pk, m_pk)] * STEPS, "cn_1g", "ddr_mn")
    e_elastic = energy_joules(series, "cn_1g", "ddr_mn")
    rec_disagg = 1 - e_elastic / e_fixed
    idle_frac = (idle_cn_h / (n_pk * 24.0) + idle_mn_h / (m_pk * 24.0)) / 2
    row("elastic_fixed_peak_idle_frac_pct", 100 * idle_frac,
        f"fixed {{{n_pk} CN, {m_pk} MN}} idles "
        f"{idle_cn_h:.0f} CN-h + {idle_mn_h:.0f} MN-h/day "
        f"(paper Fig. 11: <=30% of TCO)")
    saved_usd = (e_fixed - e_elastic) * LIFETIME_DAYS * hw.ELECTRICITY_RATE
    row("elastic_disagg_energy_recovered_pct", 100 * rec_disagg,
        f"${saved_usd:,.0f} energy opex over {hw.LIFETIME_YEARS:.0f}y "
        f"vs fixed-peak")
    out["idle_frac"] = idle_frac
    out["recovered_disagg"] = rec_disagg

    mono = Autoscaler.monolithic(m, "so1s_1g")
    sm = mono.series(PEAK_LOAD, STEPS)
    mono_pk = max(n for n, _ in sm)
    e_mfix = energy_joules([(mono_pk, 0)] * STEPS, "so1s_1g", "")
    e_mel = energy_joules(sm, "so1s_1g", "")
    rec_mono = 1 - e_mel / e_mfix
    row("elastic_mono_energy_recovered_pct", 100 * rec_mono,
        f"floor {mono.cfg.min_cn} servers (must hold the model), "
        f"peak {mono_pk}")
    row("elastic_disagg_vs_mono_day_energy_pct",
        100 * (1 - e_elastic / e_mel),
        "elastic disagg vs elastic monolithic, same day of load")
    out["recovered_mono"] = rec_mono
    out["disagg_vs_mono"] = 1 - e_elastic / e_mel

    # ---- 2. cross-check vs the failure-aware allocator ---------------
    unit = UnitSpec(3, "cn_1g", 8, "ddr_mn")
    plan = allocator.allocate_from_model(m, unit, PEAK_LOAD)
    idle_unit_h = (sum(plan.n_peak - nu for nu in plan.n_units)
                   * 24.0 / len(plan.n_units))
    row("allocator_idle_unit_hours_per_day", idle_unit_h,
        f"= AllocationPlan.idle_units ({plan.idle_units:.2f}) x 24h "
        f"[match: {abs(idle_unit_h - plan.idle_units * 24.0) < 1e-9}]; "
        f"n_peak={plan.n_peak}")
    out["idle_unit_hours"] = idle_unit_h
    out["idle_units"] = plan.idle_units

    # ---- 3. executable slice: resizes on a real stream ---------------
    # both runs go through the scenario front door on the shared smoke
    # topology: a fixed-peak spec with an empty timeline vs the same
    # spec carrying the autoscaler's plan as typed Resize events
    cfg = configs.get_reduced("rm1")
    model = DLRMModel(cfg)
    params = model.init(0)
    n_req = 16 if smoke else 48
    span = 0.002 * n_req
    # map the diurnal day onto the stream with a toy policy whose peak
    # saturates the fixed pool below
    toy = Autoscaler(AutoscalerConfig(
        qps_per_cn=1.0, qps_per_mn=0.5, min_cn=1, min_mn=2,
        max_cn=3, max_mn=6))
    events = tuple(Resize(e.time_s, n_cn=e.n_cn, m_mn=e.m_mn)
                   for e in toy.plan(peak_load=3.0, duration_s=span,
                                     steps=6 if smoke else 12))
    topo = smoke_topology(n_cn=3, m_mn=6)
    wl = Workload(requests=n_req, seed=0)

    rep_fixed = run_scenario(
        ScenarioSpec(name="elastic-fixed", topology=topo, workload=wl),
        model=model, params=params)
    rep_el = run_scenario(
        ScenarioSpec(name="elastic-diurnal", topology=topo, workload=wl,
                     events=events),
        model=model, params=params)
    st_fixed, st_el = rep_fixed.stats, rep_el.stats

    bitwise = rep_el.bitwise_equal(rep_fixed)
    row("elastic_engine_bitwise", float(bitwise),
        f"{st_el.resizes} resizes over {n_req} queries, pool "
        f"{{{rep_el.final_n_cn} CN, {rep_el.final_m_mn} MN}} at end — "
        f"scores identical to fixed {{3 CN, 6 MN}}: {bitwise}")
    row("elastic_engine_migration_bytes", st_el.migration_bytes,
        f"shard bytes drained/topped-up across {st_el.resizes} resizes; "
        f"p95 {st_el.p95 * 1e3:.3f}ms vs fixed {st_fixed.p95 * 1e3:.3f}ms")
    out["bitwise"] = bitwise
    out["resizes"] = st_el.resizes
    out["migration_bytes"] = st_el.migration_bytes
    if not bitwise:
        raise AssertionError("elastic resize broke score parity")
    return out


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small request stream (CI)")
    args = p.parse_args(argv)
    run(smoke=args.smoke)
    return 0


if __name__ == "__main__":
    sys.exit(main())
