"""Paper Fig. 14: heterogeneity provisioning — NMP-DIMMs in monolithic
servers vs as a disaggregated MN pool, across the 3-year evolution."""
from __future__ import annotations

from repro.configs import rm1, rm2
from repro.core import allocator, tco

from benchmarks.common import row

PEAK_LOAD = 2e5


def run() -> dict:
    out = {}
    for fam, mod in (("rm1", rm1), ("rm2", rm2)):
        sav = []
        for v in range(6):
            m = mod.generation(v)
            cands_mono = tco.monolithic_candidates() + \
                tco.monolithic_nmp_candidates()
            cands_dis = (tco.disagg_candidates()
                         + tco.disagg_candidates(mn_type="nmp_mn"))
            try:
                bm, _ = allocator.best_unit(m, cands_mono, PEAK_LOAD)
                bd, _ = allocator.best_unit(m, cands_dis, PEAK_LOAD)
            except ValueError:
                continue
            s = 1 - bd.tco / bm.tco
            sav.append(s)
            nmp = "nmp" in bd.unit.mn_type
            row(f"fig14_{fam}_v{v}_saving_pct", 100 * s,
                f"disagg_mn={bd.unit.mn_type} ({'NMP pool' if nmp else 'DDR'})")
        out[fam] = sav
        if sav:
            row(f"fig14_{fam}_saving_range_pct",
                100 * min(sav), f"to {100 * max(sav):.1f}% (paper: 21-43.6%)")
    return out
