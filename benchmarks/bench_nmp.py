"""Paper Fig. 14: heterogeneity provisioning — TCO savings from deploying
NMP-DIMM memory nodes in the disaggregated pool, across the 3-year
evolution.

The headline comparison (the paper's 21-43.6% band) is the best
disaggregated unit when the MN pool may use NMP-DIMM memory nodes vs the
best DDR-only disaggregated pool, per generation: for the memory-bound
RM1 every generation saves ~39-42%; for the fleet (RM1 + RM2 served
together, the datacenter view) savings decay from ~34% to ~22% as RM2's
DenseNet growth shifts TCO toward compute the NMP pool cannot help —
the paper's narrative in miniature.  Monolithic-cluster rows (incl.
NMP-DIMM monolithic servers) are reported for context.

`tests/test_nmp_golden.py` pins these figures so allocator/TCO edits
cannot silently drift the headline.
"""
from __future__ import annotations

from repro.configs import rm1, rm2
from repro.core import allocator, tco

from benchmarks.common import row

PEAK_LOAD = 2e5
PAPER_BAND = (0.21, 0.436)


def run() -> dict:
    out = {"rm1": [], "rm2": [], "fleet": [], "vs_mono": {}}
    tcos = {}                        # (fam, v) -> (ddr_tco, nmp_tco)
    for fam, mod in (("rm1", rm1), ("rm2", rm2)):
        sav = []
        for v in range(6):
            m = mod.generation(v)
            try:
                bd, _ = allocator.best_unit(m, tco.disagg_candidates(),
                                            PEAK_LOAD)
                bn, _ = allocator.best_unit(
                    m, tco.disagg_candidates(mn_type="nmp_mn"), PEAK_LOAD)
            except ValueError:
                continue
            win = bn if bn.tco <= bd.tco else bd   # NMP allowed, not forced
            tcos[(fam, v)] = (bd.tco, win.tco)
            s = 1 - win.tco / bd.tco
            sav.append(s)
            row(f"fig14_{fam}_v{v}_saving_pct", 100 * s,
                f"disagg {win.unit.n}x{win.unit.cn_type}+"
                f"{win.unit.m}x{win.unit.mn_type} vs DDR pool")
            # context: best monolithic cluster (NMP DIMM servers allowed)
            try:
                bm, _ = allocator.best_unit(
                    m, tco.monolithic_candidates()
                    + tco.monolithic_nmp_candidates(), PEAK_LOAD)
                sm = 1 - win.tco / bm.tco
                out["vs_mono"][(fam, v)] = sm
                row(f"fig14_{fam}_v{v}_vs_mono_pct", 100 * sm,
                    f"vs best monolithic ({bm.unit.cn_type})")
            except ValueError:
                pass
        out[fam] = sav
        if sav:
            row(f"fig14_{fam}_saving_range_pct",
                100 * min(sav), f"to {100 * max(sav):.1f}% (paper: 21-43.6%)")

    # fleet view: the datacenter serves both families each generation
    fleet = []
    for v in range(6):
        if ("rm1", v) in tcos and ("rm2", v) in tcos:
            ddr = tcos[("rm1", v)][0] + tcos[("rm2", v)][0]
            nmp = tcos[("rm1", v)][1] + tcos[("rm2", v)][1]
            s = 1 - nmp / ddr
            fleet.append(s)
            row(f"fig14_fleet_v{v}_saving_pct", 100 * s,
                "rm1+rm2 combined (paper band 21-43.6%)")
    out["fleet"] = fleet
    if fleet:
        row("fig14_fleet_saving_range_pct", 100 * min(fleet),
            f"to {100 * max(fleet):.1f}% (paper: 21-43.6%)")
    return out
