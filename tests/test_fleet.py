"""Multi-model fleet serving (issue #10): spec serde + normalization,
single-model bitwise parity, the merged fleet stream (rate shares,
ShiftTraffic, per-model phases), owner-scoped hotness/placement, cache
budget partitions, and the shared-pool engine end to end.

The tentpole invariants:

- ``models`` round-trips through serde; the legacy singular ``model``
  key stays accepted as an alias and the two forms normalize to the
  same value (``model is models[0]`` always);
- a one-model fleet spec runs bitwise-identically — scores AND the
  full ClusterStats — to the same spec expressed through the legacy
  singular field (the HEAD single-model path);
- under a fleet, one model's traffic cannot demote another model's hot
  tables (owner-scoped hotness), and per-model cache partitions hold
  their byte budgets.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs import rm1
from repro.core import embedding_manager as em
from repro.models.dlrm import DLRMModel
from repro.serving.cache import RowCache
from repro.serving.fleet import (FleetModel, build_fleet,
                                 plan_fleet_workload, run_fleet)
from repro.serving.scenario import (ModelRef, ScenarioSpec, SetWorkload,
                                    ShiftTraffic, Workload, preset,
                                    run_scenario, smoke_topology)

CFG_A = rm1.CONFIG.replace(
    name="fleet-a",
    dlrm=rm1.DLRMConfig(num_tables=5, rows_per_table=48, embed_dim=8,
                        avg_pooling=4, num_dense_features=8,
                        bottom_mlp=(16, 8), top_mlp=(32, 16, 1)),
)
# a second member with a different table count but the same (rows, dim)
# — the uniform-shape requirement of the shared MN pool
CFG_B = CFG_A.replace(
    name="fleet-b",
    dlrm=dataclasses.replace(CFG_A.dlrm, num_tables=3, avg_pooling=6),
)


def _tiny_fleet():
    ma, mb = DLRMModel(CFG_A), DLRMModel(CFG_B)
    return [FleetModel("rm1", ModelRef(arch="rm1"), ma, ma.init(0)),
            FleetModel("rm2", ModelRef(arch="rm2"), mb, mb.init(1))]


def _fleet_spec(events=(), requests=24, shares=(0.5, 0.5), **wkw):
    return ScenarioSpec(
        name="fleet-t",
        models=(ModelRef(arch="rm1", rate_share=shares[0]),
                ModelRef(arch="rm2", rate_share=shares[1])),
        topology=smoke_topology(batch_size=8, cache_mb=0.02),
        workload=Workload(requests=requests, mean_size=4.0, max_size=12,
                          gap_s=0.004, **wkw),
        events=tuple(events))


# ------------------------------------------------------------- serde
def test_models_round_trip():
    spec = _fleet_spec(events=(
        ShiftTraffic(0.02, from_model="rm1", to_model="rm2", share=0.2),
        SetWorkload(0.03, alpha=1.05, model="rm2")))
    rt = ScenarioSpec.from_json(spec.to_json())
    assert rt == spec
    d = spec.to_dict()
    assert "model" not in d
    assert [m["arch"] for m in d["models"]] == ["rm1", "rm2"]


def test_legacy_singular_model_alias():
    d = {"name": "t", "model": {"arch": "rm1"},
         "topology": {}, "workload": {}}
    spec = ScenarioSpec.from_dict(d)
    assert spec.models == (ModelRef(arch="rm1"),)
    assert spec.model == spec.models[0]
    # serde now emits the plural form; the value round-trips
    assert ScenarioSpec.from_json(spec.to_json()) == spec


def test_one_model_fleet_normalizes_to_singular():
    a = ScenarioSpec(name="t", model=ModelRef(arch="rm1"))
    b = ScenarioSpec(name="t", models=(ModelRef(arch="rm1"),))
    assert a == b
    assert b.model == b.models[0]


def test_replace_keeps_normalization():
    spec = _fleet_spec()
    moved = dataclasses.replace(spec, sla_p99_s=0.5)
    assert moved.models == spec.models
    single = ScenarioSpec(name="t", model=ModelRef(arch="rm1"))
    swapped = dataclasses.replace(single, model=ModelRef(arch="rm2"))
    assert swapped.models == (ModelRef(arch="rm2"),)


@pytest.mark.parametrize("mutate", [
    # both keys in one payload
    lambda d: {**d, "model": {"arch": "rm1"},
               "models": [{"arch": "rm1"}]},
    # empty fleet
    lambda d: {**d, "models": []},
    lambda d: {**d, "models": "rm1,rm2"},
])
def test_serde_garbage_rejected(mutate):
    base = {"name": "t", "topology": {}, "workload": {}}
    with pytest.raises((ValueError, TypeError)):
        ScenarioSpec.from_dict(mutate(base))


@pytest.mark.parametrize("build", [
    # duplicate arch names
    lambda: ScenarioSpec(
        name="t", models=(ModelRef(arch="rm1"), ModelRef(arch="rm1")),
        topology=smoke_topology(batch_size=8),
        workload=Workload(requests=8)),
    # non-positive rate share
    lambda: _fleet_spec(shares=(0.0, 1.0)),
    # shift naming an unknown model
    lambda: _fleet_spec(events=(ShiftTraffic(
        0.01, from_model="rm1", to_model="rm9", share=0.1),)),
    # shift draining more share than the model holds
    lambda: _fleet_spec(shares=(0.2, 0.8), events=(ShiftTraffic(
        0.01, from_model="rm1", to_model="rm2", share=0.9),)),
    # scoped SetWorkload may not move the rate
    lambda: _fleet_spec(events=(SetWorkload(
        0.01, gap_s=0.001, model="rm1"),)),
    # scoped SetWorkload naming an unknown model
    lambda: _fleet_spec(events=(SetWorkload(
        0.01, alpha=1.0, model="rm9"),)),
    # fleets cannot replay an absolute trace
    lambda: _fleet_spec(arrival="trace", trace_path="x.json"),
])
def test_validate_rejects_bad_fleet(build):
    spec = build()
    with pytest.raises(ValueError):
        spec.validate()


def test_shift_on_single_model_rejected():
    spec = ScenarioSpec(
        name="t", model=ModelRef(arch="rm1"),
        topology=smoke_topology(batch_size=8),
        workload=Workload(requests=8),
        events=(ShiftTraffic(0.01, from_model="rm1", to_model="rm2",
                             share=0.1),))
    with pytest.raises(ValueError):
        spec.validate()


def test_conflicting_model_and_models_rejected():
    with pytest.raises(ValueError):
        ScenarioSpec(name="t", model=ModelRef(arch="rm1"),
                     models=(ModelRef(arch="rm2"),
                             ModelRef(arch="rm3")))


# ----------------------------------------- single-model bitwise parity
def _stats_equal(a, b) -> bool:
    return _nan_eq(dataclasses.asdict(a), dataclasses.asdict(b))


def _nan_eq(a, b) -> bool:
    if isinstance(a, float) and isinstance(b, float):
        return (math.isnan(a) and math.isnan(b)) or a == b
    if isinstance(a, dict) and isinstance(b, dict):
        return (a.keys() == b.keys()
                and all(_nan_eq(a[k], b[k]) for k in a))
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return (len(a) == len(b)
                and all(_nan_eq(x, y) for x, y in zip(a, b)))
    return a == b


PARITY_GRID = [
    dict(),
    dict(requests=16, seed=3),
    dict(alpha=1.05),
    dict(arrival="poisson", seed=5),
]


def _parity_pair(wkw):
    topo = smoke_topology(batch_size=8, cache_mb=0.02)
    w = Workload(requests=wkw.pop("requests", 12), mean_size=4.0,
                 max_size=12, gap_s=0.004, **wkw)
    legacy = ScenarioSpec(name="p", model=ModelRef(arch="rm1"),
                          topology=topo, workload=w)
    fleet = ScenarioSpec(name="p", models=(ModelRef(arch="rm1"),),
                         topology=topo, workload=w)
    return legacy, fleet


@pytest.mark.parametrize("wkw", [dict(g) for g in PARITY_GRID])
def test_one_model_fleet_bitwise_parity_pinned(wkw):
    """Acceptance: a one-model fleet spec scores bitwise-identically to
    the legacy single-model path — results AND the full ClusterStats,
    per-model breakdown included."""
    legacy, fleet = _parity_pair(dict(wkw))
    rep_l, rep_f = run_scenario(legacy), run_scenario(fleet)
    assert rep_l.bitwise_equal(rep_f)
    assert _stats_equal(rep_l.stats, rep_f.stats)
    assert len(rep_f.stats.per_model) == 1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), requests=st.integers(4, 20),
       alpha=st.sampled_from([0.0, 1.05]))
def test_one_model_fleet_bitwise_parity_property(seed, requests, alpha):
    legacy, fleet = _parity_pair(
        dict(seed=seed, requests=requests, alpha=alpha))
    rep_l, rep_f = run_scenario(legacy), run_scenario(fleet)
    assert rep_l.bitwise_equal(rep_f)
    assert _stats_equal(rep_l.stats, rep_f.stats)


# ------------------------------------------------- fleet stream plan
def test_fleet_stream_rate_shares():
    spec = _fleet_spec(requests=40, shares=(0.75, 0.25))
    reqs, phases = plan_fleet_workload(spec, _tiny_fleet())
    assert len(reqs) == 40
    assert [r.rid for r in reqs] == list(range(40))
    # arrivals merged in global time order
    assert all(a.arrival <= b.arrival for a, b in zip(reqs, reqs[1:]))
    counts = {0: 0, 1: 0}
    for r in reqs:
        counts[r.model] += 1
    assert counts[0] > 2 * counts[1]        # ~3:1 split


def test_shift_traffic_moves_rate():
    ev = ShiftTraffic(0.05, from_model="rm1", to_model="rm2", share=0.4)
    spec = _fleet_spec(requests=60, events=(ev,))
    reqs, phases = plan_fleet_workload(spec, _tiny_fleet())
    before = [r for r in reqs if r.arrival < ev.time_s]
    after = [r for r in reqs if r.arrival >= ev.time_s]
    n_b = sum(1 for r in before if r.model == 1)
    n_a = sum(1 for r in after if r.model == 1)
    # rm2 went from 0.5 to 0.9 share: its post-shift fraction must rise
    assert n_a / max(1, len(after)) > n_b / max(1, len(before))
    # every event starts a phase with a contiguous rid range
    assert len(phases) == 2
    assert phases[0].rid_end == phases[1].rid_start
    assert phases[1].rid_end == len(reqs)


def test_shift_to_zero_silences_model():
    ev = ShiftTraffic(0.04, from_model="rm1", to_model="rm2", share=0.5)
    spec = _fleet_spec(requests=40, events=(ev,))
    reqs, _ = plan_fleet_workload(spec, _tiny_fleet())
    assert all(r.model == 1 for r in reqs if r.arrival >= ev.time_s)


def test_unscoped_gap_change_moves_aggregate_rate():
    # an unscoped SetWorkload gap_s change realigns EVERY model's
    # arrival process at the event time
    ev = SetWorkload(0.04, gap_s=0.001)
    spec = _fleet_spec(requests=60, events=(ev,))
    reqs, phases = plan_fleet_workload(spec, _tiny_fleet())
    assert len(reqs) == 60
    after = [r.arrival for r in reqs if r.arrival >= ev.time_s]
    gaps = [b - a for a, b in zip(after, after[1:])]
    # aggregate gap dropped 0.004 -> 0.001: mean inter-arrival follows
    assert sum(gaps) / len(gaps) < 0.002
    assert phases[-1].gap_s == 0.001
    # both models keep arriving after the realign
    assert {r.model for r in reqs if r.arrival >= ev.time_s} == {0, 1}


def test_scoped_setworkload_only_touches_target():
    ev = SetWorkload(0.04, mean_size=10.0, model="rm2")
    spec = _fleet_spec(requests=60, events=(ev,))
    reqs, _ = plan_fleet_workload(spec, _tiny_fleet())
    base = _fleet_spec(requests=60)
    reqs0, _ = plan_fleet_workload(base, _tiny_fleet())
    # rm1's queries are untouched by rm2's phase change
    a = [(r.rid, r.size, r.arrival) for r in reqs if r.model == 0]
    b = [(r.rid, r.size, r.arrival) for r in reqs0 if r.model == 0]
    assert [x[1:] for x in a] == [x[1:] for x in b]
    # rm2's post-event sizes moved (mean 10 vs 4)
    post = [r.size for r in reqs if r.model == 1
            and r.arrival >= ev.time_s]
    pre = [r.size for r in reqs0 if r.model == 1
           and r.arrival >= ev.time_s]
    assert post != pre


# --------------------------------------- owner-scoped hotness (sat. 2)
def _tables(n, rows=32, dim=8, pool=4):
    return [em.TableInfo(t, rows, dim, float(pool)) for t in range(n)]


def test_hotness_owner_scoped_no_cross_model_eviction():
    """Regression: model A's heavy traffic must not demote model B's hot
    tables.  Unscoped, B's densities all fall below the global median
    cut; owner-scoped, each model keeps its own hot set."""
    tables = _tables(8)
    owners = [0, 0, 0, 0, 1, 1, 1, 1]
    counts = [10000, 100, 100, 100,    # model 0: tid 0 hot
              50, 1, 1, 1]            # model 1: tid 4 hot (but cold vs A)
    hot = em.HotnessCounter(len(tables), owners=owners)
    hot.update(range(8), counts)
    scoped = hot.hot_tables(tables)
    assert scoped == {0, 4}            # each model keeps its own hot set
    flat = em.HotnessCounter(len(tables))
    flat.update(range(8), counts)
    unscoped = flat.hot_tables(tables)
    # the failure mode the scoping fixes: under one global median, B's
    # entire traffic sits below A's and B loses its hot classification
    assert unscoped == {0}


def test_hotness_owner_totals():
    tables = _tables(4)
    hot = em.HotnessCounter(4, owners=[0, 0, 1, 1])
    hot.update([0, 1, 2, 3], [10, 20, 5, 5])
    totals = hot.owner_totals(tables)
    assert totals[0] == 30 * 8 * 4 and totals[1] == 10 * 8 * 4


def test_hotness_owners_length_mismatch():
    with pytest.raises(ValueError):
        em.HotnessCounter(4, owners=[0, 1])


def test_allocate_fleet_owner_scoped_placement():
    tables = _tables(8, rows=64)
    owners = [0, 0, 0, 0, 1, 1, 1, 1]
    # per-model hot/cold split: tid 0 hot within model 0, tid 4 hot
    # within model 1 (even though 50 sits below the global median)
    ab = [10000.0, 100.0, 100.0, 100.0, 50.0, 1.0, 1.0, 1.0]
    cap = [2 * sum(t.size_bytes for t in tables)] * 2
    alloc = em.allocate_fleet(tables, cap, ["ddr_mn", "nmp_mn"], owners,
                              n_replicas=1, access_bytes=ab)
    # each model's hot table (above its own median) lands on DDR
    for tid in (0, 4):
        assert alloc.replicas[tid] == [0], f"tid {tid} misplaced"
    for tid in (1, 2, 3, 5, 6, 7):
        assert alloc.replicas[tid] == [1], f"tid {tid} misplaced"


def test_allocate_fleet_owner_length_mismatch():
    tables = _tables(4)
    with pytest.raises(ValueError):
        em.allocate_fleet(tables, [10 ** 9], ["ddr_mn"], [0, 0, 1],
                          n_replicas=1)


# --------------------------------------- cache partitions (satellite)
def test_cache_partition_budgets_respected():
    row_b = 32
    c = RowCache(10 * row_b, row_b, "lru")
    c.set_partitions({0: 0, 1: 1}, {0: 6 * row_b, 1: 4 * row_b})
    for r in range(8):
        c.admit(0, r)
        c.admit(1, r)
    assert c.partition_bytes(0) <= 6 * row_b
    assert c.partition_bytes(1) <= 4 * row_b
    assert c.size_bytes <= 10 * row_b
    # partition 0 evicted its own rows, never partition 1's
    assert c.table_rows(0) == 6 and c.table_rows(1) == 4


def test_cache_rebalance_evicts_to_new_budget():
    row_b = 32
    c = RowCache(10 * row_b, row_b, "lru")
    c.set_partitions({0: 0, 1: 1}, {0: 6 * row_b, 1: 4 * row_b})
    for r in range(6):
        c.admit(0, r)
    evicted = c.rebalance({0: 2 * row_b, 1: 8 * row_b})
    assert evicted == 4
    assert c.partition_bytes(0) == 2 * row_b
    for r in range(8):
        c.admit(1, r)
    assert c.table_rows(1) == 8


def test_cache_partition_validation():
    c = RowCache(1024, 32, "lru")
    with pytest.raises(ValueError):
        c.set_partitions({0: 0}, None)
    with pytest.raises(ValueError):
        c.set_partitions(None, {0: 64})


# ---------------------------------------------------- end-to-end run
def test_run_fleet_end_to_end():
    spec = _fleet_spec(requests=24, events=(
        ShiftTraffic(0.04, from_model="rm1", to_model="rm2", share=0.3),))
    rep = run_fleet(spec, fleet=_tiny_fleet())
    assert rep.completed == rep.total == 24
    assert set(rep.stats.per_model) == {"rm1", "rm2"}
    pm = rep.stats.per_model
    assert sum(m.queries for m in pm.values()) == 24
    assert all(m.completed == m.queries for m in pm.values())
    assert all(np.isfinite(m.p99) for m in pm.values())
    # the audit trail recorded the shift (audit-only at dispatch)
    kinds = [r.event.kind for r in rep.stats.events]
    assert "shift_traffic" in kinds


def test_run_fleet_per_model_sla_controllers():
    base = _fleet_spec(requests=24)
    spec = ScenarioSpec(
        name=base.name,
        models=(ModelRef(arch="rm1", rate_share=0.5, sla_p99_s=10.0),
                ModelRef(arch="rm2", rate_share=0.5, sla_p99_s=20.0)),
        topology=base.topology, workload=base.workload)
    rep = run_fleet(spec, fleet=_tiny_fleet())
    assert rep.completed == rep.total
    # generous targets: controllers attach but never act
    assert rep.stats.sla_actions == 0


def test_run_scenario_delegates_fleet_specs():
    # the front door: a multi-model spec reaches run_fleet, which
    # builds the real fleet members itself (no injection)
    spec = ScenarioSpec(
        name="fleet-front-door",
        models=(ModelRef(arch="rm1", rate_share=0.5),
                ModelRef(arch="rm2", rate_share=0.5)),
        topology=smoke_topology(batch_size=8),
        workload=Workload(requests=12, mean_size=4.0, max_size=12,
                          gap_s=0.004))
    rep = run_scenario(spec)
    assert rep.completed == rep.total == 12
    assert set(rep.stats.per_model) == {"rm1", "rm2"}


def test_run_fleet_rejects_single_model():
    spec = ScenarioSpec(name="t", model=ModelRef(arch="rm1"),
                        topology=smoke_topology(batch_size=8),
                        workload=Workload(requests=8))
    with pytest.raises(ValueError):
        run_fleet(spec)


def test_fleet_preset_builds_and_validates():
    spec = preset("fleet_shift")
    spec.validate()
    assert len(spec.models) == 2
    rt = ScenarioSpec.from_json(spec.to_json())
    assert rt == spec


def test_build_fleet_materializes_members():
    spec = _fleet_spec(requests=8)
    members = build_fleet(spec)
    assert [m.name for m in members] == ["rm1", "rm2"]
    assert all(m.params is not None for m in members)


def test_fleet_uniform_shape_enforced():
    from repro.serving.cluster import ClusterConfig, ClusterEngine
    ma = DLRMModel(CFG_A)
    bad = CFG_A.replace(
        name="fleet-bad",
        dlrm=dataclasses.replace(CFG_A.dlrm, embed_dim=16))
    mb = DLRMModel(bad)
    with pytest.raises(ValueError):
        ClusterEngine(ma, ma.init(0),
                      ClusterConfig(n_cn=1, m_mn=2, batch_size=8),
                      fleet=[("a", ma, ma.init(0)),
                             ("b", mb, mb.init(1))])
