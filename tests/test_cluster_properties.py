"""Property-based ClusterEngine routing invariants (issue #2 satellite).

Across random {n CN, m MN, replication, DDR/NMP mix} configurations:

- every (task, table) pair routes to exactly one live replica-holding MN;
- per-task shard assignments partition the table set, and the per-MN
  scatter accounts for every valid lookup exactly once (shard row counts
  sum to the batch's rows);
- an MN failure + re-route preserves bitwise outputs.

Hot-row cache properties (issue #4 satellite): for random query streams,
failure times, and resize schedules, a cached engine's scores are
bitwise-equal to the uncached engine's, and on DDR pools the byte
accounting identity ``bytes_saved == uncached.gather - cached.gather``
holds exactly (gather totals are occurrence counts there, so they are
routing-invariant; the identity is checked whenever neither run had to
re-issue a batch mid-MN-stage, the one event that changes the
occurrence multiset between runs).

Plain parametrized fallbacks cover pinned configs on bare environments
(the hypothesis shim skips the property variants there).
"""
import numpy as np
import pytest

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.configs import rm1
from repro.core import embedding_manager as em
from repro.data.queries import QueryDist, dlrm_batch
from repro.models.dlrm import DLRMModel
from repro.serving.cluster import ClusterConfig, ClusterEngine
from repro.serving.engine import Request

CFG = rm1.CONFIG.replace(
    name="rm1-prop",
    dlrm=rm1.DLRMConfig(num_tables=5, rows_per_table=48, embed_dim=8,
                        avg_pooling=4, num_dense_features=8,
                        bottom_mlp=(16, 8), top_mlp=(32, 16, 1)),
)
MODEL = DLRMModel(CFG)
PARAMS = MODEL.init(0)
T = CFG.dlrm.num_tables


def _requests(n, seed):
    rng = np.random.RandomState(seed)
    sizes = QueryDist(mean_size=4.0, max_size=12).sample(rng, n)
    reqs = []
    for i, s in enumerate(sizes):
        b = dlrm_batch(CFG, int(s), rng)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]},
                            int(s), 0.004 * i))
    return reqs


def _engine(n_cn, m_mn, nrep, nmp_count):
    mn_types = (["nmp_mn"] * nmp_count
                + ["ddr_mn"] * (m_mn - nmp_count))
    return ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=n_cn, m_mn=m_mn, batch_size=8, n_replicas=nrep,
        mn_types=mn_types))


def _check_routing_invariants(n_cn, m_mn, nrep, nmp_count):
    eng = _engine(n_cn, m_mn, nrep, nmp_count)
    # every table holds nrep distinct replicas
    for tid, reps in eng.alloc.replicas.items():
        assert len(reps) == len(set(reps)) == min(nrep, m_mn)
    # every (task, table) routes to exactly one live replica-holding MN
    for task in range(n_cn):
        for tid in range(T):
            dest = eng.routing.routes[(task, tid)]
            assert dest in eng.alloc.replicas[tid]
            assert dest not in eng.dead
        # shard assignment partitions the table set for this task
        shards = em.shard_assignment(eng.alloc, eng.routing, T, m_mn, task)
        routed = sorted(t for tids in shards for t in tids)
        assert routed == list(range(T))
    # scatter accounting: every valid lookup lands on exactly one MN, so
    # per-MN shard row counts sum to the batch's rows (and bytes)
    rng = np.random.RandomState(7)
    batch = dlrm_batch(CFG, 8, rng)
    _, mem_j, gat_j = eng._execute(0, batch["dense"], batch["indices"])
    valid = int((batch["indices"] >= 0).sum())
    assert sum(mem_j) == pytest.approx(valid * CFG.dlrm.embed_dim * 4)
    # DDR shards ship what they scan; NMP shards ship strictly less
    # whenever pooling compresses (> 1 valid slot somewhere in the bag)
    for j in range(m_mn):
        if mem_j[j] == 0:
            continue
        if eng.mn_nmp[j]:
            assert gat_j[j] <= mem_j[j]
        else:
            assert gat_j[j] == mem_j[j]


def _check_failure_preserves_outputs(n_cn, m_mn, nrep, nmp_count,
                                     fail_mn, t_fail):
    reqs = _requests(10, seed=fail_mn + 13)
    clean = _engine(n_cn, m_mn, nrep, nmp_count)
    res_c, _ = clean.serve(reqs)
    eng = _engine(n_cn, m_mn, nrep, nmp_count)
    res_f, stats = eng.serve(reqs, failures=[(t_fail, fail_mn)])
    assert stats.completed == len(reqs)
    want = {r.rid: r.outputs for r in res_c}
    for r in res_f:
        assert np.array_equal(r.outputs, want[r.rid])
    # fast path only (a late fail time applies at the end-of-stream
    # event flush; a reinit restores the full pool): the dead MN must
    # carry no routes
    if stats.reroutes and not stats.reinits:
        for (task, tid), dest in eng.routing.routes.items():
            assert dest != fail_mn


def _check_cache_bitwise_and_bytes(n_cn, m_mn, alpha, cache_mb, policy,
                                   fails, resizes, seed):
    """Cached vs uncached on the same stream + failure/resize schedule:
    scores must be bitwise-equal; on the all-DDR pool the byte identity
    is exact unless an in-flight re-issue perturbed one run's
    occurrence multiset (vanishingly rare — the MN stage is
    microseconds against millisecond event times)."""
    rng = np.random.RandomState(seed)
    qd = QueryDist(mean_size=4.0, max_size=12, alpha=alpha)
    sizes = qd.sample(rng, 10)
    reqs = []
    for i, s in enumerate(sizes):
        b = dlrm_batch(CFG, int(s), rng, alpha=alpha)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]},
                            int(s), 0.004 * i))
    events = dict(failures=list(fails), resizes=list(resizes))
    base = ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=n_cn, m_mn=m_mn, batch_size=8, n_replicas=2))
    res_b, st_b = base.serve(reqs, **events)
    eng = ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=n_cn, m_mn=m_mn, batch_size=8, n_replicas=2,
        cache_mb=cache_mb, cache_policy=policy))
    res_c, st_c = eng.serve(reqs, **events)
    assert st_c.completed == st_b.completed == len(reqs)
    want = {r.rid: r.outputs for r in res_b}
    for r in res_c:
        assert np.array_equal(r.outputs, want[r.rid])
    assert st_c.cache_bytes_saved == st_c.cache_hits * CFG.dlrm.embed_dim * 4
    if st_b.reissues == st_c.reissues == 0:
        gat_b = sum(st_b.mn_gather_bytes) + st_b.retired_gather_bytes
        gat_c = sum(st_c.mn_gather_bytes) + st_c.retired_gather_bytes
        assert st_c.cache_bytes_saved == gat_b - gat_c
        mem_b = sum(st_b.mn_access_bytes) + st_b.retired_access_bytes
        mem_c = sum(st_c.mn_access_bytes) + st_c.retired_access_bytes
        assert st_c.cache_bytes_saved == mem_b - mem_c


def _check_pipeline_depth_invariance(n_cn, m_mn, depth, seed):
    """Issue #6: for any seeded stream, any ``inflight_depth`` d >= 1
    yields per-query scores bitwise-identical to the sequential d=1
    clock, and modeled throughput is monotonically non-decreasing in d
    (event-free streams: a re-issue would change byte demand)."""
    rng = np.random.RandomState(seed)
    sizes = QueryDist(mean_size=4.0, max_size=12).sample(rng, 16)
    reqs = []
    for i, s in enumerate(sizes):
        b = dlrm_batch(CFG, int(s), rng)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]},
                            int(s), 0.0))
    prev_qps = None
    base = None
    for d in sorted({1, max(1, depth // 2), depth}):
        eng = ClusterEngine(MODEL, PARAMS, ClusterConfig(
            n_cn=n_cn, m_mn=m_mn, batch_size=8, n_replicas=2,
            inflight_depth=d))
        res, stats = eng.serve(reqs)
        assert stats.completed == len(reqs)
        assert stats.inflight_depth == d
        if base is None:
            base = {r.rid: r.outputs for r in res}
        else:
            for r in res:
                assert np.array_equal(r.outputs, base[r.rid]), (d, r.rid)
        if prev_qps is not None:
            assert stats.throughput_qps >= prev_qps * (1 - 1e-9), \
                (d, prev_qps, stats.throughput_qps)
        prev_qps = stats.throughput_qps


def _check_cn_router_score_invariance(n_cn, m_mn, depth, seed):
    """Issue #9: the CN router policy decides placement between
    identical CNs — it moves batches in time, never values.  Every
    policy scores bitwise-identically to the legacy cpu_free router on
    the same stream, and completes everything."""
    from repro.serving.cluster import CN_ROUTERS
    reqs = _requests(12, seed)
    base = None
    for router in CN_ROUTERS:
        eng = ClusterEngine(MODEL, PARAMS, ClusterConfig(
            n_cn=n_cn, m_mn=m_mn, batch_size=8, n_replicas=2,
            inflight_depth=depth, cn_router=router))
        res, stats = eng.serve(reqs)
        assert stats.completed == len(reqs)
        if base is None:
            base = {r.rid: r.outputs for r in res}
        else:
            for r in res:
                assert np.array_equal(r.outputs, base[r.rid]), \
                    (router, r.rid)


# --------------------------------------------------------- property form
@settings(max_examples=10, deadline=None)
@given(n_cn=st.integers(1, 3), m_mn=st.integers(2, 5),
       nrep=st.integers(1, 2), nmp_frac=st.floats(0.0, 1.0))
def test_routing_invariants_random_configs(n_cn, m_mn, nrep, nmp_frac):
    _check_routing_invariants(n_cn, m_mn, min(nrep, m_mn),
                              int(round(nmp_frac * m_mn)))


@settings(max_examples=6, deadline=None)
@given(m_mn=st.integers(2, 4), nmp_frac=st.floats(0.0, 1.0),
       fail_mn=st.integers(0, 3), t_fail=st.floats(0.0, 0.05))
def test_failure_reroute_bitwise_random_configs(m_mn, nmp_frac,
                                                fail_mn, t_fail):
    _check_failure_preserves_outputs(2, m_mn, 2, int(round(nmp_frac * m_mn)),
                                     fail_mn % m_mn, t_fail)


@settings(max_examples=8, deadline=None)
@given(alpha=st.floats(0.0, 1.3), cache_kb=st.integers(1, 64),
       policy=st.sampled_from(["lru", "lfu"]),
       fail_mn=st.integers(0, 3), t_fail=st.floats(0.0, 0.04),
       resize_m=st.integers(3, 6), t_resize=st.floats(0.0, 0.04),
       seed=st.integers(0, 99))
def test_cache_bitwise_and_bytes_random_streams(alpha, cache_kb, policy,
                                                fail_mn, t_fail,
                                                resize_m, t_resize, seed):
    _check_cache_bitwise_and_bytes(
        2, 4, alpha, cache_kb / 1000.0, policy,
        fails=[(t_fail, fail_mn)], resizes=[(t_resize, 2, resize_m)],
        seed=seed)


@settings(max_examples=10, deadline=None)
@given(n_cn=st.integers(1, 3), m_mn=st.integers(2, 5),
       depth=st.integers(1, 8), seed=st.integers(0, 999))
def test_pipeline_depth_invariance_random_streams(n_cn, m_mn, depth, seed):
    _check_pipeline_depth_invariance(n_cn, m_mn, depth, seed)


@settings(max_examples=10, deadline=None)
@given(n_cn=st.integers(1, 4), m_mn=st.integers(2, 5),
       depth=st.integers(1, 8), seed=st.integers(0, 999))
def test_cn_router_score_invariance_random_streams(n_cn, m_mn, depth, seed):
    _check_cn_router_score_invariance(n_cn, m_mn, depth, seed)


# ------------------------------------------------- pinned-config fallback
@pytest.mark.parametrize("n_cn,m_mn,nrep,nmp_count", [
    (1, 2, 1, 0), (2, 4, 2, 2), (3, 5, 2, 5), (2, 3, 1, 1),
    (2, 2, 3, 1),      # n_replicas > pool size: clamped, not a crash
])
def test_routing_invariants_pinned(n_cn, m_mn, nrep, nmp_count):
    _check_routing_invariants(n_cn, m_mn, nrep, nmp_count)


@pytest.mark.parametrize("m_mn,nmp_count,fail_mn", [
    (4, 2, 1), (4, 2, 3), (3, 3, 0),
])
def test_failure_reroute_bitwise_pinned(m_mn, nmp_count, fail_mn):
    _check_failure_preserves_outputs(2, m_mn, 2, nmp_count, fail_mn, 0.02)


@pytest.mark.parametrize("alpha,cache_mb,policy,fails,resizes,seed", [
    (1.05, 0.008, "lru", [(0.015, 1)], [], 0),
    (1.05, 0.008, "lfu", [], [(0.02, 2, 6)], 1),
    (0.0, 0.002, "lru", [(0.01, 0)], [(0.025, 2, 3)], 2),
    (1.2, 0.001, "lfu", [(0.03, 2)], [(0.012, 3, 5)], 3),
])
def test_cache_bitwise_and_bytes_pinned(alpha, cache_mb, policy,
                                        fails, resizes, seed):
    _check_cache_bitwise_and_bytes(2, 4, alpha, cache_mb, policy,
                                   fails, resizes, seed)


@pytest.mark.parametrize("n_cn,m_mn,depth,seed", [
    (2, 4, 4, 0), (1, 2, 2, 7), (3, 5, 8, 13), (2, 3, 6, 42),
])
def test_pipeline_depth_invariance_pinned(n_cn, m_mn, depth, seed):
    _check_pipeline_depth_invariance(n_cn, m_mn, depth, seed)


@pytest.mark.parametrize("n_cn,m_mn,depth,seed", [
    (2, 4, 1, 0), (2, 4, 4, 7), (1, 3, 2, 13), (3, 5, 8, 42),
])
def test_cn_router_score_invariance_pinned(n_cn, m_mn, depth, seed):
    _check_cn_router_score_invariance(n_cn, m_mn, depth, seed)
