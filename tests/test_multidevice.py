"""Multi-device SPMD correctness: run small models on 8 fake host devices
in a SUBPROCESS (the test process itself must keep the default single
device; jax locks device count at first init)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro import configs
from repro.configs.base import ShapeConfig
from repro.launch.steps import build_program
from repro.models import registry
from repro.train import optimizer as opt_mod
from repro.train.optimizer import OptConfig
from repro.distributed import sharding as shd

out = {}
mesh = jax.make_mesh((2, 4), ("data", "model"))

# --- dense arch: sharded loss == single-device loss ---
cfg = configs.get_reduced("llama3-8b").replace(
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16, dtype="float32", param_dtype="float32")
model = registry.build(cfg)
params = model.init(0)
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, 256, (4, 64)), jnp.int32),
         "labels": jnp.asarray(rng.randint(0, 256, (4, 64)), jnp.int32)}
loss_1dev = float(jax.jit(model.loss)(params, batch))

rules = registry.make_rules(cfg, mesh, "train")
with shd.use_mesh(mesh, rules):
    loss_sharded = float(jax.jit(model.loss)(params, batch))
out["dense_loss_match"] = abs(loss_1dev - loss_sharded) < 1e-4

# --- train step compiles + runs with explicit shardings ---
shape = ShapeConfig("t", 64, 4, "train")
jitted, args, rules = build_program(cfg, shape, mesh)
p = model.init(0)
st = opt_mod.init_state(OptConfig(), p)
p2, st2, metrics = jitted(p, st, batch)
out["train_step_finite"] = bool(np.isfinite(float(metrics["loss"])))

# --- MoE with real expert parallelism: matches single-device ---
mcfg = configs.get_reduced("phi3.5-moe-42b-a6.6b").replace(
    dtype="float32", param_dtype="float32")
import dataclasses
mcfg = mcfg.replace(moe=dataclasses.replace(mcfg.moe, capacity_factor=8.0))
mmodel = registry.build(mcfg)
mparams = mmodel.init(0)
mb = {"tokens": jnp.asarray(rng.randint(0, 256, (4, 32)), jnp.int32),
      "labels": jnp.asarray(rng.randint(0, 256, (4, 32)), jnp.int32)}
l1 = float(jax.jit(mmodel.loss)(mparams, mb))
mrules = registry.make_rules(mcfg, mesh, "train")
with shd.use_mesh(mesh, mrules):
    l2 = float(jax.jit(mmodel.loss)(mparams, mb))
out["moe_ep_loss_match"] = abs(l1 - l2) < 1e-3

# --- decode with sequence-sharded KV cache == unsharded decode ---
dcfg = cfg
dmodel = registry.build(dcfg)
dparams = dmodel.init(0)
toks = jnp.asarray(rng.randint(0, 256, (4, 32)), jnp.int32)
lp, cache = dmodel.prefill(dparams, {"tokens": toks}, cache_len=64)
ld_ref, _ = dmodel.decode_step(dparams, cache,
                               {"tokens": toks[:, :1]})
drules = registry.make_rules(dcfg, mesh, "decode")
with shd.use_mesh(mesh, drules):
    lp2, cache2 = jax.jit(
        lambda p, b: dmodel.prefill(p, b, cache_len=64))(dparams,
                                                         {"tokens": toks})
    ld_sh, _ = jax.jit(dmodel.decode_step)(dparams, cache2,
                                           {"tokens": toks[:, :1]})
out["decode_seqshard_match"] = bool(
    np.max(np.abs(np.asarray(ld_ref) - np.asarray(ld_sh))) < 1e-3)

# --- disaggregated embedding lookup across a 4-shard MN pool ---
from repro.core import sharding as core_shd
tables = jnp.asarray(rng.randn(8, 64, 16), jnp.float32)
idx = jnp.asarray(rng.randint(0, 64, (4, 8, 5)), jnp.int32)
from repro.models.dlrm import embedding_bag_ref
want = embedding_bag_ref(tables, idx)
with shd.use_mesh(mesh, None):
    got = core_shd.disagg_embedding_lookup(tables, idx, mesh=mesh)
out["disagg_lookup_match"] = bool(
    np.max(np.abs(np.asarray(got) - np.asarray(want))) < 1e-4)

# --- elastic: reshard onto a shrunken mesh after 'failures' ---
from repro.distributed import elastic
small = elastic.healthy_mesh({"model": 4}, failed_fraction=0.4)
out["elastic_mesh_devices"] = int(small.devices.size)
p_resh = elastic.reshard_tree(params, model.param_specs(), small, rules)
out["elastic_reshard_ok"] = bool(np.isfinite(
    float(jax.jit(model.loss)(p_resh, batch))))

print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def spmd_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_dense_sharded_loss_matches(spmd_results):
    assert spmd_results["dense_loss_match"]


def test_train_step_runs_sharded(spmd_results):
    assert spmd_results["train_step_finite"]


def test_moe_expert_parallel_matches(spmd_results):
    assert spmd_results["moe_ep_loss_match"]


def test_decode_sequence_sharded_cache_matches(spmd_results):
    assert spmd_results["decode_seqshard_match"]


def test_disaggregated_embedding_lookup(spmd_results):
    assert spmd_results["disagg_lookup_match"]


def test_elastic_reshard(spmd_results):
    assert spmd_results["elastic_mesh_devices"] == 4  # 8*0.6 -> 4 (4x1)
    assert spmd_results["elastic_reshard_ok"]
