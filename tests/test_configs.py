"""Config registry + analytic parameter counting."""
import jax
import pytest

from repro import configs
from repro.models import registry


def test_registry_lists_all_assigned():
    assert len(configs.ASSIGNED_ARCHS) == 10
    for a in configs.ASSIGNED_ARCHS:
        cfg = configs.get_config(a)
        assert cfg.name.startswith(a.split("-")[0].split(".")[0][:4]) or True
        assert cfg.d_model > 0


def _pad_overhead(cfg) -> int:
    """Implementation padding not in the analytic count: padded vocab rows
    + padded (masked, never-routed) EP experts."""
    from repro.models.transformer import padded_vocab
    pad = padded_vocab(cfg.vocab_size) - cfg.vocab_size
    tied = getattr(cfg, "tie_embeddings", False)
    total = pad * cfg.d_model * (1 if tied else 2)
    if cfg.moe is not None:
        extra = cfg.moe.padded_experts - cfg.moe.num_experts
        total += (extra * (3 * cfg.d_model * cfg.moe.d_ff_expert
                           + cfg.d_model) * cfg.num_layers)
    hp = cfg.padded_heads - cfg.num_heads
    if hp and cfg.family in ("dense", "vlm", "moe"):
        hd = cfg.resolved_head_dim
        per = 2 * hp * hd * cfg.d_model + (hp * hd if cfg.attn_bias else 0)
        total += per * cfg.num_layers
    return total


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_analytic_count_matches_init(arch):
    """counting.py formulas == actual initialized leaf sizes (reduced)."""
    cfg = configs.get_reduced(arch)
    model = registry.build(cfg)
    analytic = cfg.param_count()
    actual = sum(x.size for x in jax.tree.leaves(model.init(0)))
    assert actual - _pad_overhead(cfg) == analytic


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_table_count_matches_analytic_fullsize(arch):
    """Full-size param tables (no allocation) == analytic formulas."""
    cfg = configs.get_config(arch)
    model = registry.build(cfg)
    assert model.param_count() - _pad_overhead(cfg) == cfg.param_count()


def test_published_sizes():
    """Spot-check against published parameter counts."""
    expect = {
        "qwen2.5-14b": (14.8e9, 0.02),
        "llama3-8b": (8.0e9, 0.01),
        "smollm-135m": (135e6, 0.03),
        "phi3.5-moe-42b-a6.6b": (41.9e9, 0.02),
        "zamba2-7b": (7.0e9, 0.05),
    }
    for arch, (n, tol) in expect.items():
        got = configs.get_config(arch).param_count()
        assert abs(got - n) / n < tol, (arch, got)
    # MoE active params
    assert abs(configs.get_config("phi3.5-moe-42b-a6.6b").active_param_count()
               - 6.6e9) / 6.6e9 < 0.02
    assert abs(configs.get_config("qwen2-moe-a2.7b").active_param_count()
               - 2.7e9) / 2.7e9 < 0.02


def test_rm_generations_hit_paper_curves():
    from repro.configs import rm1, rm2
    assert abs(rm1.size_bytes(0) - 1.4e12) / 1.4e12 < 0.01   # 1.4 TB
    assert abs(rm1.size_bytes(5) - 7.8e12) / 7.8e12 < 0.01   # 7.8 TB


def test_shape_applicability():
    from repro.configs.base import SHAPES, shape_applicable
    long = SHAPES["long_500k"]
    ok, _ = shape_applicable(configs.get_config("llama3-8b"), long)
    assert not ok
    ok, _ = shape_applicable(configs.get_config("rwkv6-3b"), long)
    assert ok
    ok, _ = shape_applicable(configs.get_config("zamba2-7b"), long)
    assert ok
