"""Golden regression for the hot-row cache headline (issue #4 satellite).

`benchmarks.bench_cache.run(smoke=True)` serves the same Zipf-skewed
stream uncached and with a 64 MB per-CN RowCache over a 128 MB table
pool.  The acceptance claim is >30% gather-byte reduction at Zipf
alpha=1.05 with the 64 MB budget; the measured smoke point lands near
59% hit rate / 59% gather reduction / 33% p99 reduction, and the
uniform (alpha=0) stream must stay near zero — the saving comes from
skew, not from accounting.  Bands are pinned (mirroring
`test_nmp_golden.py`) so cache/accounting edits cannot silently drift
the headline; bitwise parity is asserted by the bench itself.
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import bench_cache  # noqa: E402

HOT = (1.05, 64.0)
COLD = (0.0, 64.0)


@pytest.fixture(scope="module")
def sweep():
    return bench_cache.run(smoke=True)


def test_smoke_covers_the_pinned_points(sweep):
    assert HOT in sweep and COLD in sweep
    assert all(v["bitwise"] for v in sweep.values())


def test_hot_point_hit_rate_band(sweep):
    hr = sweep[HOT]["hit_rate"]
    assert 0.45 <= hr <= 0.80, f"alpha=1.05/64MB hit rate drifted: {hr:.3f}"


def test_hot_point_gather_reduction_band(sweep):
    red = sweep[HOT]["reduction"]
    assert red > 0.30, f"headline claim broken: {red:.2%} <= 30%"
    assert red <= 0.80, f"implausibly high reduction: {red:.2%}"


def test_hot_point_p99_reduction(sweep):
    drop = sweep[HOT]["p99_drop"]
    assert 0.10 <= drop <= 0.60, f"p99 reduction drifted: {drop:.2%}"


def test_uniform_stream_barely_benefits(sweep):
    """alpha=0 leaves only intra-stream duplicate hits: if the uniform
    stream shows a large reduction, the accounting is lying about skew."""
    assert sweep[COLD]["reduction"] < 0.10
    assert sweep[COLD]["hit_rate"] < 0.10
