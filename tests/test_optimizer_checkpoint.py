"""Optimizers, gradient compression, checkpointing, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.optimizer import OptConfig


def quad_problem():
    target = jnp.asarray(np.random.RandomState(0).randn(32), jnp.float32)
    params = {"w": jnp.zeros(32, jnp.float32)}

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss_fn, target


@pytest.mark.parametrize("kind", ["adam", "adagrad", "sgd"])
def test_optimizers_converge_quadratic(kind):
    params, loss_fn, target = quad_problem()
    cfg = OptConfig(kind=kind, lr=0.1 if kind != "sgd" else 0.05,
                    grad_clip=1e9)
    state = opt_mod.init_state(cfg, params)
    for _ in range(300):
        grads = jax.grad(loss_fn)(params)
        params, state = opt_mod.apply_updates(cfg, params, grads, state)
    assert float(loss_fn(params)) < 0.05 * float(
        jnp.sum(target ** 2))


def test_grad_compression_error_feedback():
    """int8 compression with error feedback still converges."""
    params, loss_fn, target = quad_problem()
    cfg = OptConfig(kind="adam", lr=0.1, compress_grads=True, grad_clip=1e9)
    state = opt_mod.init_state(cfg, params)
    for _ in range(400):
        grads = jax.grad(loss_fn)(params)
        params, state = opt_mod.apply_updates(cfg, params, grads, state)
    assert float(loss_fn(params)) < 0.1 * float(jnp.sum(target ** 2))


def test_compress_int8_bound():
    g = jnp.asarray(np.random.RandomState(1).randn(1000), jnp.float32)
    err0 = jnp.zeros_like(g)
    deq, err = opt_mod.compress_int8(g, err0)
    # quantization error bounded by one step of the scale
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.abs(g - deq).max()) <= scale * 0.51 + 1e-6
    np.testing.assert_allclose(np.asarray(g), np.asarray(deq + err),
                               rtol=1e-5, atol=1e-6)


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    cfg = OptConfig(kind="sgd", lr=1.0, grad_clip=1.0)
    state = opt_mod.init_state(cfg, params)
    big = {"w": jnp.full(4, 100.0)}
    p2, _ = opt_mod.apply_updates(cfg, params, big, state)
    assert float(jnp.linalg.norm(p2["w"])) <= 1.0 + 1e-5


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    cfg = OptConfig()
    state = opt_mod.init_state(cfg, params)
    d = str(tmp_path)
    ckpt.save(d, params, state, 42)
    assert ckpt.latest_step(d) == 42
    p2, s2, step = ckpt.try_restore(d, params, state)
    assert step == 42
    np.testing.assert_array_equal(np.asarray(p2["a"]), np.asarray(params["a"]))
    assert p2["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_latest_wins(tmp_path):
    params = {"a": jnp.zeros(3)}
    state = opt_mod.init_state(OptConfig(), params)
    d = str(tmp_path)
    ckpt.save(d, params, state, 10)
    ckpt.save(d, {"a": jnp.ones(3)}, state, 20)
    p2, _, step = ckpt.try_restore(d, params, state)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(p2["a"]), np.ones(3))


def test_train_loop_fault_recovery(tmp_path):
    """Simulated node failure mid-training: loop restores the checkpoint
    and completes (the CN-failure recovery path)."""
    from repro import configs
    from repro.data.queries import ShardedLoader, lm_batch
    from repro.models import registry
    from repro.train.train_loop import TrainLoopConfig, run_train_loop

    cfg = configs.get_reduced("smollm-135m")
    model = registry.build(cfg)
    gen = lambda rng: lm_batch(cfg.vocab_size, 2, 16, rng)
    fired = {"n": 0}

    def fault_hook(step):
        if step == 7 and fired["n"] == 0:
            fired["n"] = 1
            raise RuntimeError("injected node failure")

    loop_cfg = TrainLoopConfig(steps=12, log_every=4, checkpoint_every=5,
                               checkpoint_dir=str(tmp_path))
    params, state, hist = run_train_loop(
        model, OptConfig(lr=1e-3), ShardedLoader(gen), loop_cfg,
        fault_hook=fault_hook, log_fn=lambda *a: None)
    assert fired["n"] == 1
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_state_specs_zero1_no_axis_conflict():
    """ZeRO specs never map one mesh axis to two dims (regression)."""
    import jax
    from repro import configs
    from repro.distributed import sharding as shd
    from repro.models import registry

    cfg = configs.get_config("smollm-135m")
    model = registry.build(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = registry.__dict__["make_rules"](cfg, mesh, "train")
    with shd.use_mesh(mesh, rules):
        specs = opt_mod.state_specs(OptConfig(), model.param_specs(),
                                    model.param_shapes())
        for leaf in jax.tree.leaves(specs,
                                    is_leaf=lambda x: isinstance(x, tuple)):
            if not isinstance(leaf, tuple):
                continue
            axes = []
            for n in leaf:
                r = shd.resolve((n,))[0]
                if r is not None:
                    axes += [r] if isinstance(r, str) else list(r)
            assert len(axes) == len(set(axes)), leaf
