"""Data pipeline: query distribution, arrivals, hashing, batches."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.data import queries as q


def test_query_sizes_heavy_tailed(rng):
    d = q.QueryDist(mean_size=64.0, sigma=1.0)
    s = d.sample(rng, 50_000)
    assert s.min() >= 1 and s.max() <= d.max_size
    assert np.percentile(s, 99) > 6 * np.median(s)   # Fig. 2a heavy tail


def test_poisson_rate(rng):
    arr = q.poisson_arrivals(1000.0, 10.0, rng)
    assert len(arr) == pytest.approx(10_000, rel=0.1)
    assert (np.diff(arr) >= 0).all()


def test_hash_deterministic_and_in_range():
    raw = np.arange(1000).reshape(10, 100)
    h1 = q.hash_features(raw, 997)
    h2 = q.hash_features(raw, 997)
    np.testing.assert_array_equal(h1, h2)
    assert (h1 >= 0).all() and (h1 < 997).all()
    # different salt decorrelates
    h3 = q.hash_features(raw, 997, salt=1)
    assert (h1 != h3).mean() > 0.9


def test_dlrm_batch_valid(rng):
    cfg = configs.get_reduced("rm1")
    b = q.dlrm_batch(cfg, 32, rng)
    r = cfg.dlrm
    assert b["dense"].shape == (32, r.num_dense_features)
    assert b["indices"].shape == (32, r.num_tables, r.avg_pooling)
    valid = b["indices"][b["indices"] >= 0]
    assert (valid < r.rows_per_table).all()
    assert ((b["indices"] >= 0).sum(axis=-1) >= 1).all()  # >=1 per bag
    assert set(np.unique(b["labels"])) <= {0, 1}


def test_sharded_loader_disjoint_streams():
    cfg = configs.get_reduced("rm1")
    gen = lambda rng: q.dlrm_batch(cfg, 4, rng)
    it0 = iter(q.ShardedLoader(gen, host_id=0, num_hosts=2, seed=1))
    it1 = iter(q.ShardedLoader(gen, host_id=1, num_hosts=2, seed=1))
    b0, b1 = next(it0), next(it1)
    assert not np.array_equal(b0["dense"], b1["dense"])
    # determinism per host
    it0b = iter(q.ShardedLoader(gen, host_id=0, num_hosts=2, seed=1))
    np.testing.assert_array_equal(next(it0b)["dense"], b0["dense"])


def test_zipf_indices_skewed_and_deterministic(rng):
    idx = q.zipf_indices(rng, (64, 4, 16), num_rows=1000, alpha=1.05)
    assert idx.dtype == np.int32
    assert (idx >= 0).all() and (idx < 1000).all()
    # the hot head: rank 0..99 (10% of rows) absorbs most of the mass
    head = (idx < 100).mean()
    assert head > 0.5
    idx2 = q.zipf_indices(np.random.RandomState(0), (64, 4, 16), 1000, 1.05)
    np.testing.assert_array_equal(idx, idx2)
    # steeper skew concentrates harder
    hotter = q.zipf_indices(np.random.RandomState(0), (64, 4, 16), 1000, 1.5)
    assert (hotter < 100).mean() > head


def test_dlrm_batch_alpha_zero_matches_legacy_stream():
    """alpha=0 must preserve the exact uniform-hash RNG stream (seeded
    goldens depend on it): the kwarg default cannot perturb sampling."""
    cfg = configs.get_reduced("rm1")
    a = q.dlrm_batch(cfg, 16, np.random.RandomState(3))
    b = q.dlrm_batch(cfg, 16, np.random.RandomState(3), alpha=0.0)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_dlrm_batch_zipf_mode(rng):
    cfg = configs.get_reduced("rm1")
    b = q.dlrm_batch(cfg, 64, rng, alpha=1.2)
    r = cfg.dlrm
    assert b["indices"].shape == (64, r.num_tables, r.avg_pooling)
    valid = b["indices"][b["indices"] >= 0]
    assert (valid < r.rows_per_table).all()
    # hot head present: low row ids dominate the valid lookups
    assert (valid < r.rows_per_table // 10).mean() > 0.4


def test_dlrm_request_stream_seeded_and_reproducible():
    cfg = configs.get_reduced("rm1")
    qd = q.QueryDist(mean_size=6.0, max_size=16, alpha=1.05)
    s1 = q.dlrm_request_stream(cfg, 8, seed=5, dist=qd, gap_s=0.001)
    s2 = q.dlrm_request_stream(cfg, 8, seed=5, dist=qd, gap_s=0.001)
    assert [t[0] for t in s1] == list(range(8))
    for (i1, p1, n1, t1), (i2, p2, n2, t2) in zip(s1, s2):
        assert (i1, n1, t1) == (i2, n2, t2)
        np.testing.assert_array_equal(p1["indices"], p2["indices"])
        np.testing.assert_array_equal(p1["dense"], p2["dense"])
    s3 = q.dlrm_request_stream(cfg, 8, seed=6, dist=qd, gap_s=0.001)
    assert not np.array_equal(s1[0][1]["dense"], s3[0][1]["dense"])


@settings(max_examples=25, deadline=None)
@given(mean=st.floats(2.0, 256.0), sigma=st.floats(0.1, 1.5),
       seed=st.integers(0, 999))
def test_query_dist_mean_tracks(mean, sigma, seed):
    d = q.QueryDist(mean_size=mean, sigma=sigma, max_size=100_000)
    s = d.sample(np.random.RandomState(seed), 20_000)
    # ceil() biases the mean up by <1; heavy tails add sampling noise
    assert mean * 0.75 <= s.mean() <= mean * 1.3 + 1.0
