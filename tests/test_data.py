"""Data pipeline: query distribution, arrivals, hashing, batches."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.data import queries as q


def test_query_sizes_heavy_tailed(rng):
    d = q.QueryDist(mean_size=64.0, sigma=1.0)
    s = d.sample(rng, 50_000)
    assert s.min() >= 1 and s.max() <= d.max_size
    assert np.percentile(s, 99) > 6 * np.median(s)   # Fig. 2a heavy tail


def test_poisson_rate(rng):
    arr = q.poisson_arrivals(1000.0, 10.0, rng)
    assert len(arr) == pytest.approx(10_000, rel=0.1)
    assert (np.diff(arr) >= 0).all()


def test_hash_deterministic_and_in_range():
    raw = np.arange(1000).reshape(10, 100)
    h1 = q.hash_features(raw, 997)
    h2 = q.hash_features(raw, 997)
    np.testing.assert_array_equal(h1, h2)
    assert (h1 >= 0).all() and (h1 < 997).all()
    # different salt decorrelates
    h3 = q.hash_features(raw, 997, salt=1)
    assert (h1 != h3).mean() > 0.9


def test_dlrm_batch_valid(rng):
    cfg = configs.get_reduced("rm1")
    b = q.dlrm_batch(cfg, 32, rng)
    r = cfg.dlrm
    assert b["dense"].shape == (32, r.num_dense_features)
    assert b["indices"].shape == (32, r.num_tables, r.avg_pooling)
    valid = b["indices"][b["indices"] >= 0]
    assert (valid < r.rows_per_table).all()
    assert ((b["indices"] >= 0).sum(axis=-1) >= 1).all()  # >=1 per bag
    assert set(np.unique(b["labels"])) <= {0, 1}


def test_sharded_loader_disjoint_streams():
    cfg = configs.get_reduced("rm1")
    gen = lambda rng: q.dlrm_batch(cfg, 4, rng)
    it0 = iter(q.ShardedLoader(gen, host_id=0, num_hosts=2, seed=1))
    it1 = iter(q.ShardedLoader(gen, host_id=1, num_hosts=2, seed=1))
    b0, b1 = next(it0), next(it1)
    assert not np.array_equal(b0["dense"], b1["dense"])
    # determinism per host
    it0b = iter(q.ShardedLoader(gen, host_id=0, num_hosts=2, seed=1))
    np.testing.assert_array_equal(next(it0b)["dense"], b0["dense"])


@settings(max_examples=25, deadline=None)
@given(mean=st.floats(2.0, 256.0), sigma=st.floats(0.1, 1.5),
       seed=st.integers(0, 999))
def test_query_dist_mean_tracks(mean, sigma, seed):
    d = q.QueryDist(mean_size=mean, sigma=sigma, max_size=100_000)
    s = d.sample(np.random.RandomState(seed), 20_000)
    # ceil() biases the mean up by <1; heavy tails add sampling noise
    assert mean * 0.75 <= s.mean() <= mean * 1.3 + 1.0
