"""ClusterEngine: multi-unit routed serving + MN failure survival.

Ground truth for outputs is the model's own serve_step on each query's
full payload — the cluster's scatter/fused-pool/gather path must score
every query identically regardless of batching, routing, or failures.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs import rm1
from repro.core.scheduler import Batcher, Query
from repro.data.queries import QueryDist, dlrm_batch
from repro.models.dlrm import DLRMModel
from repro.serving.cluster import ClusterConfig, ClusterEngine
from repro.serving.engine import Request

CFG = rm1.CONFIG.replace(
    name="rm1-test",
    dlrm=rm1.DLRMConfig(num_tables=6, rows_per_table=64, embed_dim=8,
                        avg_pooling=5, num_dense_features=8,
                        bottom_mlp=(16, 8), top_mlp=(32, 16, 1)),
)


@pytest.fixture(scope="module")
def model_and_params():
    model = DLRMModel(CFG)
    return model, model.init(0)


def make_requests(n, seed=0, mean_size=5.0, max_size=24):
    rng = np.random.RandomState(seed)
    sizes = QueryDist(mean_size=mean_size, max_size=max_size).sample(rng, n)
    reqs = []
    for i, s in enumerate(sizes):
        b = dlrm_batch(CFG, int(s), rng)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]},
                            int(s), 0.005 * i))
    return reqs


def direct_scores(model, params, reqs):
    out = {}
    for r in reqs:
        batch = {"dense": jnp.asarray(r.payload["dense"]),
                 "indices": jnp.asarray(r.payload["indices"])}
        out[r.rid] = np.asarray(model.serve_step(params, batch))
    return out


def test_cluster_end_to_end(model_and_params):
    model, params = model_and_params
    reqs = make_requests(20)
    eng = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=16, n_replicas=2))
    results, stats = eng.serve(reqs)
    assert stats.completed == len(reqs)
    assert sorted(r.rid for r in results) == list(range(len(reqs)))
    want = direct_scores(model, params, reqs)
    for r in results:
        assert r.outputs.shape == (reqs[r.rid].size,)
        np.testing.assert_allclose(r.outputs, want[r.rid],
                                   atol=1e-5, rtol=1e-5)
    # every query saw a positive modeled latency
    assert all(r.latency > 0 for r in results)
    # greedy routing kept the MN pool roughly balanced
    assert stats.imbalance < 2.0


def test_cluster_replication_places_tables(model_and_params):
    model, params = model_and_params
    eng = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, n_replicas=2))
    for tid, reps in eng.alloc.replicas.items():
        assert len(reps) == 2
    # union of shards covers all tables
    covered = sorted({t for tids in eng._shard_tids for t in tids})
    assert covered == list(range(CFG.dlrm.num_tables))


def test_cluster_survives_mn_failure_mid_stream(model_and_params):
    """Kill one MN while queries are in flight: all queries must still
    complete, with outputs identical to the failure-free run, and no
    traffic may reach the dead MN afterwards."""
    model, params = model_and_params
    reqs = make_requests(20)
    cc = ClusterConfig(n_cn=2, m_mn=4, batch_size=16, n_replicas=2)

    clean = ClusterEngine(model, params, cc)
    res_clean, _ = clean.serve(reqs)
    want = {r.rid: r.outputs for r in res_clean}

    eng = ClusterEngine(model, params, cc)
    t_fail = 0.03                      # mid-stream: arrivals span 0..0.1
    res, stats = eng.serve(reqs, failures=[(t_fail, 1)])
    assert stats.failures == 1
    assert stats.reroutes >= 1 and stats.reinits == 0
    assert stats.completed == len(reqs)          # no dropped queries
    for r in res:
        np.testing.assert_allclose(r.outputs, want[r.rid],
                                   atol=1e-5, rtol=1e-5)
    assert 1 in eng.dead
    # post-failure routing never targets the dead MN
    for (task, tid), dest in eng.routing.routes.items():
        assert dest != 1


def test_cluster_reinit_when_last_replica_lost(model_and_params):
    """n_replicas=1: an MN failure loses tables entirely -> the engine
    re-initializes shards from params and keeps serving correctly."""
    model, params = model_and_params
    reqs = make_requests(12)
    eng = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=3, batch_size=16, n_replicas=1))
    lost_tables = list(eng._shard_tids[0])
    assert lost_tables                 # MN 0 held something
    res, stats = eng.serve(reqs, failures=[(0.02, 0)])
    assert stats.completed == len(reqs)
    assert stats.reinits == 1
    want = direct_scores(model, params, reqs)
    for r in res:
        np.testing.assert_allclose(r.outputs, want[r.rid],
                                   atol=1e-5, rtol=1e-5)


def test_cluster_kernel_matches_ref_path(model_and_params):
    model, params = model_and_params
    reqs = make_requests(8)
    cc = dict(n_cn=2, m_mn=4, batch_size=16, n_replicas=2)
    r_k, _ = ClusterEngine(model, params,
                           ClusterConfig(use_kernel=True, **cc)).serve(reqs)
    r_r, _ = ClusterEngine(model, params,
                           ClusterConfig(use_kernel=False, **cc)).serve(reqs)
    for a, b in zip(r_k, r_r):
        np.testing.assert_allclose(a.outputs, b.outputs,
                                   atol=1e-6, rtol=1e-6)


def test_cluster_latency_model_cross_validates(model_and_params):
    """The engine's virtual clock is built from the analytic stage model
    with measured G_S bytes — unloaded they must agree closely."""
    model, params = model_and_params
    eng = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=16, n_replicas=2))
    eng.serve(make_requests(16))
    v = eng.validate_latency_model()
    assert 0.3 < v["ratio"] < 3.0


# ------------------------------------------------------ NMP memory nodes
MIX = ["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"]


def test_parse_mn_types_specs():
    from repro.serving.cluster import parse_mn_types
    assert parse_mn_types("ddr_mn", 3) == ["ddr_mn"] * 3
    assert parse_mn_types("nmp_mn", 2) == ["nmp_mn"] * 2
    assert parse_mn_types("ddr_mn,nmp_mn", 2) == ["ddr_mn", "nmp_mn"]
    assert parse_mn_types("2xddr_mn+2xnmp_mn", 4) == MIX
    with pytest.raises(ValueError):
        parse_mn_types("2xddr_mn", 4)          # wrong pool size
    with pytest.raises(ValueError):
        parse_mn_types("cn_1g", 1)             # not a memory node


def test_cluster_hetero_bitwise_and_gather_savings(model_and_params):
    """Acceptance: a mixed DDR+NMP cluster scores bitwise-identically to
    the all-DDR baseline while NMP-sourced shards move strictly fewer
    gather bytes at strictly lower modeled G_S time."""
    model, params = model_and_params
    reqs = make_requests(20)
    cc = dict(n_cn=2, m_mn=4, batch_size=16, n_replicas=2)
    eng_d = ClusterEngine(model, params, ClusterConfig(**cc))
    res_d, st_d = eng_d.serve(reqs)
    eng_m = ClusterEngine(model, params, ClusterConfig(mn_types=MIX, **cc))
    res_m, st_m = eng_m.serve(reqs)

    want = {r.rid: r.outputs for r in res_d}
    assert st_m.completed == len(reqs)
    for r in res_m:
        assert np.array_equal(r.outputs, want[r.rid])   # bitwise

    # NMP shards ship pooled Fsum vectors: strictly fewer fabric bytes
    # than the rows they scan; DDR shards ship exactly what they scan
    for j, t in enumerate(st_m.mn_types):
        if st_m.mn_access_bytes[j] == 0:
            continue
        if "nmp" in t:
            assert st_m.mn_gather_bytes[j] < st_m.mn_access_bytes[j]
        else:
            assert st_m.mn_gather_bytes[j] == st_m.mn_access_bytes[j]
    assert sum(st_m.mn_gather_bytes) < sum(st_d.mn_gather_bytes)

    # modeled per-MN G_S time: the NMP shards finish strictly faster
    # even though node-type-aware routing steers them MORE traffic
    ddr_stage = [eng_m.mn_stage_s[j] for j in range(4) if not eng_m.mn_nmp[j]]
    nmp_stage = [eng_m.mn_stage_s[j] for j in range(4) if eng_m.mn_nmp[j]]
    assert max(nmp_stage) < min(ddr_stage)
    nmp_mem = sum(st_m.mn_access_bytes[j] for j in range(4)
                  if eng_m.mn_nmp[j])
    ddr_mem = sum(st_m.mn_access_bytes[j] for j in range(4)
                  if not eng_m.mn_nmp[j])
    assert nmp_mem > ddr_mem

    # all-NMP pool: strictly lower batch-gating MN stage than all-DDR
    eng_n = ClusterEngine(model, params, ClusterConfig(
        mn_type="nmp_mn", **cc))
    res_n, st_n = eng_n.serve(reqs)
    for r in res_n:
        assert np.array_equal(r.outputs, want[r.rid])
    assert (eng_n._mn_stage_max_sum / eng_n._n_batches
            < eng_d._mn_stage_max_sum / eng_d._n_batches)


def test_cluster_hetero_replicas_span_classes(model_and_params):
    """With replication >= 2 in a mixed pool, every table keeps one copy
    in each node class (type-diverse replication)."""
    model, params = model_and_params
    eng = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, n_replicas=2, mn_types=MIX))
    for tid, reps in eng.alloc.replicas.items():
        classes = {("nmp" if eng.mn_nmp[j] else "ddr") for j in reps}
        assert classes == {"ddr", "nmp"}


def test_cluster_hetero_survives_mn_failure(model_and_params):
    """Killing a DDR MN in a mixed pool mid-stream re-routes its tables
    onto their NMP replicas with bitwise-identical outputs."""
    model, params = model_and_params
    reqs = make_requests(16)
    cc = ClusterConfig(n_cn=2, m_mn=4, batch_size=16, n_replicas=2,
                       mn_types=MIX)
    clean = ClusterEngine(model, params, cc)
    res_c, _ = clean.serve(reqs)
    eng = ClusterEngine(model, params, cc)
    res_f, stats = eng.serve(reqs, failures=[(0.03, 0)])
    assert stats.completed == len(reqs)
    assert stats.reroutes >= 1 and stats.reinits == 0
    want = {r.rid: r.outputs for r in res_c}
    for r in res_f:
        assert np.array_equal(r.outputs, want[r.rid])
    for (task, tid), dest in eng.routing.routes.items():
        assert dest != 0


def test_cluster_nmp_latency_model_regression(model_and_params):
    """Satellite: the executable all-NMP cluster's virtual-clock latency
    agrees with the analytic `nmp_mn` ServingUnitModel prediction.

    Full batches (query size == batch size) isolate the model from
    partial-batch scaling; stated tolerance: engine/analytic within
    [0.5, 2.0] end-to-end and the measured G_S+gather stage within
    [0.3, 2.0] of the analytic sparse+comm-out stages."""
    from repro.core.serving_unit import ServingUnitModel, UnitSpec
    model, params = model_and_params
    rng = np.random.RandomState(3)
    reqs = []
    for i in range(12):
        b = dlrm_batch(CFG, 16, rng)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]}, 16, 0.005 * i))
    eng = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=16, n_replicas=2, mn_type="nmp_mn"))
    eng.serve(reqs)
    assert all(eng.mn_nmp)
    # the engine's analytic reference IS the nmp_mn unit spec
    assert eng.unit_model.unit.mn_type == "nmp_mn"
    want = ServingUnitModel(model.cfg, UnitSpec(
        2, "cn_1g", 4, "nmp_mn")).stage_times(16).total()
    v = eng.validate_latency_model()
    assert v["analytic_s"] == pytest.approx(want)
    assert 0.5 < v["ratio"] < 2.0
    assert 0.3 < v["mn_stage_ratio"] < 2.0


def test_serve_deterministic_across_runs(model_and_params):
    """Seed standardization (issue #4 satellite): building the stream
    from `dlrm_request_stream(seed)` and the engine from
    `ClusterConfig.seed` twice must reproduce the *entire* ClusterStats
    byte-for-byte — scores, latencies, and every counter."""
    import dataclasses
    from repro.data.queries import QueryDist, dlrm_request_stream
    model, params = model_and_params

    def one_run():
        qd = QueryDist(mean_size=5.0, max_size=24, alpha=1.05)
        reqs = [Request(*t) for t in
                dlrm_request_stream(CFG, 14, seed=42, dist=qd,
                                    gap_s=0.005)]
        eng = ClusterEngine(model, params, ClusterConfig(
            n_cn=2, m_mn=4, batch_size=16, n_replicas=2, seed=42,
            cache_mb=0.01))
        res, st = eng.serve(reqs, failures=[(0.03, 1)])
        return res, st

    res_a, st_a = one_run()
    res_b, st_b = one_run()
    assert dataclasses.asdict(st_a) == dataclasses.asdict(st_b)
    for a, b in zip(res_a, res_b):
        assert a.rid == b.rid and a.latency == b.latency
        assert np.array_equal(a.outputs, b.outputs)


def test_batcher_parts_conservation():
    """Batch.parts records exactly each query's row contribution."""
    b = Batcher(batch_size=16)
    out = []
    sizes = [5, 40, 3, 3, 64, 1]
    for i, size in enumerate(sizes):
        out += b.offer(Query(i, float(i), size), float(i))
    out += [bt for bt in [b._form(99.0)] if bt.size]
    got = {}
    for bt in out:
        assert sum(n for _, n in bt.parts) == bt.size
        for q, n in bt.parts:
            got[q.qid] = got.get(q.qid, 0) + n
    assert got == {i: s for i, s in enumerate(sizes)}
