"""ClusterEngine: multi-unit routed serving + MN failure survival.

Ground truth for outputs is the model's own serve_step on each query's
full payload — the cluster's scatter/fused-pool/gather path must score
every query identically regardless of batching, routing, or failures.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs import rm1
from repro.core.scheduler import Batcher, Query
from repro.data.queries import QueryDist, dlrm_batch
from repro.models.dlrm import DLRMModel
from repro.serving.cluster import ClusterConfig, ClusterEngine
from repro.serving.engine import Request

CFG = rm1.CONFIG.replace(
    name="rm1-test",
    dlrm=rm1.DLRMConfig(num_tables=6, rows_per_table=64, embed_dim=8,
                        avg_pooling=5, num_dense_features=8,
                        bottom_mlp=(16, 8), top_mlp=(32, 16, 1)),
)


@pytest.fixture(scope="module")
def model_and_params():
    model = DLRMModel(CFG)
    return model, model.init(0)


def make_requests(n, seed=0, mean_size=5.0, max_size=24):
    rng = np.random.RandomState(seed)
    sizes = QueryDist(mean_size=mean_size, max_size=max_size).sample(rng, n)
    reqs = []
    for i, s in enumerate(sizes):
        b = dlrm_batch(CFG, int(s), rng)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]},
                            int(s), 0.005 * i))
    return reqs


def direct_scores(model, params, reqs):
    out = {}
    for r in reqs:
        batch = {"dense": jnp.asarray(r.payload["dense"]),
                 "indices": jnp.asarray(r.payload["indices"])}
        out[r.rid] = np.asarray(model.serve_step(params, batch))
    return out


def test_cluster_end_to_end(model_and_params):
    model, params = model_and_params
    reqs = make_requests(20)
    eng = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=16, n_replicas=2))
    results, stats = eng.serve(reqs)
    assert stats.completed == len(reqs)
    assert sorted(r.rid for r in results) == list(range(len(reqs)))
    want = direct_scores(model, params, reqs)
    for r in results:
        assert r.outputs.shape == (reqs[r.rid].size,)
        np.testing.assert_allclose(r.outputs, want[r.rid],
                                   atol=1e-5, rtol=1e-5)
    # every query saw a positive modeled latency
    assert all(r.latency > 0 for r in results)
    # greedy routing kept the MN pool roughly balanced
    assert stats.imbalance < 2.0


def test_cluster_replication_places_tables(model_and_params):
    model, params = model_and_params
    eng = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, n_replicas=2))
    for tid, reps in eng.alloc.replicas.items():
        assert len(reps) == 2
    # union of shards covers all tables
    covered = sorted({t for tids in eng._shard_tids for t in tids})
    assert covered == list(range(CFG.dlrm.num_tables))


def test_cluster_survives_mn_failure_mid_stream(model_and_params):
    """Kill one MN while queries are in flight: all queries must still
    complete, with outputs identical to the failure-free run, and no
    traffic may reach the dead MN afterwards."""
    model, params = model_and_params
    reqs = make_requests(20)
    cc = ClusterConfig(n_cn=2, m_mn=4, batch_size=16, n_replicas=2)

    clean = ClusterEngine(model, params, cc)
    res_clean, _ = clean.serve(reqs)
    want = {r.rid: r.outputs for r in res_clean}

    eng = ClusterEngine(model, params, cc)
    t_fail = 0.03                      # mid-stream: arrivals span 0..0.1
    res, stats = eng.serve(reqs, failures=[(t_fail, 1)])
    assert stats.failures == 1
    assert stats.reroutes >= 1 and stats.reinits == 0
    assert stats.completed == len(reqs)          # no dropped queries
    for r in res:
        np.testing.assert_allclose(r.outputs, want[r.rid],
                                   atol=1e-5, rtol=1e-5)
    assert 1 in eng.dead
    # post-failure routing never targets the dead MN
    for (task, tid), dest in eng.routing.routes.items():
        assert dest != 1


def test_cluster_reinit_when_last_replica_lost(model_and_params):
    """n_replicas=1: an MN failure loses tables entirely -> the engine
    re-initializes shards from params and keeps serving correctly."""
    model, params = model_and_params
    reqs = make_requests(12)
    eng = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=3, batch_size=16, n_replicas=1))
    lost_tables = list(eng._shard_tids[0])
    assert lost_tables                 # MN 0 held something
    res, stats = eng.serve(reqs, failures=[(0.02, 0)])
    assert stats.completed == len(reqs)
    assert stats.reinits == 1
    want = direct_scores(model, params, reqs)
    for r in res:
        np.testing.assert_allclose(r.outputs, want[r.rid],
                                   atol=1e-5, rtol=1e-5)


def test_cluster_kernel_matches_ref_path(model_and_params):
    model, params = model_and_params
    reqs = make_requests(8)
    cc = dict(n_cn=2, m_mn=4, batch_size=16, n_replicas=2)
    r_k, _ = ClusterEngine(model, params,
                           ClusterConfig(use_kernel=True, **cc)).serve(reqs)
    r_r, _ = ClusterEngine(model, params,
                           ClusterConfig(use_kernel=False, **cc)).serve(reqs)
    for a, b in zip(r_k, r_r):
        np.testing.assert_allclose(a.outputs, b.outputs,
                                   atol=1e-6, rtol=1e-6)


def test_cluster_latency_model_cross_validates(model_and_params):
    """The engine's virtual clock is built from the analytic stage model
    with measured G_S bytes — unloaded they must agree closely."""
    model, params = model_and_params
    eng = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=16, n_replicas=2))
    eng.serve(make_requests(16))
    v = eng.validate_latency_model()
    assert 0.3 < v["ratio"] < 3.0


def test_batcher_parts_conservation():
    """Batch.parts records exactly each query's row contribution."""
    b = Batcher(batch_size=16)
    out = []
    sizes = [5, 40, 3, 3, 64, 1]
    for i, size in enumerate(sizes):
        out += b.offer(Query(i, float(i), size), float(i))
    out += [bt for bt in [b._form(99.0)] if bt.size]
    got = {}
    for bt in out:
        assert sum(n for _, n in bt.parts) == bt.size
        for q, n in bt.parts:
            got[q.qid] = got.get(q.qid, 0) + n
    assert got == {i: s for i, s in enumerate(sizes)}
