"""CN-side hot-row cache: policy units + engine coherence (issue #4).

Three layers:

1. ``RowCache`` units: admission, LRU/LFU eviction order, byte budget,
   hot-table priority, value fidelity, invalidation/flush counters.
2. Bitwise parity: on a pinned grid of {policy, budget, skew, pool mix}
   a cached engine must score bitwise-identically to the uncached
   baseline while the byte accounting identity
   ``bytes_saved == uncached_gather - cached_gather`` holds exactly.
3. Coherence regressions: ``fail_mn`` / ``recover_mn`` / ``resize``
   invalidate exactly the tables whose authoritative serving copy
   (the routed MN) moved; ``reload_params`` flushes everything; the
   measured hotness counters steer placement and admission.
"""
import numpy as np
import pytest

from repro.configs import rm1
from repro.core import embedding_manager as em
from repro.data.queries import QueryDist, dlrm_request_stream
from repro.models.dlrm import DLRMModel
from repro.serving.cache import RowCache
from repro.serving.cluster import ClusterConfig, ClusterEngine
from repro.serving.engine import Request

CFG = rm1.CONFIG.replace(
    name="rm1-cache-test",
    dlrm=rm1.DLRMConfig(num_tables=6, rows_per_table=64, embed_dim=8,
                        avg_pooling=5, num_dense_features=8,
                        bottom_mlp=(16, 8), top_mlp=(32, 16, 1)),
)
T = CFG.dlrm.num_tables
ROW_B = CFG.dlrm.embed_dim * 4


@pytest.fixture(scope="module")
def model_and_params():
    model = DLRMModel(CFG)
    return model, model.init(0)


def make_requests(n, seed=0, alpha=0.0):
    qd = QueryDist(mean_size=5.0, max_size=24, alpha=alpha)
    return [Request(*t) for t in
            dlrm_request_stream(CFG, n, seed=seed, dist=qd, gap_s=0.005)]


def make_engine(model, params, cache_mb=0.001, policy="lru", **kw):
    kw.setdefault("n_cn", 2)
    kw.setdefault("m_mn", 4)
    kw.setdefault("batch_size", 16)
    kw.setdefault("n_replicas", 2)
    return ClusterEngine(model, params, ClusterConfig(
        cache_mb=cache_mb, cache_policy=policy, **kw))


# ------------------------------------------------------------- RowCache units
def test_cache_admission_and_byte_budget():
    c = RowCache(capacity_bytes=4 * 32, row_bytes=32)
    for row in range(6):
        assert not c.lookup(0, row)          # cold miss, admitted
    assert len(c) == 4                       # budget: 4 rows resident
    assert c.size_bytes <= c.capacity_bytes
    assert c.stats.misses == 6 and c.stats.evictions == 2


def test_cache_lru_eviction_order():
    c = RowCache(capacity_bytes=3 * 32, row_bytes=32, policy="lru")
    for row in (0, 1, 2):
        c.admit(0, row)
    assert c.probe(0, 0)                     # 0 becomes most-recent
    c.admit(0, 3)                            # evicts 1 (least recent)
    assert (0, 1) not in c
    assert all((0, r) in c for r in (0, 2, 3))
    c.admit(0, 4)                            # evicts 2
    assert (0, 2) not in c and (0, 0) in c


def test_cache_lfu_eviction_order():
    c = RowCache(capacity_bytes=3 * 32, row_bytes=32, policy="lfu")
    for row in (0, 1, 2):
        c.admit(0, row)
    for _ in range(3):
        assert c.probe(0, 0)
    assert c.probe(0, 2)
    c.admit(0, 3)                            # evicts 1: lowest frequency
    assert (0, 1) not in c
    c.admit(0, 4)                            # ties (freq 1): 3 older than 4
    assert (0, 3) not in c and (0, 0) in c and (0, 2) in c


def test_cache_lfu_heap_bounded_on_hit_dominated_stream():
    """A hit-dominated LFU stream (few evictions) must not grow the lazy
    heap per probe: stale tuples compact once they outnumber residents."""
    c = RowCache(capacity_bytes=8 * 32, row_bytes=32, policy="lfu")
    for row in range(8):
        c.admit(0, row)
    for _ in range(500):
        for row in range(8):
            assert c.probe(0, row)
    assert len(c._heap) <= 4 * len(c) + 64
    c.admit(0, 99)                           # eviction still works after
    assert (0, 99) in c and len(c) == 8


def test_cache_zero_capacity_rejects():
    c = RowCache(capacity_bytes=16, row_bytes=32)
    assert not c.admit(0, 1)
    assert len(c) == 0 and c.stats.rejects == 1


def test_cache_hot_table_priority():
    """A cold-table row must never displace the hot working set, and a
    hot row evicts cold residents first."""
    c = RowCache(capacity_bytes=3 * 32, row_bytes=32, policy="lru")
    c.set_hot_tables({1})
    for row in (0, 1, 2):
        c.admit(1, row)                      # hot rows fill the budget
    assert not c.admit(0, 7)                 # cold incoming: rejected
    assert c.stats.rejects == 1 and len(c) == 3
    c.invalidate_table(1)
    c.admit(0, 7)                            # cold admits into free space
    c.admit(1, 0)
    c.admit(1, 1)
    c.admit(1, 2)                            # full again: evicts cold (0,7)
    assert (0, 7) not in c
    assert all((1, r) in c for r in (0, 1, 2))


def test_cache_value_fidelity_and_invalidation():
    c = RowCache(capacity_bytes=8 * 32, row_bytes=32)
    v0 = np.arange(8.0)
    c.admit(2, 5, v0)
    c.admit(3, 5, v0 * 2)
    np.testing.assert_array_equal(c.get(2, 5), v0)
    assert c.table_rows(2) == 1
    assert c.invalidate_table(2) == 1        # only table 2's rows drop
    assert (2, 5) not in c and (3, 5) in c
    assert c.stats.invalidations == 1
    assert c.invalidate_table(2) == 0        # idempotent
    assert c.flush() == 1                    # weight reload drops the rest
    assert len(c) == 0 and c.stats.invalidations == 2


def test_cache_rejects_unknown_policy(model_and_params):
    with pytest.raises(ValueError):
        RowCache(1024, 32, policy="fifo")
    with pytest.raises(ValueError):
        make_engine(*model_and_params, policy="mru")


# --------------------------------------------------- bitwise parity + bytes
@pytest.mark.parametrize("policy,cache_mb,alpha,mn_types", [
    ("lru", 1.0, 0.0, None),
    ("lru", 1.0, 1.05, None),
    ("lfu", 1.0, 1.05, None),
    ("lru", 0.002, 1.05, None),              # tight budget: evictions fire
    ("lru", 1.0, 1.05, ["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"]),
])
def test_cached_scores_bitwise_equal_uncached(model_and_params, policy,
                                              cache_mb, alpha, mn_types):
    model, params = model_and_params
    reqs = make_requests(12, seed=3, alpha=alpha)
    kw = {} if mn_types is None else {"mn_types": mn_types}
    base = make_engine(model, params, cache_mb=0.0, **kw)
    res_b, st_b = base.serve(reqs)
    eng = make_engine(model, params, cache_mb=cache_mb, policy=policy, **kw)
    res_c, st_c = eng.serve(reqs)
    assert st_c.completed == len(reqs)
    want = {r.rid: r.outputs for r in res_b}
    for r in res_c:
        assert np.array_equal(r.outputs, want[r.rid])
    assert st_c.cache_hits > 0
    # exact byte accounting: every hit is a gather byte that never
    # crossed the fabric (and a scan byte that never hit the MN bus)
    assert st_c.cache_bytes_saved == \
        sum(st_b.mn_gather_bytes) - sum(st_c.mn_gather_bytes)
    assert st_c.cache_bytes_saved == st_c.cache_hits * ROW_B
    if cache_mb == 0.002:
        assert st_c.cache_evictions > 0


def test_skew_raises_hit_rate(model_and_params):
    """The cache is worth its budget only because the stream is skewed:
    Zipf alpha=1.05 must hit far more often than the uniform stream."""
    model, params = model_and_params
    rates = {}
    for alpha in (0.0, 1.05):
        eng = make_engine(model, params, cache_mb=0.002)
        _, st = eng.serve(make_requests(12, seed=3, alpha=alpha))
        rates[alpha] = st.cache_hits / (st.cache_hits + st.cache_misses)
    assert rates[1.05] > rates[0.0] + 0.15


# ------------------------------------------------------ coherence regressions
def _resident_by_table(cache):
    return {tid: cache.table_rows(tid) for tid in range(T)}


def _routes(eng, task):
    return {tid: eng.routing.routes[(task, tid)] for tid in range(T)}


def test_fail_mn_invalidates_exactly_moved_tables(model_and_params):
    model, params = model_and_params
    eng = make_engine(model, params, cache_mb=1.0)
    eng.serve(make_requests(10, seed=5, alpha=1.05))
    before = [_routes(eng, task) for task in range(eng.n_cn)]
    resident = [_resident_by_table(c) for c in eng.caches]
    assert any(sum(r.values()) for r in resident)
    eng.fail_mn(1)
    for task, cache in enumerate(eng.caches):
        after = _routes(eng, task)
        for tid in range(T):
            if before[task][tid] != after[tid]:      # authoritative copy moved
                assert cache.table_rows(tid) == 0
            else:                                    # untouched tables survive
                assert cache.table_rows(tid) == resident[task][tid]
    moved_rows = sum(resident[task][tid]
                     for task in range(eng.n_cn) for tid in range(T)
                     if before[task][tid] != _routes(eng, task)[tid])
    assert eng.cache_stats().invalidations == moved_rows > 0


def test_recover_mn_invalidates_moved_tables(model_and_params):
    model, params = model_and_params
    eng = make_engine(model, params, cache_mb=1.0)
    eng.serve(make_requests(8, seed=6, alpha=1.05))
    eng.fail_mn(2)
    inv_after_fail = eng.cache_stats().invalidations
    eng.serve(make_requests(8, seed=7, alpha=1.05))   # re-warm on survivors
    before = [_routes(eng, task) for task in range(eng.n_cn)]
    resident = [_resident_by_table(c) for c in eng.caches]
    eng.recover_mn(2)
    for task, cache in enumerate(eng.caches):
        after = _routes(eng, task)
        for tid in range(T):
            if before[task][tid] != after[tid]:
                assert cache.table_rows(tid) == 0
            else:
                assert cache.table_rows(tid) == resident[task][tid]
    assert eng.cache_stats().invalidations > inv_after_fail


def test_resize_invalidates_moved_tables_and_scores_survive(model_and_params):
    model, params = model_and_params
    reqs = make_requests(14, seed=8, alpha=1.05)
    base = make_engine(model, params, cache_mb=0.0)
    res_b, _ = base.serve(reqs)
    eng = make_engine(model, params, cache_mb=1.0)
    span = 0.005 * len(reqs)
    res_c, st = eng.serve(reqs, resizes=[(span * 0.3, 2, 6),
                                         (span * 0.7, 2, 3)])
    assert st.resizes == 2
    assert st.completed == len(reqs)
    want = {r.rid: r.outputs for r in res_b}
    for r in res_c:
        assert np.array_equal(r.outputs, want[r.rid])
    assert st.cache_invalidations > 0        # migration moved serving copies


def test_resize_cn_pool_cache_lifecycle(model_and_params):
    """A joining CN starts with a cold cache; a departing CN retires its
    counters into the aggregate rather than losing them."""
    model, params = model_and_params
    eng = make_engine(model, params, cache_mb=1.0, n_cn=3)
    eng.serve(make_requests(10, seed=9, alpha=1.05))
    hits_before = eng.cache_stats().hits
    assert hits_before > 0
    eng.resize(n_cn=1)
    assert len(eng.caches) == 1
    assert eng.cache_stats().hits == hits_before     # retired, not lost
    eng.resize(n_cn=2)
    assert len(eng.caches) == 2
    assert len(eng.caches[1]) == 0                   # joiner is cold


def test_reload_params_flushes_everything(model_and_params):
    model, params = model_and_params
    eng = make_engine(model, params, cache_mb=1.0)
    reqs = make_requests(8, seed=10, alpha=1.05)
    eng.serve(reqs)
    assert any(len(c) for c in eng.caches)
    fresh = model.init(1)
    eng.reload_params(fresh)
    assert all(len(c) == 0 for c in eng.caches)
    # and the engine now scores with the new weights, matching an
    # engine built directly on them
    res, _ = eng.serve(reqs)
    want_eng = make_engine(model, fresh, cache_mb=0.0)
    res_w, _ = want_eng.serve(reqs)
    want = {r.rid: r.outputs for r in res_w}
    for r in res:
        assert np.array_equal(r.outputs, want[r.rid])


# ------------------------------------------------------- measured hotness
def test_hotness_counters_track_valid_lookups(model_and_params):
    model, params = model_and_params
    eng = make_engine(model, params, cache_mb=0.0)
    reqs = make_requests(6, seed=11)
    valid = sum(int((r.payload["indices"] >= 0).sum()) for r in reqs)
    eng.serve(reqs)
    assert sum(eng.hotness.lookups) == valid
    assert eng.hotness.measured_access_bytes(eng.tables) is not None


def test_measured_hotness_overrides_assumed_placement():
    """allocate_heterogeneous with measured counters flips a table whose
    live traffic contradicts its assumed avg_pooling profile."""
    tables = [em.TableInfo(t, rows=64, dim=8, avg_pooling=4.0)
              for t in range(4)]
    caps = [4 * tables[0].size_bytes] * 4
    types = ["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"]
    # assumed: all densities equal -> nothing is "hot" (> median)
    assumed = em.allocate_heterogeneous(tables, caps, types, n_replicas=1)
    # measured: table 3 absorbs nearly all lookups -> hot -> DDR first copy
    hot = em.HotnessCounter(4)
    hot.update([0, 1, 2, 3], [1.0, 1.0, 1.0, 1000.0])
    measured = em.allocate_heterogeneous(
        tables, caps, types, n_replicas=1,
        access_bytes=hot.measured_access_bytes(tables))
    assert set(hot.hot_tables(tables)) == {3}
    assert all(j in (2, 3) for j in assumed.replicas[3])   # cold -> NMP
    assert all(j in (0, 1) for j in measured.replicas[3])  # hot -> DDR


def test_healthy_serve_installs_measured_hot_set(model_and_params):
    """Admission priority must engage on an event-free run: after enough
    batches the caches carry the measured hot-table classification, not
    the cold-start None."""
    model, params = model_and_params
    eng = make_engine(model, params, cache_mb=1.0)
    eng.serve(make_requests(20, seed=13, alpha=1.05))
    for cache in eng.caches:                 # periodic in-serve refresh
        assert cache._hot is not None
    want = eng.hotness.hot_tables(eng.tables)
    eng.serve([])                            # serve-entry refresh syncs up
    for cache in eng.caches:
        assert cache._hot == want


def test_replan_placement_skips_dead_mns(model_and_params):
    """Replanning while MNs are down must not park replicas on them —
    that would silently shrink the effective replication factor."""
    model, params = model_and_params
    eng = make_engine(model, params, cache_mb=1.0)
    reqs = make_requests(8, seed=14, alpha=1.05)
    eng.serve(reqs)
    eng.fail_mn(0)
    eng.fail_mn(3)
    eng.replan_placement()
    for tid, reps in eng.alloc.replicas.items():
        assert not set(reps) & eng.dead
        assert len(reps) == 2            # replication held on survivors
    res, st = eng.serve(reqs)
    assert st.completed == len(reqs)


def test_replan_placement_uses_measured_hotness(model_and_params):
    """After serving a skewed stream, replanning placement from measured
    hotness keeps serving bitwise-identically (placement moves bytes,
    never values) and re-syncs cache coherence."""
    model, params = model_and_params
    reqs = make_requests(10, seed=12, alpha=1.05)
    base = make_engine(model, params, cache_mb=0.0,
                       mn_types=["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"])
    res_b, _ = base.serve(reqs)
    eng = make_engine(model, params, cache_mb=1.0,
                      mn_types=["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"])
    eng.serve(reqs)
    eng.replan_placement()
    for tid, reps in eng.alloc.replicas.items():   # still class-spanning
        assert {("nmp" if eng.mn_nmp[j] else "ddr") for j in reps} == \
            {"ddr", "nmp"}
    res_c, st = eng.serve(reqs)
    want = {r.rid: r.outputs for r in res_b}
    for r in res_c:
        assert np.array_equal(r.outputs, want[r.rid])
