import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
