"""Elastic CN/MN autoscaling (issue #3): resize bitwise parity, the
incremental migration planner, the diurnal autoscaler policy, and the
ingress/accounting bugfix sweep that rode along.

The tentpole invariant is bitwise: scores before, during, and after any
resize — grow or shrink, CN-only / MN-only / both — must equal a
fixed-pool run on the same request stream.  Placement decides WHERE a
table pools, never the slot accumulation order.
"""
import math

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.configs import rm1
from repro.core import embedding_manager as em
from repro.core.scheduler import Batcher, Query
from repro.data.queries import QueryDist, dlrm_batch
from repro.models.dlrm import DLRMModel
from repro.serving.autoscaler import (Autoscaler, AutoscalerConfig,
                                      ResizeEvent, energy_joules,
                                      idle_node_hours, node_hours)
from repro.serving.cluster import ClusterConfig, ClusterEngine
from repro.serving.engine import Request

CFG = rm1.CONFIG.replace(
    name="rm1-elastic",
    dlrm=rm1.DLRMConfig(num_tables=5, rows_per_table=48, embed_dim=8,
                        avg_pooling=4, num_dense_features=8,
                        bottom_mlp=(16, 8), top_mlp=(32, 16, 1)),
)
MODEL = DLRMModel(CFG)
PARAMS = MODEL.init(0)


def _requests(n, seed=0):
    rng = np.random.RandomState(seed)
    sizes = QueryDist(mean_size=4.0, max_size=12).sample(rng, n)
    reqs = []
    for i, s in enumerate(sizes):
        b = dlrm_batch(CFG, int(s), rng)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]},
                            int(s), 0.004 * i))
    return reqs


def _engine(n_cn=2, m_mn=4, nrep=2, **kw):
    return ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=n_cn, m_mn=m_mn, batch_size=8, n_replicas=nrep, **kw))


@pytest.fixture(scope="module")
def baseline():
    reqs = _requests(12)
    eng = _engine()
    res, _ = eng.serve(reqs)
    return reqs, {r.rid: r.outputs for r in res}


# ------------------------------------------------- batcher deadline fix
def test_split_remainder_waits_full_window():
    """A split query's remainder is fresh work: its flush deadline must
    restart at the forming instant, not inherit the stale head-of-queue
    clock (which would already be in the past)."""
    b = Batcher(batch_size=16, max_wait_s=0.01)
    assert b.offer(Query(0, 0.0, 4), 0.0) == []
    out = b.offer(Query(1, 0.05, 20), 0.05)      # 4+20: one full batch
    assert len(out) == 1 and out[0].size == 16
    # remainder of 8 rows waits its own full window from t=0.05
    assert b.next_deadline() == pytest.approx(0.06)
    assert b.next_deadline() > 0.05              # NOT the stale 0.01
    assert b.flush(0.055) == []                  # not due yet
    flushed = b.flush(b.next_deadline())
    assert [bt.size for bt in flushed] == [8]


def test_batcher_empty_after_exact_fill_has_no_deadline():
    b = Batcher(batch_size=8, max_wait_s=0.01)
    out = b.offer(Query(0, 0.0, 8), 0.0)
    assert len(out) == 1 and b.next_deadline() is None


# --------------------------------------------- incremental alloc + plan
def _tables(n=6, rows=64, dim=8):
    return [em.TableInfo(t, rows, dim, 4.0) for t in range(n)]


def test_plan_migration_moves_only_changed_tables():
    tabs = _tables()
    old = em.Allocation(replicas={0: [0, 1], 1: [1, 2], 2: [0, 2]},
                        mn_used=[0] * 3, n_replicas=2)
    new = em.Allocation(replicas={0: [0, 1], 1: [1, 3], 2: [0, 2]},
                        mn_used=[0] * 4, n_replicas=2)
    plan = em.plan_migration(old, new, tabs)
    assert plan.moves == [(1, 1, 3)]             # src = surviving replica
    assert plan.dropped == [(1, 2)]
    assert plan.bytes_moved == tabs[1].size_bytes


def test_plan_migration_drains_departing_copy():
    tabs = _tables(1)
    old = em.Allocation(replicas={0: [2]}, mn_used=[0] * 3, n_replicas=1)
    new = em.Allocation(replicas={0: [0]}, mn_used=[0] * 3, n_replicas=1)
    plan = em.plan_migration(old, new, tabs)
    assert plan.moves == [(0, 2, 0)]             # drained, not re-streamed
    assert plan.bytes_moved == tabs[0].size_bytes


def test_allocate_incremental_identity_when_pool_unchanged():
    tabs = _tables()
    caps = [10 * t.size_bytes for t in tabs][:4]
    prev = em.allocate_greedy(tabs, caps, n_replicas=2)
    new = em.allocate_incremental(tabs, caps, ["ddr_mn"] * 4, prev=prev,
                                  n_replicas=2)
    assert new.replicas == prev.replicas
    assert em.plan_migration(prev, new, tabs).n_moves == 0


def test_allocate_incremental_grow_rebalances_onto_new_mn():
    """Routing only targets replica holders, so a grown pool must
    receive shard copies — and the spread stays balanced."""
    tabs = _tables()
    caps4 = [10 * t.size_bytes for t in tabs][:4]
    prev = em.allocate_greedy(tabs, caps4, n_replicas=2)
    caps6 = caps4 + caps4[:2]
    new = em.allocate_incremental(tabs, caps6, ["ddr_mn"] * 6, prev=prev,
                                  n_replicas=2)
    plan = em.plan_migration(prev, new, tabs)
    assert plan.n_moves > 0 and plan.bytes_moved > 0
    assert all(u > 0 for u in new.mn_used)       # joiners absorbed load
    assert max(new.mn_used) - min(new.mn_used) <= tabs[0].size_bytes


def test_allocate_incremental_shrink_drains_to_survivors():
    tabs = _tables()
    caps4 = [10 * t.size_bytes for t in tabs][:4]
    prev = em.allocate_greedy(tabs, caps4, n_replicas=2)
    new = em.allocate_incremental(tabs, caps4[:2], ["ddr_mn"] * 2,
                                  prev=prev, n_replicas=2)
    # every table keeps 2 distinct replicas inside the shrunk pool
    for tid, reps in new.replicas.items():
        assert len(set(reps)) == 2 and all(j < 2 for j in reps)
    plan = em.plan_migration(prev, new, tabs)
    stranded = sum(1 for t in tabs for j in prev.replicas[t.tid] if j >= 2)
    assert plan.n_moves == stranded


def test_allocate_incremental_respects_exclude():
    tabs = _tables()
    caps = [10 * t.size_bytes for t in tabs][:4]
    prev = em.allocate_greedy(tabs, caps, n_replicas=2)
    new = em.allocate_incremental(tabs, caps, ["ddr_mn"] * 4, prev=prev,
                                  n_replicas=2, exclude=[1])
    for reps in new.replicas.values():
        assert 1 not in reps


# -------------------------------------------------- resize bitwise parity
def _assert_bitwise(reqs, want, resizes, n_cn=2, m_mn=4, **kw):
    eng = _engine(n_cn, m_mn, **kw)
    res, stats = eng.serve(reqs, resizes=resizes)
    assert stats.completed == len(reqs)
    for r in res:
        assert np.array_equal(r.outputs, want[r.rid])
    return eng, stats


@pytest.mark.parametrize("resizes", [
    [(0.015, 3, 4)],                     # CN-only grow
    [(0.015, 1, 4)],                     # CN-only shrink
    [(0.015, 2, 6)],                     # MN-only grow
    [(0.015, 2, 2)],                     # MN-only shrink
    [(0.015, 4, 7)],                     # both grow
    [(0.015, 1, 2)],                     # both shrink
    [(0.01, 1, 2), (0.03, 3, 6)],        # shrink then grow past start
    [(0.0, 1, 2)],                       # resize before the first batch
])
def test_resize_bitwise_pinned(baseline, resizes):
    reqs, want = baseline
    eng, stats = _assert_bitwise(reqs, want, resizes)
    assert stats.resizes == len(resizes)
    assert (eng.n_cn, eng.m_mn) == resizes[-1][1:]
    # routing covers every task of the final CN pool, no departed MN
    for task in range(eng.n_cn):
        for tid in range(CFG.dlrm.num_tables):
            assert eng.routing.routes[(task, tid)] < eng.m_mn


@settings(max_examples=8, deadline=None)
@given(n_cn=st.integers(1, 4), m_mn=st.integers(1, 7),
       t_frac=st.floats(0.0, 1.0))
def test_resize_bitwise_random_configs(baseline, n_cn, m_mn, t_frac):
    reqs, want = baseline
    span = 0.004 * len(reqs)
    _assert_bitwise(reqs, want, [(t_frac * span, n_cn, m_mn)])


def test_resize_with_failure_bitwise(baseline):
    """A resize and an MN failure on the same stream: still bitwise."""
    reqs, want = baseline
    eng, stats = _assert_bitwise(reqs, want, [(0.02, 3, 5)])
    eng2 = _engine()
    res2, st2 = eng2.serve(reqs, failures=[(0.01, 1)],
                           resizes=[(0.02, 3, 5)])
    assert st2.completed == len(reqs)
    for r in res2:
        assert np.array_equal(r.outputs, want[r.rid])
    assert st2.failures == 1 and st2.resizes == 1


def test_cn_shrink_inside_pre_window_hands_off():
    """A CN shrink whose timestamp lands inside a batch's G_P/scatter
    window must hand the batch off to a surviving CN — not execute with
    a stale task index (routing KeyError).  Full-size queries at t=0
    form batches immediately, so a sub-microsecond grid of resize
    instants sweeps through the stage windows deterministically."""
    rng = np.random.RandomState(11)
    reqs = []
    for i in range(3):
        b = dlrm_batch(CFG, 8, rng)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]}, 8, 0.0))
    clean = _engine(3, 4)
    res_c, _ = clean.serve(reqs)
    want = {r.rid: r.outputs for r in res_c}
    for k in range(20):
        t = 1e-8 + k * 2.5e-8
        eng = _engine(3, 4)
        res, stats = eng.serve(reqs, resizes=[(t, 1, 4)])
        assert stats.completed == len(reqs), f"t={t}"
        for r in res:
            assert np.array_equal(r.outputs, want[r.rid])


def test_invalid_failure_event_rejected_upfront():
    """A failure id outside the pool at serve start is a caller error,
    not a silent no-op (a typo'd --fail-mn must not fake a clean run)."""
    eng = _engine()
    with pytest.raises(ValueError):
        eng.serve(_requests(4, seed=9), failures=[(0.01, 99)])
    with pytest.raises(ValueError):
        eng.serve(_requests(4, seed=9), failures=[(0.01, -1)])


def test_failure_event_for_departed_mn_is_dropped(baseline):
    """A timed failure aimed at an MN that already shrank out of the
    pool is a no-op — the machine isn't there to fail."""
    reqs, want = baseline
    eng = _engine()
    res, stats = eng.serve(reqs, failures=[(0.03, 3)],
                           resizes=[(0.01, 2, 2)])
    assert stats.completed == len(reqs)
    assert stats.failures == 0 and stats.resizes == 1
    for r in res:
        assert np.array_equal(r.outputs, want[r.rid])


def test_resize_migration_accounting(baseline):
    reqs, want = baseline
    # MN shrink must drain shards: bytes move and are counted, and the
    # departed MNs' accumulated traffic is retired, not vanished — the
    # grand total still accounts every scanned byte
    _, st_shrink = _assert_bitwise(reqs, want, [(0.015, 2, 2)])
    assert st_shrink.migration_bytes > 0
    assert st_shrink.retired_access_bytes > 0
    _, st_fixed = _assert_bitwise(reqs, want, [])
    assert (sum(st_shrink.mn_access_bytes) + st_shrink.retired_access_bytes
            == pytest.approx(sum(st_fixed.mn_access_bytes)))
    # CN-only resize holds no embedding state: nothing migrates
    _, st_cn = _assert_bitwise(reqs, want, [(0.015, 3, 4)])
    assert st_cn.migration_bytes == 0


def test_resize_mid_stream_latency_model_still_valid(baseline):
    reqs, _ = baseline
    eng, _ = _assert_bitwise(reqs, {r: o for r, o in baseline[1].items()},
                             [(0.02, 3, 6)])
    v = eng.validate_latency_model()
    assert 0.1 < v["ratio"] < 10.0


def test_resize_hetero_pool_preserves_class_span(baseline):
    reqs, want = baseline
    mix = ["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"]
    eng = _engine(mn_types=mix)
    plan = eng.resize(m_mn=6, mn_type="nmp_mn")
    assert plan.bytes_moved > 0
    assert eng.mn_types == mix + ["nmp_mn", "nmp_mn"]
    for tid, reps in eng.alloc.replicas.items():
        cls = {("nmp" if eng.mn_nmp[j] else "ddr") for j in reps}
        assert cls == {"ddr", "nmp"}
    res, _ = eng.serve(reqs)
    for r in res:
        assert np.array_equal(r.outputs, want[r.rid])


def test_resize_validation():
    eng = _engine()
    with pytest.raises(ValueError):
        eng.resize(n_cn=0)
    with pytest.raises(ValueError):
        eng.resize(m_mn=-1)
    plan = eng.resize()                          # no-op
    assert plan.n_moves == 0 and eng.resizes == 0


# ------------------------------------- recover_mn + empty-stream stats
def test_recover_mn_bounds_and_counter():
    eng = _engine()
    with pytest.raises(ValueError):
        eng.recover_mn(99)
    with pytest.raises(ValueError):
        eng.recover_mn(-1)
    eng.fail_mn(1)
    eng.recover_mn(1)
    assert eng.recoveries == 1 and not eng.dead
    eng.recover_mn(1)                            # idempotent
    assert eng.recoveries == 1
    for (task, tid), dest in eng.routing.routes.items():
        assert 0 <= dest < eng.m_mn
    reqs = _requests(6, seed=3)
    _, stats = eng.serve(reqs)
    assert stats.recoveries == 1


def test_empty_stream_reports_nan_latency():
    _, stats = _engine().serve([])
    assert math.isnan(stats.mean_latency)
    assert math.isnan(stats.p50) and math.isnan(stats.p95)
    assert stats.completed == 0


# ---------------------------------------------- mid-stage failure bytes
def test_failed_scan_bytes_are_charged():
    """A batch re-issued after a mid-stage MN failure pays for BOTH
    scans: the wasted first pass's bytes accumulate on top of the
    survivors' rerun instead of being overwritten.

    The MN stage of the virtual clock is microseconds wide at real
    bandwidths, so the test throttles the engines' per-MN scan
    bandwidth (G_S only — scatter/gather untouched) to stretch the
    window and land the failure deterministically mid-stage."""
    reqs = _requests(12, seed=5)
    clean = _engine()
    _, st_clean = clean.serve(reqs)              # bytes are bw-independent
    eng = _engine()
    eng.mn_bw = [1.0] * eng.m_mn                 # stretch the MN stage
    # kill an MN the first batch (task 0) actually scans, so the
    # in-flight re-issue path triggers
    victim = eng.routing.routes[(0, 0)]
    _, st_fail = eng.serve(reqs, failures=[(0.012, victim)])
    assert st_fail.failures == 1
    assert st_fail.reroutes == 1 and st_fail.reinits == 0
    # the aborted scan is strictly additive: total bus traffic exceeds
    # the clean run's by the wasted pass
    assert sum(st_fail.mn_access_bytes) > sum(st_clean.mn_access_bytes)
    assert sum(st_fail.mn_gather_bytes) > sum(st_clean.mn_gather_bytes)


# --------------------------------------------------------- autoscaler
def test_autoscaler_monotone_and_floored():
    a = Autoscaler(AutoscalerConfig(qps_per_cn=100.0, qps_per_mn=50.0,
                                    min_cn=1, min_mn=3))
    n0, m0 = a.units_for(0.0)
    assert (n0, m0) == (1, 3)                    # floors hold at idle
    prev = (0, 0)
    for load in (10.0, 100.0, 500.0, 5000.0):
        n, m = a.units_for(load)
        assert n >= prev[0] and m >= prev[1]
        prev = (n, m)


def test_autoscaler_plan_follows_diurnal_curve():
    a = Autoscaler(AutoscalerConfig(qps_per_cn=1.0, qps_per_mn=0.5,
                                    min_cn=1, min_mn=2,
                                    max_cn=8, max_mn=16))
    events = a.plan(peak_load=6.0, duration_s=60.0, steps=24)
    assert events and events[0].time_s == 0.0
    assert all(isinstance(e, ResizeEvent) for e in events)
    assert all(0 <= e.time_s < 60.0 for e in events)
    ns = [e.n_cn for e in events]
    ms = [e.m_mn for e in events]
    assert max(ns) > min(ns) and max(ms) > min(ms)   # the curve moves
    assert all(1 <= n <= 8 for n in ns)
    assert all(2 <= m <= 16 for m in ms)
    # consecutive events always change the pool (no no-op events)
    pairs = [(e.n_cn, e.m_mn) for e in events]
    assert all(a_ != b_ for a_, b_ in zip(pairs, pairs[1:]))


def test_autoscaler_for_model_capacity_floor():
    m = rm1.generation(0)
    a = Autoscaler.for_model(m, n_replicas=2)
    assert a.cfg.min_mn >= 1
    n_tr, m_tr = a.units_for(0.0)
    assert m_tr == a.cfg.min_mn                  # trough: floor only
    mono = Autoscaler.monolithic(m)
    assert mono.cfg.min_cn >= 1                  # must hold the model
    n, mm = mono.units_for(1e9)
    assert mm == 0                               # one pool only


def test_autoscaler_accounting_helpers():
    series = [(2, 4), (1, 2), (1, 2), (2, 4)]
    cn_h, mn_h = node_hours(series, duration_s=4 * 3600.0)
    assert (cn_h, mn_h) == (6.0, 12.0)
    idle_cn, idle_mn = idle_node_hours(series, duration_s=4 * 3600.0)
    assert (idle_cn, idle_mn) == (2.0, 4.0)
    e = energy_joules(series, "cn_1g", "ddr_mn", duration_s=4 * 3600.0)
    assert e > 0
    # elastic never exceeds fixed-peak energy
    e_fix = energy_joules([(2, 4)] * 4, "cn_1g", "ddr_mn",
                          duration_s=4 * 3600.0)
    assert e <= e_fix


def test_engine_consumes_autoscaler_plan(baseline):
    """End-to-end: the policy's ResizeEvents ARE serve()'s resize feed."""
    reqs, want = baseline
    span = 0.004 * len(reqs)
    toy = Autoscaler(AutoscalerConfig(qps_per_cn=0.5, qps_per_mn=0.25,
                                      min_cn=1, min_mn=2,
                                      max_cn=2, max_mn=4))
    events = toy.plan(peak_load=0.95, duration_s=span, steps=6)
    eng, stats = _assert_bitwise(reqs, want, events)
    assert stats.resizes >= 1
