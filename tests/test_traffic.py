"""Traffic realism & SLA feedback: arrival processes, the phase-boundary
drift fix, queueing-delay accounting, hedged re-issue, and the
SLAController loop.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs import rm1
from repro.data.queries import (ARRIVALS, BURST_EPISODE_MEAN,
                                ArrivalProcess, load_trace)
from repro.serving.autoscaler import SLAController, SLAControllerConfig
from repro.serving.scenario import (DegradeMN, ScenarioSpec, SetWorkload,
                                    Workload, nearest_rank, plan_workload,
                                    preset, run_scenario, smoke_topology,
                                    validate_events)

from tests._hypothesis_compat import given, settings, st

CFG = rm1.CONFIG.replace(
    name="rm1-traffic",
    dlrm=rm1.DLRMConfig(num_tables=5, rows_per_table=48, embed_dim=8,
                        avg_pooling=4, num_dense_features=8,
                        bottom_mlp=(16, 8), top_mlp=(32, 16, 1)),
)


def _proc(kind, gap_s=0.001, seed=0, **kw):
    if kind == "trace":
        kw.setdefault("trace", [0.0, 0.0005, 0.002, 0.0021])
    return ArrivalProcess(kind, gap_s, seed=seed, **kw)


# ------------------------------------------------- process unit behavior
def test_linear_reproduces_grid_exactly():
    p = _proc("linear", gap_s=0.004)
    assert [p.next() for _ in range(4)] == [
        0.0 + 0.004 * i for i in range(4)]


def test_poisson_pinned_golden():
    p = _proc("poisson", gap_s=0.001, seed=3)
    got = [p.next() for _ in range(4)]
    assert got == [7.570625938602191e-06, 0.0006666285307648349,
                   0.0006719952769095463, 0.0012514770597200418]


def test_bursty_pinned_golden():
    p = _proc("bursty", gap_s=0.001, seed=3, burstiness=4.0)
    got = [p.next() for _ in range(4)]
    assert got == [0.002636231619304931, 0.0026576986038837763,
                   0.0032461709175041296, 0.0035581901491522562]


def test_trace_replays_then_extends_linearly():
    p = _proc("trace", gap_s=0.001)
    assert [p.next() for _ in range(6)] == [
        0.0, 0.0005, 0.002, 0.0021, 0.0021 + 0.001, 0.0021 + 0.002]


def test_trace_realign_rewinds_discarded_candidate():
    # the planner's discard-and-regenerate protocol must not drop a
    # trace arrival: realign rewinds the cursor one step
    p = _proc("trace", gap_s=0.001)
    assert p.next() == 0.0
    assert p.next() == 0.0005       # candidate discarded by the caller
    p.realign(0.0004, 0.002)
    assert p.next() == 0.0005       # re-delivered, not dropped
    assert p.next() == 0.002


def test_arrival_process_validation():
    with pytest.raises(ValueError):
        ArrivalProcess("uniform", 0.001)
    with pytest.raises(ValueError):
        ArrivalProcess("trace", 0.001)          # no trace supplied
    with pytest.raises(ValueError):
        ArrivalProcess("bursty", 0.001, burstiness=0.5)


def test_load_trace_validation(tmp_path):
    path = tmp_path / "trace.json"
    path.write_text(json.dumps({"arrivals": [0.002, 0.0, 0.001]}))
    assert load_trace(str(path)) == [0.0, 0.001, 0.002]   # sorted
    path.write_text(json.dumps(["a", 1.0]))
    with pytest.raises(ValueError):
        load_trace(str(path))
    path.write_text(json.dumps([-1.0, 1.0]))
    with pytest.raises(ValueError):
        load_trace(str(path))


@pytest.mark.parametrize("kind", ARRIVALS)
def test_arrivals_non_decreasing_and_seed_deterministic(kind):
    a = _proc(kind, seed=11)
    b = _proc(kind, seed=11)
    xs = [a.next() for _ in range(64)]
    assert xs == [b.next() for _ in range(64)]
    assert all(x <= y for x, y in zip(xs, xs[1:]))
    if kind in ("poisson", "bursty"):
        c = _proc(kind, seed=12)
        assert xs != [c.next() for _ in range(64)]


@pytest.mark.parametrize("kind", ("linear", "poisson", "bursty"))
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 20),
       gaps=st.lists(st.floats(1e-6, 1e-2), min_size=2, max_size=4),
       t_step=st.floats(1e-5, 1e-2))
def test_realign_property(kind, seed, gaps, t_step):
    """After realign(t_start, gap), every arrival of the new phase is
    >= t_start, the stream stays non-decreasing, and the whole
    trajectory is seed-deterministic."""
    def gen():
        p = _proc(kind, gap_s=gaps[0], seed=seed)
        out = [p.next() for _ in range(8)]
        t = max(out)
        for g in gaps[1:]:
            t = t + t_step
            p.realign(t, g)
            phase = [p.next() for _ in range(8)]
            assert all(x >= t for x in phase)
            out.extend(phase)
        return out
    xs = gen()
    assert xs == gen()
    for lo, hi in zip(xs, xs[1:]):
        assert lo <= hi


# ------------------------------------------- the phase-boundary drift fix
def test_two_phase_realign_golden():
    """The historical bug: a SetWorkload off the arrival grid re-based
    the stream on the stale-gap extrapolation instead of the declared
    phase start.  Pinned: the first post-event arrival lands exactly ON
    the event time and the new gap applies from there."""
    spec = ScenarioSpec(
        name="t", topology=smoke_topology(batch_size=8),
        workload=Workload(requests=6, mean_size=4.0, max_size=12,
                          gap_s=0.004, seed=0),
        events=(SetWorkload(0.007, gap_s=0.001),))
    reqs, phases = plan_workload(spec, CFG)
    assert [r.arrival for r in reqs] == [
        0.0 + 0.004 * 0, 0.0 + 0.004 * 1,
        0.007 + 0.001 * 0, 0.007 + 0.001 * 1,
        0.007 + 0.001 * 2, 0.007 + 0.001 * 3]
    assert [(p.index, p.t_start, p.rid_start, p.rid_end)
            for p in phases] == [(0, 0.0, 0, 2), (1, 0.007, 2, 6)]


@pytest.mark.parametrize("kind", ARRIVALS)
def test_phase_arrivals_respect_phase_start(kind, tmp_path):
    extra = {}
    if kind == "trace":
        path = tmp_path / "trace.json"
        path.write_text(json.dumps(
            [i * 0.0008 for i in range(24)]))
        extra["trace_path"] = str(path)
    spec = ScenarioSpec(
        name="t", topology=smoke_topology(batch_size=8),
        workload=Workload(requests=24, mean_size=4.0, max_size=12,
                          gap_s=0.001, seed=9, arrival=kind, **extra),
        events=(SetWorkload(0.005, gap_s=0.0005),
                SetWorkload(0.011, gap_s=0.002)))
    reqs, phases = plan_workload(spec, CFG)
    arrivals = [r.arrival for r in reqs]
    assert arrivals == sorted(arrivals)
    assert sum(p.requests for p in phases) == 24
    for p in phases:
        chunk = arrivals[p.rid_start:p.rid_end]
        if kind != "trace":     # a trace is absolute: phases only
            assert all(t >= p.t_start for t in chunk)   # re-shape payloads


def test_linear_multiphase_arrivals_unchanged_single_phase():
    """arrival='linear' with no events is bitwise the historical
    stream: 0.0 + gap * i."""
    spec = ScenarioSpec(
        name="t", topology=smoke_topology(batch_size=8),
        workload=Workload(requests=8, mean_size=4.0, max_size=12,
                          gap_s=0.004, seed=0))
    reqs, _ = plan_workload(spec, CFG)
    assert [r.arrival for r in reqs] == [0.0 + 0.004 * i
                                         for i in range(8)]


def test_stochastic_arrivals_leave_payloads_untouched():
    """Switching the arrival process moves timestamps only — the
    size/payload RNG stream must not shift."""
    def payloads(kind):
        spec = ScenarioSpec(
            name="t", topology=smoke_topology(batch_size=8),
            workload=Workload(requests=8, mean_size=4.0, max_size=12,
                              gap_s=0.004, seed=3, arrival=kind))
        return plan_workload(spec, CFG)[0]
    lin, poi = payloads("linear"), payloads("poisson")
    for a, b in zip(lin, poi):
        assert a.size == b.size
        assert np.array_equal(a.payload["indices"], b.payload["indices"])
        assert np.array_equal(a.payload["dense"], b.payload["dense"])
        assert a.arrival != b.arrival or a.arrival == 0.0


# -------------------------------------------------- percentile convention
def test_nearest_rank_units():
    assert np.isnan(nearest_rank([], 99))
    assert nearest_rank([7.0], 50) == 7.0
    assert nearest_rank([4.0, 1.0, 3.0, 2.0], 50) == 2.0
    assert nearest_rank(list(range(1, 21)), 95) == 19
    assert nearest_rank(list(range(1, 33)), 99) == 32   # an actual sample


# --------------------------------------------------- queueing accounting
def test_unloaded_run_has_exactly_zero_queue_wait():
    """Batch-filling queries at generous gaps: every batch forms on
    arrival with an idle CPU, so arrival->admission delay is exactly
    0.0 (not merely small) — and validate_latency_model's unloaded
    queue-wait term is pinned to 0.0."""
    spec = ScenarioSpec(
        name="t", topology=smoke_topology(),
        workload=Workload(requests=6, mean_size=64.0, sigma=0.25,
                          max_size=32, gap_s=0.002, seed=5))
    rep = run_scenario(spec)
    assert rep.stats.queue_wait_mean == 0.0
    assert rep.stats.queue_wait_p99 == 0.0
    assert rep.latency_model["queue_wait_s"] == 0.0


def test_overload_charges_queue_wait_into_latency():
    spec = ScenarioSpec(
        name="t",
        topology=smoke_topology(inflight_depth=4, max_wait_s=2e-5),
        workload=Workload(requests=128, gap_s=1e-7, seed=5))
    st_ = run_scenario(spec).stats
    assert st_.queue_wait_p99 > 0.0
    assert st_.p99 >= st_.queue_wait_p99      # waits are inside latency


# ------------------------------------------------ DegradeMN + hedged scans
def test_degrade_mn_validation():
    with pytest.raises(ValueError):
        validate_events((DegradeMN(0.01, mn=0, factor=0.5),), 4)
    with pytest.raises(ValueError):
        validate_events((DegradeMN(0.01, mn=0, factor="x"),), 4)
    with pytest.raises(ValueError):
        validate_events((DegradeMN(0.01, mn=9, factor=2.0),), 4)
    validate_events((DegradeMN(0.01, mn=3, factor=1.0),), 4)


def test_degrade_without_hedging_slows_tail_only():
    base = ScenarioSpec(
        name="t", topology=smoke_topology(inflight_depth=4,
                                          max_wait_s=2e-5),
        workload=Workload(requests=128, gap_s=1e-6, seed=7))
    clean = run_scenario(base)
    deg = run_scenario(dataclasses.replace(
        base, events=(DegradeMN(5e-5, mn=1, factor=8.0),)))
    assert deg.stats.degrades == 1
    assert deg.stats.p99 > clean.stats.p99
    assert deg.bitwise_equal(clean)     # degradation moves time, not values


def test_hedging_cuts_p99_and_preserves_scores():
    base = ScenarioSpec(
        name="t", topology=smoke_topology(inflight_depth=4,
                                          max_wait_s=2e-5),
        workload=Workload(requests=128, gap_s=1e-6, seed=7),
        events=(DegradeMN(5e-5, mn=1, factor=8.0),))
    off = run_scenario(base)
    on = run_scenario(dataclasses.replace(
        base, topology=dataclasses.replace(base.topology,
                                           hedge_multiplier=2.0)))
    assert on.stats.hedges > 0
    assert on.stats.hedge_wins > 0
    assert on.stats.p99 < off.stats.p99
    assert on.bitwise_equal(off)
    # hedge traffic is real: the replica buses were charged for it
    assert sum(on.stats.mn_access_bytes) > sum(off.stats.mn_access_bytes)


def test_hedging_disabled_is_bitwise_noop():
    """hedge_multiplier=0.0 (the default) must leave an undegraded run
    bitwise-identical in every stat — parity by construction."""
    base = ScenarioSpec(
        name="t", topology=smoke_topology(inflight_depth=4),
        workload=Workload(requests=24, mean_size=4.0, max_size=12,
                          gap_s=0.001, seed=3))
    a, b = run_scenario(base), run_scenario(base)
    assert a.bitwise_equal(b)
    assert a.stats.p99 == b.stats.p99
    assert a.stats.hedges == 0 and a.stats.degrades == 0


# ------------------------------------------------------ SLA feedback loop
def test_sla_controller_unit_convergence():
    cfg = SLAControllerConfig(sla_p99_s=0.010, window=4, cooldown=2,
                              step=1, max_scale=3)
    c = SLAController(cfg, n_cn=1, m_mn=2)
    # breach: scale up once the window fills and cooldown passes
    acts = []
    for i in range(8):
        acts += c.observe(0.001 * i, 0.050)
    assert acts and acts[0].n_cn == 2 and acts[0].m_mn == 3
    # keep breaching: climbs to the ceiling and stops there
    for i in range(40):
        acts += c.observe(0.008 + 0.001 * i, 0.050)
    assert (c.n_cn, c.m_mn) == (3, 6)       # max_scale x initial
    # recover: drop below band_low x sla -> scales back to the floor
    for i in range(60):
        acts += c.observe(0.050 + 0.001 * i, 0.001)
    assert (c.n_cn, c.m_mn) == (1, 2)
    times = [a.time_s for a in acts]
    assert times == sorted(times)           # audit trail stays ordered
    assert all(a.time_s >= 0 for a in acts)


def test_sla_controller_no_double_step_on_stale_window():
    """The stale-window bugfix: emission clears the p99 window, so with
    cooldown < window a sustained breach steps once per *window* of
    fresh completions — never twice on the same stale measurements
    before the resize's effect shows."""
    cfg = SLAControllerConfig(sla_p99_s=0.010, window=4, cooldown=2,
                              step=1, max_scale=8)
    c = SLAController(cfg, n_cn=1, m_mn=1)
    acts = []
    emitted_at = []
    for i in range(16):
        got = c.observe(0.001 * i, 0.050)
        acts += got
        if got:
            emitted_at.append(i)
    # one step per full window of post-action completions: 16 breaches
    # at window=4 is exactly 4 actions (the buggy cadence was every
    # cooldown=2 completions — 7 actions and a badly overshot pool)
    assert len(acts) == 4, acts
    assert (c.n_cn, c.m_mn) == (5, 5)
    assert all(b - a >= cfg.window
               for a, b in zip(emitted_at, emitted_at[1:]))


def test_sla_controller_decoupled_binding_pool_attribution():
    """Decoupled mode scales the pool whose per-node queueing pressure
    dominates: CN-bound tails buy CNs, scan-bound tails buy MNs, and
    only a genuinely mixed tail (pressures within mix_band) buys both.
    Emitted events carry only the dims that change."""
    cfg = SLAControllerConfig(sla_p99_s=0.010, window=2, cooldown=0,
                              step=1, max_scale=4, mode="decoupled")
    c = SLAController(cfg, n_cn=2, m_mn=2)
    def breach_until_act(pressure):
        for i in range(8):
            got = c.observe(0.0, 0.050, pressure=pressure)
            if got:
                return got[0]
        raise AssertionError("no action fired")
    # compute-bound tail: CN-only partial resize
    act = breach_until_act((10.0, 1.0))
    assert (act.n_cn, act.m_mn) == (3, None)
    assert (c.n_cn, c.m_mn) == (3, 2)
    # scan/bus-bound tail: MN-only partial resize
    act = breach_until_act((1.0, 10.0))
    assert (act.n_cn, act.m_mn) == (None, 3)
    assert (c.n_cn, c.m_mn) == (3, 3)
    # genuinely mixed (within the mix_band factor): both pools step
    act = breach_until_act((5.0, 6.0))
    assert (act.n_cn, act.m_mn) == (4, 4)
    # recovery releases both pools toward their floors
    acts = []
    for i in range(20):
        acts += c.observe(0.0, 0.001, pressure=(10.0, 1.0))
    assert (c.n_cn, c.m_mn) == (2, 2)
    assert acts and all(a.n_cn is not None and a.m_mn is not None
                        for a in acts)


def test_sla_decoupled_scores_bitwise_with_coupled():
    """The controller mode moves capacity and time, never values:
    coupled and decoupled runs of the same crowd score identically."""
    spec = preset("flash_crowd")
    coupled = run_scenario(spec)
    dec = run_scenario(dataclasses.replace(spec, sla_mode="decoupled"))
    assert dec.bitwise_equal(coupled)
    assert dec.stats.sla_window_filled


def test_sla_window_filled_stat_and_warning():
    """A run shorter than the controller window must say so instead of
    silently doing nothing: sla_window_filled goes False and the report
    carries a warning line."""
    spec = ScenarioSpec(
        name="t", topology=smoke_topology(),
        workload=Workload(requests=8, mean_size=4.0, max_size=12,
                          gap_s=0.001, seed=3),
        sla_p99_s=1e-6)             # default window=32 > 8 completions
    rep = run_scenario(spec)
    assert rep.stats.sla_actions == 0
    assert rep.stats.sla_window_filled is False
    assert any("window never filled" in ln for ln in rep.summary())
    # no controller attached: vacuously filled, no warning
    plain = run_scenario(dataclasses.replace(spec, sla_p99_s=None))
    assert plain.stats.sla_window_filled is True
    assert not any("window never filled" in ln for ln in plain.summary())


def test_sla_controller_config_validation():
    with pytest.raises(ValueError):
        SLAControllerConfig(sla_p99_s=0.0) and SLAController(
            SLAControllerConfig(sla_p99_s=0.0), 1, 1)
    with pytest.raises(ValueError):
        SLAController(SLAControllerConfig(sla_p99_s=0.01, window=0), 1, 1)
    with pytest.raises(ValueError):
        SLAController(SLAControllerConfig(sla_p99_s=0.01, band_low=1.0),
                      1, 1)
    with pytest.raises(ValueError):
        SLAController(SLAControllerConfig(sla_p99_s=0.01, max_scale=0),
                      1, 1)


def test_flash_crowd_preset_controller_full_arc():
    """The flash_crowd preset end-to-end: the controller scales the
    pool up against the crowd and returns it to the floor once traffic
    recedes."""
    spec = preset("flash_crowd")
    rep = run_scenario(spec)
    st_ = rep.stats
    assert st_.sla_actions > 0
    assert st_.resizes == st_.sla_actions   # every resize was feedback
    peak_cn = max(r.n_cn for r in st_.events)
    assert peak_cn > spec.topology.n_cn     # it scaled up...
    assert (rep.final_n_cn, rep.final_m_mn) == (
        spec.topology.n_cn, spec.topology.m_mn)     # ...and back down
    assert rep.completed == spec.workload.requests


def test_sla_p99_s_serialization_roundtrip():
    spec = preset("flash_crowd")
    again = ScenarioSpec.from_json(spec.to_json())
    assert again == spec
    assert again.sla_p99_s == spec.sla_p99_s
    # absent when unset: old scenario files stay loadable byte-for-byte
    plain = preset("failover_storm")
    assert plain.sla_p99_s is None
    assert "sla_p99_s" not in plain.to_dict()
