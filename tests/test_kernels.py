"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("T,R,D,B,P", [
    (1, 64, 8, 4, 4), (4, 100, 16, 8, 10), (3, 257, 32, 5, 7),
    (2, 128, 128, 16, 20),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(T, R, D, B, P, dtype):
    rng = np.random.RandomState(0)
    tables = jnp.asarray(rng.randn(T, R, D), dtype)
    idx = rng.randint(0, R, (B, T, P)).astype(np.int32)
    idx[rng.rand(B, T, P) < 0.25] = -1
    idx = jnp.asarray(idx)
    out_k = np.asarray(ops.embedding_bag(tables, idx), np.float32)
    out_r = np.asarray(ref.embedding_bag_ref(tables, idx), np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(out_k, out_r, atol=tol, rtol=tol)


def test_embedding_bag_all_padded():
    tables = jnp.ones((2, 10, 8), jnp.float32)
    idx = -jnp.ones((3, 2, 5), jnp.int32)
    out = ops.embedding_bag(tables, idx)
    assert float(jnp.abs(out).max()) == 0.0


# ------------------------------------------------- fused multi-table bag
def _mixed_pooling_idx(rng, R, B, T, P):
    """Per-bag pooling factors from 0..P: -1 padding tails of mixed
    length, including some fully-padded bags."""
    idx = rng.randint(0, R, (B, T, P)).astype(np.int32)
    lens = rng.randint(0, P + 1, (B, T))
    mask = np.arange(P)[None, None, :] < lens[..., None]
    return np.where(mask, idx, -1).astype(np.int32)


@pytest.mark.parametrize("T,R,D,B,P", [
    (1, 64, 8, 4, 4), (4, 100, 16, 8, 10), (3, 257, 32, 5, 7),
    (2, 128, 128, 16, 20),
])
def test_embedding_bag_fused_bitwise_fp32(T, R, D, B, P):
    """One pallas_call over all tables == slot-order reference, bitwise."""
    rng = np.random.RandomState(0)
    tables = jnp.asarray(rng.randn(T, R, D), jnp.float32)
    idx = jnp.asarray(_mixed_pooling_idx(rng, R, B, T, P))
    out_f = np.asarray(ops.embedding_bag_fused(tables, idx))
    out_s = np.asarray(ref.embedding_bag_seq_ref(tables, idx))
    out_v = np.asarray(ops.embedding_bag(tables, idx))
    assert np.array_equal(out_f, out_s)          # bitwise vs order-exact ref
    assert np.array_equal(out_f, out_v)          # bitwise vs vmapped kernel
    np.testing.assert_allclose(out_f, np.asarray(
        ref.embedding_bag_ref(tables, idx)), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_fused_dtypes(dtype):
    rng = np.random.RandomState(1)
    tables = jnp.asarray(rng.randn(4, 64, 16), dtype)
    idx = jnp.asarray(_mixed_pooling_idx(rng, 64, 6, 4, 8))
    out_f = np.asarray(ops.embedding_bag_fused(tables, idx), np.float32)
    out_r = np.asarray(ref.embedding_bag_ref(tables, idx), np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(out_f, out_r, atol=tol, rtol=tol)


def test_embedding_bag_fused_all_padded():
    tables = jnp.ones((3, 10, 8), jnp.float32)
    idx = -jnp.ones((4, 3, 5), jnp.int32)
    out = ops.embedding_bag_fused(tables, idx)
    assert float(jnp.abs(out).max()) == 0.0


def test_embedding_bag_fused_flat_shard_offsets():
    """The MN-shard entry point: a flat shard buffer addressed through
    scalar-prefetched per-table offsets, in non-contiguous slot order."""
    rng = np.random.RandomState(2)
    T, R, D, B, P = 5, 40, 16, 6, 6
    tables = jnp.asarray(rng.randn(T, R, D), jnp.float32)
    flat = tables.reshape(T * R, D)
    idx = _mixed_pooling_idx(rng, R, B, T, P)
    # route a shuffled subset of tables, as a shard assignment would
    slots = np.array([3, 0, 4], np.int32)
    offsets = jnp.asarray(slots * R)
    out = np.asarray(ops.embedding_bag_fused_flat(
        flat, offsets, jnp.asarray(idx[:, slots, :])))
    want = np.asarray(ref.embedding_bag_seq_ref(
        tables[jnp.asarray(slots)], jnp.asarray(idx[:, slots, :])))
    assert np.array_equal(out, want)


# ------------------------------------------------- near-memory (NMP) bag
@pytest.mark.parametrize("T,R,D,B,P", [
    (1, 64, 8, 4, 4), (4, 100, 16, 8, 10), (3, 257, 32, 5, 7),
    (2, 128, 128, 16, 20),
    (3, 96, 13, 6, 5),        # D not a multiple of the lane width
    (2, 50, 8, 5, 1),         # single-slot bags
])
def test_embedding_bag_nmp_bitwise_fp32(T, R, D, B, P):
    """The on-MN pooling kernel (in-kernel bag reduction) must be
    bitwise-equal to the slot-order reference AND to the fused CN-side
    bag — ragged bags, empty bags, any D — so a heterogeneous cluster
    scores identically whichever node type pools a shard."""
    rng = np.random.RandomState(0)
    tables = jnp.asarray(rng.randn(T, R, D), jnp.float32)
    idx = jnp.asarray(_mixed_pooling_idx(rng, R, B, T, P))
    out_n = np.asarray(ops.embedding_bag_nmp(tables, idx))
    assert np.array_equal(out_n, np.asarray(ref.embedding_bag_seq_ref(
        tables, idx)))
    assert np.array_equal(out_n, np.asarray(ops.embedding_bag_fused(
        tables, idx)))
    np.testing.assert_allclose(out_n, np.asarray(
        ref.embedding_bag_ref(tables, idx)), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_nmp_dtypes(dtype):
    rng = np.random.RandomState(1)
    tables = jnp.asarray(rng.randn(4, 64, 16), dtype)
    idx = jnp.asarray(_mixed_pooling_idx(rng, 64, 6, 4, 8))
    out_n = np.asarray(ops.embedding_bag_nmp(tables, idx), np.float32)
    out_r = np.asarray(ref.embedding_bag_ref(tables, idx), np.float32)
    tol = 1e-5 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(out_n, out_r, atol=tol, rtol=tol)


def test_embedding_bag_nmp_all_padded():
    tables = jnp.ones((3, 10, 8), jnp.float32)
    idx = -jnp.ones((4, 3, 5), jnp.int32)
    out = ops.embedding_bag_nmp(tables, idx)
    assert out.shape == (4, 3, 8)
    assert float(jnp.abs(out).max()) == 0.0


def test_embedding_bag_nmp_flat_shard_offsets():
    """The NMP shard entry point matches the fused CN-side shard entry
    point bitwise on the same shuffled table subset."""
    rng = np.random.RandomState(2)
    T, R, D, B, P = 5, 40, 16, 6, 6
    tables = jnp.asarray(rng.randn(T, R, D), jnp.float32)
    flat = tables.reshape(T * R, D)
    idx = _mixed_pooling_idx(rng, R, B, T, P)
    slots = np.array([3, 0, 4], np.int32)
    offsets = jnp.asarray(slots * R)
    sub = jnp.asarray(idx[:, slots, :])
    out_n = np.asarray(ops.embedding_bag_nmp_flat(flat, offsets, sub))
    out_f = np.asarray(ops.embedding_bag_fused_flat(flat, offsets, sub))
    want = np.asarray(ref.embedding_bag_seq_ref(
        tables[jnp.asarray(slots)], sub))
    assert np.array_equal(out_n, out_f)
    assert np.array_equal(out_n, want)


@pytest.mark.parametrize("B,H,Hkv,S,D,qb,kb", [
    (1, 4, 4, 128, 32, 64, 64),
    (2, 8, 2, 256, 32, 64, 128),
    (2, 4, 1, 128, 64, 128, 32),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, S, D, qb, kb, causal, dtype):
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(B, H, S, D), dtype)
    k = jnp.asarray(rng.randn(B, Hkv, S, D), dtype)
    v = jnp.asarray(rng.randn(B, Hkv, S, D), dtype)
    o_k = np.asarray(ops.flash_attention(q, k, v, causal=causal,
                                         q_block=qb, kv_block=kb), np.float32)
    o_r = np.asarray(ref.flash_attention_ref(q, k, v, causal=causal),
                     np.float32)
    tol = 2e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(o_k, o_r, atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,Hkv,T,D,kb", [
    (2, 8, 2, 128, 32, 32), (1, 4, 4, 256, 64, 64), (3, 6, 2, 96, 16, 32),
])
@pytest.mark.parametrize("pos_frac", [0.1, 0.5, 1.0])
def test_flash_decode_sweep(B, H, Hkv, T, D, kb, pos_frac):
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randn(B, H, D), jnp.float32)
    kc = jnp.asarray(rng.randn(B, T, Hkv, D), jnp.float32)
    vc = jnp.asarray(rng.randn(B, T, Hkv, D), jnp.float32)
    pos = jnp.asarray(int(pos_frac * (T - 1)), jnp.int32)
    o1, l1, m1 = ops.flash_decode_partial(q, kc, vc, pos, kv_block=kb)
    o2, l2, m2 = ref.flash_decode_ref(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2),
                               atol=1e-5, rtol=1e-5)


def test_flash_decode_combine_matches_full():
    """Partial kernel + combine == normalized reference attention, and
    shard-split partials combine to the same result (the Fsum pattern)."""
    from repro.models.layers import combine_partials
    rng = np.random.RandomState(3)
    B, H, Hkv, T, D = 2, 8, 4, 128, 32
    q = jnp.asarray(rng.randn(B, H, D), jnp.float32)
    kc = jnp.asarray(rng.randn(B, T, Hkv, D), jnp.float32)
    vc = jnp.asarray(rng.randn(B, T, Hkv, D), jnp.float32)
    pos = jnp.asarray(100, jnp.int32)
    o, l, m = ops.flash_decode_partial(q, kc, vc, pos)
    full = np.asarray(o / np.maximum(np.asarray(l)[..., None], 1e-37))
    want = np.asarray(ref.decode_attention_full_ref(q, kc, vc, pos))
    np.testing.assert_allclose(full, want, atol=1e-4, rtol=1e-4)

    # split the cache in two "memory-node" shards; combine partials
    o1, l1, m1 = ops.flash_decode_partial(q, kc[:, :64], vc[:, :64], pos,
                                          kv_offset=0)
    o2, l2, m2 = ops.flash_decode_partial(q, kc[:, 64:], vc[:, 64:], pos,
                                          kv_offset=64)
    mg = np.maximum(m1, m2)
    c1, c2 = np.exp(m1 - mg), np.exp(m2 - mg)
    lg = l1 * c1 + l2 * c2
    og = (np.asarray(o1) * np.asarray(c1)[..., None]
          + np.asarray(o2) * np.asarray(c2)[..., None])
    np.testing.assert_allclose(og / np.maximum(lg, 1e-37)[..., None], want,
                               atol=1e-4, rtol=1e-4)
