"""Runtime clock-sanitizer battery (``repro.analysis.clocksan``).

Positive half: with ``REPRO_CLOCKSAN=1`` the full pipeline serves at
every inflight depth 1-8 with zero sanitizer findings — including under
mid-stage failure aborts — and enabling the sanitizer changes *nothing*
(depth-1 runs are bitwise-identical with it on and off: the sanitizer
is a pure observer).

Negative half: each invariant class — causality, time-travel,
FIFO/overlap, double-commit, out-of-band mutation, busy-time
conservation, stats folds, audit completeness — is violated on purpose
and must raise :class:`ClockSanError` naming the violation.
"""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis import clocksan
from repro.analysis.clocksan import ClockSanError
from repro.configs import rm1
from repro.data.queries import QueryDist, dlrm_batch
from repro.models.dlrm import DLRMModel
from repro.serving.cluster import ClusterConfig, ClusterEngine
from repro.serving.engine import Request
from repro.serving.pipeline import Interval, ResourceClock
from repro.serving.scenario import FailMN, RecoverMN, Resize

CFG = rm1.CONFIG.replace(
    name="rm1-clocksan",
    dlrm=rm1.DLRMConfig(num_tables=5, rows_per_table=48, embed_dim=8,
                        avg_pooling=4, num_dense_features=8,
                        bottom_mlp=(16, 8), top_mlp=(32, 16, 1)),
)
MODEL = DLRMModel(CFG)
PARAMS = MODEL.init(0)


def _requests(n, seed, gap_s=0.0):
    rng = np.random.RandomState(seed)
    sizes = QueryDist(mean_size=4.0, max_size=12).sample(rng, n)
    reqs = []
    for i, s in enumerate(sizes):
        b = dlrm_batch(CFG, int(s), rng)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]},
                            int(s), gap_s * i))
    return reqs


def _serve(depth, n=24, seed=7, gap_s=0.0, events=(), **kw):
    kw.setdefault("mn_types", ["ddr_mn"] * 4)
    eng = ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=8, n_replicas=2,
        inflight_depth=depth, **kw))
    res, stats = eng.serve(_requests(n, seed, gap_s), events=list(events))
    return eng, res, stats


@pytest.fixture
def sane(monkeypatch):
    monkeypatch.setenv("REPRO_CLOCKSAN", "1")
    clocksan.reset()
    yield
    clocksan.reset()


# ------------------------------------------------------------- the gate
def test_enabled_gate(monkeypatch):
    monkeypatch.delenv("REPRO_CLOCKSAN", raising=False)
    assert not clocksan.enabled()
    monkeypatch.setenv("REPRO_CLOCKSAN", "0")
    assert not clocksan.enabled()
    monkeypatch.setenv("REPRO_CLOCKSAN", "1")
    assert clocksan.enabled()


# ------------------------------------------------- end-to-end positives
@pytest.mark.parametrize("depth", [1, 2, 3, 4, 5, 6, 7, 8])
def test_depth_sweep_zero_findings(sane, depth):
    """Acceptance: the pipeline serves at every depth 1-8 under the
    sanitizer with zero findings (a finding raises out of serve)."""
    _, res, stats = _serve(depth)
    assert stats.completed == len(res) > 0
    assert stats.inflight_depth == depth


def test_events_and_midstage_abort_zero_findings(sane):
    """The abort path (charged in-flight prefixes) and the boundary
    event path both sanitize clean."""
    eng = ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=8, n_replicas=2, inflight_depth=3,
        mn_types=["ddr_mn"] * 4))
    eng.mn_bw = [1.0] * eng.m_mn      # seconds-long scans: failure lands
    res, stats = eng.serve(_requests(16, 3),
                           events=[FailMN(0.5, mn=0)])
    assert stats.reissues >= 1
    assert any(iv.aborted for c in eng.last_resources
               for iv in c.intervals)
    _serve(3, gap_s=0.0004,
           events=(FailMN(0.001, mn=1), RecoverMN(0.004, mn=1),
                   Resize(0.006, n_cn=3, m_mn=5)))


def test_sanitizer_is_a_pure_observer(monkeypatch):
    """Enabling clocksan must not perturb the run: depth-1 scores,
    latencies, and every stat are bitwise-identical with it on and off
    (this is what keeps the depth-1 parity claims valid under CI's
    sanitized job)."""
    monkeypatch.delenv("REPRO_CLOCKSAN", raising=False)
    _, res_off, st_off = _serve(1, gap_s=0.0004)
    monkeypatch.setenv("REPRO_CLOCKSAN", "1")
    clocksan.reset()
    _, res_on, st_on = _serve(1, gap_s=0.0004)
    assert len(res_off) == len(res_on)
    for a, b in zip(res_off, res_on):
        assert a.rid == b.rid and a.latency == b.latency
        assert np.array_equal(a.outputs, b.outputs)
    assert dataclasses.asdict(st_off) == dataclasses.asdict(st_on)


# --------------------------------------------------- booking negatives
def test_causality_violation_raises(sane):
    c = ResourceClock("r")
    c.book(0.0, 0.0, 2.0)
    with pytest.raises(ClockSanError, match="FIFO"):
        c.book(0.0, 1.0, 3.0)         # starts before free_at
    with pytest.raises(ClockSanError, match="causality"):
        c.book(5.0, 4.0, 6.0)         # starts before ready


def test_time_travel_raises(sane):
    c = ResourceClock("r")
    with pytest.raises(ClockSanError, match="time-travel"):
        c.book(0.0, 1.0, 0.5)


def test_out_of_band_mutation_and_double_commit(sane):
    """A desynced clock (free_at rewound behind the sanitizer's back)
    cannot sneak a booking through: the shadow, the interval list, and
    the duplicate set all catch it."""
    c = ResourceClock("r")
    c.book(0.0, 0.0, 2.0, tag=7)
    c.free_at = 0.0                   # out-of-band rewind
    with pytest.raises(ClockSanError) as ei:
        c.book(0.0, 0.0, 2.0, tag=7)  # identical re-commit
    msg = str(ei.value)
    assert "double-commit" in msg
    assert "overlap" in msg
    assert "out-of-band" in msg


# -------------------------------------------------- verify_run negatives
def _committed_clock(name="r"):
    c = ResourceClock(name)
    c.book(0.0, 0.0, 2.0, tag=1)
    c.book(1.0, 2.0, 3.5, tag=2)
    return c


def test_verify_run_clean_clock_passes(sane):
    clocksan.verify_run([_committed_clock()])


def test_conservation_violation_raises(sane):
    c = _committed_clock()
    c.busy_s += 0.25                  # busy time no longer == intervals
    with pytest.raises(ClockSanError, match="not conserved"):
        clocksan.verify_run([c])


def test_interval_overlap_detected_post_hoc(sane):
    c = ResourceClock("r")
    c.intervals.append(Interval(0.0, 2.0))
    c.intervals.append(Interval(1.0, 3.0))   # overlaps its predecessor
    c.busy_s = 4.0
    c.free_at = 3.0
    with pytest.raises(ClockSanError, match="overlap"):
        clocksan.verify_run([c])


def test_free_at_desync_detected_post_hoc(sane):
    c = _committed_clock()
    c.free_at = 99.0
    with pytest.raises(ClockSanError, match="free_at"):
        clocksan.verify_run([c])


def test_stats_fold_mismatch_raises(sane):
    c = _committed_clock("mn_bus:0")
    good = SimpleNamespace(resource_busy_s={"mn_bus:0": c.busy_s},
                           resource_queue_s={"mn_bus:0": c.queue_s})
    clocksan.verify_run([c], stats=good)
    bad = SimpleNamespace(resource_busy_s={"mn_bus:0": c.busy_s + 1.0},
                          resource_queue_s={"mn_bus:0": c.queue_s})
    with pytest.raises(ClockSanError, match="resource_busy_s"):
        clocksan.verify_run([c], stats=bad)


def test_phantom_pre_commit_on_retired_cn_raises(sane):
    """The same batch tag committed (non-aborted) on two cn_cpu
    incarnations is the retired-CN phantom-booking signature: the
    handoff must abort the superseded pre, never leave it committed."""
    a = ResourceClock("cn_cpu:1")       # retired incarnation
    a.book(0.0, 0.0, 1.0, tag=5)
    b = ResourceClock("cn_cpu:0")       # survivor redid the pre
    b.book(0.0, 0.0, 1.0, tag=5)
    with pytest.raises(ClockSanError, match="phantom"):
        clocksan.verify_run([a, b])
    # the correct shape — superseded interval aborted — passes
    clocksan.reset()
    a2 = ResourceClock("cn_cpu:1")
    a2.charge_abort(0.0, 1.0, tag=5)
    b2 = ResourceClock("cn_cpu:0")
    b2.book(0.0, 0.0, 1.0, tag=5)
    clocksan.verify_run([a2, b2])


def test_cn_shrink_handoff_sanitizes_clean(sane):
    """A CN shrink landing inside a batch's G_P/scatter window (the
    handoff-abort path) serves with zero findings: the superseded pre
    on the retired clock is an abort, busy time conserved."""
    eng0, _, _ = _serve(1, n=24, seed=11, gap_s=0.0)
    tr = next(t for t in eng0.last_trace[:-1] if t.task == 1)
    eng, res, stats = _serve(1, n=24, seed=11, gap_s=0.0,
                             events=[Resize(tr.mn_start, n_cn=1)])
    assert stats.resizes == 1 and stats.completed == len(res)
    assert any(iv.aborted for c in eng.last_resources
               if c.name == "cn_cpu:1" for iv in c.intervals)


def test_audit_completeness(sane):
    clocksan.verify_run([], audit=["a", "b"], n_audit_expected=2)
    with pytest.raises(ClockSanError, match="audit"):
        clocksan.verify_run([], audit=["a"], n_audit_expected=2)


def test_disabled_means_no_checks(monkeypatch):
    """With the gate off, a booking that would trip the sanitizer only
    hits the clock's own (cheaper) assertion — and carries no shadow."""
    monkeypatch.delenv("REPRO_CLOCKSAN", raising=False)
    clocksan.reset()
    c = ResourceClock("r")
    c.book(0.0, 0.0, 2.0)
    with pytest.raises(AssertionError):
        c.book(0.0, 1.0, 3.0)
    assert clocksan._shadows.get(c) is None
