"""Golden regression for the Fig. 14 NMP headline (issue #2 satellite).

`benchmarks.bench_nmp.run()` reports TCO savings from deploying NMP-DIMM
memory nodes in the disaggregated pool. The paper's headline band is
21-43.6%; the memory-bound RM1 must stay in-band for every generation,
as must the fleet view (RM1+RM2 served together). RM2 alone decays out
of the band once its DenseNet growth makes generations compute-bound
(NMP cannot buy back GPU TCO) — its values are pinned as goldens so
allocator/TCO edits cannot silently drift any of the three series.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import bench_nmp  # noqa: E402

BAND_LO, BAND_HI = bench_nmp.PAPER_BAND

GOLDEN = {
    "rm1": [0.3899, 0.4085, 0.3897, 0.3985, 0.4193, 0.4135],
    "rm2": [0.2189, 0.2361, 0.1908, 0.0510, 0.0366, 0.0303],
    "fleet": [0.3396, 0.3559, 0.3188, 0.2632, 0.2462, 0.2158],
}


@pytest.fixture(scope="module")
def savings():
    return bench_nmp.run()


def test_rm1_every_generation_in_paper_band(savings):
    assert len(savings["rm1"]) == 6
    for v, s in enumerate(savings["rm1"]):
        assert BAND_LO <= s <= BAND_HI, f"rm1 v{v}: {s:.3f} out of band"


def test_fleet_every_generation_in_paper_band(savings):
    assert len(savings["fleet"]) == 6
    for v, s in enumerate(savings["fleet"]):
        assert BAND_LO <= s <= BAND_HI, f"fleet v{v}: {s:.3f} out of band"


def test_fleet_savings_decay_with_compute_growth(savings):
    """RM2's DenseNet growth shifts fleet TCO toward compute, so the
    NMP saving must decline monotonically after the early generations —
    the shape of the paper's Fig. 14 narrative."""
    fleet = savings["fleet"]
    assert all(a >= b for a, b in zip(fleet[1:], fleet[2:]))
    assert fleet[-1] < fleet[1]


def test_golden_values_pinned(savings):
    for series, want in GOLDEN.items():
        np.testing.assert_allclose(savings[series], want, atol=2e-3,
                                   err_msg=f"{series} savings drifted")
