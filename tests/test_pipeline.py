"""Pipelined execution battery (issue #6): per-resource FIFO clocks,
depth-d admission, and the correctness invariants of the overlapped
virtual clock.

The tentpole invariants:

- **causality/conservation** — no resource is ever double-booked, every
  batch's completion dominates its critical path, utilization never
  exceeds 1, and at most ``inflight_depth`` batches overlap inside the
  MN stage;
- **depth-1 parity** — ``inflight_depth=1`` is the sequential clock:
  the admission floor degenerates to the global barrier and the
  wait-free commit path reuses the closed-form gate arithmetic, so
  scores and stats are bitwise-identical to the pre-pipeline model
  (pinned here by a golden, and by the untouched legacy parity grid in
  ``tests/test_scenario.py``);
- **cross-depth parity** — scores are bitwise-identical at every depth
  (the clock changes, never the math), including under mid-stream
  failures and resizes;
- **saturation** — throughput rises with depth and saturates at the
  bottleneck resource (golden-pinned sweep; the analytic bound
  ``completed / max_r busy_r`` is approached as depth -> inf).

Hypothesis properties randomize streams x depths x failure times when
the package is installed; pinned parametrize fallbacks keep bare envs
covered (tests/_hypothesis_compat.py convention).
"""
import math

import numpy as np
import pytest

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.configs import rm1
from repro.data.queries import QueryDist, dlrm_batch
from repro.models.dlrm import DLRMModel
from repro.serving.cluster import ClusterConfig, ClusterEngine
from repro.serving.engine import Request
from repro.serving.pipeline import (AdmissionWindow, ResourceClock,
                                    fit_clocks, summarize_resources)
from repro.serving.scenario import FailMN, RecoverMN, Resize

CFG = rm1.CONFIG.replace(
    name="rm1-pipeline",
    dlrm=rm1.DLRMConfig(num_tables=5, rows_per_table=48, embed_dim=8,
                        avg_pooling=4, num_dense_features=8,
                        bottom_mlp=(16, 8), top_mlp=(32, 16, 1)),
)
MODEL = DLRMModel(CFG)
PARAMS = MODEL.init(0)


def _requests(n, seed, gap_s=0.0):
    rng = np.random.RandomState(seed)
    sizes = QueryDist(mean_size=4.0, max_size=12).sample(rng, n)
    reqs = []
    for i, s in enumerate(sizes):
        b = dlrm_batch(CFG, int(s), rng)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]},
                            int(s), gap_s * i))
    return reqs


def _engine(depth, n_cn=2, m_mn=4, **kw):
    kw.setdefault("mn_types", ["ddr_mn"] * m_mn)
    return ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=n_cn, m_mn=m_mn, batch_size=8, n_replicas=2,
        inflight_depth=depth, **kw))


def _serve(depth, n=30, seed=7, gap_s=0.0, events=(), **kw):
    eng = _engine(depth, **kw)
    res, stats = eng.serve(_requests(n, seed, gap_s), events=list(events))
    return eng, res, stats


# --------------------------------------------------- ResourceClock unit
def test_clock_reserve_is_fifo():
    c = ResourceClock("r")
    s0, e0 = c.reserve(0.0, 2.0)
    assert (s0, e0) == (0.0, 2.0)
    # ready before free_at: queued behind the first booking
    s1, e1 = c.reserve(1.0, 3.0)
    assert (s1, e1) == (2.0, 5.0)
    assert c.queue_s == 1.0
    assert c.busy_s == 5.0
    # ready after free_at: starts when ready, no queueing
    s2, e2 = c.reserve(7.0, 1.0)
    assert (s2, e2) == (7.0, 8.0)
    assert c.queue_s == 1.0
    assert c.bookings == 3


def test_clock_book_rejects_causality_violations():
    c = ResourceClock("r")
    c.book(0.0, 0.0, 2.0)
    with pytest.raises(AssertionError):
        c.book(0.0, 1.0, 3.0)       # starts before free_at
    with pytest.raises(AssertionError):
        c.book(5.0, 4.0, 6.0)       # starts before ready
    with pytest.raises(AssertionError):
        c.book(2.0, 3.0, 2.5)       # ends before it starts


def test_clock_charge_abort():
    c = ResourceClock("r")
    c.charge_abort(1.0, 0.5)        # failure before work started: no-op
    assert c.bookings == 0 and c.busy_s == 0.0
    c.charge_abort(1.0, 1.75, tag=3)
    assert c.bookings == 1
    assert c.busy_s == 0.75
    assert c.intervals[0].aborted and c.intervals[0].tag == 3
    assert c.free_at == 1.75


def test_admission_window_depth1_is_the_barrier():
    w = AdmissionWindow(1)
    assert w.floor() == 0.0
    w.complete(3.0)
    w.complete(1.0)
    assert w.floor() == 3.0         # max previous done == legacy barrier


def test_admission_window_order_statistic():
    w = AdmissionWindow(3)
    for t in (5.0, 2.0, 9.0, 4.0):
        w.complete(t)
    # 4 done, depth 3 -> floor is the 2nd smallest (4-3+1)
    assert w.floor() == 4.0
    assert AdmissionWindow(8).floor() == 0.0
    with pytest.raises(ValueError):
        AdmissionWindow(0)


def test_fit_clocks_grow_shrink_and_registry():
    reg = []
    a = fit_clocks([], 2, "x", 0.0, reg)
    assert [c.name for c in a] == ["x:0", "x:1"]
    a[1].reserve(0.0, 1.0)
    b = fit_clocks(a, 1, "x", 5.0, reg)         # shrink retires x:1
    assert [c.name for c in b] == ["x:0"]
    c2 = fit_clocks(b, 3, "x", 5.0, reg)        # regrow: fresh from t=5
    assert [c.name for c in c2] == ["x:0", "x:1", "x:2"]
    assert c2[1].free_at == 5.0
    # retired incarnation's stats still aggregate under its slot name
    busy, queue, util, occ = summarize_resources(reg, 10.0)
    assert busy["x:1"] == 1.0 and util["x:1"] == 0.1
    assert len(reg) == 4            # x:0, old x:1, new x:1, x:2


# ------------------------------------------- causality / conservation
def _check_invariants(eng, res, stats, depth):
    trace = eng.last_trace
    assert len(res) > 0 and len(trace) > 0
    for c in eng.last_resources:
        # no double-booking: intervals chain FIFO on every clock
        for a, b in zip(c.intervals, c.intervals[1:]):
            assert a.end <= b.start + 1e-18, c.name
        assert c.busy_s <= stats.makespan_s + 1e-12
        # busy time conserved: the clock's counter is its interval sum
        assert math.isclose(
            c.busy_s, sum(iv.end - iv.start for iv in c.intervals),
            rel_tol=1e-9, abs_tol=1e-15)
    for k, u in stats.resource_util.items():
        assert 0.0 <= u <= 1.0 + 1e-9, (k, u)
    for t in trace:
        # stage chain is causal
        assert t.pre[0] <= t.pre[1] <= t.chain_ready <= t.mn_start
        for _, s, e in t.scans:
            assert t.mn_start <= s <= e <= t.mn_done + 1e-18
        assert t.gather[0] <= t.gather[1] <= t.mn_done + 1e-18
        assert t.mn_done <= t.dense[0] <= t.dense[1] == t.done
        # completion dominates the critical path through the stages
        crit = ((t.pre[1] - t.pre[0]) + (t.chain_ready - t.pre[1])
                + max((e - s for _, s, e in t.scans), default=0.0)
                + (t.gather[1] - t.gather[0]) + (t.dense[1] - t.dense[0]))
        assert t.done - t.pre[0] >= crit - 1e-12
    # at most `depth` batches concurrently inside the MN stage
    marks = ([(t.mn_start, 1) for t in trace]
             + [(t.mn_done, -1) for t in trace])
    marks.sort(key=lambda m: (m[0], m[1]))
    inflight = peak = 0
    for _, dm in marks:
        inflight += dm
        peak = max(peak, inflight)
    assert peak <= depth, (peak, depth)


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_invariants_clean_stream(depth):
    eng, res, stats = _serve(depth, n=30, seed=7)
    assert stats.inflight_depth == depth
    _check_invariants(eng, res, stats, depth)


@pytest.mark.parametrize("depth", [1, 3])
def test_invariants_under_events(depth):
    eng, res, stats = _serve(
        depth, n=30, seed=3, gap_s=0.0004,
        events=[FailMN(0.001, mn=1), RecoverMN(0.004, mn=1),
                Resize(0.006, n_cn=3, m_mn=5)])
    assert stats.failures == 1 and stats.recoveries == 1
    _check_invariants(eng, res, stats, depth)


# ----------------------------------------------------- depth-1 parity
GOLDEN_D1 = {
    # _serve(1, n=24, seed=11, gap_s=0.0004) on the reduced RM1 pool
    "digest": 49.4315071105957,
    "mean_latency": 0.0005170557741906275,
    "makespan_s": 0.011200189040144295,
    "access_bytes": 52928.0,
}


def test_depth1_is_the_config_default():
    """Omitting ``inflight_depth`` serves on the sequential clock:
    bitwise-identical results and stats to an explicit depth=1 run."""
    import dataclasses
    eng_d = ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=8, n_replicas=2,
        mn_types=["ddr_mn"] * 4))
    res_d, st_d = eng_d.serve(_requests(20, 5, 0.0004))
    eng_1, res_1, st_1 = _serve(1, n=20, seed=5, gap_s=0.0004)
    assert _scores_equal(res_d, res_1)
    assert [r.latency for r in res_d] == [r.latency for r in res_1]
    assert dataclasses.asdict(st_d) == dataclasses.asdict(st_1)


def test_depth1_stats_golden():
    """Golden pin of the depth-1 clock on a fixed stream: any change to
    the sequential semantics — scores, latency chain, byte counters —
    trips this before the parity grid does."""
    _, res, stats = _serve(1, n=24, seed=11, gap_s=0.0004)
    assert stats.completed == 24
    digest = float(np.sum([np.sum(r.outputs) for r in res]))
    assert digest == pytest.approx(GOLDEN_D1["digest"], rel=0, abs=0)
    assert stats.mean_latency == GOLDEN_D1["mean_latency"]
    assert stats.makespan_s == GOLDEN_D1["makespan_s"]
    assert sum(stats.mn_access_bytes) == GOLDEN_D1["access_bytes"]
    # the sequential clock never queues a batch behind admission: the
    # MN-stage resources were always free by the time it arrived
    assert stats.resource_queue_s["cn_nic:0"] == 0.0
    assert all(v == 0.0 for k, v in stats.resource_queue_s.items()
               if k.startswith(("cn_nic:", "mn_bus:")))
    assert stats.inflight_depth == 1


# ------------------------------------------------- cross-depth parity
def _scores_equal(a, b):
    return (len(a) == len(b)
            and all(x.rid == y.rid and np.array_equal(x.outputs, y.outputs)
                    for x, y in zip(a, b)))


def _check_scores_and_monotone(seed, depths, events=()):
    base = prev_qps = None
    for d in depths:
        _, res, stats = _serve(d, n=24, seed=seed, events=events)
        if base is None:
            base = res
        else:
            assert _scores_equal(base, res), (seed, d)
        if not events:           # reissues change demand: event-free only
            if prev_qps is not None:
                assert stats.throughput_qps >= prev_qps * (1 - 1e-9), \
                    (seed, d, prev_qps, stats.throughput_qps)
            prev_qps = stats.throughput_qps


@pytest.mark.parametrize("seed", [0, 7, 13])
def test_scores_bitwise_and_throughput_monotone_pinned(seed):
    _check_scores_and_monotone(seed, (1, 2, 3, 4, 8))


@pytest.mark.parametrize("seed", [2, 9])
def test_scores_bitwise_under_failure_pinned(seed):
    _check_scores_and_monotone(
        seed, (1, 2, 4),
        events=(FailMN(1e-6, mn=2), RecoverMN(5e-3, mn=2)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       depths=st.lists(st.integers(1, 8), min_size=2, max_size=4,
                       unique=True))
def test_scores_bitwise_and_throughput_monotone_property(seed, depths):
    _check_scores_and_monotone(seed, sorted(depths))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       depth=st.integers(2, 8),
       t_fail=st.floats(1e-7, 5e-3),
       mn=st.integers(0, 3))
def test_scores_bitwise_under_failure_property(seed, depth, t_fail, mn):
    ev = (FailMN(t_fail, mn=mn),)
    _, base, _ = _serve(1, n=24, seed=seed, events=ev)
    _, res, _ = _serve(depth, n=24, seed=seed, events=ev)
    assert _scores_equal(base, res)


# --------------------------------------------- mid-stage abort charging
def _throttled_failure(depth):
    eng = _engine(depth)
    eng.mn_bw = [1.0] * eng.m_mn     # seconds-long scans: easy to hit
    reqs = _requests(16, 3)
    res, stats = eng.serve(reqs, events=[FailMN(0.5, mn=0)])
    return eng, res, stats


@pytest.mark.parametrize("depth", [1, 3])
def test_midstage_abort_charges_the_right_resource(depth):
    eng, res, stats = _throttled_failure(depth)
    assert stats.reissues >= 1
    aborted = [(c.name, iv) for c in eng.last_resources
               for iv in c.intervals if iv.aborted]
    assert aborted, "no aborted interval charged"
    # every aborted interval is an in-flight prefix truncated at the
    # failure instant (never extends past it)
    for name, iv in aborted:
        assert iv.end <= 0.5 + 1e-12, (name, iv)
        assert name.startswith(("mn_bus:", "cn_nic:")), name
    # and the re-issued batches still produce the failure-free scores
    eng2 = _engine(depth)
    res2, _ = eng2.serve(_requests(16, 3))
    assert _scores_equal(res2, res)


def test_cn_shrink_handoff_aborts_retired_pre():
    """A CN shrink landing inside the G_P/scatter window hands the
    batch's pre stage off to a survivor.  The superseded pre interval on
    the retired CN cpu clock must be charged as an abort (mirroring
    ``_mn_abort``) — never left committed, which would double-count the
    pre work in ``resource_busy_s`` via the ``fit_clocks`` registry."""
    eng0, res0, _ = _serve(1, n=24, seed=11, gap_s=0.0)
    # pick an offer-formed batch routed to CN 1 (the final batch is
    # deadline-flushed: _drain_due injects at the flush deadline before
    # running it, so a resize timed there never lands mid-batch)
    tr = next(t for t in eng0.last_trace[:-1] if t.task == 1)
    eng, res, stats = _serve(1, n=24, seed=11, gap_s=0.0,
                             events=[Resize(tr.mn_start, n_cn=1)])
    assert stats.resizes == 1
    assert _scores_equal(res, res0)
    cpu_clocks = [c for c in eng.last_resources
                  if c.name.startswith("cn_cpu")]
    # each batch commits its pre stage on exactly one CN incarnation
    committed = {}
    for c in cpu_clocks:
        for iv in c.intervals:
            if iv.tag >= 0 and not iv.aborted:
                assert iv.tag not in committed, (
                    f"tag {iv.tag} pre-committed on both "
                    f"{committed[iv.tag]} and {c.name}")
                committed[iv.tag] = c.name
    # and the retired incarnation carries the superseded pre as an
    # abort, truncated at the shrink instant
    retired = [iv for c in cpu_clocks if c.name == "cn_cpu:1"
               for iv in c.intervals if iv.aborted]
    assert retired, "superseded pre on the retired CN was not aborted"
    assert all(iv.end <= tr.mn_start + 1e-12 for iv in retired)


# ---------------------------------------------------------- CN routing
def _burst_requests(n, seed, burst=12, gap_between=2e-4):
    """Arrival bursts with idle gaps: the stream shape that separates
    the routing policies (inside a burst the cpu clocks tie, so the
    legacy router is blind to downstream backlog)."""
    rng = np.random.RandomState(seed)
    sizes = QueryDist(mean_size=4.0, max_size=12).sample(rng, n)
    reqs, t = [], 0.0
    for i, s in enumerate(sizes):
        if i and i % burst == 0:
            t += gap_between
        b = dlrm_batch(CFG, int(s), rng)
        reqs.append(Request(i, {"dense": b["dense"],
                                "indices": b["indices"]},
                            int(s), t))
    return reqs


def test_cn_router_default_is_cpu_free_bitwise():
    """``cn_router`` defaults to the legacy cpu_free policy: an explicit
    cpu_free run is bitwise-identical to an unconfigured one — and still
    hits the depth-1 golden, so the default config reproduces HEAD."""
    import dataclasses
    _, res_d, st_d = _serve(1, n=24, seed=11, gap_s=0.0004)
    _, res_e, st_e = _serve(1, n=24, seed=11, gap_s=0.0004,
                            cn_router="cpu_free")
    assert _scores_equal(res_d, res_e)
    assert [r.latency for r in res_d] == [r.latency for r in res_e]
    assert dataclasses.asdict(st_d) == dataclasses.asdict(st_e)
    digest = float(np.sum([np.sum(r.outputs) for r in res_e]))
    assert digest == pytest.approx(GOLDEN_D1["digest"], rel=0, abs=0)


def test_cn_router_unknown_rejected():
    with pytest.raises(ValueError, match="cn_router"):
        _serve(1, cn_router="fastest")


def _burst_serve(router, seed, slow=4000):
    """Two CNs over a deliberately slow MN pool (scan times comparable
    to the burst period, as in test_clocksan's throttled runs) so the
    per-CN gather/dense backlog is what sets the tail."""
    eng = _engine(4, n_cn=2, m_mn=2, max_wait_s=2e-5, cn_router=router,
                  mn_types=["ddr_mn"] * 2)
    eng.mn_bw = [bw / slow for bw in eng.mn_bw]
    res, stats = eng.serve(_burst_requests(64, seed))
    return res, stats


@pytest.mark.parametrize("seed", [3, 7, 11, 13, 42])
def test_pipeline_free_lowers_p99_under_bursts(seed):
    """The tentpole claim: routing on the whole cpu/nic/gpu pipeline
    drain strictly lowers p99 over the cpu-only policy once downstream
    backlog dominates (depth >= 2, bursty arrivals) — while scores stay
    bitwise-identical (placement moves time, never values)."""
    res_c, st_c = _burst_serve("cpu_free", seed)
    res_p, st_p = _burst_serve("pipeline_free", seed)
    assert st_p.p99 < st_c.p99, (seed, st_c.p99, st_p.p99)
    key = lambda r: r.rid
    assert _scores_equal(sorted(res_c, key=key), sorted(res_p, key=key))
    # least_outstanding also serves the burst to completion with the
    # same values (its tail is workload-dependent, not pinned)
    res_l, st_l = _burst_serve("least_outstanding", seed)
    assert st_l.completed == st_c.completed == 64
    assert _scores_equal(sorted(res_c, key=key), sorted(res_l, key=key))


# ------------------------------------------------- saturation goldens
SWEEP_DEPTHS = (1, 2, 4, 8)


def _sweep(n=60, seed=5):
    out = {}
    base = None
    for d in SWEEP_DEPTHS:
        _, res, stats = _serve(d, n=n, seed=seed, max_wait_s=1e-6)
        if base is None:
            base = res
        else:
            assert _scores_equal(base, res)
        out[d] = stats
    return out


def test_depth_sweep_saturation_golden():
    """The acceptance pin: the RM1-reduced smoke pool reaches >= 1.5x
    modeled throughput at depth 4 vs depth 1, throughput is monotone in
    depth, and the curve saturates at the gather-NIC bottleneck."""
    sweep = _sweep()
    qps = {d: s.throughput_qps for d, s in sweep.items()}
    assert qps[2] >= qps[1] and qps[4] >= qps[2] and qps[8] >= qps[4]
    assert qps[4] / qps[1] >= 1.5, qps
    # saturated: depth 8 adds little over depth 4
    assert qps[8] / qps[4] < 1.25, qps
    # the bottleneck is a gather NIC, near-fully utilized at depth 8
    top = max(sweep[8].resource_util, key=sweep[8].resource_util.get)
    assert top.startswith("cn_nic:"), sweep[8].resource_util
    assert sweep[8].resource_util[top] > 0.7
    # golden band for the curve itself (loose: model-level pin)
    assert 1.7 <= qps[4] / qps[1] <= 2.3, qps


# ------------------------------------- analytic model cross-validation
def test_depth1_single_batch_matches_analytic_chain():
    """Unloaded single-batch latency at depth 1 is exactly the stage
    chain the analytic model predicts — same floating-point operation
    order as the dispatcher."""
    eng = _engine(1)
    rng = np.random.RandomState(0)
    b = dlrm_batch(CFG, 8, rng)      # exactly one full batch: scale = 1
    res, stats = eng.serve([Request(0, {"dense": b["dense"],
                                        "indices": b["indices"]}, 8, 0.0)])
    assert len(res) == 1
    st_ = eng.unit_model.stage_times(8)
    v = eng.validate_latency_model()
    t_mn = v["engine_mn_stage_s"]
    expected = ((st_.t_pre * 1.0 + st_.t_comm_in * 1.0) + t_mn
                + st_.t_dense * 1.0)
    assert res[0].latency == expected        # bitwise: same chain order
    assert stats.makespan_s == expected


def test_depth_inf_approaches_bottleneck_bound():
    """As depth -> inf the modeled throughput approaches (and never
    exceeds) the analytic bottleneck-resource bound
    ``completed / max_r busy_r``."""
    _, res, stats = _serve(64, n=160, seed=7, max_wait_s=1e-6)
    busiest = max(stats.resource_busy_s.values())
    bound = len(res) / busiest
    assert stats.throughput_qps <= bound * (1 + 1e-9)
    assert stats.throughput_qps >= 0.9 * bound, (
        stats.throughput_qps, bound)


# --------------------------------------------------- stats plumbing
def test_resource_stats_exposed_and_consistent():
    _, res, stats = _serve(3, n=30, seed=1)
    names = set(stats.resource_util)
    assert {"cn_cpu:0", "cn_nic:0", "cn_gpu:0", "mn_bus:0"} <= names
    for k in names:
        busy = stats.resource_busy_s[k]
        q = stats.resource_queue_s[k]
        assert busy >= 0.0 and q >= 0.0
        assert stats.resource_occupancy[k] == pytest.approx(
            (busy + q) / stats.makespan_s)
    assert stats.makespan_s > 0
    assert stats.throughput_qps == pytest.approx(
        len(res) / stats.makespan_s)
    assert stats.admission_wait_s >= 0.0
