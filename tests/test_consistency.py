"""Prefill vs chained-decode consistency: teacher-forced prefill logits
must equal step-by-step decode logits (exact in fp32) for every arch —
this pins the KV-cache/pos/state semantics across all five families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry


def _fp32(cfg):
    cfg = cfg.replace(dtype="float32", param_dtype="float32")
    if cfg.moe is not None:
        # capacity drops are batch-dependent; disable for equivalence
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    return cfg


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_prefill_matches_chained_decode(arch):
    cfg = _fp32(configs.get_reduced(arch))
    model = registry.build(cfg)
    params = model.init(0)
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 48)), jnp.int32)

    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jnp.asarray(
            rng.randn(2, cfg.encdec.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extra["images"] = jnp.asarray(
            rng.randn(2, cfg.vlm.num_patches, cfg.d_model), jnp.float32)

    def prefill(t):
        return jax.jit(lambda p, b: model.prefill(p, b, cache_len=96))(
            params, dict(tokens=t, **extra))

    _, cache = prefill(toks[:, :46])
    decode = jax.jit(model.decode_step)
    l1, cache = decode(params, cache, {"tokens": toks[:, 46:47]})
    l2, cache = decode(params, cache, {"tokens": toks[:, 47:48]})
    want, _ = prefill(toks)
    np.testing.assert_allclose(
        np.asarray(l2, np.float32), np.asarray(want, np.float32),
        atol=2e-4, rtol=2e-4)
