"""C1: table-sharded embedding with shard-local reduction (+ layout)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import sharding as core_shd
from repro.models.dlrm import embedding_bag_ref


def test_disagg_lookup_matches_ref_single_host():
    rng = np.random.RandomState(0)
    tables = jnp.asarray(rng.randn(8, 64, 16), jnp.float32)
    idx = rng.randint(0, 64, (4, 8, 5)).astype(np.int32)
    idx[rng.rand(4, 8, 5) < 0.2] = -1
    idx = jnp.asarray(idx)
    out = core_shd.disagg_embedding_lookup(tables, idx, mesh=None)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(embedding_bag_ref(tables, idx)),
                               rtol=1e-6)


def test_disagg_lookup_kernel_path():
    rng = np.random.RandomState(1)
    tables = jnp.asarray(rng.randn(4, 32, 8), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 32, (2, 4, 3)), jnp.int32)
    out = core_shd.disagg_embedding_lookup(tables, idx, mesh=None,
                                           use_kernel=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(embedding_bag_ref(tables, idx)),
                               rtol=1e-5, atol=1e-5)


def test_greedy_table_layout_is_permutation():
    cfg = configs.get_reduced("rm1")
    perm, inv, alloc, routing = core_shd.greedy_table_layout(cfg, m=4)
    n = cfg.dlrm.num_tables
    assert sorted(perm.tolist()) == list(range(n))
    np.testing.assert_array_equal(perm[inv], np.arange(n))
    # balanced shard cardinality for the stacked layout
    assert len(perm) % 4 == 0


def test_layout_heterogeneous_balances_bytes():
    cfg = configs.get_reduced("rm1")
    perm, inv, alloc, routing = core_shd.greedy_table_layout(
        cfg, m=4, heterogeneous_seed=3)
    from repro.core.embedding_manager import imbalance
    assert imbalance(alloc.mn_used) < 1.5
