"""Declarative scenario API (issue #5): serde round-trips, the unified
timeline dispatcher, legacy-kwarg bitwise parity, timed recoveries, the
schedule-aware failure bounds check, and the per-event audit trail.

The tentpole invariants:

- every event type survives dict/JSON round-trip with equality;
- a shuffled event list executes identically to a pre-sorted one (the
  dispatcher owns the ordering guarantee);
- a legacy ``serve(failures=, resizes=)`` run is bitwise-identical —
  scores, latencies, and every ClusterStats counter — to the same
  sequence expressed as a ``ScenarioSpec`` through ``run_scenario``.
"""
import dataclasses
import json
import math
import pathlib
import random

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro import configs
from repro.configs import rm1
from repro.data.queries import QueryDist, dlrm_request_stream
from repro.models.dlrm import DLRMModel
from repro.serving import scenario as sc
from repro.serving.cluster import ClusterConfig, ClusterEngine
from repro.serving.engine import Request
from repro.serving.scenario import (DegradeMN, FailMN, ModelRef, RecoverMN,
                                    ReloadParams, ReplanPlacement, Resize,
                                    ScenarioSpec, SetWorkload, Topology,
                                    Workload, plan_workload, preset,
                                    run_scenario, smoke_topology)
from repro.serving.timeline import EventRecord, legacy_events

CFG = rm1.CONFIG.replace(
    name="rm1-scenario",
    dlrm=rm1.DLRMConfig(num_tables=5, rows_per_table=48, embed_dim=8,
                        avg_pooling=4, num_dense_features=8,
                        bottom_mlp=(16, 8), top_mlp=(32, 16, 1)),
)
MODEL = DLRMModel(CFG)
PARAMS = MODEL.init(0)

ALL_EVENTS = (
    FailMN(0.01, mn=1),
    RecoverMN(0.02, mn=1),
    Resize(0.03, n_cn=3, m_mn=5),
    Resize(0.035, m_mn=4, mn_type="nmp_mn"),
    ReloadParams(0.04, seed=7),
    ReplanPlacement(0.05),
    SetWorkload(0.06, alpha=1.05, gap_s=0.001, mean_size=6.0,
                sigma=0.5, max_size=32),
    DegradeMN(0.07, mn=2, factor=4.0),
)


def _workload(requests=12, **kw):
    kw.setdefault("mean_size", 4.0)
    kw.setdefault("max_size", 12)
    kw.setdefault("gap_s", 0.004)
    return Workload(requests=requests, **kw)


def _spec(events=(), topology=None, workload=None, name="t"):
    return ScenarioSpec(name=name,
                        topology=topology or smoke_topology(batch_size=8),
                        workload=workload or _workload(),
                        events=tuple(events))


def _legacy_requests(spec):
    w = spec.workload
    qd = QueryDist(mean_size=w.mean_size, sigma=w.sigma,
                   max_size=w.max_size, alpha=w.alpha)
    return [Request(*t) for t in dlrm_request_stream(
        CFG, w.requests, seed=w.seed, dist=qd, gap_s=w.gap_s)]


# ------------------------------------------------------------ serde
@pytest.mark.parametrize("ev", ALL_EVENTS, ids=lambda e: e.kind)
def test_event_dict_round_trip(ev):
    d = ev.to_dict()
    assert d["type"] == ev.kind
    assert sc.event_from_dict(json.loads(json.dumps(d))) == ev


def test_spec_json_round_trip_every_event_type():
    spec = ScenarioSpec(
        name="all-events",
        description="every event type at once",
        model=ModelRef(arch="rm1", reduced=True, init_seed=3),
        topology=smoke_topology(
            mn_types=("ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"),
            cache_mb=1.5, cache_policy="lfu"),
        workload=Workload(requests=20, mean_size=6.0, sigma=0.8,
                          max_size=48, alpha=1.05, gap_s=0.003, seed=11),
        events=ALL_EVENTS,
    )
    spec.validate()
    rt = ScenarioSpec.from_json(spec.to_json())
    assert rt == spec
    assert rt.topology.mn_types == spec.topology.mn_types  # tuple, not list
    # and via a real file
    assert ScenarioSpec.from_dict(json.loads(spec.to_json())) == spec


def test_spec_serde_rejects_garbage():
    with pytest.raises(ValueError):
        sc.event_from_dict({"type": "explode_mn", "time_s": 0.1})
    with pytest.raises(ValueError):
        sc.event_from_dict({"type": "fail_mn"})             # no time_s
    with pytest.raises(ValueError):
        sc.event_from_dict({"type": "fail_mn", "time_s": 0.1, "mmn": 2})
    with pytest.raises(ValueError):
        ScenarioSpec.from_dict({"topology": {}})            # no name
    with pytest.raises(ValueError):
        ScenarioSpec.from_dict({"name": "x", "topolgy": {}})
    with pytest.raises(ValueError):
        ScenarioSpec.from_dict({"name": "x", "topology": {"n_cns": 2}})


def test_spec_validate_rejects_bad_fields():
    with pytest.raises(ValueError):
        _spec(topology=smoke_topology(n_cn=0)).validate()
    with pytest.raises(ValueError):
        _spec(topology=smoke_topology(cache_policy="mru")).validate()
    with pytest.raises(ValueError):
        _spec(topology=smoke_topology(mn_types=("ddr_mn",))).validate()
    with pytest.raises(ValueError):
        _spec(topology=smoke_topology(cn_type="ddr_mn")).validate()
    with pytest.raises(ValueError):
        _spec(workload=_workload(requests=-1)).validate()
    with pytest.raises(ValueError):
        _spec(events=[Resize(0.01, m_mn=0)]).validate()
    with pytest.raises(ValueError):
        _spec(events=[FailMN(float("nan"), mn=0)]).validate()
    with pytest.raises(ValueError):
        _spec(events=[SetWorkload(0.01, alpha=-1.0)]).validate()
    with pytest.raises(ValueError):
        _spec(events=[Resize(0.01, mn_type="cn_1g")]).validate()


def test_validate_rejects_fractional_ids_and_counts():
    """A lint-passing JSON scenario must not smuggle float ids into the
    engine: fail_mn(1.5) would land in the dead set without ever
    matching a real MN."""
    with pytest.raises(ValueError):
        _spec(events=[FailMN(0.01, mn=1.5)]).validate()
    with pytest.raises(ValueError):
        _spec(events=[RecoverMN(0.01, mn=True)]).validate()
    with pytest.raises(ValueError):
        _spec(events=[Resize(0.01, m_mn=2.5)]).validate()
    with pytest.raises(ValueError):
        _spec(events=[ReloadParams(0.01, seed=1.5)]).validate()
    with pytest.raises(ValueError):
        _spec(events=[SetWorkload(0.01, max_size=8.5)]).validate()
    with pytest.raises(ValueError):
        _spec(workload=_workload(requests=3.5)).validate()
    with pytest.raises(ValueError):
        _spec(topology=smoke_topology(m_mn=4.0)).validate()
    # string-typed numerics are a lint ValueError, not a raw TypeError
    with pytest.raises(ValueError):
        _spec(events=[SetWorkload(0.01, alpha="1.2")]).validate()
    with pytest.raises(ValueError):
        _spec(workload=_workload(mean_size="8.0")).validate()


def test_identity_resize_recorded_as_noop():
    """A resize targeting the pool's current shape returns early inside
    the engine without counting — the audit record must say so, keeping
    'applied resize records == stats.resizes' consistent."""
    spec = _spec(events=[Resize(0.01, n_cn=2, m_mn=4)])   # already {2,4}
    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    assert rep.stats.resizes == 0
    recs = [r for r in rep.stats.events if isinstance(r.event, Resize)]
    assert len(recs) == 1 and not recs[0].applied


def test_trailing_events_flush_at_end_of_stream():
    """Events stamped after the last batch deadline still belong to the
    scenario: they apply (in time order) once the stream drains, so the
    report's final pool matches the declared timeline and the audit
    trail records every event."""
    spec = _spec(workload=_workload(requests=6),
                 events=[FailMN(0.008, mn=1),
                         RecoverMN(5.0, mn=1),       # long after the end
                         Resize(6.0, n_cn=3, m_mn=5)])
    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    assert rep.completed == rep.total
    assert rep.stats.failures == 1 and rep.stats.recoveries == 1
    assert rep.stats.resizes == 1
    assert (rep.final_n_cn, rep.final_m_mn) == (3, 5)
    assert [r.event.kind for r in rep.stats.events] == [
        "fail_mn", "recover_mn", "resize"]
    assert rep.stats.events[-1].applied
    assert not rep.engine.dead            # the recovery really landed


# ------------------------------------- schedule-aware failure bounds fix
def test_failure_after_timed_grow_is_accepted():
    """Satellite: a failure aimed at an MN that only exists after a
    scheduled grow must validate against the schedule-aware maximum
    pool, not the pool at serve start — and actually fire."""
    spec = _spec(events=[Resize(0.01, n_cn=2, m_mn=6),
                         FailMN(0.03, mn=5)])
    spec.validate()                        # MN 5 exists once m_mn=6
    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    assert rep.completed == rep.total
    assert rep.stats.failures == 1
    fired = [r for r in rep.stats.events
             if isinstance(r.event, FailMN) and r.applied]
    assert fired and fired[0].m_mn == 6 and 5 in fired[0].dead


def test_failure_before_its_enabling_grow_rejected():
    """A grow scheduled AFTER the failure cannot justify its id: the
    schedule never reaches that pool state in time, so accepting it
    would let the event silently no-op against the un-grown pool."""
    spec = _spec(events=[FailMN(0.01, mn=5), Resize(0.05, m_mn=6)])
    with pytest.raises(ValueError):
        spec.validate()
    # ...while the same pair in fire order is accepted
    _spec(events=[Resize(0.005, m_mn=6), FailMN(0.01, mn=5)]).validate()


def test_failure_beyond_schedule_max_still_rejected():
    spec = _spec(events=[Resize(0.01, m_mn=6), FailMN(0.03, mn=6)])
    with pytest.raises(ValueError):
        spec.validate()
    with pytest.raises(ValueError):
        run_scenario(spec, model=MODEL, params=PARAMS)
    # the engine-level timeline rejects too (no spec in the way)
    eng = ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=8, n_replicas=2))
    with pytest.raises(ValueError):
        eng.serve(_legacy_requests(_spec()),
                  events=[RecoverMN(0.01, mn=9)])


def test_legacy_failure_bounds_still_enforced():
    eng = ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=8, n_replicas=2))
    reqs = _legacy_requests(_spec())
    with pytest.raises(ValueError):
        eng.serve(reqs, failures=[(0.01, 99)])
    # ...but the same id is fine when the schedule grows the pool first
    res, stats = eng.serve(reqs, failures=[(0.03, 5)],
                           resizes=[(0.01, 2, 6)])
    assert stats.completed == len(reqs) and stats.failures == 1


# ---------------------------------------------- legacy bitwise parity
def _stats_equal(a, b) -> bool:
    da, db = dataclasses.asdict(a), dataclasses.asdict(b)
    # the audit trail differs only in event *values* when the two runs
    # were fed different-but-equivalent inputs; here we require full
    # equality (the shim builds identical typed events)
    return _nan_eq(da, db)


def _nan_eq(a, b) -> bool:
    if isinstance(a, float) and isinstance(b, float):
        return (math.isnan(a) and math.isnan(b)) or a == b
    if isinstance(a, dict) and isinstance(b, dict):
        return (a.keys() == b.keys()
                and all(_nan_eq(a[k], b[k]) for k in a))
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return (len(a) == len(b)
                and all(_nan_eq(x, y) for x, y in zip(a, b)))
    return a == b


PARITY_GRID = [
    # (failures, resizes) legacy kwargs and their event equivalents
    ([(0.015, 1)], []),
    ([], [(0.015, 3, 6)]),
    ([(0.01, 1)], [(0.02, 3, 5)]),
    ([(0.02, 2)], [(0.01, 1, 2)]),
    ([(0.015, 0), (0.03, 2)], [(0.02, 2, 6), (0.04, 1, 3)]),
    ([(0.02, 1)], [(0.02, 3, 5)]),          # tie: failure fires first
]


@pytest.mark.parametrize("failures,resizes", PARITY_GRID)
def test_legacy_kwargs_bitwise_equal_scenario_events(failures, resizes):
    """Acceptance: the same sequence expressed through legacy kwargs
    and through typed events scores bitwise-identically — results,
    latencies, and the entire ClusterStats including the audit trail."""
    spec = _spec(events=legacy_events(failures, resizes))
    reqs = _legacy_requests(spec)
    cc = spec.topology.cluster_config(seed=spec.workload.seed)

    legacy = ClusterEngine(MODEL, PARAMS, cc)
    res_l, st_l = legacy.serve(_legacy_requests(spec),
                               failures=failures, resizes=resizes)
    typed = ClusterEngine(MODEL, PARAMS, cc)
    res_t, st_t = typed.serve(reqs, events=spec.events)
    assert _stats_equal(st_l, st_t)
    for a, b in zip(res_l, res_t):
        assert a.rid == b.rid and a.latency == b.latency
        assert np.array_equal(a.outputs, b.outputs)

    # and through the declarative front door (stream rebuilt from the
    # spec's workload — must reproduce dlrm_request_stream exactly)
    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    assert _stats_equal(st_l, rep.stats)
    for a, b in zip(res_l, rep.results):
        assert a.rid == b.rid and a.latency == b.latency
        assert np.array_equal(a.outputs, b.outputs)


def test_report_bitwise_equal_helper():
    """The shared parity predicate the benches/examples assert."""
    clean = run_scenario(_spec(), model=MODEL, params=PARAMS)
    evd = run_scenario(_spec(events=[FailMN(0.015, mn=1)]),
                       model=MODEL, params=PARAMS)
    assert evd.bitwise_equal(clean) and clean.bitwise_equal(evd)
    other = run_scenario(
        _spec(events=[ReloadParams(0.01, seed=9)]),
        model=MODEL, params=PARAMS)        # weights changed mid-stream
    assert not other.bitwise_equal(clean)


def test_plan_workload_single_phase_matches_request_stream():
    spec = _spec(workload=_workload(requests=9, alpha=1.05, seed=5))
    reqs, phases = plan_workload(spec, CFG)
    want = _legacy_requests(spec)
    assert len(phases) == 1 and phases[0].requests == 9
    assert len(reqs) == len(want)
    for a, b in zip(reqs, want):
        assert a.rid == b.rid and a.size == b.size
        assert a.arrival == b.arrival
        assert np.array_equal(a.payload["dense"], b.payload["dense"])
        assert np.array_equal(a.payload["indices"], b.payload["indices"])


# ------------------------------------------- timeline ordering property
def _run_events(events):
    spec = _spec(events=events, workload=_workload(requests=10, seed=3))
    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    key = [(dataclasses.asdict(r.event) | {"kind": r.event.kind},
            r.n_cn, r.m_mn, r.dead, r.applied) for r in rep.stats.events]
    scores = np.concatenate([r.outputs for r in rep.results])
    return key, scores, rep.stats


_EVENT_POOL = [
    FailMN(0.008, mn=1), RecoverMN(0.017, mn=1), Resize(0.012, n_cn=3),
    Resize(0.022, m_mn=5), ReplanPlacement(0.027), FailMN(0.031, mn=2),
    SetWorkload(0.014, alpha=1.05), RecoverMN(0.036, mn=2),
]


@settings(max_examples=10, deadline=None)
@given(mask=st.integers(1, 2 ** len(_EVENT_POOL) - 1),
       seed=st.integers(0, 999))
def test_shuffled_events_execute_identically(mask, seed):
    """Property: a shuffled event list executes identically to the
    pre-sorted one — the dispatcher, not the caller, owns time order."""
    chosen = [e for i, e in enumerate(_EVENT_POOL) if mask >> i & 1]
    shuffled = list(chosen)
    random.Random(seed).shuffle(shuffled)
    key_a, scores_a, _ = _run_events(sc.sort_events(chosen))
    key_b, scores_b, _ = _run_events(shuffled)
    assert key_a == key_b
    assert np.array_equal(scores_a, scores_b)


def test_shuffled_events_execute_identically_pinned():
    shuffled = [_EVENT_POOL[i] for i in (5, 0, 7, 2, 4, 1, 6, 3)]
    key_a, scores_a, st_a = _run_events(sc.sort_events(_EVENT_POOL))
    key_b, scores_b, st_b = _run_events(shuffled)
    assert key_a == key_b
    assert np.array_equal(scores_a, scores_b)
    assert st_a.failures == st_b.failures == 2
    assert st_a.recoveries == st_b.recoveries == 2


# -------------------------------------- timed recovery + audit trail
def test_failure_recovery_resize_chain_bitwise_and_audited():
    """The chain no legacy kwarg can express: fail -> timed recover ->
    resize, scores bitwise-identical to the event-free run, and every
    step in the audit trail with its real fire timestamp and resulting
    pool shape."""
    events = (FailMN(0.01, mn=1), RecoverMN(0.022, mn=1),
              Resize(0.034, n_cn=3, m_mn=6))
    spec = _spec(events=events)
    clean = run_scenario(_spec(), model=MODEL, params=PARAMS)
    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    assert rep.completed == rep.total
    want = {r.rid: r.outputs for r in clean.results}
    for r in rep.results:
        assert np.array_equal(r.outputs, want[r.rid])

    recs = rep.stats.events
    assert [r.event for r in recs] == list(events)
    assert [r.time_s for r in recs] == [0.01, 0.022, 0.034]
    # recoveries appear with real timestamps, not untimed method calls
    rec = recs[1]
    assert isinstance(rec.event, RecoverMN) and rec.applied
    assert rec.time_s == 0.022 and rec.dead == ()
    assert recs[0].dead == (1,)
    assert (recs[2].n_cn, recs[2].m_mn) == (3, 6)
    assert rep.stats.recoveries == 1 and rep.stats.resizes == 1
    assert (rep.final_n_cn, rep.final_m_mn) == (3, 6)


def test_mid_stage_failure_defers_to_earlier_recovery():
    """A failure whose timestamp lands inside a batch's MN stage must
    NOT jump ahead of an earlier-timed recovery of the same MN queued
    before it — both apply at the boundary in true time order, so the
    MN ends dead (recover@t1 then fail@t2), not alive, and the audit
    trail stays time-sorted.  The MN stage is microseconds wide at real
    bandwidths, so the engine's scan bandwidth is throttled to stretch
    the window across both timestamps."""
    eng = ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=8, n_replicas=2))
    eng.fail_mn(1)                       # dead before the stream starts
    eng.mn_bw = [1.0] * eng.m_mn         # stretch the MN stage window
    reqs = _legacy_requests(_spec())
    res, stats = eng.serve(reqs, events=[RecoverMN(0.01, mn=1),
                                         FailMN(0.02, mn=1)])
    assert stats.completed == len(reqs)
    assert 1 in eng.dead                 # time order: recover, THEN fail
    assert stats.recoveries == 1 and stats.failures == 2
    times = [r.time_s for r in stats.events]
    assert times == sorted(times)


def test_mid_stage_failure_waits_for_pending_grow():
    """A failure whose target MN is created by an earlier-timed grow in
    the same MN-stage window must defer to the boundary (where the grow
    applies first) instead of firing early against the un-grown pool
    and silently no-opping — the schedule-aware validation promised the
    event would land."""
    eng = ClusterEngine(MODEL, PARAMS, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=8, n_replicas=2))
    eng.mn_bw = [1.0] * eng.m_mn         # stretch the MN stage window
    reqs = _legacy_requests(_spec())
    res, stats = eng.serve(reqs, events=[Resize(0.01, m_mn=6),
                                         FailMN(0.02, mn=5)])
    assert stats.completed == len(reqs)
    assert stats.resizes == 1 and stats.failures == 1
    assert 5 in eng.dead                 # the promised failure landed
    times = [r.time_s for r in stats.events]
    assert times == sorted(times)
    assert all(r.applied for r in stats.events)


def test_recovery_no_op_recorded_not_applied():
    spec = _spec(events=[RecoverMN(0.01, mn=2)])     # never failed
    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    recs = rep.stats.events
    assert len(recs) == 1 and not recs[0].applied
    assert rep.stats.recoveries == 0


def test_failure_for_shrunk_away_mn_recorded_as_noop():
    spec = _spec(events=[Resize(0.008, m_mn=2), FailMN(0.02, mn=3)])
    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    assert rep.completed == rep.total
    assert rep.stats.failures == 0
    fail_rec = [r for r in rep.stats.events
                if isinstance(r.event, FailMN)][0]
    assert not fail_rec.applied and fail_rec.m_mn == 2


def test_reload_params_event_reloads_and_flushes():
    spec = _spec(events=[ReloadParams(0.02, seed=9)],
                 topology=smoke_topology(batch_size=8, cache_mb=0.01))
    clean = run_scenario(_spec(), model=MODEL, params=PARAMS)
    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    assert rep.completed == rep.total
    # weights changed mid-stream: later queries score differently
    want = {r.rid: r.outputs for r in clean.results}
    assert any(not np.array_equal(r.outputs, want[r.rid])
               for r in rep.results)
    assert any(isinstance(r.event, ReloadParams) and r.applied
               for r in rep.stats.events)


# ------------------------------------------------ SetWorkload phases
def test_set_workload_phases_change_stream_and_report():
    spec = _spec(
        workload=_workload(requests=12, alpha=0.0, seed=4),
        events=[SetWorkload(0.016, alpha=1.3),
                SetWorkload(0.032, gap_s=0.002, mean_size=6.0)])
    reqs, phases = plan_workload(spec, CFG)
    assert [p.index for p in phases] == [0, 1, 2]
    assert [p.alpha for p in phases] == [0.0, 1.3, 1.3]
    assert phases[2].gap_s == 0.002 and phases[2].mean_size == 6.0
    assert sum(p.requests for p in phases) == 12
    assert all(p.requests > 0 for p in phases)
    # arrivals respect each phase's gap
    a = [r.arrival for r in reqs]
    assert a == sorted(a)
    assert a[phases[2].rid_start + 1] - a[phases[2].rid_start] \
        == pytest.approx(0.002)
    # skew actually moved: the Zipf phase concentrates on low row ids
    ph0 = np.concatenate([reqs[i].payload["indices"].ravel()
                          for i in range(phases[0].rid_start,
                                         phases[0].rid_end)])
    ph1 = np.concatenate([reqs[i].payload["indices"].ravel()
                          for i in range(phases[1].rid_start,
                                         phases[1].rid_end)])
    assert np.median(ph1[ph1 >= 0]) < np.median(ph0[ph0 >= 0])

    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    assert len(rep.phases) == 3
    assert [p.requests for p in rep.phases] == [p.requests for p in phases]
    assert sum(p.completed for p in rep.phases) == rep.completed


def test_set_workload_at_t0_overrides_base():
    spec = _spec(workload=_workload(requests=6, alpha=0.0),
                 events=[SetWorkload(0.0, alpha=1.2)])
    _, phases = plan_workload(spec, CFG)
    assert phases[0].requests == 0          # base phase never sampled
    assert phases[1].alpha == 1.2 and phases[1].requests == 6


# --------------------------------------------------- presets + lint CLI
@pytest.mark.parametrize("name", sorted(sc.PRESETS))
def test_preset_json_files_match_builders(name):
    """examples/scenarios/*.json are the serialized preset builders —
    one source of truth, pinned here."""
    spec = preset(name)
    spec.validate()
    root = pathlib.Path(__file__).resolve().parent.parent
    disk = ScenarioSpec.load(str(root / "examples" / "scenarios"
                                 / f"{name}.json"))
    assert disk == spec
    assert ScenarioSpec.from_json(spec.to_json()) == spec


def test_preset_unknown_name():
    with pytest.raises(KeyError):
        preset("nope")


def test_scenario_lint_cli(tmp_path, capsys):
    p = tmp_path / "s.json"
    spec = _spec(events=[FailMN(0.01, mn=1)], name="lint-me")
    spec.save(str(p))
    assert sc.main([str(p)]) == 0
    out = capsys.readouterr().out
    assert "lint-me" in out and "ok" in out
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"name": "x",
                               "events": [{"type": "nope", "time_s": 1}]}))
    with pytest.raises(ValueError):
        sc.main([str(bad)])


def test_scenario_run_cli_builds_model_from_spec(tmp_path, capsys):
    """`python -m repro.serving.scenario --run file.json` end-to-end:
    the spec's model section (arch/reduced/init_seed) builds the DLRM
    when run_scenario isn't handed one."""
    spec = ScenarioSpec(
        name="cli-run",
        topology=smoke_topology(batch_size=8),
        workload=Workload(requests=6, mean_size=4.0, max_size=8,
                          gap_s=0.004, seed=1),
        events=(FailMN(0.008, mn=0),))
    p = tmp_path / "r.json"
    spec.save(str(p))
    assert sc.main([str(p), "--run"]) == 0
    out = capsys.readouterr().out
    assert "cli-run" in out and "6/6" in out


def test_scenario_write_presets_cli(tmp_path):
    assert sc.main(["--write-presets", str(tmp_path)]) == 0
    for name in sc.PRESETS:
        assert ScenarioSpec.load(str(tmp_path / f"{name}.json")) \
            == preset(name)


def test_run_scenario_front_door_smoke():
    """Acceptance: a spec containing {fail, recover, resize,
    set-workload} events round-trips through JSON and runs via
    run_scenario on the reduced model."""
    spec = ScenarioSpec(
        name="acceptance",
        topology=smoke_topology(batch_size=8),
        workload=_workload(requests=10, seed=2),
        events=(FailMN(0.008, mn=1), RecoverMN(0.016, mn=1),
                Resize(0.024, n_cn=3, m_mn=5),
                SetWorkload(0.02, alpha=1.05)),
    )
    rt = ScenarioSpec.from_json(spec.to_json())
    assert rt == spec
    rep = run_scenario(rt, model=MODEL, params=PARAMS)
    assert rep.completed == rep.total == 10
    assert rep.stats.failures == 1 and rep.stats.recoveries == 1
    assert rep.stats.resizes == 1
    assert len(rep.phases) == 2
    assert {r.event.kind for r in rep.stats.events} == {
        "fail_mn", "recover_mn", "resize", "set_workload"}
    d = rep.to_dict()
    json.dumps(d)                       # report is JSON-able
    assert d["final_pool"] == {"n_cn": 3, "m_mn": 5,
                               "mn_types": ["ddr_mn"] * 5}
    # audit events keep their type discriminator in the JSON report
    assert [e["event"]["type"] for e in d["events"]] == [
        "fail_mn", "recover_mn", "set_workload", "resize"]
    assert rep.summary()


# ---------------------------------- events under pipelined overlap (#6)
def _burst_spec(depth, events=(), requests=24, seed=5):
    return _spec(
        events=events,
        topology=smoke_topology(batch_size=8, inflight_depth=depth,
                                max_wait_s=2e-5),
        workload=_workload(requests=requests, gap_s=0.0, seed=seed))


def test_topology_inflight_depth_serde_and_validation():
    spec = _burst_spec(4)
    assert spec.topology.inflight_depth == 4
    rt = ScenarioSpec.from_json(spec.to_json())
    assert rt == spec and rt.topology.inflight_depth == 4
    assert spec.topology.cluster_config().inflight_depth == 4
    with pytest.raises(ValueError):
        dataclasses.replace(
            spec, topology=dataclasses.replace(
                spec.topology, inflight_depth=0)).validate()
    with pytest.raises(ValueError):
        dataclasses.replace(
            spec, topology=dataclasses.replace(
                spec.topology, inflight_depth=2.5)).validate()


def test_topology_cn_router_serde_and_validation():
    spec = _burst_spec(2)
    assert spec.topology.cn_router == "cpu_free"
    routed = dataclasses.replace(
        spec, topology=dataclasses.replace(
            spec.topology, cn_router="pipeline_free"))
    rt = ScenarioSpec.from_json(routed.to_json())
    assert rt == routed and rt.topology.cn_router == "pipeline_free"
    assert routed.topology.cluster_config().cn_router == "pipeline_free"
    with pytest.raises(ValueError):
        dataclasses.replace(
            spec, topology=dataclasses.replace(
                spec.topology, cn_router="fastest")).validate()


@pytest.mark.parametrize("events", [
    (FailMN(2e-6, mn=1),),
    (FailMN(2e-6, mn=2), RecoverMN(1e-4, mn=2)),
    (Resize(3e-6, n_cn=3, m_mn=6),),
    (ReloadParams(5e-6, seed=9),),
], ids=["fail", "fail+recover", "resize", "reload"])
def test_events_with_batches_in_flight_deterministic(events):
    """An event firing while k>1 batches are in flight drains or
    re-issues deterministically: two identical runs agree bitwise on
    scores, latencies, and the full audit trail."""
    a = run_scenario(_burst_spec(4, events), model=MODEL, params=PARAMS)
    b = run_scenario(_burst_spec(4, events), model=MODEL, params=PARAMS)
    assert a.completed == a.total
    assert _stats_equal(a.stats, b.stats)
    for x, y in zip(a.results, b.results):
        assert x.rid == y.rid and x.latency == y.latency
        assert np.array_equal(x.outputs, y.outputs)


@pytest.mark.parametrize("events", [
    (FailMN(2e-6, mn=1),),
    (FailMN(2e-6, mn=2), RecoverMN(1e-4, mn=2),
     Resize(2e-4, n_cn=3, m_mn=6)),
], ids=["fail", "chain"])
def test_events_under_overlap_scores_match_depth1(events):
    """Routing reacts to the event at the same stream position at every
    depth, so scores stay bitwise-identical to the sequential clock
    even when the event lands among k>1 in-flight batches."""
    d1 = run_scenario(_burst_spec(1, events), model=MODEL, params=PARAMS)
    d4 = run_scenario(_burst_spec(4, events), model=MODEL, params=PARAMS)
    want = {r.rid: r.outputs for r in d1.results}
    for r in d4.results:
        assert np.array_equal(r.outputs, want[r.rid])


def test_audit_trail_ordering_under_overlap():
    """Fire times in the audit trail stay sorted against resource time
    with k>1 batches in flight, and every record keeps its declared
    event timestamp."""
    events = (FailMN(2e-6, mn=1), RecoverMN(1e-4, mn=1),
              Resize(2e-4, m_mn=6))
    rep = run_scenario(_burst_spec(4, events), model=MODEL, params=PARAMS)
    recs = rep.stats.events
    assert [r.event for r in recs] == list(events)
    times = [r.time_s for r in recs]
    assert times == sorted(times)
    assert times == [e.time_s for e in events]
    assert rep.stats.failures == 1 and rep.stats.recoveries == 1


# ------------------------------ out-of-order completion stamping (#6)
def test_split_query_latency_is_last_part_done():
    """Issue #6 satellite: a query split across batches completes when
    its LAST part's dense stage finishes — under pipelined overlap the
    batch that zeroes its remaining rows need not finish last, so the
    old 'stamp at the zeroing batch' rule underestimated latency."""
    rep = run_scenario(_burst_spec(4, requests=24, seed=5),
                       model=MODEL, params=PARAMS)
    eng = rep.engine
    # recompute each query's completion from the booked trace
    done_by_qid = {}
    for t in eng.last_trace:
        for qid in t.qids:
            done_by_qid[qid] = max(done_by_qid.get(qid, 0.0), t.done)
    arrivals = {r.rid: 0.0 for r in rep.results}   # backlogged burst
    for r in rep.results:
        assert r.latency == done_by_qid[r.rid] - arrivals[r.rid]
    # at least one query genuinely spanned multiple batches (else the
    # regression tests nothing)
    spans = [qid for qid, n in
             ((q, sum(q in t.qids for t in eng.last_trace))
              for q in done_by_qid) if n > 1]
    assert spans, "stream produced no split query; pick a new seed"


def test_out_of_order_completion_report_consistent():
    """ScenarioReport per-phase accounting keys on rid ranges, not
    completion order: totals reconcile when batches complete out of
    submission order."""
    spec = _burst_spec(4, events=(SetWorkload(1e-5, alpha=1.05),),
                       requests=24, seed=5)
    rep = run_scenario(spec, model=MODEL, params=PARAMS)
    assert rep.completed == rep.total == 24
    assert sum(p.completed for p in rep.phases) == rep.completed
    assert sum(p.requests for p in rep.phases) == rep.total
    lats = sorted(r.latency for r in rep.results)
    assert rep.stats.mean_latency == pytest.approx(float(np.mean(lats)))
