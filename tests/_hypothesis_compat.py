"""Optional-hypothesis shim: property tests skip cleanly on bare envs.

`from tests._hypothesis_compat import given, settings, st` gives the real
hypothesis decorators when the package is installed; otherwise stand-ins
that mark each property test skipped while every plain test in the module
keeps running (a bare `pytest.importorskip` would skip the whole module).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # bare environment
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Any `st.<name>(...)` resolves to an inert placeholder."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
