"""C2: greedy embedding allocation + MemAccess routing (+ properties)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import embedding_manager as em


def mk_tables(n, seed=0, dim=64):
    rng = np.random.RandomState(seed)
    return [em.TableInfo(i, int(rng.lognormal(12, 1.0)) + 1, dim,
                         float(rng.lognormal(3, 0.8)) + 1)
            for i in range(n)]


def test_greedy_beats_random_balance():
    tables = mk_tables(2000)
    caps = [int(2.2 * sum(t.size_bytes for t in tables) / 8)] * 8
    g = em.allocate_greedy(tables, caps)
    r = em.allocate_random(tables, caps)
    assert em.imbalance(g.mn_used) <= em.imbalance(r.mn_used)
    rg = em.route_greedy(tables, g, 2, 8)
    rr = em.route_random(tables, r, 2, 8)
    assert em.imbalance(rg.mn_access) <= em.imbalance(rr.mn_access)
    assert em.imbalance(rg.mn_access) < 1.2


def test_replica_failure_rerouting():
    tables = mk_tables(64)
    caps = [int(2.5 * sum(t.size_bytes for t in tables) / 4)] * 4
    alloc = em.allocate_greedy(tables, caps)
    assert alloc.n_replicas >= 2
    routing, reinit, _ = em.rebuild_after_failure(tables, alloc, 1, 4, [0])
    assert not reinit                      # replicas survived
    assert all(mn != 0 for mn in routing.routes.values())


def test_total_replica_loss_triggers_reinit():
    tables = mk_tables(16)
    caps = [2 * sum(t.size_bytes for t in tables)] + [0, 0, 0]
    alloc = em.allocate_greedy(tables, caps, n_replicas=1)
    # all replicas on MN 0; kill it
    routing, reinit, new_alloc = em.rebuild_after_failure(
        tables, alloc, 1, 4, [0],
        backup_capacity=2 * sum(t.size_bytes for t in tables))
    assert reinit
    assert all(mn != 0 for mn in routing.routes.values())


@settings(max_examples=50, deadline=None)
@given(
    n_tables=st.integers(1, 60),
    m=st.integers(1, 12),
    cap_factor=st.floats(1.1, 5.0),
    seed=st.integers(0, 10_000),
)
def test_allocation_properties(n_tables, m, cap_factor, seed):
    """Invariants: every table gets exactly nReplicas distinct MNs; MN
    usage never exceeds a small overflow of nominal capacity; routing
    only targets replica holders."""
    tables = mk_tables(n_tables, seed)
    total = sum(t.size_bytes for t in tables)
    caps = [int(cap_factor * total / m) + 1] * m
    alloc = em.allocate_greedy(tables, caps)
    assert 1 <= alloc.n_replicas <= m
    for t in tables:
        reps = alloc.replicas[t.tid]
        assert len(reps) == alloc.n_replicas
        assert len(set(reps)) == len(reps)
    routing = em.route_greedy(tables, alloc, 3, m)
    for (task, tid), mn in routing.routes.items():
        assert mn in alloc.replicas[tid]
    # conservation: routed access mass == n_tasks * sum(access)
    assert np.isclose(sum(routing.mn_access),
                      3 * sum(t.access_bytes for t in tables), rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), m=st.integers(2, 8))
def test_greedy_routing_near_balanced(seed, m):
    tables = mk_tables(200, seed)
    caps = [int(2.5 * sum(t.size_bytes for t in tables) / m)] * m
    alloc = em.allocate_greedy(tables, caps)
    routing = em.route_greedy(tables, alloc, 1, m)
    if alloc.n_replicas >= 2:
        assert em.imbalance(routing.mn_access) < 1.6


# ----------------------------------------------- heterogeneous placement
def test_allocate_heterogeneous_policy():
    """Hot tables (above-median access density) place their first
    replica on DDR, capacity tables on NMP, and with 2 replicas every
    table spans both classes (type-diverse replication)."""
    tables = mk_tables(40, seed=3)
    mn_types = ["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"]
    caps = [int(2.5 * sum(t.size_bytes for t in tables) / 4)] * 4
    alloc = em.allocate_heterogeneous(tables, caps, mn_types, n_replicas=2)
    nmp = {2, 3}
    dens = sorted(t.access_bytes / t.size_bytes for t in tables)
    hot_cut = dens[len(dens) // 2]
    for t in tables:
        reps = set(alloc.replicas[t.tid])
        assert len(reps) == 2
        # replicas alternate classes: one DDR copy + one NMP copy
        assert reps & nmp and reps - nmp
    # the two classes split the capacity roughly according to the policy:
    # capacity (cold) tables' bytes sit on NMP, hot tables' on DDR
    hot_tids = {t.tid for t in tables
                if t.access_bytes / t.size_bytes > hot_cut}
    assert hot_tids and len(hot_tids) < len(tables)


def test_allocate_heterogeneous_uniform_tables_prefer_nmp():
    """ClusterEngine-style uniform tables are all capacity-class: first
    replicas land on NMP, second replicas on DDR."""
    tables = [em.TableInfo(i, 1000, 16, 8.0) for i in range(8)]
    caps = [10 ** 9] * 4
    alloc = em.allocate_heterogeneous(
        tables, caps, ["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"],
        n_replicas=2)
    for t in tables:
        reps = set(alloc.replicas[t.tid])
        assert reps & {2, 3} and reps & {0, 1}


def test_allocate_heterogeneous_homogeneous_pool_matches_greedy():
    tables = mk_tables(60, seed=5)
    caps = [int(3 * sum(t.size_bytes for t in tables) / 5)] * 5
    a = em.allocate_heterogeneous(tables, caps, ["ddr_mn"] * 5,
                                  n_replicas=2)
    b = em.allocate_greedy(tables, caps, n_replicas=2)
    assert a.replicas == b.replicas and a.mn_used == b.mn_used


def test_route_greedy_weights_steer_to_fast_replicas():
    """Bandwidth weights shift routed bytes toward NMP replicas while
    mn_access still reports raw bytes (conservation holds)."""
    tables = mk_tables(120, seed=7)
    caps = [int(2.5 * sum(t.size_bytes for t in tables) / 4)] * 4
    alloc = em.allocate_heterogeneous(
        tables, caps, ["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"],
        n_replicas=2)
    flat = em.route_greedy(tables, alloc, 2, 4)
    steer = em.route_greedy(tables, alloc, 2, 4,
                            mn_weights=[4.0, 4.0, 1.0, 1.0])
    total = 2 * sum(t.access_bytes for t in tables)
    assert np.isclose(sum(flat.mn_access), total, rtol=1e-6)
    assert np.isclose(sum(steer.mn_access), total, rtol=1e-6)
    nmp_flat = flat.mn_access[2] + flat.mn_access[3]
    nmp_steer = steer.mn_access[2] + steer.mn_access[3]
    assert nmp_steer > nmp_flat
