"""Per-arch reduced-config smoke tests: forward/train step on CPU,
asserting output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry


def make_batch(cfg, model, B=2, S=32, seed=0):
    rng = np.random.RandomState(seed)
    b = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.randn(B, cfg.encdec.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        b["images"] = jnp.asarray(
            rng.randn(B, cfg.vlm.num_patches, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return b


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get_reduced(arch)
    model = registry.build(cfg)
    params = model.init(0)
    batch = make_batch(cfg, model)
    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # one optimizer step moves the loss
    from repro.train.optimizer import OptConfig
    from repro.train.train_loop import make_train_step
    step = jax.jit(make_train_step(model, OptConfig(lr=1e-2)))
    from repro.train import optimizer as opt_mod
    state = opt_mod.init_state(OptConfig(lr=1e-2), params)
    p2, s2, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    loss2 = float(jax.jit(model.loss)(p2, batch))
    assert loss2 < float(loss)


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS)
def test_serve_smoke(arch):
    cfg = configs.get_reduced(arch)
    model = registry.build(cfg)
    params = model.init(0)
    batch = make_batch(cfg, model)
    pf_batch = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=48))(params, pf_batch)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    l2, cache = jax.jit(model.decode_step)(
        params, cache, {"tokens": jnp.zeros((2, 1), jnp.int32)})
    assert np.isfinite(np.asarray(l2, np.float32)).all()
    prompt = 32 + (cfg.vlm.num_patches if cfg.family == "vlm" else 0)
    assert int(cache["pos"]) == prompt  # advanced past the prompt


def test_dlrm_smoke():
    from repro.data.queries import dlrm_batch
    cfg = configs.get_reduced("rm1")
    model = registry.build(cfg)
    params = model.init(0)
    rng = np.random.RandomState(0)
    batch = jax.tree.map(jnp.asarray, dlrm_batch(cfg, 16, rng))
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    scores = jax.jit(model.serve_step)(params, batch)
    assert scores.shape == (16,)
    assert ((np.asarray(scores) >= 0) & (np.asarray(scores) <= 1)).all()
