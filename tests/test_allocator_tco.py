"""C4/C5: failure-aware allocation (Eq. 1-3) + TCO accounting."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import rm1, rm2
from repro.core import allocator, hardware as hw, tco
from repro.core.serving_unit import ServingUnitModel, UnitSpec


def test_eq2_failure_margin_monotone():
    u = UnitSpec(3, "cn_1g", 8, "ddr_mn")
    base = allocator.allocate(u, 1000.0, u.power(), 50_000.0)
    worse = allocator.allocate(u, 1000.0, u.power(), 50_000.0,
                               f_cn=0.5, f_mn=0.1)
    assert worse.n_peak >= base.n_peak
    assert worse.failure_units > base.failure_units


def test_diurnal_allocation_covers_load():
    u = UnitSpec(3, "cn_1g", 8, "ddr_mn")
    plan = allocator.allocate(u, 1000.0, u.power(), 50_000.0)
    loads = allocator.diurnal_load(50_000.0)
    for n, L in zip(plan.n_units, loads):
        assert n * plan.qps_per_unit >= L        # constraint (2), R%>=0


def test_mn_failure_rate_lowers_overprovision():
    """Disagg exploits MN reliability: same node count, lower margin."""
    mono = UnitSpec(11, "so1s_1g", scheme="distributed")
    disagg = UnitSpec(3, "cn_1g", 8, "ddr_mn")
    pm = allocator.allocate(mono, 1000.0, mono.power(), 50_000.0)
    pd = allocator.allocate(disagg, 1000.0, disagg.power(), 50_000.0)
    assert pd.failure_units < pm.failure_units


def test_monolithic_margin_counts_both_part_failures():
    """Eq. 2 for a monolithic server: it is lost when EITHER its compute
    or its memory fails, so the margin rate is f_cn + f_mn — not f_cn."""
    mono = UnitSpec(8, "so1s_1g", scheme="distributed")
    p = allocator.allocate(mono, 1000.0, mono.power(), 50_000.0)
    want = (hw.FAIL_CN + hw.FAIL_MN) * 50_000.0 / 1000.0
    assert p.failure_units == pytest.approx(want)
    # and the margin responds to the memory failure rate
    worse = allocator.allocate(mono, 1000.0, mono.power(), 50_000.0,
                               f_mn=0.1)
    assert worse.failure_units > p.failure_units


def test_capacity_model_matches_paper_claims():
    """Fig. 4/12/14 structural claims."""
    m = rm1.generation(0)
    naive = ServingUnitModel(m, UnitSpec(1, "su2s", scheme="su_naive"))
    aware = ServingUnitModel(m, UnitSpec(1, "su2s", scheme="su_numa"))
    # NUMA-aware cuts SparseNet time by >50% (paper: >60% incl. queueing)
    r = (naive.stage_times(128).t_sparse / aware.stage_times(128).t_sparse)
    assert r > 2.0
    # NUMA-aware comm overhead < 8% of query time (paper Fig. 4)
    st = aware.stage_times(128)
    assert (st.t_comm_in + st.t_comm_out) / st.total() < 0.15

    # {3 CN, 8 MN} within a few % of 8 monolithic SO-1S (paper: -2%)
    so8 = ServingUnitModel(m, UnitSpec(8, "so1s_1g", scheme="distributed"))
    dis = ServingUnitModel(m, UnitSpec(3, "cn_1g", 8, "ddr_mn"))
    q1, _ = so8.latency_bounded_qps(sla=0.1)
    q2, _ = dis.latency_bounded_qps(sla=0.1)
    assert abs(q1 - q2) / q1 < 0.05

    # NMP-DIMMs raise RM1 throughput ~3-4x on SO-1S (paper: up to 3.64x)
    ddr1 = ServingUnitModel(m, UnitSpec(1, "so1s_1g", scheme="distributed"))
    nmp1 = ServingUnitModel(m, UnitSpec(1, "so1s_1g_nmp",
                                        scheme="distributed"))
    assert 2.5 < nmp1.peak_qps() / ddr1.peak_qps() < 4.5


def test_disagg_tco_saving_rm1():
    """Headline claim: disaggregation cuts TCO vs monolithic (paper: up
    to 49.3% for RM1)."""
    m = rm1.generation(0)
    best_m, _ = allocator.best_unit(m, tco.monolithic_candidates(), 2e5)
    best_d, _ = allocator.best_unit(m, tco.disagg_candidates(), 2e5)
    saving = 1 - best_d.tco / best_m.tco
    assert saving > 0.30


def test_memory_capacity_gate():
    big = rm1.generation(5)                    # 7.8 TB
    sm = ServingUnitModel(big, UnitSpec(1, "su2s", scheme="su_numa"))
    assert not sm.fits()
    sm = ServingUnitModel(big, UnitSpec(2, "cn_1g", 9, "ddr_mn"))
    assert sm.fits()


@settings(max_examples=40, deadline=None)
@given(load=st.floats(1e3, 1e6), qps=st.floats(100.0, 1e5))
def test_allocation_scales_linearly_in_load(load, qps):
    u = UnitSpec(3, "cn_1g", 8, "ddr_mn")
    p1 = allocator.allocate(u, qps, u.power(), load)
    p2 = allocator.allocate(u, qps, u.power(), 2 * load)
    assert p2.n_peak >= p1.n_peak
    assert p2.tco >= p1.tco
    assert p1.n_peak >= math.ceil((1 + hw.LOAD_VARIANCE_R) * load / qps)


def test_idleness_breakdown_fig11():
    m = rm1.generation(0)
    out = tco.idleness_breakdown(m, UnitSpec(8, "so1s_1g",
                                             scheme="distributed"), 2e5)
    # RM1 wastes expensive GPUs: pipeline idleness is a large TCO share
    assert 0.05 < out["pipeline_idle_tco_frac"] < 0.6
    assert 0.0 < out["overprovision_tco_frac"] < 0.2
