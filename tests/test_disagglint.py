"""disagglint battery (``repro.analysis``): fixture pairs per rule.

Every rule gets a bad fixture (must produce exactly its expected
finding(s)) and a good twin (zero findings).  Fixtures are string
literals written into tmp trees that mirror the scoped directory
structure (``<tmp>/src/repro/serving/...``) — embedding them as strings
keeps the fixtures themselves out of the repo's own lint run, and the
tokenize-based suppression parser means directives inside these strings
are inert when THIS file is linted.

The cross-module rules get deletion cases: removing any one serde tag,
``EVENT_TYPES`` entry, dispatcher arm, or ``ClusterStats``
serialization/docs entry must flip the fixture from clean to failing
(the ISSUE's acceptance criterion).

The meta-test at the bottom shells out ``python -m repro.analysis`` over
the real tree: HEAD must lint clean, with the JSON report byte-stable.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import Finding, LintResult, lint_paths, render_json
from repro.analysis.engine import parse_suppressions

REPO = Path(__file__).resolve().parents[1]


def lint(tmp_path, files, only=None):
    """Write {relpath: source} into a tmp tree and lint it whole."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return lint_paths([str(tmp_path)], root=str(tmp_path), only=only)


def rules_of(result):
    return [f.rule for f in result.findings]


# ------------------------------------------------------------ wallclock
WALLCLOCK_BAD = """
    import time

    def stamp():
        return time.time()
"""
WALLCLOCK_GOOD = """
    def stamp(now_s):
        return now_s
"""


def test_wallclock_pair(tmp_path):
    bad = lint(tmp_path, {"src/repro/util.py": WALLCLOCK_BAD})
    assert rules_of(bad) == ["wallclock"]
    assert bad.findings[0].file == "src/repro/util.py"
    good = lint(tmp_path, {"src/repro/util.py": WALLCLOCK_GOOD})
    assert good.ok and good.files_checked == 1


def test_wallclock_aliases_and_from_import(tmp_path):
    src = """
        import time as _t
        from time import perf_counter

        def f():
            return _t.monotonic() + perf_counter()
    """
    res = lint(tmp_path, {"src/repro/x.py": src})
    # the from-import and both call sites
    assert rules_of(res) == ["wallclock"] * 3


def test_wallclock_out_of_scope_is_silent(tmp_path):
    res = lint(tmp_path, {"benchmarks/common.py": WALLCLOCK_BAD})
    assert res.ok      # benchmarks measure wall time on purpose


# ----------------------------------------------------------- global-rng
def test_global_rng_pair(tmp_path):
    bad = """
        import numpy as np

        def f():
            return np.random.rand(3)
    """
    good = """
        import numpy as np

        def f(seed):
            return np.random.RandomState(seed).rand(3)
    """
    assert rules_of(lint(tmp_path, {"src/repro/a.py": bad})) \
        == ["global-rng"]
    assert lint(tmp_path, {"src/repro/a.py": good}).ok


def test_global_rng_unseeded_ctor_and_stdlib(tmp_path):
    src = """
        import numpy as np
        import random

        def f():
            a = np.random.RandomState()     # unseeded: entropy
            b = random.random()             # process-global
            c = random.Random(7)            # fine: seeded instance
            return a, b, c
    """
    res = lint(tmp_path, {"src/repro/a.py": src})
    assert rules_of(res) == ["global-rng", "global-rng"]


def test_global_rng_jax_prngkey_not_flagged(tmp_path):
    src = """
        import jax
        from jax import random

        def f(seed):
            key = jax.random.PRNGKey(seed)
            return random.uniform(random.PRNGKey(seed), (3,)), key
    """
    # `random` here is jax.random (keyed, functional), not the stdlib
    assert lint(tmp_path, {"src/repro/a.py": src}).ok


def test_global_rng_applies_to_examples(tmp_path):
    bad = """
        import numpy as np
        x = np.random.rand(4)
    """
    assert rules_of(lint(tmp_path, {"examples/demo.py": bad})) \
        == ["global-rng"]


# ------------------------------------------------------------- set-iter
def test_set_iter_pair(tmp_path):
    bad = """
        def order(names):
            dead = {3, 1, 2}
            out = []
            for j in dead:
                out.append(j)
            return out
    """
    good = """
        def order(names):
            dead = {3, 1, 2}
            return [j for j in sorted(dead)]
    """
    assert rules_of(lint(tmp_path, {"src/repro/serving/x.py": bad})) \
        == ["set-iter"]
    assert lint(tmp_path, {"src/repro/serving/x.py": good}).ok


def test_set_iter_comprehension_and_scope(tmp_path):
    bad = """
        def f(xs):
            return [x for x in set(xs)]
    """
    assert rules_of(lint(tmp_path / "a", {"src/repro/serving/y.py": bad})) \
        == ["set-iter"]
    # outside serving/ the rule is silent (order doesn't feed a clock)
    assert lint(tmp_path / "b", {"src/repro/core/y.py": bad}).ok


# ------------------------------------------------------- frozen-setattr
def test_frozen_setattr_pair(tmp_path):
    bad = """
        from dataclasses import dataclass

        @dataclass(frozen=True)
        class Spec:
            x: int

            def bump(self):
                object.__setattr__(self, "x", self.x + 1)
    """
    good = """
        from dataclasses import dataclass

        @dataclass(frozen=True)
        class Spec:
            x: int

            def __post_init__(self):
                object.__setattr__(self, "x", int(self.x))
    """
    assert rules_of(lint(tmp_path, {"src/repro/spec.py": bad})) \
        == ["frozen-setattr"]
    assert lint(tmp_path, {"src/repro/spec.py": good}).ok


# -------------------------------------------------------- registry-sync
REGISTRY_SCENARIO = """
    class ScenarioEvent:
        time_s: float

    class FailMN(ScenarioEvent):
        kind = "fail_mn"

    class Extra(ScenarioEvent):
        kind = "extra"

    EVENT_TYPES = {c.kind: c for c in (FailMN, Extra)}
"""
REGISTRY_TIMELINE = """
    class TimelineDispatcher:
        def _apply(self, ev):
            if isinstance(ev, FailMN):
                return "fail"
            elif isinstance(ev, Extra):
                return "extra"
"""


def test_registry_sync_clean(tmp_path):
    res = lint(tmp_path, {"src/repro/serving/scenario.py": REGISTRY_SCENARIO,
                          "src/repro/serving/timeline.py": REGISTRY_TIMELINE},
               only=["registry-sync"])
    assert res.ok


def test_registry_sync_missing_kind(tmp_path):
    broken = REGISTRY_SCENARIO.replace('kind = "extra"', "pass")
    res = lint(tmp_path, {"src/repro/serving/scenario.py": broken,
                          "src/repro/serving/timeline.py": REGISTRY_TIMELINE},
               only=["registry-sync"])
    assert rules_of(res) == ["registry-sync"]
    assert "kind" in res.findings[0].message


def test_registry_sync_missing_event_types_entry(tmp_path):
    broken = REGISTRY_SCENARIO.replace("(FailMN, Extra)", "(FailMN,)")
    res = lint(tmp_path, {"src/repro/serving/scenario.py": broken,
                          "src/repro/serving/timeline.py": REGISTRY_TIMELINE},
               only=["registry-sync"])
    assert rules_of(res) == ["registry-sync"]
    assert "EVENT_TYPES" in res.findings[0].message


def test_registry_sync_missing_dispatch_arm(tmp_path):
    broken = REGISTRY_TIMELINE.replace(
        """elif isinstance(ev, Extra):
                return "extra\"""", "")
    res = lint(tmp_path, {"src/repro/serving/scenario.py": REGISTRY_SCENARIO,
                          "src/repro/serving/timeline.py": broken},
               only=["registry-sync"])
    assert rules_of(res) == ["registry-sync"]
    assert "dispatch arm" in res.findings[0].message


def test_registry_sync_silent_without_anchors(tmp_path):
    # linting a tree with no ScenarioEvent at all: nothing to check
    res = lint(tmp_path, {"src/repro/other.py": "x = 1\n"},
               only=["registry-sync"])
    assert res.ok


# ---------------------------------------------------------- stats-drift
STATS_CLUSTER = """
    class ClusterStats:
        completed: int
        p95: float
"""
STATS_TIMELINE = """
    def run():
        return ClusterStats(completed=1, p95=0.0)
"""
STATS_DOCS = "| `completed` | queries | | `p95` | seconds |\n"


def _stats_tree(tmp_path, cluster=STATS_CLUSTER, timeline=STATS_TIMELINE,
                docs=STATS_DOCS):
    (tmp_path / "docs").mkdir(parents=True, exist_ok=True)
    (tmp_path / "docs" / "architecture.md").write_text(docs)
    return lint(tmp_path, {"src/repro/serving/cluster.py": cluster,
                           "src/repro/serving/timeline.py": timeline},
                only=["stats-drift"])


def test_stats_drift_clean(tmp_path):
    assert _stats_tree(tmp_path).ok


def test_stats_drift_missing_serialization_kwarg(tmp_path):
    res = _stats_tree(
        tmp_path,
        timeline=STATS_TIMELINE.replace(", p95=0.0", ""))
    assert rules_of(res) == ["stats-drift"]
    assert "p95" in res.findings[0].message


def test_stats_drift_missing_docs_entry(tmp_path):
    res = _stats_tree(tmp_path, docs="| `completed` | queries |\n")
    assert rules_of(res) == ["stats-drift"]
    assert "docs" in res.findings[0].message


# the rule generalizes over STATS_CLASSES: ModelStats (the per-model
# fleet breakdown) is held to the same serialize-and-document contract
STATS_MODEL_CLUSTER = """
    class ModelStats:
        queries: int
        p99: float

    class ClusterStats:
        completed: int
"""
STATS_MODEL_TIMELINE = """
    def run():
        ms = ModelStats(queries=1, p99=0.0)
        return ClusterStats(completed=1)
"""
STATS_MODEL_DOCS = ("| `completed` | queries |\n"
                    "| `queries` | per-model count | | `p99` | seconds |\n")


def test_stats_drift_model_stats_clean(tmp_path):
    res = _stats_tree(tmp_path, cluster=STATS_MODEL_CLUSTER,
                      timeline=STATS_MODEL_TIMELINE,
                      docs=STATS_MODEL_DOCS)
    assert res.ok


def test_stats_drift_model_stats_missing_kwarg(tmp_path):
    res = _stats_tree(tmp_path, cluster=STATS_MODEL_CLUSTER,
                      timeline=STATS_MODEL_TIMELINE.replace(
                          ", p99=0.0", ""),
                      docs=STATS_MODEL_DOCS)
    assert rules_of(res) == ["stats-drift"]
    assert "ModelStats" in res.findings[0].message
    assert "p99" in res.findings[0].message


def test_stats_drift_model_stats_missing_docs_entry(tmp_path):
    # docs cover ClusterStats.completed (and, incidentally, the word
    # "queries") but never mention p99: ModelStats is the class in drift
    res = _stats_tree(tmp_path, cluster=STATS_MODEL_CLUSTER,
                      timeline=STATS_MODEL_TIMELINE,
                      docs="| `completed` | queries |\n")
    assert rules_of(res) == ["stats-drift"]
    assert "ModelStats" in res.findings[0].message
    assert "p99" in res.findings[0].message


# ------------------------------------------------------------- cli-sync
CLI_GOOD = """
    import argparse

    class Topology:
        n_cn: int
        m_mn: int

    def build(argv):
        p = argparse.ArgumentParser()
        p.add_argument("--n-cn", type=int, default=2)
        p.add_argument("--m-mn", type=int, default=4)
        args = p.parse_args(argv)
        return Topology(n_cn=args.n_cn, m_mn=args.m_mn)
"""


def test_cli_sync_clean(tmp_path):
    assert lint(tmp_path, {"src/repro/launch/serve.py": CLI_GOOD},
                only=["cli-sync"]).ok


def test_cli_sync_dead_flag(tmp_path):
    broken = CLI_GOOD.replace(
        'p.add_argument("--m-mn", type=int, default=4)',
        'p.add_argument("--m-mn", type=int, default=4)\n'
        '        p.add_argument("--orphan", type=int, default=0)')
    res = lint(tmp_path, {"src/repro/launch/serve.py": broken},
               only=["cli-sync"])
    assert rules_of(res) == ["cli-sync"]
    assert "orphan" in res.findings[0].message


def test_cli_sync_unknown_spec_keyword(tmp_path):
    broken = CLI_GOOD.replace("m_mn=args.m_mn", "m_mns=args.m_mn")
    res = lint(tmp_path, {"src/repro/launch/serve.py": broken},
               only=["cli-sync"])
    assert rules_of(res) == ["cli-sync"]
    assert "m_mns" in res.findings[0].message


# ------------------------------------------------------- pallas-hygiene
PALLAS_GOOD = """
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[0] = x_ref[0]

    def run(x, interpret=False):
        spec = pl.BlockSpec((1, 4), lambda i: (i, 0))
        return pl.pallas_call(kernel, out_shape=None,
                              interpret=interpret)(x)
"""


def test_pallas_clean(tmp_path):
    assert lint(tmp_path, {"src/repro/kernels/k.py": PALLAS_GOOD},
                only=["pallas-hygiene"]).ok


def test_pallas_missing_interpret(tmp_path):
    broken = PALLAS_GOOD.replace(",\n                              "
                                 "interpret=interpret", "")
    res = lint(tmp_path, {"src/repro/kernels/k.py": broken},
               only=["pallas-hygiene"])
    assert rules_of(res) == ["pallas-hygiene"]
    assert "interpret" in res.findings[0].message


def test_pallas_python_branch_on_ref(tmp_path):
    broken = PALLAS_GOOD.replace(
        "o_ref[0] = x_ref[0]",
        "if x_ref[0] > 0:\n            o_ref[0] = 1")
    res = lint(tmp_path, {"src/repro/kernels/k.py": broken},
               only=["pallas-hygiene"])
    assert rules_of(res) == ["pallas-hygiene"]
    assert "pl.when" in res.findings[0].message


def test_pallas_dynamic_block_shape(tmp_path):
    broken = PALLAS_GOOD.replace("pl.BlockSpec((1, 4)",
                                 "pl.BlockSpec((pick(), 4)")
    res = lint(tmp_path, {"src/repro/kernels/k.py": broken},
               only=["pallas-hygiene"])
    assert rules_of(res) == ["pallas-hygiene"]
    assert "static" in res.findings[0].message


def test_pallas_silent_without_pallas_import(tmp_path):
    src = """
        def run(x):
            return pallas_call(x)     # not a pallas module: no import
    """
    assert lint(tmp_path, {"src/repro/kernels/k.py": src},
                only=["pallas-hygiene"]).ok


# ------------------------------------------------------------- clock-eq
def test_clock_eq_pair(tmp_path):
    bad = """
        def same(start_s, end_s):
            return start_s == end_s
    """
    good = """
        def same(start_s, end_s, tol):
            assert start_s == end_s        # declared exact-parity pin
            return abs(start_s - end_s) <= tol
    """
    assert rules_of(lint(tmp_path, {"src/repro/t.py": bad})) \
        == ["clock-eq"]
    assert lint(tmp_path, {"src/repro/t.py": good}).ok


def test_clock_eq_out_of_scope_in_tests(tmp_path):
    bad = "def f(a_s, b_s):\n    return a_s == b_s\n"
    assert lint(tmp_path, {"tests/test_x.py": bad}).ok


# --------------------------------------------------------- suppressions
def test_suppression_with_reason_suppresses(tmp_path):
    src = """
        import time

        def stamp():
            return time.time()  # disagglint: disable=wallclock -- fixture exercising the suppression path
    """
    res = lint(tmp_path, {"src/repro/u.py": src})
    assert res.ok
    assert res.suppressed == 1


def test_suppression_without_reason_is_a_finding(tmp_path):
    src = """
        import time

        def stamp():
            return time.time()  # disagglint: disable=wallclock
    """
    res = lint(tmp_path, {"src/repro/u.py": src})
    # the reasonless directive is itself flagged AND does not suppress
    assert sorted(rules_of(res)) == ["bad-suppression", "wallclock"]


def test_suppression_wrong_rule_does_not_suppress(tmp_path):
    src = """
        import time

        def stamp():
            return time.time()  # disagglint: disable=clock-eq -- wrong rule on purpose
    """
    res = lint(tmp_path, {"src/repro/u.py": src})
    assert rules_of(res) == ["wallclock"]


def test_directive_inside_string_is_inert():
    src = ('BAD = "x = 1  # disagglint: disable=wallclock"\n'
           'y = 2  # disagglint: disable=clock-eq -- a real comment\n')
    sups, problems = parse_suppressions(src)
    assert [s.line for s in sups] == [2]
    assert problems == []


# ---------------------------------------------------- engine & reporters
def test_parse_error_is_a_finding(tmp_path):
    res = lint(tmp_path, {"src/repro/broken.py": "def f(:\n"})
    assert rules_of(res) == ["parse-error"]
    assert res.exit_code() == 1


def test_json_report_is_byte_stable():
    r = LintResult(findings=[
        Finding("b.py", 2, "wallclock", "msg"),
        Finding("a.py", 9, "clock-eq", "msg"),
    ], files_checked=2)
    one, two = render_json(r), render_json(r)
    assert one == two
    doc = json.loads(one)
    # findings sorted by (file, line), keys sorted
    assert [f["file"] for f in doc["findings"]] == ["a.py", "b.py"]
    assert list(doc) == sorted(doc)
    assert doc["ok"] is False


def test_cli_json_and_exit_codes(tmp_path):
    (tmp_path / "src" / "repro").mkdir(parents=True)
    f = tmp_path / "src" / "repro" / "m.py"
    f.write_text("import time\nx = time.time()\n")
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src",
         "--root", str(tmp_path), "--format", "json"],
        cwd=tmp_path, env=env, capture_output=True, text=True)
    assert proc.returncode == 1
    doc = json.loads(proc.stdout)
    assert doc["findings"][0]["rule"] == "wallclock"
    f.write_text("x = 1\n")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src",
         "--root", str(tmp_path), "--format", "json"],
        cwd=tmp_path, env=env, capture_output=True, text=True)
    assert proc.returncode == 0
    assert json.loads(proc.stdout)["ok"] is True


# ------------------------------------------------------------ meta-test
def test_head_lints_clean():
    """Tier-1 acceptance: the repo's own tree passes its own linter —
    src, tests, benchmarks, and examples — with every suppression
    carrying a reason (reasonless ones are findings and would fail)."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "tests",
         "benchmarks", "examples", "--format", "json"],
        cwd=REPO, env=env, capture_output=True, text=True)
    doc = json.loads(proc.stdout)
    assert proc.returncode == 0, doc["findings"]
    assert doc["ok"] is True
    assert doc["files_checked"] > 50
