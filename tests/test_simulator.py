"""C3: discrete-event simulator — scheduling policy + failures."""
import numpy as np
import pytest

from repro.configs import rm1
from repro.core.scheduler import INTERLEAVED, SEQUENTIAL, Batcher, Query
from repro.core.serving_unit import ServingUnitModel, UnitSpec
from repro.serving.simulator import ClusterSim, SimConfig, _ps_schedule


def _sim(policy, **kw):
    m = rm1.generation(0)
    um = ServingUnitModel(m, UnitSpec(2, "cn_1g", 2, "ddr_mn"))
    cfg = SimConfig(policy=policy, batch_size=128, duration_s=6.0,
                    warmup_s=1.0, seed=3, **kw)
    return ClusterSim(um, cfg)


def test_sequential_beats_interleaved_latency_bounded():
    qs = _sim(SEQUENTIAL).latency_bounded_qps(sla=0.25, iters=8)
    qi = _sim(INTERLEAVED).latency_bounded_qps(sla=0.25, iters=8)
    assert qs > qi * 1.05        # paper Fig. 8(b): ~28% gain


def test_policies_similar_peak_throughput():
    qs = _sim(SEQUENTIAL).latency_bounded_qps(sla=5.0, iters=8)
    qi = _sim(INTERLEAVED).latency_bounded_qps(sla=5.0, iters=8)
    assert abs(qs - qi) / qs < 0.15   # "similar peak if ignoring latency"


def test_throughput_conservation():
    sim = _sim(SEQUENTIAL)
    st = sim.run(50.0)
    assert st.completed > 0
    assert st.throughput_qps == pytest.approx(50.0, rel=0.25)
    assert st.p95 >= st.p50


def test_failure_injection_increases_latency():
    base = _sim(SEQUENTIAL).run(100.0)
    faulty = _sim(SEQUENTIAL, inject_failures=True)
    faulty.cfg.seed = 7
    # force failures: window-scaled probability ~1 within the sim horizon
    import repro.core.failure as fm
    old_cn, old_mn = fm.hw.FAIL_CN, fm.hw.FAIL_MN
    fm.hw.FAIL_CN = 86400.0 / faulty.cfg.duration_s  # p_window -> 1
    fm.hw.FAIL_MN = 86400.0 / faulty.cfg.duration_s
    try:
        st = faulty.run(100.0)
    finally:
        fm.hw.FAIL_CN, fm.hw.FAIL_MN = old_cn, old_mn
    assert st.failures >= 1
    assert st.p95 >= base.p95   # recovery pauses surface in the tail


def test_ps_schedule_basic():
    # two equal jobs arriving together: PS finishes both at 2x service
    done = _ps_schedule(np.array([0.0, 0.0]), np.array([1.0, 1.0]))
    assert np.allclose(done, [2.0, 2.0])
    # sequential arrival: FIFO-like
    done = _ps_schedule(np.array([0.0, 10.0]), np.array([1.0, 1.0]))
    assert np.allclose(done, [1.0, 11.0])


def test_ps_overhead_slows_concurrency():
    d0 = _ps_schedule(np.array([0.0, 0.0]), np.array([1.0, 1.0]),
                      overhead=0.0)
    d1 = _ps_schedule(np.array([0.0, 0.0]), np.array([1.0, 1.0]),
                      overhead=0.5)
    assert d1.max() > d0.max()


def test_ps_concurrency_cap():
    # 8 unit jobs, cap 2: makespan == 8 (pairwise PS, no overhead)
    arr = np.zeros(8)
    work = np.ones(8)
    done = _ps_schedule(arr, work, overhead=0.0, max_concurrency=2)
    assert done.max() == pytest.approx(8.0)


def test_batcher_conservation():
    b = Batcher(batch_size=16)
    total = 0
    out = []
    for i, size in enumerate([5, 40, 3, 3, 64, 1]):
        total += size
        out += b.offer(Query(i, float(i), size), float(i))
    out += [bt for bt in [b._form(99.0)] if bt.size]
    assert sum(bt.size for bt in out) == total
    for bt in out[:-1]:
        assert bt.size == 16
