"""CN-side hot-row embedding cache (FlexEMR-style; Huang et al.).

Production embedding access streams are heavily Zipf-skewed (Gupta et
al.): a small hot set of rows absorbs most lookups.  In a disaggregated
serving unit every one of those lookups otherwise pays gather bytes over
the CN's back-end NIC (the G_S stage), so each CN carves a byte budget
out of its HBM and keeps the hot rows local.  ``RowCache`` is that
budget: a per-CN, per-table row cache keyed by ``(table id, row id)``.

Policies
--------
- ``lru``: evict the least-recently-probed row.
- ``lfu``: evict the least-frequently-probed row (ties: oldest touch).

Skew awareness: the engine feeds the cache the *measured* per-table
hotness classification (``core.embedding_manager.HotnessCounter``).
Rows of hot tables outrank rows of cold tables at eviction time — a
victim is always drawn from the lowest priority class first, and a cold
row is refused admission rather than displace a hot resident — so a cold
capacity-table scan cannot flush the hot working set.

Fleet partitioning: under multi-model serving one CN cache is shared by
every model's lookup stream, so an aggressive model could flush the
others' hot rows.  ``set_partitions`` installs a per-model byte budget
(tid -> owning model, model -> budget bytes): each admission is charged
to the owning model's partition and evicts only within it, and
``rebalance`` re-installs new budgets mid-run (shrinking partitions
shed their coldest residents immediately).  Without partitions the
cache behaves exactly as before.

Coherence: the cache stores *bitwise copies* of authoritative MN rows,
so serving a hit is numerically indistinguishable from re-fetching; what
must be protocol-correct is residency.  ``invalidate_table`` drops every
row of one table (the engine calls it for exactly the tables whose
authoritative serving copy moved under ``fail_mn`` / ``recover_mn`` /
``resize`` migration) and ``flush`` clears the cache (DLRM weight
reload).  All bookkeeping is deterministic: same probe stream, same
state — the engine's bitwise-parity and determinism suites rely on it.
"""
from __future__ import annotations

import heapq
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Set, Tuple

POLICIES = ("lru", "lfu")


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0           # rows dropped by coherence events
    rejects: int = 0                 # admissions refused (cold vs hot set)

    @property
    def probes(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.probes if self.probes else 0.0

    def absorb(self, other: "CacheStats") -> None:
        """Fold another counter set in (retiring a departed CN's cache)."""
        self.hits += other.hits
        self.misses += other.misses
        self.evictions += other.evictions
        self.invalidations += other.invalidations
        self.rejects += other.rejects


class RowCache:
    """Byte-budgeted (table, row) cache with LRU/LFU + hot-table priority.

    Entries may carry a value (the embedding row) for content-fidelity
    tests; the engine itself passes ``value=None`` because the shard
    storage already holds the authoritative bitwise rows.
    """

    def __init__(self, capacity_bytes: int, row_bytes: int,
                 policy: str = "lru"):
        if policy not in POLICIES:
            raise ValueError(f"unknown cache policy {policy!r} "
                             f"(choose from {POLICIES})")
        if row_bytes <= 0:
            raise ValueError("row_bytes must be positive")
        self.capacity_bytes = int(capacity_bytes)
        self.row_bytes = int(row_bytes)
        self.policy = policy
        self.stats = CacheStats()
        # entries: key -> value, in recency order (oldest first) for LRU
        self._entries: "OrderedDict[Tuple[int, int], object]" = OrderedDict()
        self._freq: Dict[Tuple[int, int], int] = {}       # lfu counters
        self._touch: Dict[Tuple[int, int], int] = {}      # last-touch tick
        self._heap: List[Tuple[int, int, int, Tuple[int, int]]] = []
        self._tick = 0
        self._hot: Optional[Set[int]] = None              # hot table ids
        self._n_by_pri = {0: 0, 1: 0}
        self._rows_by_table: Dict[int, int] = {}
        self._owner_of: Optional[Dict[int, int]] = None   # tid -> model
        self._budgets: Dict[int, int] = {}                # model -> bytes
        self._bytes_by_part: Dict[int, int] = {}

    # ------------------------------------------------------------ introspection
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Tuple[int, int]) -> bool:
        return tuple(key) in self._entries

    @property
    def size_bytes(self) -> int:
        return len(self._entries) * self.row_bytes

    def table_rows(self, tid: int) -> int:
        """Resident row count for one table."""
        return self._rows_by_table.get(tid, 0)

    def get(self, tid: int, row: int):
        """Stored value for a resident row (no stats/recency side effects)."""
        return self._entries.get((tid, row))

    # ---------------------------------------------------------------- priority
    def set_hot_tables(self, hot: Optional[Iterable[int]]) -> None:
        """Install the measured hot-table set (None = no classification:
        every table is priority 1 and the cache degenerates to plain
        LRU/LFU).  Resident entries are re-classified in place."""
        self._hot = set(hot) if hot is not None else None
        self._n_by_pri = {0: 0, 1: 0}
        for tid, _ in self._entries:
            self._n_by_pri[self._pri(tid)] += 1
        if self.policy == "lfu":        # priorities changed: rebuild heap
            self._rebuild_heap()

    def _rebuild_heap(self) -> None:
        self._heap = [(self._pri(k[0]), self._freq[k], self._touch[k], k)
                      for k in self._entries]
        heapq.heapify(self._heap)

    def _pri(self, tid: int) -> int:
        if self._hot is None:
            return 1
        return 1 if tid in self._hot else 0

    # -------------------------------------------------------------- partitions
    def _part(self, tid: int) -> Optional[int]:
        if self._owner_of is None:
            return None
        return self._owner_of.get(tid)

    def partition_bytes(self, part: int) -> int:
        """Resident bytes currently charged to one partition."""
        return self._bytes_by_part.get(part, 0)

    def set_partitions(self, owner_of: Optional[Dict[int, int]],
                       budgets: Optional[Dict[int, int]]) -> int:
        """Install per-model byte budgets (fleet serving).

        ``owner_of`` maps table id -> partition (model) id, ``budgets``
        maps partition id -> byte budget; a tid without an owner is only
        bounded by the global capacity.  ``None`` for both disables
        partitioning.  Residents are re-attributed, and any partition
        now over budget sheds rows immediately; returns rows evicted.
        """
        if (owner_of is None) != (budgets is None):
            raise ValueError("owner_of and budgets must be set together")
        self._owner_of = dict(owner_of) if owner_of is not None else None
        self._bytes_by_part = {}
        if self._owner_of is not None:
            for tid, _ in self._entries:
                p = self._part(tid)
                if p is not None:
                    self._bytes_by_part[p] = (self._bytes_by_part.get(p, 0)
                                              + self.row_bytes)
        return self.rebalance(budgets or {})

    def rebalance(self, budgets: Dict[int, int]) -> int:
        """Re-install partition budgets mid-run (the fleet rebalance
        hook): partitions shrunk below their residency shed their
        lowest-priority rows now.  Returns rows evicted."""
        self._budgets = {int(p): int(b) for p, b in budgets.items()}
        evicted = 0
        for p in sorted(self._budgets):
            budget = self._budgets[p]
            while self._bytes_by_part.get(p, 0) > budget:
                if not (self._evict_one(max_pri=0, part=p)
                        or self._evict_one(max_pri=1, part=p)):
                    break
                evicted += 1
        return evicted

    # ------------------------------------------------------------------ probes
    def probe(self, tid: int, row: int) -> bool:
        """One lookup: True on hit (recency/frequency updated)."""
        key = (tid, row)
        self._tick += 1
        if key in self._entries:
            self.stats.hits += 1
            self._touch[key] = self._tick
            if self.policy == "lru":
                self._entries.move_to_end(key)
            else:
                f = self._freq[key] + 1
                self._freq[key] = f
                heapq.heappush(self._heap,
                               (self._pri(tid), f, self._tick, key))
                # stale tuples are normally reclaimed at eviction time;
                # a hit-dominated stream (few evictions) would grow the
                # lazy heap per probe, so compact once it outnumbers the
                # residents severalfold
                if len(self._heap) > 4 * len(self._entries) + 64:
                    self._rebuild_heap()
            return True
        self.stats.misses += 1
        return False

    def lookup(self, tid: int, row: int, value=None) -> bool:
        """Serving fast path: probe, and on a miss admit the fetched row
        (fetch-on-miss).  Returns True on hit."""
        if self.probe(tid, row):
            return True
        self.admit(tid, row, value)
        return False

    # --------------------------------------------------------------- admission
    def admit(self, tid: int, row: int, value=None) -> bool:
        """Insert a row, evicting within the byte budget.  A row whose
        table outranks every candidate victim is refused (returns False)
        rather than displace the hot set."""
        key = (tid, row)
        if key in self._entries:
            self._entries[key] = value
            return True
        if self.capacity_bytes < self.row_bytes:
            self.stats.rejects += 1
            return False
        pri = self._pri(tid)
        part = self._part(tid)
        if part is not None and part in self._budgets:
            budget = self._budgets[part]
            if budget < self.row_bytes:
                self.stats.rejects += 1
                return False
            while (self._bytes_by_part.get(part, 0) + self.row_bytes
                   > budget):
                if not self._evict_one(max_pri=pri, part=part):
                    self.stats.rejects += 1
                    return False
        while self.size_bytes + self.row_bytes > self.capacity_bytes:
            if not self._evict_one(max_pri=pri):
                self.stats.rejects += 1
                return False
        self._tick += 1
        self._entries[key] = value
        self._freq[key] = 1
        self._touch[key] = self._tick
        self._n_by_pri[pri] += 1
        self._rows_by_table[tid] = self._rows_by_table.get(tid, 0) + 1
        if part is not None:
            self._bytes_by_part[part] = (self._bytes_by_part.get(part, 0)
                                         + self.row_bytes)
        if self.policy == "lfu":
            heapq.heappush(self._heap, (pri, 1, self._tick, key))
        return True

    def _evict_one(self, max_pri: int, part: Optional[int] = None) -> bool:
        """Evict one victim of priority <= max_pri; False if none exists.
        With ``part`` the victim must belong to that partition (scan-based
        selection: partitions are a fleet feature with no lazy-heap
        index, and resident counts stay small per CN)."""
        if part is not None:
            best = None
            for key in self._entries:          # recency order (oldest first)
                if self._part(key[0]) != part:
                    continue
                pri = self._pri(key[0])
                if pri > max_pri:
                    continue
                if self.policy == "lru":
                    best = key                 # oldest eligible wins
                    break
                cand = (pri, self._freq[key], self._touch[key], key)
                if best is None or cand < best:
                    best = cand
            if best is None:
                return False
            self._drop(best if self.policy == "lru" else best[3])
            self.stats.evictions += 1
            return True
        if sum(n for p, n in self._n_by_pri.items() if p <= max_pri) == 0:
            return False
        if self.policy == "lru":
            for key in self._entries:          # oldest first
                if self._pri(key[0]) <= max_pri:
                    self._drop(key)
                    self.stats.evictions += 1
                    return True
            return False
        while self._heap:                      # lfu: lazy-invalidated heap
            pri, f, tick, key = self._heap[0]
            if (key not in self._entries or pri != self._pri(key[0])
                    or f != self._freq[key] or tick != self._touch[key]):
                heapq.heappop(self._heap)      # stale entry
                continue
            if pri > max_pri:
                return False                   # heap min outranks incoming
            heapq.heappop(self._heap)
            self._drop(key)
            self.stats.evictions += 1
            return True
        return False

    def _drop(self, key: Tuple[int, int]) -> None:
        del self._entries[key]
        self._freq.pop(key, None)
        self._touch.pop(key, None)
        self._n_by_pri[self._pri(key[0])] -= 1
        tid = key[0]
        part = self._part(tid)
        if part is not None and part in self._bytes_by_part:
            left_b = self._bytes_by_part[part] - self.row_bytes
            if left_b > 0:
                self._bytes_by_part[part] = left_b
            else:
                del self._bytes_by_part[part]
        left = self._rows_by_table[tid] - 1
        if left:
            self._rows_by_table[tid] = left
        else:
            del self._rows_by_table[tid]

    # --------------------------------------------------------------- coherence
    def invalidate_table(self, tid: int) -> int:
        """Drop every resident row of one table (its authoritative copy
        moved).  Returns the number of rows invalidated."""
        if not self._rows_by_table.get(tid):
            return 0
        victims = [k for k in self._entries if k[0] == tid]
        for k in victims:
            self._drop(k)
        self.stats.invalidations += len(victims)
        return len(victims)

    def flush(self) -> int:
        """Drop everything (DLRM weight reload: all rows went stale)."""
        n = len(self._entries)
        self._entries.clear()
        self._freq.clear()
        self._touch.clear()
        self._heap.clear()
        self._n_by_pri = {0: 0, 1: 0}
        self._rows_by_table.clear()
        self._bytes_by_part.clear()
        self.stats.invalidations += n
        return n
