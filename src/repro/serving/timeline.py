"""Event-timeline dispatcher: `ClusterEngine.serve`'s execution core.

This module is the un-nesting of what used to be a ~270-line closure
stack inside ``ClusterEngine.serve``: one :class:`TimelineDispatcher`
owns a serve call's transient state (the ingress batcher, per-CN clock
arrays, per-request assembly buffers) and consumes a **unified, typed
event queue** (``serving.scenario`` events) in global time order.

Dispatch semantics (the ordering guarantees the scenario API documents):

- Events are stable-sorted by ``time_s``; equal times fire in listed
  order.  The legacy ``failures=``/``resizes=`` kwargs are converted by
  :func:`legacy_events` with failures listed before resizes, preserving
  the historical tie-break — legacy runs are bitwise-identical to their
  ``ScenarioSpec`` equivalents by construction.
- All events apply at batch boundaries on the virtual clock (before the
  next batch whose MN stage starts at or after their fire time), except
  ``FailMN``: a failure landing *inside* a batch's MN stage hits packets
  in flight — the batch's wasted first pass is charged, routing rebuilds
  over the survivors, and the batch re-issues (``reissues`` counter).
  A failure queued *behind* an earlier-timed pool-state event
  (``RecoverMN``/``ReloadParams``/``ReplanPlacement``) defers to the
  boundary so state changes on the same resource apply in true time
  order (see ``_next_fail``).
- A ``FailMN``/``RecoverMN`` aimed at an MN that has shrunk out of the
  pool by fire time is a recorded no-op (the machine isn't there), and a
  ``RecoverMN`` for a live MN likewise.  One asymmetry is deliberate
  (and pinned by legacy bitwise parity): a shrink stamped earlier
  *inside the same MN stage* has not taken effect yet when a failure
  strikes packets in flight — the MN is still live mid-stage, so the
  failure fires; the shrink lands at the next boundary.  Only at batch
  boundaries is "shrunk away" meaningful.  Validation happens up front
  against the *schedule-aware maximum* pool
  (``scenario.validate_events``), so a failure scheduled after a timed
  grow is accepted even though the target MN doesn't exist yet at serve
  start.
- ``SetWorkload`` is consumed when the stream is built
  (``scenario.plan_workload``); here it is audit-trail-only.

Every applied (or skipped) event lands in the audit trail as an
:class:`EventRecord` — event, fire time, resulting pool shape — which
``serve`` returns on ``ClusterStats.events``.

**Pipelined execution** (``serving.pipeline``): the virtual clock is a
set of per-resource FIFO timelines — each CN's preprocess core, gather
NIC, and GPU, and each MN's memory bus — and a batch's completion time
is the max over its resource chains.  ``ClusterConfig.inflight_depth``
bounds how many batches may be inside the MN stage (scans + gather) at
once; at depth 1 the admission floor degenerates to the old global
``mn_barrier`` and the dispatcher commits every stage with the
sequential clock's closed-form arithmetic, so depth-1 runs are
bitwise-identical to the pre-pipeline engine (scores, latencies, and
every ClusterStats counter).  At depth > 1 batch k+1's scans overlap
batch k's gather and dense stages, with per-resource queueing charged
where contention actually happens.  A mid-stage ``FailMN`` aborts the
struck batch's planned intervals at the failure instant — the in-
flight prefix of each scan/gather is charged to its resource — before
the batch re-issues on the survivors.

**Traffic realism** (this layer's additions on top of the pipeline):
per-query queueing delay (arrival -> first batch admission) is
measured into ``ClusterStats.queue_wait_{mean,p99}``; ``DegradeMN``
events slow an MN's bus by a factor (a batch-boundary pool-state
event, and — like every non-Resize/SetWorkload event — a barrier for
the mid-stage failure scan in ``_next_fail``); scans straggling past
``ClusterConfig.hedge_multiplier x`` their nominal time are hedged
onto replica buses (``_mn_plan``); and an optional ``SLAController``
is fed every completion, its emitted ``Resize`` events joining the
live queue via ``_enqueue``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis import clocksan
from repro.core import embedding_manager as em
from repro.core import hardware as hw
from repro.core.scheduler import Batch, Batcher, Query
from repro.serving.cluster import CN_ROUTERS, ClusterStats, ModelStats
from repro.serving.engine import Request, Result
from repro.serving.pipeline import (AdmissionWindow, BatchTrace, HedgeIssue,
                                    MNPlan, fit_clocks, summarize_resources)
from repro.serving.scenario import (DegradeMN, FailMN, RecoverMN,
                                    ReloadParams, ReplanPlacement, Resize,
                                    ScenarioEvent, SetWorkload, ShiftTraffic,
                                    _lat_stats, sort_events, validate_events)


def legacy_events(failures: Sequence[Tuple[float, int]],
                  resizes: Sequence[Tuple[float, int, int]]
                  ) -> List[ScenarioEvent]:
    """Shim the historical ``serve(failures=, resizes=)`` kwargs into
    typed events.  Failures are listed before resizes so the stable
    time-sort reproduces the old tie-break (a failure and a resize at
    the same instant applied the failure first)."""
    evs: List[ScenarioEvent] = [
        FailMN(float(t), mn=int(j)) for t, j in sorted(failures)]
    evs += [Resize(float(t), n_cn=int(n), m_mn=int(m))
            for t, n, m in sorted(resizes)]
    return evs


@dataclass(frozen=True)
class EventRecord:
    """Audit-trail entry: one timeline event and the pool it left
    behind (``applied=False`` marks a recorded no-op — e.g. a failure
    aimed at an MN that had already shrunk away)."""
    event: ScenarioEvent
    time_s: float
    n_cn: int
    m_mn: int
    dead: Tuple[int, ...]
    applied: bool = True


class TimelineDispatcher:
    """One serve call: consume the event queue in global time order
    while batching, routing, and scoring the request stream on the
    engine's virtual clock."""

    def __init__(self, engine, requests: Sequence[Request],
                 events: Sequence[ScenarioEvent], controller=None,
                 controllers: Optional[Dict[int, object]] = None):
        self.eng = engine
        if engine.cfg.cn_router not in CN_ROUTERS:
            raise ValueError(
                f"unknown cn_router {engine.cfg.cn_router!r}; "
                f"choose from {CN_ROUTERS}")
        self.requests = list(requests)
        self.queue: List[ScenarioEvent] = sort_events(events)
        validate_events(self.queue, engine.m_mn)
        self.audit: List[EventRecord] = []
        # optional SLA feedback controller(s)
        # (serving.autoscaler.SLAController): fed every completion,
        # emitted Resize events join the live queue.  The fleet form
        # `controllers` maps model index -> controller, so each model's
        # latency window and SLA target are tracked independently over
        # the shared pool; the legacy singular kwarg is the one-entry
        # dict keyed by model 0.
        if controller is not None and controllers:
            raise ValueError("give either controller (single) or "
                             "controllers (fleet), not both")
        self.controllers: Dict[int, object] = (
            dict(controllers) if controllers
            else ({0: controller} if controller is not None else {}))
        self.sla_actions = 0
        self.sla_actions_cn = 0
        self.sla_actions_mn = 0
        # retire instant of every clock a CN shrink removed, keyed by
        # object id (safe: the registry keeps retired clocks alive, so
        # ids are never reused within a run) — the truncation point for
        # a superseded pre-stage booking's abort charge
        self._retire_s: Dict[int, float] = {}
        # audit-completeness accounting (checked by clocksan when
        # REPRO_CLOCKSAN=1): every event ever on the queue — initial
        # timeline plus dynamically enqueued — must land in the audit
        self._n_events0 = len(self.queue)
        self._n_enqueued = 0

    # ------------------------------------------------------ event apply
    def _record(self, ev: ScenarioEvent, applied: bool = True) -> None:
        e = self.eng
        self.audit.append(EventRecord(ev, ev.time_s, e.n_cn, e.m_mn,
                                      tuple(sorted(e.dead)), applied))

    def _apply(self, ev: ScenarioEvent) -> None:
        """Apply one batch-boundary event and record the resulting pool
        shape.  (Mid-MN-stage failures take the in-flight path in
        ``_run_batch`` instead.)"""
        e = self.eng
        if isinstance(ev, FailMN):
            if ev.mn < e.m_mn:      # an MN that shrank away can't fail
                already = ev.mn in e.dead
                e.fail_mn(ev.mn)
                self._record(ev, applied=not already)
            else:
                self._record(ev, applied=False)
        elif isinstance(ev, RecoverMN):
            if ev.mn < e.m_mn and ev.mn in e.dead:
                e.recover_mn(ev.mn)
                self._record(ev)
            else:                   # departed, never failed, or healed
                self._record(ev, applied=False)
        elif isinstance(ev, Resize):
            # an identity resize (the pool already has the target shape)
            # returns early inside the engine without counting — mirror
            # that in the audit so applied records match stats.resizes
            changed = ((e.n_cn if ev.n_cn is None else ev.n_cn,
                        e.m_mn if ev.m_mn is None else ev.m_mn)
                       != (e.n_cn, e.m_mn))
            plan = e.resize(ev.n_cn, ev.m_mn, ev.mn_type)
            self.st = e.unit_model.stage_times(e.cfg.batch_size)
            self.mn_bw = np.asarray(e.mn_bw)
            self.mn_slow = np.asarray(e.mn_slow)
            # joining nodes are idle from the resize instant; a
            # departing node's clocks retire with their accumulated
            # stats (they stay in the registry for end-of-run
            # aggregation).  Batches are placed by the configured
            # cn_router policy over the live pool.
            for c in self.cn_cpu[e.n_cn:]:   # CN shrink: remember when
                self._retire_s[id(c)] = ev.time_s
            self.cn_cpu = fit_clocks(self.cn_cpu, e.n_cn, "cn_cpu",
                                     ev.time_s, self._clocks)
            self.cn_nic = fit_clocks(self.cn_nic, e.n_cn, "cn_nic",
                                     ev.time_s, self._clocks)
            self.cn_gpu = fit_clocks(self.cn_gpu, e.n_cn, "cn_gpu",
                                     ev.time_s, self._clocks)
            self.mn_bus = fit_clocks(self.mn_bus, e.m_mn, "mn_bus",
                                     ev.time_s, self._clocks)
            # migration bytes stream over the fabric in the background,
            # starting when the resize fires
            self.mig_end = (max(self.mig_end, ev.time_s)
                            + plan.bytes_moved / hw.NIC_BW)
            # under multi-controller fleet serving every controller's
            # internal pool view tracks the shared pool, whichever
            # controller (or scheduled event) moved it — a single
            # controller keeps the historical own-emissions-only view
            if len(self.controllers) > 1:
                for c in self.controllers.values():
                    c.sync_pool(e.n_cn, e.m_mn)
            self._record(ev, applied=changed)
        elif isinstance(ev, ReloadParams):
            e.reload_seed(ev.seed)
            self._record(ev)
        elif isinstance(ev, ReplanPlacement):
            e.replan_placement()
            self._record(ev)
        elif isinstance(ev, DegradeMN):
            if ev.mn < e.m_mn:
                changed = e.degrade_mn(ev.mn, ev.factor)
                self.mn_slow = np.asarray(e.mn_slow)
                self._record(ev, applied=changed)
            else:                   # departed via an earlier shrink
                self._record(ev, applied=False)
        elif isinstance(ev, ShiftTraffic):
            # consumed at stream build (fleet.plan_fleet_workload);
            # audit-trail only at dispatch, like SetWorkload
            self._record(ev)
        else:       # SetWorkload: consumed at stream build; audit only
            self._record(ev)

    def _inject(self, upto: float) -> None:
        """Apply every queued event with fire time <= `upto`, in global
        time order (batch-boundary semantics)."""
        while self.queue and self.queue[0].time_s <= upto:
            self._apply(self.queue.pop(0))

    def _enqueue(self, ev: ScenarioEvent) -> None:
        """Insert a dynamically emitted event (SLA controller feedback)
        into the live queue, keeping the time sort; equal times land
        after existing entries (stable, matching listed-order
        semantics).  The event applies at the next batch boundary like
        any other — emission never reaches back in time."""
        i = len(self.queue)
        while i > 0 and self.queue[i - 1].time_s > ev.time_s:
            i -= 1
        self.queue.insert(i, ev)
        self._n_enqueued += 1

    def _next_fail(self) -> Tuple[Optional[int], Optional[FailMN]]:
        """The next failure eligible for the in-flight mid-stage path.

        ``Resize`` and ``SetWorkload`` are pure batch-boundary events
        and may be scanned past (the historical semantics: a failure
        strikes packets in flight even if a resize is stamped earlier
        inside the same stage — legacy parity pins this).  Pool-*state*
        events on the queue (``RecoverMN``/``ReloadParams``/
        ``ReplanPlacement``) are barriers instead: a failure behind one
        defers to the next boundary, where `_inject` applies both in
        true time order — otherwise a later failure of an MN could
        apply before its earlier-timed recovery and leave the pool in
        the time-reversed state (and the audit trail out of order).
        Likewise a failure whose target MN only exists after a pending
        earlier-timed grow defers to the boundary — popping it now
        (pool not yet grown) would silently no-op an event the
        schedule-aware validation promised would fire."""
        m_pend = self.eng.m_mn       # pool size at the failure's fire
        for i, ev in enumerate(self.queue):  # time, per pending resizes
            if isinstance(ev, FailMN):
                if ev.mn >= self.eng.m_mn and ev.mn < m_pend:
                    return None, None     # exists only after the grow
                return i, ev
            if isinstance(ev, Resize):
                if ev.m_mn is not None:
                    m_pend = ev.m_mn
                continue
            if isinstance(ev, SetWorkload):
                continue
            if isinstance(ev, ShiftTraffic):  # stream-build-time event:
                continue                      # scannable-past, like
            return None, None                 # SetWorkload
        return None, None

    # --------------------------------------------------------- routing
    def _outstanding(self, i: int, now: float) -> int:
        """Bookings on CN ``i``'s clocks (cpu/nic/gpu) not yet finished
        at ``now``.  FIFO clocks have nondecreasing interval ends, so a
        reverse scan stops at the first finished one."""
        n = 0
        for clocks in (self.cn_cpu, self.cn_nic, self.cn_gpu):
            for iv in reversed(clocks[i].intervals):
                if iv.end > now:
                    n += 1
                else:
                    break
        return n

    def _route_cn(self, now: float) -> int:
        """Pick the CN for the next batch per ``ClusterConfig.cn_router``.
        Ties break to the lowest index on every policy (``min`` over the
        index range) — routing is deterministic by construction.

        - ``cpu_free`` (legacy default): earliest-free preprocess core;
          bitwise-identical to the historical placement.
        - ``pipeline_free``: earliest point where the CN's *whole*
          pipeline (cpu, gather NIC, GPU) has drained — sees the per-CN
          NIC/GPU backlogs the cpu clock is blind to.
        - ``least_outstanding``: fewest uncommitted bookings across the
          CN's three clocks at ``now`` (join-shortest-queue).
        """
        policy = self.eng.cfg.cn_router
        if policy == "pipeline_free":
            def key(i):
                return max(self.cn_cpu[i].free_at,
                           self.cn_nic[i].free_at,
                           self.cn_gpu[i].free_at)
        elif policy == "least_outstanding":
            def key(i):
                return self._outstanding(i, now)
        else:                        # cpu_free
            def key(i):
                return self.cn_cpu[i].free_at
        return min(range(len(self.cn_cpu)), key=key)

    def _pool_pressure(self) -> Tuple[float, float]:
        """Per-node accumulated queueing seconds of each pool over the
        *live* clocks — the binding-pool attribution signal the
        decoupled SLA controller consumes.  CN pressure folds the cpu,
        gather-NIC, and GPU queues; MN pressure the memory buses."""
        cn = (sum(c.queue_s for c in self.cn_cpu)
              + sum(c.queue_s for c in self.cn_nic)
              + sum(c.queue_s for c in self.cn_gpu))
        mn = sum(c.queue_s for c in self.mn_bus)
        return (cn / max(1, len(self.cn_cpu)),
                mn / max(1, len(self.mn_bus)))

    # --------------------------------------------------------- serving
    def _stage_account(self, mem_j: np.ndarray,
                       gat_j: np.ndarray) -> np.ndarray:
        """Per-MN stage-seconds contributions (scan at the MN's bus
        bandwidth, slowed by any ``DegradeMN`` factor, + its share of
        the gather serialization) — the byte-derived accounting the
        sequential engine charged per batch.  ``mem_j * 1.0`` is
        float-exact, so an undegraded pool reproduces the historical
        numbers bit-for-bit."""
        return (mem_j * self.mn_slow) / self.mn_bw + gat_j / hw.NIC_BW

    def _mn_plan(self, task: int, mn_start: float, mem_j: np.ndarray,
                 gat_j: np.ndarray, cache_s: float) -> MNPlan:
        """Plan (without committing) one batch's MN stage on the
        per-resource clocks: every routed MN scans (and, for NMP, pools
        — a bandwidth-bound streaming reduction) locally in parallel on
        its own memory bus, then the batch's gather bytes serialize
        into the owning CN's back-end NIC once every scan and the
        CN-side cache probe (which overlaps the remote scans — hits
        never wait on the fabric) have drained.

        The closed-form gate ``t_gate`` is computed with the sequential
        clock's exact floating-point arithmetic; it is the committed
        stage time whenever no resource queues the batch (always true
        at depth 1), which is what makes depth-1 runs bitwise-identical
        to the pre-pipeline engine.

        **Hedged re-issue** (``ClusterConfig.hedge_multiplier > 0``,
        FlexEMR's optimistic get): a scan whose degraded duration
        exceeds ``multiplier x`` its nominal (undegraded) duration is
        re-issued at the detection instant — per table, on the fastest
        live replica bus holding that table — and the batch proceeds at
        the first finisher.  Both issues are charged to their buses.
        Hedging is all-or-nothing per scan: if any of the straggler's
        tables has no live alternate replica, no hedge is issued.  A
        plan with hedges always takes the queued commit path."""
        e = self.eng
        mult = float(e.cfg.hedge_multiplier)
        scans: List[Tuple[int, float, float]] = []
        max_dur = 0.0
        queued = False
        bus_tail: Dict[int, float] = {}   # overlay: planned FIFO tails
        for j in np.nonzero(mem_j > 0)[0]:
            dur = (mem_j[j] * self.mn_slow[j]) / self.mn_bw[j]
            s = self.mn_bus[j].peek(mn_start)
            if s > mn_start:
                queued = True
            scans.append((int(j), s, dur))
            bus_tail[int(j)] = s + dur
            if dur > max_dur:
                max_dur = dur
        # effective per-scan completion: the original end, or the hedge
        # end when the hedge wins
        ends: Dict[int, float] = {j: s + dur for j, s, dur in scans}
        hedges: List[HedgeIssue] = []
        if mult > 0:
            for j, s, dur in scans:
                nom = mem_j[j] / self.mn_bw[j]   # undegraded expectation
                if not dur > mult * nom:
                    continue
                detect = s + mult * nom
                per_table = e._last_scan.get(j, [])
                tot = sum(b for _, b in per_table)
                if tot <= 0:
                    continue
                # _last_scan holds raw per-table demand; rescale so the
                # hedge moves exactly the cache-adjusted bytes the
                # original scan was charged for
                scale = float(mem_j[j]) / tot
                groups: Dict[int, float] = {}
                ok = True
                for tid, b in per_table:
                    alts = [m for m in e.alloc.replicas.get(tid, ())
                            if m != j and m not in e.dead and m < e.m_mn]
                    if not alts:
                        ok = False      # all-or-nothing: no partial hedge
                        break
                    m2 = min(alts, key=lambda m: (
                        self.mn_slow[m] / self.mn_bw[m], m))
                    groups[m2] = groups.get(m2, 0.0) + b * scale
                if not ok or not groups:
                    continue
                issues: List[Tuple[int, float, float, float]] = []
                hend = detect
                for m2 in sorted(groups):
                    b2 = groups[m2]
                    d2 = (b2 * self.mn_slow[m2]) / self.mn_bw[m2]
                    s2 = max(self.mn_bus[m2].peek(detect),
                             bus_tail.get(m2, 0.0))
                    bus_tail[m2] = s2 + d2
                    issues.append((m2, s2, d2, b2))
                    if s2 + d2 > hend:
                        hend = s2 + d2
                won = hend < s + dur
                hedges.extend(
                    HedgeIssue(src_mn=j, alt_mn=m2, detect_s=detect,
                               start_s=s2, dur_s=d2, bytes_b=b2, won=won)
                    for m2, s2, d2, b2 in issues)
                if won:
                    ends[j] = hend
                queued = True           # alternate buses were planned
        scan_end = mn_start
        for j, s, dur in scans:
            if ends[j] > scan_end:
                scan_end = ends[j]
        g_dur = float(gat_j.sum() / hw.NIC_BW)
        t_gate = float(max(max_dur, cache_s) + g_dur)
        gather_ready = max(scan_end, mn_start + cache_s)
        if g_dur > 0:
            g_start = self.cn_nic[task].peek(gather_ready)
            if g_start > gather_ready:
                queued = True
        else:
            g_start = gather_ready
        end = (g_start + g_dur) if queued else (mn_start + t_gate)
        return MNPlan(mn_start=mn_start, scans=scans, t_gate=t_gate,
                      gather_ready=gather_ready, gather_start=g_start,
                      gather_dur=g_dur, queued=queued, end=end,
                      hedges=tuple(hedges))

    def _mn_abort(self, task: int, plan: MNPlan, t_fail: float,
                  bid: int) -> None:
        """An in-flight MN failure killed this batch's first pass at
        ``t_fail``: the traffic already on the buses and the NIC was
        real, so each planned interval's in-flight prefix is charged to
        its resource before the batch re-issues.  (The byte counters
        charge the full pass, matching the sequential engine.)

        Hedge prefixes are charged after the originals — a hedge's
        start never precedes its bus's planned tail, so FIFO causality
        holds.  Aborted hedges charge bus *time* only, not bytes: the
        full original pass's bytes (which the hedge duplicated a subset
        of) are already charged by the re-issue path."""
        for j, s, dur in plan.scans:
            self.mn_bus[j].charge_abort(s, min(s + dur, t_fail), bid)
        for h in plan.hedges:
            self.mn_bus[h.alt_mn].charge_abort(
                h.start_s, min(h.end_s, t_fail), bid)
        if plan.gather_dur > 0 and plan.gather_start < t_fail:
            self.cn_nic[task].charge_abort(
                plan.gather_start, min(plan.end, t_fail), bid)

    def _mn_commit(self, task: int, plan: MNPlan, extra_gather: float,
                   bid: int) -> Tuple[float, float, Tuple[float, float]]:
        """Commit the settled plan to the clocks.  Returns (stage done
        time, stage span, gather interval).  ``extra_gather`` is the
        in-flight shard migration's fair-share extension of the gather
        serialization.  Wait-free commits reproduce the sequential
        clock's closed-form chain bit-for-bit; queued commits follow
        the per-resource chain."""
        mn_start = plan.mn_start
        if plan.queued:
            g_dur = plan.gather_dur + extra_gather
            mn_done = (plan.gather_start + g_dur if plan.gather_dur > 0
                       else plan.gather_ready)
            t_mn = mn_done - mn_start
        else:
            t_mn = plan.t_gate
            if extra_gather:
                t_mn = t_mn + extra_gather
            mn_done = mn_start + t_mn
        for j, s, dur in plan.scans:
            self.mn_bus[j].book(mn_start, s, s + dur, bid)
        # hedges book after the originals: each hedge's start is at or
        # beyond its bus's planned tail, so FIFO causality holds.  The
        # hedge's bytes and stage-seconds are charged to the alternate
        # MN — the duplicate traffic is real, win or lose.
        e = self.eng
        for h in plan.hedges:
            self.mn_bus[h.alt_mn].book(h.detect_s, h.start_s, h.end_s,
                                       bid)
            e.mn_access_bytes[h.alt_mn] += h.bytes_b
            e.mn_stage_s[h.alt_mn] += h.dur_s
        if plan.hedges:
            e.hedges += len({h.src_mn for h in plan.hedges})
            e.hedge_wins += len({h.src_mn for h in plan.hedges if h.won})
        gather = (plan.gather_start, plan.gather_start)
        if plan.gather_dur > 0:
            self.cn_nic[task].book(plan.gather_ready, plan.gather_start,
                                   mn_done, bid)
            gather = (plan.gather_start, mn_done)
        return mn_done, t_mn, gather

    def _run_batch(self, b: Batch, now: float) -> None:
        e = self.eng
        cfg = e.cfg
        st = self.st
        # assemble real rows from each member query's payload
        dense_rows, idx_rows = [], []
        for q, nrows in b.parts:
            c = self.row_cursor[q.qid]
            dense_rows.append(self.payload[q.qid]["dense"][c:c + nrows])
            idx_rows.append(self.payload[q.qid]["indices"][c:c + nrows])
            self.row_cursor[q.qid] = c + nrows
        dense = np.concatenate(dense_rows)
        idx = np.concatenate(idx_rows)
        pad = cfg.batch_size - dense.shape[0]
        if pad > 0:
            dense = np.concatenate(
                [dense, np.zeros_like(dense[:1]).repeat(pad, 0)])
            idx = np.concatenate(
                [idx, -np.ones_like(idx[:1]).repeat(pad, 0)])

        scale = b.size / cfg.batch_size
        # plan-then-commit: peek the pre stage without booking, inject
        # any events due by mn_start, and only commit the pre on the CN
        # that survives them.  (Booking up front would leave a phantom
        # busy interval on a CN a shrink retires mid-window — and the
        # superseded booking would advance free_at past the abort's
        # start, so the FIFO clock could never take the charge back.)
        task = self._route_cn(now)
        cpu = self.cn_cpu[task]
        pre_start = cpu.peek(now)
        pre_done = pre_start + st.t_pre * scale  # reserve's exact chain
        chain_ready = pre_done + st.t_comm_in * scale
        mn_start = max(chain_ready, self.window.floor())

        # MNs that died during G_P/scatter are gone before this batch's
        # MN stage begins: re-route first, then execute
        self._inject(mn_start)
        # a CN shrink landing inside the G_P/scatter window may have
        # retired the chosen CN: charge the superseded pre's in-flight
        # prefix to the retired clock as an abort (mirroring _mn_abort)
        # and hand the batch off to a survivor
        while task >= len(self.cn_cpu):
            t_ret = self._retire_s.get(id(cpu), mn_start)
            cpu.charge_abort(pre_start, min(pre_done, t_ret), b.bid)
            st = self.st
            task = self._route_cn(now)
            cpu = self.cn_cpu[task]
            pre_start = cpu.peek(now)
            pre_done = pre_start + st.t_pre * scale
            chain_ready = pre_done + st.t_comm_in * scale
            mn_start = max(chain_ready, self.window.floor())
            self._inject(mn_start)
        st = self.st
        cpu.book(now, pre_start, pre_done, b.bid)
        self.window.wait_s += mn_start - chain_ready
        # per-query queueing delay: arrival -> first batch admission
        # (the instant its first part starts preprocessing).  Charged
        # once per query, at the part that admits it.
        for q, _ in b.parts:
            if q.qid not in self.first_admit:
                self.first_admit[q.qid] = pre_start
                self.queue_waits.append(pre_start - self.arrival[q.qid])
                self.m_queue_waits.setdefault(b.model, []).append(
                    pre_start - self.arrival[q.qid])
        scores, mem_j, gat_j = e._execute(task, dense, idx, model=b.model)
        stage_j = self._stage_account(mem_j, gat_j)
        plan = self._mn_plan(task, mn_start, mem_j, gat_j,
                             e._batch_cache_s)

        # a failure landing inside this batch's MN stage hits packets
        # in flight: rebuild routing, re-issue on the survivors
        reissued = 0
        while True:
            qi, nxt = self._next_fail()
            if nxt is None or not (mn_start < nxt.time_s <= plan.end):
                break
            self.queue.pop(qi)
            t_fail, j = nxt.time_s, nxt.mn
            if j >= e.m_mn:         # departed via an earlier shrink
                self._record(nxt, applied=False)
                continue
            hit = mem_j[j] > 0
            already = j in e.dead
            e.fail_mn(j)
            self._record(nxt, applied=not already)
            if hit:
                # the aborted pass's traffic was already on the wire
                # and the bus — charge the wasted bytes in full and
                # each planned interval's in-flight prefix to its
                # resource, then re-issue on the survivors
                e.reissues += 1
                reissued += 1
                e.mn_access_bytes += mem_j
                e.mn_gather_bytes += gat_j
                e.mn_stage_s += stage_j
                self._mn_abort(task, plan, t_fail, b.bid)
                scores, mem_j, gat_j = e._execute(task, dense, idx,
                                                  model=b.model)
                stage_j = self._stage_account(mem_j, gat_j)
                mn_start = t_fail + cfg.mn_recovery_s
                plan = self._mn_plan(task, mn_start, mem_j, gat_j,
                                     e._batch_cache_s)
        # an in-flight shard migration fair-shares the gather NIC path
        # with this batch: each stream extends by the other's demand
        # for the overlap
        extra = 0.0
        if mn_start < self.mig_end and gat_j.sum() > 0:
            extra = float(gat_j.sum()) / hw.NIC_BW
            self.mig_end += extra
        mn_done, t_mn, gather_iv = self._mn_commit(task, plan, extra,
                                                   b.bid)
        self.window.complete(mn_done)
        e.mn_access_bytes += mem_j
        e.mn_gather_bytes += gat_j
        e.mn_stage_s += stage_j
        e._mn_stage_max_sum += t_mn
        e._n_batches += 1
        # keep admission priorities tracking the live workload even on
        # an event-free run (deterministic: a pure function of the
        # stream prefix served so far)
        if e.caches and e._n_batches % 8 == 0:
            e._refresh_hot_tables()

        d_start, done = self.cn_gpu[task].reserve(
            mn_done, st.t_dense * scale, b.bid)
        if done > self.last_done:
            self.last_done = done
        self.trace.append(BatchTrace(
            bid=b.bid, task=task, size=b.size, pre=(pre_start, pre_done),
            chain_ready=chain_ready, mn_start=mn_start,
            scans=tuple((j, s, s + dur) for j, s, dur in plan.scans),
            gather=gather_iv, mn_done=mn_done, dense=(d_start, done),
            done=done, reissues=reissued,
            qids=tuple(q.qid for q, _ in b.parts),
            hedges=plan.hedges))

        o = 0
        for q, nrows in b.parts:
            self.pieces[q.qid].append(scores[o:o + nrows])
            o += nrows
            self.rows_left[q.qid] -= nrows
            prev = self.part_done.get(q.qid)
            if prev is None or done > prev:
                self.part_done[q.qid] = done
            if self.rows_left[q.qid] == 0:
                # a split query completes when its LAST part's dense
                # stage finishes — under pipelining (and even on the
                # sequential clock, across CNs with uneven GPU queues)
                # the batch that zeroes rows_left need not finish last
                lat = self.part_done[q.qid] - self.arrival[q.qid]
                self.latencies.append(lat)
                self.m_latencies.setdefault(b.model, []).append(lat)
                self.results.append(Result(
                    q.qid, np.concatenate(self.pieces[q.qid]), lat))
                ctl = self.controllers.get(b.model)
                if ctl is not None:
                    # feed the owning model's SLA loop; emitted resizes
                    # join the live queue and apply at the next batch
                    # boundary
                    for act in ctl.observe(
                            self.part_done[q.qid], lat,
                            pressure=self._pool_pressure()):
                        self._enqueue(act)
                        self.sla_actions += 1
                        self.m_sla_actions[b.model] = (
                            self.m_sla_actions.get(b.model, 0) + 1)
                        if act.n_cn is not None:
                            self.sla_actions_cn += 1
                        if act.m_mn is not None:
                            self.sla_actions_mn += 1

    def _drain_due(self, upto: Optional[float]) -> None:
        """Form every batch whose flush deadline has passed, earliest
        deadline first across the per-model batchers (equal deadlines
        break to the lowest model index — deterministic)."""
        while True:
            best: Optional[Tuple[int, float]] = None
            for k in sorted(self.batchers):
                dl = self.batchers[k].next_deadline()
                if dl is not None and (best is None or dl < best[1]):
                    best = (k, dl)
            if best is None or (upto is not None and best[1] > upto):
                return
            k, dl = best
            self._inject(dl)
            out = self.batchers[k].flush(dl)
            if not out:
                return
            for b in out:
                self._run_batch(b, dl)

    def run(self) -> Tuple[List[Result], ClusterStats]:
        e = self.eng
        cfg = e.cfg
        # one ingress batcher per model in the stream (a single-model
        # stream gets exactly the historical lone batcher: model 0,
        # bid_start 0, stride 1)
        models = sorted({r.model for r in self.requests}) or [0]
        self.batchers = {
            k: Batcher(cfg.batch_size, cfg.max_wait_s, model=k,
                       bid_start=i, bid_step=len(models))
            for i, k in enumerate(models)}
        self.m_latencies: Dict[int, List[float]] = {}
        self.m_queue_waits: Dict[int, List[float]] = {}
        self.m_sla_actions: Dict[int, int] = {}
        e._refresh_hot_tables()    # hotness measured by prior serving
        requests = self.requests
        self.payload = {r.rid: r.payload for r in requests}
        self.arrival = {r.rid: r.arrival for r in requests}
        self.row_cursor: Dict[int, int] = {r.rid: 0 for r in requests}
        self.pieces: Dict[int, List[np.ndarray]] = {
            r.rid: [] for r in requests}
        self.rows_left = {r.rid: r.size for r in requests}
        self.results: List[Result] = []
        self.latencies: List[float] = []

        self.st = e.unit_model.stage_times(cfg.batch_size)
        self.mn_bw = np.asarray(e.mn_bw)
        self.mn_slow = np.asarray(e.mn_slow)
        self.first_admit: Dict[int, float] = {}
        self.queue_waits: List[float] = []
        self.depth = int(cfg.inflight_depth)
        self.window = AdmissionWindow(self.depth)
        self._clocks: List = []    # every clock ever created (live+retired)
        self.cn_cpu = fit_clocks([], e.n_cn, "cn_cpu", 0.0, self._clocks)
        self.cn_nic = fit_clocks([], e.n_cn, "cn_nic", 0.0, self._clocks)
        self.cn_gpu = fit_clocks([], e.n_cn, "cn_gpu", 0.0, self._clocks)
        self.mn_bus = fit_clocks([], e.m_mn, "mn_bus", 0.0, self._clocks)
        self.mig_end = 0.0         # background migration busy-until
        self.last_done = 0.0       # makespan: latest dense finish
        self.trace: List[BatchTrace] = []
        self.part_done: Dict[int, float] = {}

        for req in sorted(requests, key=lambda r: r.arrival):
            self._drain_due(req.arrival)
            self._inject(req.arrival)
            q = Query(req.rid, req.arrival, req.size)
            for b in self.batchers[req.model].offer(q, req.arrival):
                self._run_batch(b, req.arrival)
        self._drain_due(None)
        # events stamped after the last batch deadline still belong to
        # the scenario: flush them in time order so the declared
        # end-state (and the audit trail) matches the timeline instead
        # of silently dropping the tail.  No batch runs after this, so
        # scores/latencies/bytes are untouched — only routing, pool
        # shape, and counters move.
        self._inject(math.inf)

        # nothing completed reports nan, not a fabricated 0.0
        mean_lat, p50, p95, p99 = _lat_stats(self.latencies)
        qw_mean, _, _, qw_p99 = _lat_stats(self.queue_waits)
        live = [a for j, a in enumerate(e.mn_access_bytes)
                if j not in e.dead]
        cs = e.cache_stats()
        makespan = self.last_done
        r_busy, r_queue, r_util, r_occ = summarize_resources(
            self._clocks, makespan)
        # per-model breakdown (one entry per fleet member, single-model
        # runs included — their lone entry mirrors the global fields)
        n_queries: Dict[int, int] = {}
        for r in requests:
            n_queries[r.model] = n_queries.get(r.model, 0) + 1
        per_model: Dict[str, ModelStats] = {}
        for k, name in enumerate(e.model_names):
            m_lats = self.m_latencies.get(k, [])
            _, _, _, m_p99 = _lat_stats(m_lats)
            _, _, _, m_qw99 = _lat_stats(self.m_queue_waits.get(k, []))
            per_model[name] = ModelStats(
                queries=n_queries.get(k, 0),
                completed=len(m_lats),
                p99=m_p99,
                queue_wait_p99=m_qw99,
                cache_hits=e.fleet_cache_hits[k],
                cache_bytes_saved=e.fleet_cache_bytes_saved[k],
                sla_actions=self.m_sla_actions.get(k, 0),
            )
        stats = ClusterStats(
            completed=len(self.results),
            mean_latency=mean_lat,
            p50=p50,
            p95=p95,
            failures=e.failures,
            reroutes=e.reroutes,
            reinits=e.reinits,
            mn_access_bytes=list(e.mn_access_bytes),
            mn_gather_bytes=list(e.mn_gather_bytes),
            mn_types=list(e.mn_types),
            imbalance=em.imbalance(live),
            recoveries=e.recoveries,
            resizes=e.resizes,
            migration_bytes=e.migration_bytes,
            retired_access_bytes=e.retired_access_bytes,
            retired_gather_bytes=e.retired_gather_bytes,
            p99=p99,
            reissues=e.reissues,
            cache_hits=cs.hits,
            cache_misses=cs.misses,
            cache_evictions=cs.evictions,
            cache_invalidations=cs.invalidations,
            cache_bytes_saved=e.cache_bytes_saved,
            inflight_depth=self.depth,
            makespan_s=makespan,
            throughput_qps=(len(self.results) / makespan
                            if makespan > 0 else float("nan")),
            admission_wait_s=self.window.wait_s,
            queue_wait_mean=qw_mean,
            queue_wait_p99=qw_p99,
            degrades=e.degrades,
            hedges=e.hedges,
            hedge_wins=e.hedge_wins,
            sla_actions=self.sla_actions,
            sla_actions_cn=self.sla_actions_cn,
            sla_actions_mn=self.sla_actions_mn,
            sla_window_filled=all(c.window_filled
                                  for c in self.controllers.values()),
            per_model=per_model,
            resource_busy_s=r_busy,
            resource_queue_s=r_queue,
            resource_util=r_util,
            resource_occupancy=r_occ,
            events=list(self.audit),
        )
        if clocksan.enabled():
            # post-hoc sanitize: FIFO/overlap over every clock ever
            # created (live + retired), busy-time conservation against
            # the committed intervals, the per-resource folds on stats,
            # and audit completeness (every fired event recorded)
            clocksan.verify_run(
                self._clocks, stats, audit=stats.events,
                n_audit_expected=self._n_events0 + self._n_enqueued)
        e.last_trace = self.trace
        e.last_resources = list(self._clocks)
        self.results.sort(key=lambda r: r.rid)
        return self.results, stats
