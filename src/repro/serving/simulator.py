"""Discrete-event cluster simulator (validates §III-C, §IV-C, §IV-D).

Pipeline per batch: batcher -> G_P (CN CPU) -> packet scatter -> MN pool
under INTERLEAVED (per-MN FCFS) or SEQUENTIAL (global lock-step) policy
-> Fsum gather -> G_D (CN GPU) -> done.

Why interleaving hurts (Fig. 8): packets from different CNs arrive at
MNs in different orders (network jitter); FCFS then runs query A before
B on one MN and B before A on another — every in-flight query waits for
the union. Sequential processing orders queries globally, so query i's
packets run in lock step and it completes as early as possible.

Failures (Fig. 9 / §IV-D): CN/MN failure events pause the affected
resources for their recovery time; MN failure triggers the routing
rebuild (fast) unless replicas are lost. Straggler mitigation: packets
exceeding `straggler_factor` x their nominal service are re-issued on the
least-loaded surviving MN.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import failure as fail_mod
from repro.core.scheduler import INTERLEAVED, SEQUENTIAL
from repro.core.serving_unit import ServingUnitModel
from repro.data.queries import QueryDist, poisson_arrivals


@dataclass
class SimConfig:
    batch_size: int = 128
    policy: str = SEQUENTIAL
    max_batch_wait_s: float = 0.002
    net_jitter_s: float = 0.0002
    # batch-content variability (heavy-tailed pooling factors, Fig. 2a):
    # common to all of a batch's packets
    batch_cv: float = 0.5
    # residual per-MN imbalance after greedy MemAccess routing: small
    service_cv: float = 0.05
    # memory-interference penalty when an MN interleaves multiple queries:
    # concurrent table scans destroy DRAM row locality (RecNMP-style
    # row-buffer-hit degradation); calibrated to Fig. 8
    ps_overhead: float = 0.25
    seed: int = 0
    inject_failures: bool = False
    straggler_factor: float = 3.0
    duration_s: float = 5.0
    warmup_s: float = 1.0


def _ps_schedule(arrivals: np.ndarray, works: np.ndarray,
                 busy_until: float = 0.0,
                 overhead: float = 0.0,
                 max_concurrency: int = 4) -> np.ndarray:
    """Limited processor sharing: up to `max_concurrency` jobs progress
    together at 1/(k*(1+overhead)) each (overhead = memory-interference
    loss when scans of different queries interleave); excess jobs wait
    FIFO — the memory controller's bounded in-flight queue, which makes
    interleaved peak throughput approach FCFS at saturation (Fig. 8b)."""
    n = len(arrivals)
    order = np.argsort(arrivals, kind="stable")
    done = np.empty(n)
    active: List[List] = []                 # [remaining, id]
    waiting: List[int] = []                 # FIFO of job ids
    t = busy_until
    i = 0
    while active or waiting or i < n:
        # admit from FIFO up to the concurrency cap
        while waiting and len(active) < max_concurrency:
            jid = waiting.pop(0)
            active.append([works[jid], jid])
        next_arr = arrivals[order[i]] if i < n else np.inf
        if not active:
            t = max(t, next_arr)
            waiting.append(order[i])
            i += 1
            continue
        k = len(active)
        slow = k * (1.0 + (overhead if k > 1 else 0.0))
        min_rem = min(a[0] for a in active)
        t_fin = t + min_rem * slow
        if t_fin <= next_arr:
            for a in active:
                a[0] -= min_rem
            t = t_fin
            still = []
            for a in active:
                if a[0] <= 1e-15:
                    done[a[1]] = t
                else:
                    still.append(a)
            active = still
        else:
            dt = (next_arr - t) / slow
            for a in active:
                a[0] -= dt
            t = next_arr
            waiting.append(order[i])
            i += 1
    return done


@dataclass
class SimStats:
    throughput_qps: float
    mean_latency: float
    p50: float
    p95: float
    p99: float
    completed: int
    dropped_packets: int = 0
    failures: int = 0


class ClusterSim:
    """One serving unit ({n CN, m MN} or n monolithic servers)."""

    def __init__(self, unit_model: ServingUnitModel, cfg: SimConfig):
        self.um = unit_model
        self.cfg = cfg
        self.n = unit_model.unit.n
        self.m = max(unit_model.unit.m, 1)
        self.disagg = unit_model.unit.scheme == "disagg"

    # per-batch stage service times from the analytic unit model
    def _times(self, batch: int) -> Tuple[float, float, float, float]:
        st = self.um.stage_times(batch)
        t_packet = st.t_sparse            # total MN work, split over m
        return st.t_pre, st.t_comm_in + st.t_comm_out, t_packet, st.t_dense

    def run(self, rate_qps: float, query_dist: Optional[QueryDist] = None
            ) -> SimStats:
        cfg = self.cfg
        rng = np.random.RandomState(cfg.seed)
        qd = query_dist or QueryDist()
        arrivals = poisson_arrivals(rate_qps, cfg.duration_s, rng)
        sizes = qd.sample(rng, len(arrivals))

        # ---- form batches (shared batcher, round-robin to CNs)
        batches = []       # (formed_time, batch_samples, [(qid, arrival)])
        pend: List[Tuple[int, float, int]] = []
        pend_since = None
        acc = 0
        for qid, (t, s) in enumerate(zip(arrivals, sizes)):
            remaining = int(s)
            # split large queries into sub-batches
            while remaining > 0:
                take = min(remaining, cfg.batch_size)
                pend.append((qid, t, take))
                if pend_since is None:
                    pend_since = t
                acc += take
                remaining -= take
                while acc >= cfg.batch_size:
                    grab, members, rest = cfg.batch_size, [], []
                    for q, ta, c in pend:
                        u = min(c, grab)
                        grab -= u
                        if u > 0:
                            members.append((q, ta))
                        if c - u > 0:
                            rest.append((q, ta, c - u))
                    pend = rest
                    acc -= cfg.batch_size
                    batches.append((t, cfg.batch_size, members))
                    pend_since = t if pend else None
        if pend:
            batches.append((arrivals[-1] if len(arrivals) else 0.0,
                            acc, [(q, ta) for q, ta, _ in pend]))

        # ---- discrete-event pipeline
        t_pre, t_comm, t_sparse_total, t_dense = self._times(cfg.batch_size)
        cn_free = np.zeros(self.n)            # G_P servers
        gpu_free = np.zeros(self.n)           # G_D servers
        mn_free = np.zeros(self.m)            # MN servers
        mn_queue_release = 0.0                # sequential barrier clock
        fail_until = {"cn": np.zeros(self.n), "mn": np.zeros(self.m)}
        n_failures = 0

        if cfg.inject_failures:
            # window-scaled: P(fail in window) = daily_rate * window/86400
            frac = cfg.duration_s / 86400.0
            for kind, count, rate in (("cn", self.n, fail_mod.hw.FAIL_CN),
                                      ("mn", self.m, fail_mod.hw.FAIL_MN)):
                p = min(1.0, rate * frac)
                for i in range(count):
                    if rng.rand() < p:
                        t = rng.uniform(0, cfg.duration_s)
                        fail_until[kind][i] = (
                            t + fail_mod.recovery_cost_s(kind))
                        n_failures += 1

        query_done: Dict[int, float] = {}
        query_arr: Dict[int, float] = {}
        query_parts: Dict[int, int] = {}
        for t, b, members in batches:
            for q, ta in members:
                query_arr[q] = min(query_arr.get(q, np.inf), ta)
                query_parts[q] = query_parts.get(q, 0) + 1

        stragglers = 0
        nb = len(batches)
        scales = np.array([b / cfg.batch_size for _, b, _ in batches])
        pre_done = np.empty(nb)
        cn_of = np.empty(nb, np.int64)

        # ---- G_P on the least-loaded CN
        for bi, (formed, bsize, members) in enumerate(batches):
            i = int(np.argmin(np.maximum(cn_free, fail_until["cn"])))
            start = max(formed, cn_free[i], fail_until["cn"][i])
            pre_done[bi] = start + t_pre * scales[bi]
            cn_free[i] = pre_done[bi]
            cn_of[bi] = i

        # ---- MN stage: per-batch packet arrivals and service demands.
        # The CN back-end NIC serializes the m packet sends, so a batch's
        # packets arrive staggered across MNs (the interleaving window).
        pk_service = (t_sparse_total / self.m)
        send_order = np.stack([rng.permutation(self.m) for _ in range(nb)])
        stagger = send_order * (t_comm * scales[:, None] / self.m)
        pk_arrive = (pre_done[:, None] + stagger
                     + rng.uniform(0, cfg.net_jitter_s, (nb, self.m)))
        batch_factor = np.maximum(
            0.2, rng.lognormal(0.0, cfg.batch_cv, (nb, 1)))
        pk_time = (pk_service * scales[:, None] * batch_factor * np.maximum(
            0.2, rng.lognormal(0.0, cfg.service_cv, (nb, self.m))))
        lim = pk_service * scales[:, None] * cfg.straggler_factor
        over = pk_time > lim
        stragglers = int(over.sum())
        pk_time = np.where(over, lim + pk_service * scales[:, None], pk_time)

        sparse_done = np.empty(nb)
        if cfg.policy == SEQUENTIAL:
            # global manager: lock-step in pre-completion order
            barrier = float(fail_until["mn"].max())
            for bi in np.argsort(pre_done, kind="stable"):
                start_s = max(barrier, float(pk_arrive[bi].max()))
                done_s = start_s + float(pk_time[bi].max())
                barrier = done_s
                sparse_done[bi] = done_s
        else:
            # interleaved: per-MN processor sharing (packets of concurrent
            # queries alternate at fine grain, FCFS across packet slices)
            done_each = np.empty((nb, self.m))
            for j in range(self.m):
                done_each[:, j] = _ps_schedule(
                    pk_arrive[:, j],
                    pk_time[:, j],
                    float(fail_until["mn"][j]),
                    overhead=cfg.ps_overhead)
            sparse_done = done_each.max(axis=1)

        # ---- gather + G_D in sparse-completion order
        for bi in np.argsort(sparse_done, kind="stable"):
            i = cn_of[bi]
            g_start = max(sparse_done[bi] + 0.5 * t_comm * scales[bi],
                          gpu_free[i])
            done = g_start + t_dense * scales[bi]
            gpu_free[i] = done
            for q, _ in batches[bi][2]:
                query_parts[q] -= 1
                if query_parts[q] == 0:
                    query_done[q] = done

        lats = np.array([query_done[q] - query_arr[q]
                         for q in query_done
                         if query_arr[q] >= cfg.warmup_s])
        if len(lats) == 0:
            return SimStats(0, 0, 0, 0, 0, 0, failures=n_failures)
        horizon = cfg.duration_s - cfg.warmup_s
        return SimStats(
            throughput_qps=len(lats) / horizon,
            mean_latency=float(lats.mean()),
            p50=float(np.percentile(lats, 50)),
            p95=float(np.percentile(lats, 95)),
            p99=float(np.percentile(lats, 99)),
            completed=len(lats),
            failures=n_failures,
        )

    def latency_bounded_qps(self, sla: float, lo: float = 1.0,
                            hi: Optional[float] = None,
                            iters: int = 12) -> float:
        """Pressure test: binary-search max rate with p95 <= SLA."""
        if hi is None:
            hi = self.um.peak_qps() / QueryDist().mean_size * 2.0
        best = 0.0
        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            st = self.run(mid)
            if st.p95 <= sla and st.completed > 0:
                best, lo = mid, mid
            else:
                hi = mid
        return best
