"""Autoscaling for the elastic ClusterEngine: a schedule-driven diurnal
policy (paper §III, Fig. 2b/11) and a feedback-driven SLA controller.

Two complementary controllers live here:

- :class:`Autoscaler` — *schedule-driven*: maps the diurnal load curve
  onto timed ``ResizeEvent``s ahead of time.  Right when demand is
  forecastable (the paper's provisioning argument), blind to surprises.
- :class:`SLAController` — *feedback-driven*: watches a sliding window
  of measured completion latencies against an SLA target on p99
  (``ScenarioSpec.sla_p99_s``) and emits ``Resize`` events through the
  live typed timeline the moment the measured tail leaves the band —
  scale up when p99 breaches the target, scale back down once it falls
  below ``band_low x`` target.  Right when demand is NOT forecastable
  (flash crowds, spikes compounded with failures — Gupta et al.'s
  bursty production traffic).

The paper's provisioning argument: a fixed-proportion deployment pins the
peak-hour {n CN, m MN} all day, and the diurnal trough (~40% of peak,
Fig. 2b) turns up to 30% of TCO into idle units (Fig. 11).
Disaggregation fixes the *shape* of the waste — compute can follow the
load curve independently, while the memory pool only ever shrinks to its
capacity floor (the replicated embedding tables must stay resident).  A
monolithic fleet cannot make that split: every server carries both parts,
so its floor is the number of servers needed to HOLD the model, no matter
how low the load falls.

`Autoscaler` turns that policy into timed `ResizeEvent`s that
``ClusterEngine.serve`` consumes alongside failure events, and into
per-step {n, m} series for the TCO accounting in
``benchmarks/bench_elastic.py``.  Per-node service rates come from the
same analytic `ServingUnitModel` capacities the allocator uses, so the
elastic plan and the failure-aware allocation (`core/allocator.py`,
Eq. 1-3) are cross-checkable: a fixed-peak plan's idle unit-hours equal
``AllocationPlan.idle_units`` x the horizon.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import List, NamedTuple, Optional, Sequence, Tuple

from repro.configs import counting
from repro.core import hardware as hw
from repro.core.allocator import diurnal_load
from repro.core.hardware import NODE_TYPES
from repro.core.serving_unit import ServingUnitModel, UnitSpec
from repro.serving.scenario import Resize, nearest_rank


class ResizeEvent(NamedTuple):
    """One timed resize; unpacks as the (time_s, n_cn, m_mn) tuple
    ``ClusterEngine.serve(resizes=...)`` expects."""
    time_s: float
    n_cn: int
    m_mn: int


@dataclass(frozen=True)
class AutoscalerConfig:
    qps_per_cn: float             # compute-side samples/s one CN sustains
    qps_per_mn: float             # scan-side samples/s one MN sustains
    min_cn: int = 1
    min_mn: int = 1               # capacity floor: replicas stay resident
    max_cn: Optional[int] = None
    max_mn: Optional[int] = None
    headroom: float = hw.LOAD_VARIANCE_R   # R% load-variance margin


def _clamp(v: int, lo: int, hi: Optional[int]) -> int:
    v = max(lo, v)
    return v if hi is None else min(v, hi)


class Autoscaler:
    """Demand-following sizing: n_cn tracks the load curve, m_mn tracks
    scan bandwidth demand but never drops below the capacity floor."""

    def __init__(self, cfg: AutoscalerConfig):
        if cfg.qps_per_cn <= 0 or cfg.qps_per_mn <= 0:
            raise ValueError("per-node service rates must be positive")
        self.cfg = cfg

    # ------------------------------------------------------ constructors
    @classmethod
    def for_model(cls, model_cfg, cn_type: str = "cn_1g",
                  mn_type: str = "ddr_mn", n_replicas: int = 2,
                  max_cn: Optional[int] = None,
                  max_mn: Optional[int] = None,
                  headroom: float = hw.LOAD_VARIANCE_R) -> "Autoscaler":
        """Derive per-node service rates from the analytic unit model of
        a {1 CN, 1 MN} cell — the same capacities() the allocator's
        QPS_{M,S} characterization uses."""
        um = ServingUnitModel(model_cfg, UnitSpec(1, cn_type, 1, mn_type))
        caps = um.capacities()
        qps_cn = min(caps["pre"], caps["dense"],
                     caps.get("comm", math.inf))
        qps_mn = caps["sparse"]
        size = counting.dlrm_size_bytes(model_cfg)
        mn_cap = NODE_TYPES[mn_type].mem_capacity
        min_mn = max(1, math.ceil(n_replicas * size / mn_cap))
        return cls(AutoscalerConfig(
            qps_per_cn=qps_cn, qps_per_mn=qps_mn, min_cn=1, min_mn=min_mn,
            max_cn=max_cn, max_mn=max_mn, headroom=headroom))

    @classmethod
    def monolithic(cls, model_cfg, server_type: str = "so1s_1g",
                   headroom: float = hw.LOAD_VARIANCE_R) -> "Autoscaler":
        """Elastic *monolithic* fleet: one node type carries compute AND
        memory, so the scale-down floor is the server count needed to
        hold the sharded model — the coupling the paper's Fig. 11
        charges for.  `units_for` reports (n_servers, 0)."""
        um = ServingUnitModel(model_cfg,
                              UnitSpec(1, server_type, scheme="distributed"))
        qps = min(um.capacities().values())
        size = counting.dlrm_size_bytes(model_cfg)
        floor = max(1, math.ceil(size / NODE_TYPES[server_type].mem_capacity))
        return cls(AutoscalerConfig(
            qps_per_cn=qps, qps_per_mn=math.inf, min_cn=floor, min_mn=0))

    # ------------------------------------------------------------ policy
    def units_for(self, load: float) -> Tuple[int, int]:
        c = self.cfg
        need = (1.0 + c.headroom) * max(load, 0.0)
        n = _clamp(math.ceil(need / c.qps_per_cn), c.min_cn, c.max_cn)
        if math.isinf(c.qps_per_mn):
            m = _clamp(0, c.min_mn, c.max_mn)
        else:
            m = _clamp(math.ceil(need / c.qps_per_mn), c.min_mn, c.max_mn)
        return n, m

    def series(self, peak_load: float, steps: int = 96
               ) -> List[Tuple[int, int]]:
        """Per-step {n_cn, m_mn} over one diurnal day (Fig. 2b)."""
        return [self.units_for(L) for L in diurnal_load(peak_load, steps)]

    def plan(self, peak_load: float, duration_s: float = 86400.0,
             steps: int = 96) -> List[ResizeEvent]:
        """Timed resize events over `duration_s` (the diurnal shape is
        mapped onto the horizon): one event per step where the required
        pool size changes, including the t=0 snap to the plan start."""
        out: List[ResizeEvent] = []
        prev: Optional[Tuple[int, int]] = None
        for i, (n, m) in enumerate(self.series(peak_load, steps)):
            if (n, m) != prev:
                out.append(ResizeEvent(i * duration_s / steps, n, m))
                prev = (n, m)
        return out


# ---------------------------------------------------- SLA feedback loop
@dataclass(frozen=True)
class SLAControllerConfig:
    """Feedback-control knobs.  The controller holds measured p99 inside
    ``[band_low * sla_p99_s, sla_p99_s]``: above the target it scales
    up by ``step``; below the lower band edge it scales back down —
    hysteresis that keeps a noisy tail from thrashing the pool.
    ``window`` completions form the sliding p99 estimate (nearest-rank,
    the serving layer's percentile convention) and ``cooldown``
    completions must pass between actions; the window is cleared on
    every emission, so each resize's effect is *measured* before the
    next decision (a stale window would re-trigger on the same breach).

    ``mode`` picks the scaling split — the paper's decoupled-scaling
    claim applied to feedback control:

    - ``coupled`` (default): a breach steps both pools in lockstep.
    - ``decoupled``: a breach is attributed to the *binding* pool via
      the dispatcher's per-node queueing pressure — scale CNs for a
      compute/gather-bound tail, MNs for a scan/bus-bound tail, and
      both only when the two pressures sit within a ``mix_band`` factor
      of each other (genuinely mixed).  Scale-down releases both pools
      toward their floors; every emitted ``Resize`` carries only the
      dims that actually change (partial events)."""
    sla_p99_s: float
    window: int = 32
    band_low: float = 0.5
    cooldown: int = 16
    step: int = 1
    max_scale: int = 4            # pool ceiling: max_scale x initial
    mode: str = "coupled"         # coupled | decoupled
    mix_band: float = 2.0         # decoupled: pressures within this
                                  # factor of each other scale both


class SLAController:
    """Measured-p99 feedback autoscaler.

    The dispatcher calls :meth:`observe` once per query completion with
    the virtual finish time and measured latency; the controller
    returns ``Resize`` events to enqueue into the live timeline (empty
    list almost always).  The initial topology is the scale-*down*
    floor — the replicated embedding tables were provisioned for that
    pool, so the controller only ever adds capacity on top and releases
    it again (the paper's capacity-floor argument, applied to feedback
    control).  Emission timestamps are clamped monotone so the audit
    trail stays time-ordered.
    """

    def __init__(self, cfg: SLAControllerConfig, n_cn: int, m_mn: int):
        if cfg.sla_p99_s <= 0:
            raise ValueError("sla_p99_s must be positive")
        if cfg.window < 1 or cfg.cooldown < 0 or cfg.step < 1:
            raise ValueError("window/cooldown/step out of range")
        if not 0.0 <= cfg.band_low < 1.0:
            raise ValueError("band_low must be in [0, 1)")
        if cfg.max_scale < 1:
            raise ValueError("max_scale must be >= 1")
        if cfg.mode not in ("coupled", "decoupled"):
            raise ValueError(f"unknown SLA controller mode {cfg.mode!r}")
        if cfg.mix_band < 1.0:
            raise ValueError("mix_band must be >= 1")
        self.cfg = cfg
        self.min_cn, self.min_mn = int(n_cn), int(m_mn)
        self.max_cn = self.min_cn * cfg.max_scale
        self.max_mn = self.min_mn * cfg.max_scale
        self.n_cn, self.m_mn = self.min_cn, self.min_mn
        self._lats: deque = deque(maxlen=cfg.window)
        self._since = 0             # completions since the last action
        self._last_emit = 0.0
        self.actions: List[Resize] = []     # every event ever emitted
        self.window_filled = False  # ever saw a full p99 window (a run
                                    # shorter than cfg.window can never
                                    # trigger an action — surfaced as
                                    # ClusterStats.sla_window_filled)

    def p99(self) -> float:
        """Current sliding-window p99 (nan until anything completed)."""
        return nearest_rank(list(self._lats), 99)

    def sync_pool(self, n_cn: int, m_mn: int) -> None:
        """Align the controller's internal pool view with the actual
        live pool, clamped to this controller's [min, max] bounds.

        A lone controller never needs this — its own emissions are the
        only pool movements, so the view tracks by construction.  Under
        fleet serving several controllers share one pool: the dispatcher
        calls this on every applied Resize so a controller whose peer
        (or a scheduled event) moved the pool steps relative to reality
        instead of its stale view."""
        self.n_cn = max(self.min_cn, min(int(n_cn), self.max_cn))
        self.m_mn = max(self.min_mn, min(int(m_mn), self.max_mn))

    def observe(self, t_done_s: float, latency_s: float,
                pressure: Optional[Tuple[float, float]] = None
                ) -> List[Resize]:
        """Feed one completion; returns the Resize events to enqueue.

        ``pressure`` is the dispatcher's per-node accumulated queueing
        seconds per pool ``(cn, mn)`` — the binding-pool attribution
        signal decoupled mode scales by (coupled mode ignores it)."""
        self._lats.append(float(latency_s))
        self._since += 1
        if len(self._lats) < self.cfg.window:
            return []
        self.window_filled = True
        if self._since < self.cfg.cooldown:
            return []
        p99 = self.p99()
        n, m = self.n_cn, self.m_mn
        if p99 > self.cfg.sla_p99_s:
            up_cn = up_mn = True
            if self.cfg.mode == "decoupled" and pressure is not None:
                cn_p, mn_p = pressure
                # binding-pool attribution: scale the pool whose
                # per-node queueing dominates; both only when the two
                # pressures sit within a mix_band factor (genuinely
                # mixed).  Equal (e.g. both-zero) pressure degenerates
                # to the coupled step.
                up_cn = cn_p * self.cfg.mix_band >= mn_p
                up_mn = mn_p * self.cfg.mix_band >= cn_p
            if up_cn:
                n = min(n + self.cfg.step, self.max_cn)
            if up_mn:
                m = min(m + self.cfg.step, self.max_mn)
        elif p99 < self.cfg.band_low * self.cfg.sla_p99_s:
            n = max(n - self.cfg.step, self.min_cn)
            m = max(m - self.cfg.step, self.min_mn)
        if (n, m) == (self.n_cn, self.m_mn):
            return []
        # partial event: only the dims that change ride on the Resize
        # (timeline accepts n_cn=None/m_mn=None as "keep")
        dn = n if n != self.n_cn else None
        dm = m if m != self.m_mn else None
        self.n_cn, self.m_mn = n, m
        self._since = 0
        # every completion in the window predates this action; measuring
        # them again would double-step the same breach before the
        # resize's effect shows (real whenever cooldown < window)
        self._lats.clear()
        self._last_emit = max(self._last_emit, float(t_done_s))
        ev = Resize(self._last_emit, n_cn=dn, m_mn=dm)
        self.actions.append(ev)
        return [ev]


# ------------------------------------------------------- TCO accounting
def node_hours(series: Sequence[Tuple[int, int]],
               duration_s: float = 86400.0) -> Tuple[float, float]:
    """(CN, MN) node-hours consumed by a per-step {n, m} series."""
    step_h = duration_s / 3600.0 / len(series)
    return (sum(n for n, _ in series) * step_h,
            sum(m for _, m in series) * step_h)


def idle_node_hours(series: Sequence[Tuple[int, int]],
                    duration_s: float = 86400.0) -> Tuple[float, float]:
    """Node-hours a fixed-peak deployment of the same series would idle:
    per step, (peak - demanded) for each pool."""
    n_pk = max(n for n, _ in series)
    m_pk = max(m for _, m in series)
    step_h = duration_s / 3600.0 / len(series)
    return (sum(n_pk - n for n, _ in series) * step_h,
            sum(m_pk - m for _, m in series) * step_h)


def energy_joules(series: Sequence[Tuple[int, int]], cn_type: str,
                  mn_type: str = "ddr_mn",
                  duration_s: float = 86400.0) -> float:
    """Energy of running the series for `duration_s` (constraint (3))."""
    p_cn = NODE_TYPES[cn_type].power
    p_mn = NODE_TYPES[mn_type].power if mn_type else 0.0
    step_s = duration_s / len(series)
    return sum((n * p_cn + m * p_mn) * step_s for n, m in series)
