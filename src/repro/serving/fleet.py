"""Multi-model fleet serving on one shared disaggregated pool.

A *fleet* spec (``ScenarioSpec.models`` with more than one
:class:`~repro.serving.scenario.ModelRef`) serves several DLRMs
concurrently over a single {n CN, m MN} pool instead of one isolated
pool per model.  This module owns the fleet-specific front half:

- :func:`build_fleet` materializes each member (config -> model ->
  seeded params);
- :func:`plan_fleet_workload` builds the merged request stream — one
  seeded :class:`~repro.data.queries.ArrivalProcess` per model, rates
  split by ``ModelRef.rate_share``, re-split mid-run by
  :class:`~repro.serving.scenario.ShiftTraffic` events (aggregate rate
  conserved), with per-model ``SetWorkload`` phases re-shaping only the
  scoped model's query distribution;
- :func:`run_fleet` drives :class:`~repro.serving.cluster.ClusterEngine`
  in fleet mode — model-tagged routing through the shared CN pool,
  owner-scoped placement/hotness on the shared MN pool, per-model cache
  budget partitions — with one ``SLAController`` per model sharing the
  pool (``ModelRef.sla_p99_s`` overriding the spec-level target).

``run_scenario`` delegates here for fleet specs; a one-model fleet
normalizes to the single-model spec in ``ScenarioSpec.__post_init__``
and never reaches this module — that is what pins single-model runs
bitwise-identical to the historical path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.queries import ArrivalProcess, QueryDist, dlrm_batch
from repro.serving.cluster import ClusterEngine
from repro.serving.engine import Request
from repro.serving.scenario import (PhasePlan, PhaseStats, ScenarioReport,
                                    ScenarioSpec, SetWorkload, ShiftTraffic,
                                    _lat_stats, sort_events)


@dataclass
class FleetModel:
    """One materialized fleet member: the spec's ModelRef resolved to a
    built model and its seeded parameters."""
    name: str
    ref: object                  # the spec's ModelRef
    model: object
    params: object


def build_fleet(spec: ScenarioSpec) -> List[FleetModel]:
    """Materialize every ``spec.models`` entry (reduced or full config,
    seeded init), in fleet order — member k of the returned list is
    model index k everywhere downstream (requests, batches, stats)."""
    from repro import configs
    from repro.models import registry
    out: List[FleetModel] = []
    for mref in spec.models:
        cfg = (configs.get_reduced(mref.arch) if mref.reduced
               else configs.get_config(mref.arch))
        model = registry.build(cfg)
        out.append(FleetModel(name=mref.arch, ref=mref, model=model,
                              params=model.init(mref.init_seed)))
    return out


def _fleet_seed(seed: int, k: int) -> int:
    """Derived per-model seed: member 0 keeps the workload seed, later
    members decorrelate through a large odd stride (stable across runs,
    never a bitwise contract — fleets have no legacy stream to match)."""
    return (seed + 1000003 * k) % (2 ** 31)


def plan_fleet_workload(spec: ScenarioSpec, fleet: Sequence[FleetModel]
                        ) -> Tuple[List[Request], List[PhasePlan]]:
    """Build the fleet's merged request stream.

    Each model runs its own seeded ``ArrivalProcess`` at rate
    ``share_k / gap_s`` (shares = normalized ``rate_share``); the merged
    stream takes the earliest pending candidate (ties break to the
    lowest model index).  Events are consumed in time order at stream
    build, exactly like single-model ``plan_workload``:

    - unscoped ``SetWorkload``: re-shapes every model's distribution;
      a ``gap_s`` change moves the *aggregate* rate, realigning every
      arrival process at the event time.
    - model-scoped ``SetWorkload`` (``model=...``): re-shapes only that
      model's query distribution (per-model phases).  Scoped rate
      changes are expressed through ``ShiftTraffic``, never ``gap_s`` —
      validation enforces this.
    - ``ShiftTraffic``: moves ``share`` points of rate share from one
      model to the other, conserving the aggregate rate; both affected
      processes realign at the event time (a share hitting zero silences
      that model until a later shift restores it).

    Every event starts a new :class:`PhasePlan` over a contiguous rid
    range of the merged stream (arrivals are accepted in global time
    order, so ranges stay contiguous even though models interleave).
    Scoped-event phases are labeled with the target model's resolved
    distribution; the recorded ``gap_s`` is always the aggregate gap.

    Sizes and payloads draw from per-model derived RNGs, sampled at
    acceptance under the owning model's phase distribution — one
    model's traffic never moves another's query contents.
    """
    w = spec.workload
    n_models = len(spec.models)
    events = sort_events([e for e in spec.events
                          if isinstance(e, (SetWorkload, ShiftTraffic))])
    name_to_k = {m.arch: k for k, m in enumerate(spec.models)}

    total_share = sum(m.rate_share for m in spec.models)
    shares = [m.rate_share / total_share for m in spec.models]
    agg_gap = w.gap_s
    # per-model query-distribution state (SetWorkload re-shapes it)
    cur = [{"mean_size": w.mean_size, "sigma": w.sigma,
            "max_size": w.max_size, "alpha": w.alpha}
           for _ in range(n_models)]

    def model_gap(k: int) -> float:
        return agg_gap / shares[k] if shares[k] > 0 else math.inf

    # validation guarantees every initial rate_share is positive, so
    # every process starts live; a ShiftTraffic draining a model to
    # zero share parks its candidate at +inf until a later shift
    # restores it
    procs = [ArrivalProcess(w.arrival, model_gap(k),
                            seed=_fleet_seed(w.seed, k),
                            burstiness=w.burstiness)
             for k in range(n_models)]
    cand = [procs[k].next() for k in range(n_models)]

    phases = [PhasePlan(index=0, t_start=0.0, gap_s=agg_gap, **cur[0])]
    # (arrival time, model, phase id, distribution snapshot) per
    # accepted request, in global time order — snapshotting at
    # acceptance keeps per-model phase distributions exact without a
    # second event replay
    accepted: List[Tuple[float, int, int, Dict[str, float]]] = []
    ev_i = 0
    for i in range(w.requests):
        t = min(cand)
        while ev_i < len(events) and events[ev_i].time_s <= t:
            ev = events[ev_i]
            ev_i += 1
            label_k = 0
            if isinstance(ev, SetWorkload):
                targets = ([name_to_k[ev.model]] if ev.model is not None
                           else list(range(n_models)))
                label_k = targets[0]
                for k in targets:
                    for name in ("mean_size", "sigma", "max_size",
                                 "alpha"):
                        v = getattr(ev, name)
                        if v is not None:
                            cur[k][name] = v
                if ev.gap_s is not None:        # unscoped by validation
                    agg_gap = ev.gap_s
                    for k in range(n_models):
                        if shares[k] > 0:
                            procs[k].realign(ev.time_s, model_gap(k))
                            cand[k] = procs[k].next()
            else:                               # ShiftTraffic
                kf = name_to_k[ev.from_model]
                kt = name_to_k[ev.to_model]
                shares[kf] = max(0.0, shares[kf] - ev.share)
                shares[kt] += ev.share
                for k in (kf, kt):
                    if shares[k] > 0:
                        procs[k].realign(ev.time_s, model_gap(k))
                        cand[k] = procs[k].next()
                    else:
                        cand[k] = math.inf
            phases.append(PhasePlan(
                index=len(phases), t_start=ev.time_s, gap_s=agg_gap,
                rid_start=i, rid_end=i, **cur[label_k]))
            t = min(cand)
        k = min(range(n_models), key=lambda m: (cand[m], m))
        accepted.append((cand[k], k, len(phases) - 1, dict(cur[k])))
        cand[k] = procs[k].next()

    rngs = [np.random.RandomState(_fleet_seed(w.seed, k))
            for k in range(n_models)]
    reqs: List[Request] = []
    for rid, (t, k, pid, c) in enumerate(accepted):
        qd = QueryDist(mean_size=c["mean_size"], sigma=c["sigma"],
                       max_size=c["max_size"], alpha=c["alpha"])
        size = int(qd.sample(rngs[k], 1)[0])
        b = dlrm_batch(fleet[k].model.cfg, size, rngs[k],
                       alpha=c["alpha"])
        reqs.append(Request(rid, {"dense": b["dense"],
                                  "indices": b["indices"]},
                            size, t, model=k))
        phases[pid].rid_end = rid + 1
    return reqs, phases


def run_fleet(spec: ScenarioSpec,
              fleet: Optional[Sequence[FleetModel]] = None
              ) -> ScenarioReport:
    """Serve a fleet spec end to end: build (or accept) the fleet,
    plan the merged stream, run the shared-pool engine with one SLA
    controller per model, and fold the outcome into the standard
    :class:`ScenarioReport` (with ``stats.per_model`` populated).

    ``fleet`` is an injection hook for tests that serve hand-built tiny
    models; the caller owns the invariant that it matches
    ``spec.models`` in order and count."""
    spec.validate()
    if len(spec.models) < 2:
        raise ValueError("run_fleet needs a multi-model spec; "
                         "single-model specs take run_scenario")
    members = list(fleet) if fleet is not None else build_fleet(spec)
    if len(members) != len(spec.models):
        raise ValueError(
            f"fleet has {len(members)} member(s) for "
            f"{len(spec.models)} spec model(s)")
    reqs, phases = plan_fleet_workload(spec, members)
    engine = ClusterEngine(
        members[0].model, members[0].params,
        spec.topology.cluster_config(seed=spec.workload.seed),
        fleet=[(f.name, f.model, f.params) for f in members])
    controllers: Dict[int, object] = {}
    for k, mref in enumerate(spec.models):
        target = (mref.sla_p99_s if mref.sla_p99_s is not None
                  else spec.sla_p99_s)
        if target is not None:
            from repro.serving.autoscaler import (SLAController,
                                                  SLAControllerConfig)
            controllers[k] = SLAController(
                SLAControllerConfig(sla_p99_s=target, mode=spec.sla_mode),
                n_cn=spec.topology.n_cn, m_mn=spec.topology.m_mn)
    results, stats = engine.serve(reqs, events=spec.events,
                                  controllers=controllers or None)
    by_rid = {r.rid: r for r in results}
    phase_stats = []
    for ph in phases:
        lats = [by_rid[r].latency for r in range(ph.rid_start, ph.rid_end)
                if r in by_rid]
        mean, p50, p95, p99 = _lat_stats(lats)
        phase_stats.append(PhaseStats(
            index=ph.index, t_start=ph.t_start, alpha=ph.alpha,
            gap_s=ph.gap_s, mean_size=ph.mean_size, requests=ph.requests,
            completed=len(lats), mean_latency=mean, p50=p50, p95=p95,
            p99=p99))
    return ScenarioReport(
        name=spec.name, completed=stats.completed, total=len(reqs),
        final_n_cn=engine.n_cn, final_m_mn=engine.m_mn,
        mn_types=tuple(engine.mn_types), stats=stats, phases=phase_stats,
        latency_model=engine.validate_latency_model(), results=results,
        engine=engine)
