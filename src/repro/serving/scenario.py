"""Declarative scenario API: typed event timelines, one front door.

The paper's core claims (§III-§V) are *scenario* claims — what happens
to latency, TCO, and reliability when MNs fail and recover, pools
resize diurnally, traffic skew drifts, and hardware generations mix.
This module makes a scenario a **value**: a frozen :class:`ScenarioSpec`
holding the cluster topology, the workload (with timed phase changes),
and a typed, time-ordered event timeline — with dict/JSON round-trip
serde so scenarios are files (``examples/scenarios/*.json``), not code.

Event types (all carry ``time_s``, the virtual-clock fire time):

========================  ==============================================
:class:`FailMN`           kill MN ``mn`` (replica re-route / reinit)
:class:`RecoverMN`        bring a failed MN back — *timed* recoveries
:class:`Resize`           elastic pool resize to {n_cn, m_mn}
:class:`ReloadParams`     DLRM weight reload (re-init from ``seed``)
:class:`ReplanPlacement`  re-place tables from *measured* hotness
:class:`SetWorkload`      mid-stream workload phase change (Zipf alpha,
                          arrival rate, query-size distribution;
                          ``model=`` scopes it to one fleet model)
:class:`ShiftTraffic`     move rate share from one fleet model to
                          another mid-stream (workload evolution)
========================  ==============================================

**Ordering guarantees.**  The timeline dispatcher
(``serving.timeline.TimelineDispatcher``) consumes one unified queue in
global time order; events at equal times fire in their listed order
(stable sort).  ``FailMN`` is the only event with intra-stage
semantics: a failure whose timestamp lands inside a batch's MN stage
hits packets in flight and re-issues that batch on the survivors; every
other event applies at the next batch boundary on the virtual clock.
``SetWorkload`` is consumed when the request stream is *built*
(:func:`plan_workload`) and is audit-only at dispatch time.

**Legacy parity.**  ``ClusterEngine.serve(failures=, resizes=)`` is now
a thin shim that converts the bare tuples into ``FailMN``/``Resize``
events (failures before resizes at equal times — the historical
tie-break), so legacy-kwarg runs score bitwise-identically to their
``ScenarioSpec`` equivalents (``tests/test_scenario.py`` pins a grid).

:func:`run_scenario` is the single entry point: build the model, build
the phased request stream, serve through the engine, and return a
:class:`ScenarioReport` with per-phase stats and the per-event audit
trail.  ``python -m repro.serving.scenario_cli *.json`` (note the
``_cli`` wrapper — running this module with ``-m`` executes it twice)
lints scenario files; ``--run`` executes them; ``--write-presets DIR``
re-emits the named preset library.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import sys
from dataclasses import dataclass, field
from typing import (Any, ClassVar, Dict, List, Optional, Sequence, Tuple,
                    Type)

import numpy as np

from repro.core.hardware import NODE_TYPES
from repro.data.queries import (ARRIVALS, ArrivalProcess, QueryDist,
                                dlrm_batch, load_trace)
from repro.serving.cluster import (CN_ROUTERS, ClusterConfig, ClusterEngine,
                                   ClusterStats, _validate_mn_types)
from repro.serving.engine import Request, Result


# ---------------------------------------------------------------- events
@dataclass(frozen=True)
class ScenarioEvent:
    """Base timeline event: fires at ``time_s`` on the virtual clock."""
    time_s: float
    kind: ClassVar[str] = "event"

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"type": self.kind, "time_s": self.time_s}
        for f in dataclasses.fields(self):
            if f.name == "time_s":
                continue
            v = getattr(self, f.name)
            if v is not None:
                d[f.name] = v
        return d


@dataclass(frozen=True)
class FailMN(ScenarioEvent):
    """Kill MN ``mn``: replica re-route (fast path) or re-initialize."""
    mn: int = 0
    kind: ClassVar[str] = "fail_mn"


@dataclass(frozen=True)
class RecoverMN(ScenarioEvent):
    """Bring a failed MN back into the pool (routing rebuild only)."""
    mn: int = 0
    kind: ClassVar[str] = "recover_mn"


@dataclass(frozen=True)
class Resize(ScenarioEvent):
    """Elastic resize; ``None`` keeps that pool's current size.  Grows
    add MNs of ``mn_type`` (default: the topology's pool type)."""
    n_cn: Optional[int] = None
    m_mn: Optional[int] = None
    mn_type: Optional[str] = None
    kind: ClassVar[str] = "resize"


@dataclass(frozen=True)
class ReloadParams(ScenarioEvent):
    """DLRM weight reload: re-init params from ``seed`` (``None`` =
    warm reload of the current weights — shards re-materialize and every
    CN cache flushes, values unchanged)."""
    seed: Optional[int] = None
    kind: ClassVar[str] = "reload_params"


@dataclass(frozen=True)
class ReplanPlacement(ScenarioEvent):
    """Re-run node-type-aware placement with *measured* hotness."""
    kind: ClassVar[str] = "replan_placement"


@dataclass(frozen=True)
class SetWorkload(ScenarioEvent):
    """Mid-stream workload phase change: requests arriving at or after
    ``time_s`` use the overridden parameters (``None`` keeps the current
    value).  Consumed by :func:`plan_workload` when the stream is built;
    audit-only inside the dispatcher."""
    alpha: Optional[float] = None         # Zipf row-popularity skew
    gap_s: Optional[float] = None         # inter-arrival gap (rate)
    mean_size: Optional[float] = None     # query-size distribution
    sigma: Optional[float] = None
    max_size: Optional[int] = None
    # fleet scoping: None applies to every model; a model name scopes
    # the change to that model's stream.  A model-scoped event may not
    # set gap_s — per-model rate moves only through ShiftTraffic, so
    # the aggregate arrival rate stays a single knob.
    model: Optional[str] = None
    kind: ClassVar[str] = "set_workload"


@dataclass(frozen=True)
class DegradeMN(ScenarioEvent):
    """Slow MN ``mn``'s memory bus by ``factor`` (>= 1.0; 1.0 restores
    nominal speed) — the straggler-injection event behind the hedged
    re-issue story (FlexEMR's optimistic get).  A degraded MN scans its
    bytes at ``mem_bw / factor``; everything else (routing, scores,
    gather bytes) is untouched, so a run whose degrades all carry
    ``factor=1.0`` is bitwise-identical to one without them."""
    mn: int = 0
    factor: float = 1.0
    kind: ClassVar[str] = "degrade_mn"


@dataclass(frozen=True)
class ShiftTraffic(ScenarioEvent):
    """Move ``share`` points of normalized rate share from fleet model
    ``from_model`` to ``to_model`` at ``time_s`` — the paper's
    "fast-evolving workloads" story as a timeline event.  The aggregate
    arrival rate is conserved; only the per-model split moves.  Like
    ``SetWorkload`` it is consumed when the request stream is built
    (:func:`repro.serving.fleet.plan_fleet_workload`) and audit-only at
    dispatch time.  Requires a multi-model spec."""
    from_model: str = ""
    to_model: str = ""
    share: float = 0.0
    kind: ClassVar[str] = "shift_traffic"


EVENT_TYPES: Dict[str, Type[ScenarioEvent]] = {
    c.kind: c for c in (FailMN, RecoverMN, Resize, ReloadParams,
                        ReplanPlacement, SetWorkload, DegradeMN,
                        ShiftTraffic)
}


def event_from_dict(d: Dict[str, Any]) -> ScenarioEvent:
    d = dict(d)
    kind = d.pop("type", None)
    cls = EVENT_TYPES.get(kind)
    if cls is None:
        raise ValueError(f"unknown scenario event type {kind!r} "
                         f"(known: {sorted(EVENT_TYPES)})")
    if "time_s" not in d:
        raise ValueError(f"{kind} event needs a time_s")
    return _build(cls, d, f"{kind} event")


def sort_events(events: Sequence[ScenarioEvent]) -> List[ScenarioEvent]:
    """The canonical dispatch order: stable sort by fire time — events
    at equal times fire in their listed order."""
    return sorted(events, key=lambda e: e.time_s)


def _is_int(v) -> bool:
    """JSON-sourced ids/counts must be true integers: a fractional MN id
    would land in the engine's dead set without ever matching a real
    node, and a bool is a typo, not a pool size."""
    return isinstance(v, int) and not isinstance(v, bool)


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_events(events: Sequence[ScenarioEvent], m_mn: int) -> None:
    """Schema + schedule-aware bounds validation.

    ``FailMN``/``RecoverMN`` ids are checked against the *schedule-aware
    maximum* pool — the largest ``m_mn`` the timeline provisions at or
    before the event's fire time — not the pool at serve start, so a
    failure scheduled after a timed grow is accepted (the target MN will
    exist when the event fires), while one scheduled *before* the only
    grow that would create its target is rejected (the schedule never
    reaches that pool state in time).  An id whose MN has shrunk away
    *by fire time* stays a runtime no-op (the machine isn't there to
    fail).
    """
    for ev in events:
        t = ev.time_s
        if not _is_num(t) or not math.isfinite(t) or t < 0:
            raise ValueError(f"{ev.kind} event has invalid time_s={t!r}")
        if isinstance(ev, Resize):
            if ev.n_cn is not None and (not _is_int(ev.n_cn)
                                        or ev.n_cn < 1):
                raise ValueError(f"resize event targets n_cn={ev.n_cn!r}")
            if ev.m_mn is not None and (not _is_int(ev.m_mn)
                                        or ev.m_mn < 1):
                raise ValueError(f"resize event targets m_mn={ev.m_mn!r}")
            if ev.mn_type is not None and (
                    ev.mn_type not in NODE_TYPES
                    or NODE_TYPES[ev.mn_type].kind != "mn"):
                raise ValueError(
                    f"resize event adds unknown memory-node type "
                    f"{ev.mn_type!r}")
        elif isinstance(ev, SetWorkload):
            for name, lo in (("alpha", 0.0), ("gap_s", 0.0),
                             ("mean_size", None), ("sigma", 0.0)):
                v = getattr(ev, name)
                if v is None:
                    continue
                if not _is_num(v):
                    raise ValueError(
                        f"set_workload {name} must be a number, "
                        f"got {v!r}")
                if lo is None and v <= 0:
                    raise ValueError(f"set_workload {name} must be > 0")
                if lo is not None and v < lo:
                    raise ValueError(
                        f"set_workload {name} must be >= {lo:g}")
            if ev.max_size is not None and (not _is_int(ev.max_size)
                                            or ev.max_size < 1):
                raise ValueError("set_workload max_size must be an "
                                 "integer >= 1")
            if ev.model is not None and (not isinstance(ev.model, str)
                                         or not ev.model):
                raise ValueError(
                    f"set_workload model must be a non-empty model "
                    f"name when set, got {ev.model!r}")
        elif isinstance(ev, ShiftTraffic):
            for name, v in (("from_model", ev.from_model),
                            ("to_model", ev.to_model)):
                if not isinstance(v, str) or not v:
                    raise ValueError(
                        f"shift_traffic {name} must be a non-empty "
                        f"model name, got {v!r}")
            if ev.from_model == ev.to_model:
                raise ValueError(
                    f"shift_traffic moves share from {ev.from_model!r} "
                    f"to itself")
            if (not _is_num(ev.share) or not math.isfinite(ev.share)
                    or not 0.0 < ev.share <= 1.0):
                raise ValueError(
                    f"shift_traffic share must be in (0, 1] (normalized "
                    f"rate-share points), got {ev.share!r}")
        elif isinstance(ev, ReloadParams):
            if ev.seed is not None and not _is_int(ev.seed):
                raise ValueError(
                    f"reload_params seed must be an integer, "
                    f"got {ev.seed!r}")
        elif isinstance(ev, DegradeMN):
            if (not _is_num(ev.factor) or not math.isfinite(ev.factor)
                    or ev.factor < 1.0):
                raise ValueError(
                    f"degrade_mn factor must be a finite number >= 1.0 "
                    f"(1.0 restores nominal speed), got {ev.factor!r}")
    # bounds pass in fire order: the maximum pool a fail/recover/degrade
    # id may reference is the largest m_mn provisioned AT OR BEFORE its
    # fire time — a grow scheduled after the event cannot justify it
    # (the event would silently no-op against the not-yet-grown pool)
    max_m = int(m_mn)
    for ev in sort_events(events):
        if isinstance(ev, Resize) and ev.m_mn is not None:
            max_m = max(max_m, int(ev.m_mn))
        elif isinstance(ev, (FailMN, RecoverMN, DegradeMN)):
            if not _is_int(ev.mn) or not 0 <= ev.mn < max_m:
                raise ValueError(
                    f"{ev.kind} event targets MN {ev.mn!r} outside the "
                    f"schedule-aware maximum pool of {max_m} at its "
                    f"fire time")


# ------------------------------------------------------------- the spec
@dataclass(frozen=True)
class ModelRef:
    """One DLRM the scenario serves (used when ``run_scenario`` is not
    handed a pre-built model).  Under a fleet spec (several ModelRefs),
    ``rate_share`` is the model's relative slice of the aggregate
    arrival rate (normalized across the fleet) and ``sla_p99_s`` an
    optional per-model SLA target overriding the spec-level one."""
    arch: str = "rm1"
    reduced: bool = True
    init_seed: int = 0
    rate_share: float = 1.0
    sla_p99_s: Optional[float] = None


@dataclass(frozen=True)
class Topology:
    """Cluster shape: the ``ClusterConfig`` fields that describe
    provisioning (the stream seed lives in :class:`Workload`)."""
    n_cn: int = 2
    m_mn: int = 4
    batch_size: int = 32
    max_wait_s: float = 0.002
    n_replicas: int = 2
    use_kernel: bool = True
    cn_type: str = "cn_1g"
    mn_type: str = "ddr_mn"
    mn_types: Optional[Tuple[str, ...]] = None
    cache_mb: float = 0.0
    cache_policy: str = "lru"
    # max batches concurrently inside the MN stage (1 = sequential
    # clock, bitwise-identical to the pre-pipeline model)
    inflight_depth: int = 1
    # batch -> CN placement policy (ClusterConfig.cn_router): cpu_free
    # (legacy, bitwise parity) | pipeline_free | least_outstanding
    cn_router: str = "cpu_free"
    # hedged re-issue of straggling MN scans: a scan whose projected
    # duration exceeds hedge_multiplier x its nominal (degradation-free)
    # duration is re-issued on the fastest live replica at the detection
    # instant — both issues are charged, the first finisher wins.
    # 0.0 disables hedging (the parity default).
    hedge_multiplier: float = 0.0
    # stall before a batch struck by a mid-stage MN failure re-issues
    # (ClusterConfig.mn_recovery_s).  None keeps the engine default
    # (failure-model recovery cost); scenarios running on compressed
    # virtual timescales set an on-scale value.
    mn_recovery_s: Optional[float] = None

    def cluster_config(self, seed: int = 0) -> ClusterConfig:
        extra = ({} if self.mn_recovery_s is None
                 else {"mn_recovery_s": self.mn_recovery_s})
        return ClusterConfig(
            n_cn=self.n_cn, m_mn=self.m_mn, batch_size=self.batch_size,
            max_wait_s=self.max_wait_s, n_replicas=self.n_replicas,
            use_kernel=self.use_kernel, cn_type=self.cn_type,
            mn_type=self.mn_type,
            mn_types=(list(self.mn_types) if self.mn_types is not None
                      else None),
            cache_mb=self.cache_mb, cache_policy=self.cache_policy,
            inflight_depth=self.inflight_depth,
            cn_router=self.cn_router,
            hedge_multiplier=self.hedge_multiplier,
            seed=seed, **extra)


@dataclass(frozen=True)
class Workload:
    """The base workload phase: a seeded heavy-tailed request stream
    (``data.queries.dlrm_request_stream`` convention).  ``SetWorkload``
    events override the distribution/rate parameters from their fire
    time onward; the arrival *process* (``arrival``) is stream-wide —
    phases re-shape its rate (``gap_s``), never its kind."""
    requests: int = 32
    mean_size: float = 8.0
    sigma: float = 1.0
    max_size: int = 64
    alpha: float = 0.0
    gap_s: float = 0.002
    seed: int = 0
    # arrival process: linear | poisson | bursty | trace
    # (data.queries.ArrivalProcess).  linear reproduces the historical
    # evenly-spaced stream byte-for-byte; the stochastic processes draw
    # from a separate derived RNG so payloads never move.
    arrival: str = "linear"
    burstiness: float = 4.0       # bursty: burst/lull rate swing factor
    trace_path: Optional[str] = None   # trace: JSON timestamp file


@dataclass(frozen=True)
class ScenarioSpec:
    """One serving scenario: topology + workload phases + event timeline.

    Frozen and serde-round-trippable: ``from_json(spec.to_json()) ==
    spec`` for every event type.

    ``models`` is the served fleet; the singular ``model`` is kept as a
    constructor/serde alias for single-model specs.  ``__post_init__``
    normalizes the two views (``model is models[0]`` always holds), so
    a one-model fleet spec and a legacy single-model spec are the same
    value and run the same bitwise-identical code path.
    """
    name: str
    description: str = ""
    model: Optional[ModelRef] = None
    models: Tuple[ModelRef, ...] = ()
    topology: Topology = Topology()
    workload: Workload = Workload()
    events: Tuple[ScenarioEvent, ...] = ()
    # SLA target on measured p99 latency (seconds).  When set,
    # run_scenario attaches a feedback SLAController
    # (serving.autoscaler) that watches a sliding window of completion
    # latencies and emits Resize events through the live timeline.
    # None (the default) keeps serving schedule-driven.
    sla_p99_s: Optional[float] = None
    # SLA controller scaling split (SLAControllerConfig.mode): coupled
    # (default — a breach steps both pools in lockstep) | decoupled
    # (binding-pool attribution via per-node queueing pressure emits
    # partial per-pool Resize events).  Only meaningful with sla_p99_s.
    sla_mode: str = "coupled"

    def __post_init__(self):
        models = tuple(self.models)
        if self.model is not None and models:
            if self.model != models[0]:
                if len(models) > 1:
                    raise ValueError(
                        "give either model (single-model alias) or "
                        "models (fleet), not conflicting values of both")
                models = (self.model,)     # dataclasses.replace override
        elif not models:
            models = (self.model if self.model is not None else ModelRef(),)
        object.__setattr__(self, "models", models)
        object.__setattr__(self, "model", models[0])

    # ---------------------------------------------------------- serde
    def to_dict(self) -> Dict[str, Any]:
        d = {
            "name": self.name,
            "description": self.description,
            "models": [_model_ref_dict(m) for m in self.models],
            "topology": {k: (list(v) if isinstance(v, tuple) else v)
                         for k, v in dataclasses.asdict(
                             self.topology).items()},
            "workload": dataclasses.asdict(self.workload),
            "events": [e.to_dict() for e in self.events],
        }
        if self.sla_p99_s is not None:
            d["sla_p99_s"] = self.sla_p99_s
        if self.sla_mode != "coupled":
            d["sla_mode"] = self.sla_mode
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ScenarioSpec":
        d = dict(d)
        if "name" not in d:
            raise ValueError("scenario spec needs a name")
        known = {"name", "description", "model", "models", "topology",
                 "workload", "events", "sla_p99_s", "sla_mode"}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(
                f"unknown scenario section(s): {', '.join(unknown)}")
        if "model" in d and "models" in d:
            raise ValueError("give either 'model' (single-model alias) "
                             "or 'models' (fleet), not both")
        models: Tuple[ModelRef, ...] = ()
        model = None
        if "models" in d:
            lst = d["models"]
            if not isinstance(lst, list) or not lst:
                raise ValueError("models must be a non-empty list of "
                                 "model refs")
            models = tuple(_build(ModelRef, m or {}, "models") for m in lst)
        elif "model" in d:
            model = _build(ModelRef, d["model"] or {}, "model")
        topo = dict(d.get("topology") or {})
        if topo.get("mn_types") is not None:
            topo["mn_types"] = tuple(topo["mn_types"])
        return cls(
            name=d["name"],
            description=d.get("description", ""),
            model=model,
            models=models,
            topology=_build(Topology, topo, "topology"),
            workload=_build(Workload, d.get("workload") or {}, "workload"),
            events=tuple(event_from_dict(e) for e in d.get("events") or ()),
            sla_p99_s=d.get("sla_p99_s"),
            sla_mode=d.get("sla_mode", "coupled"),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2) + "\n"

    @classmethod
    def from_json(cls, s: str) -> "ScenarioSpec":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "ScenarioSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    # ----------------------------------------------------- validation
    def validate(self) -> None:
        t, w = self.topology, self.workload
        for section, name, v in (("topology", "n_cn", t.n_cn),
                                 ("topology", "m_mn", t.m_mn),
                                 ("topology", "batch_size", t.batch_size),
                                 ("topology", "n_replicas", t.n_replicas),
                                 ("topology", "inflight_depth",
                                  t.inflight_depth),
                                 ("workload", "requests", w.requests),
                                 ("workload", "max_size", w.max_size),
                                 ("workload", "seed", w.seed)):
            if not _is_int(v):
                raise ValueError(
                    f"{section} {name} must be an integer, got {v!r}")
        for section, name, v in (("topology", "max_wait_s", t.max_wait_s),
                                 ("topology", "cache_mb", t.cache_mb),
                                 ("topology", "hedge_multiplier",
                                  t.hedge_multiplier),
                                 ("workload", "mean_size", w.mean_size),
                                 ("workload", "sigma", w.sigma),
                                 ("workload", "alpha", w.alpha),
                                 ("workload", "burstiness", w.burstiness),
                                 ("workload", "gap_s", w.gap_s)):
            if not _is_num(v):
                raise ValueError(
                    f"{section} {name} must be a number, got {v!r}")
        if t.n_cn < 1 or t.m_mn < 1:
            raise ValueError(f"topology {{n_cn={t.n_cn}, m_mn={t.m_mn}}} "
                             f"must provision both pools")
        if t.batch_size < 1:
            raise ValueError("topology batch_size must be >= 1")
        if t.n_replicas < 1:
            raise ValueError("topology n_replicas must be >= 1")
        if t.inflight_depth < 1:
            raise ValueError("topology inflight_depth must be >= 1")
        if t.cache_policy not in ("lru", "lfu"):
            raise ValueError(f"unknown cache policy {t.cache_policy!r}")
        if t.cn_router not in CN_ROUTERS:
            raise ValueError(f"unknown cn_router {t.cn_router!r} "
                             f"(known: {CN_ROUTERS})")
        if t.cache_mb < 0:
            raise ValueError("topology cache_mb must be >= 0")
        if t.cn_type not in NODE_TYPES or NODE_TYPES[t.cn_type].kind != "cn":
            raise ValueError(f"unknown compute-node type {t.cn_type!r}")
        if (t.mn_type not in NODE_TYPES
                or NODE_TYPES[t.mn_type].kind != "mn"):
            raise ValueError(f"unknown memory-node type {t.mn_type!r}")
        if t.mn_types is not None:
            _validate_mn_types(t.mn_types, t.m_mn)
        if t.hedge_multiplier < 0:
            raise ValueError("topology hedge_multiplier must be >= 0 "
                             "(0 disables hedged re-issue)")
        if t.mn_recovery_s is not None and (
                not _is_num(t.mn_recovery_s) or t.mn_recovery_s < 0):
            raise ValueError(f"topology mn_recovery_s must be a "
                             f"non-negative number when set, got "
                             f"{t.mn_recovery_s!r}")
        if w.requests < 0:
            raise ValueError("workload requests must be >= 0")
        if w.mean_size <= 0 or w.max_size < 1:
            raise ValueError("workload query sizes must be positive")
        if w.sigma < 0 or w.alpha < 0 or w.gap_s < 0:
            raise ValueError("workload sigma/alpha/gap_s must be >= 0")
        if w.arrival not in ARRIVALS:
            raise ValueError(f"unknown workload arrival process "
                             f"{w.arrival!r} (known: {ARRIVALS})")
        if w.burstiness < 1.0:
            raise ValueError("workload burstiness must be >= 1.0")
        if (w.arrival == "trace") != (w.trace_path is not None):
            raise ValueError(
                "workload trace_path must be set exactly when "
                "arrival='trace' (a path on another process is a "
                "config bug, not a silent no-op)")
        if w.trace_path is not None and not isinstance(w.trace_path, str):
            raise ValueError("workload trace_path must be a string path")
        if self.sla_p99_s is not None and (
                not _is_num(self.sla_p99_s) or self.sla_p99_s <= 0):
            raise ValueError(f"sla_p99_s must be a positive number, "
                             f"got {self.sla_p99_s!r}")
        if self.sla_mode not in ("coupled", "decoupled"):
            raise ValueError(f"unknown sla_mode {self.sla_mode!r} "
                             f"(known: coupled, decoupled)")
        for m in self.models:
            if not isinstance(m.arch, str) or not m.arch:
                raise ValueError(f"model arch must be a non-empty "
                                 f"string, got {m.arch!r}")
            if not isinstance(m.reduced, bool):
                raise ValueError(f"model reduced must be a bool, "
                                 f"got {m.reduced!r}")
            if not _is_int(m.init_seed):
                raise ValueError(f"model init_seed must be an integer, "
                                 f"got {m.init_seed!r}")
            if (not _is_num(m.rate_share) or not math.isfinite(m.rate_share)
                    or m.rate_share <= 0):
                raise ValueError(
                    f"model {m.arch!r} rate_share must be a positive "
                    f"number, got {m.rate_share!r}")
            if m.sla_p99_s is not None and (not _is_num(m.sla_p99_s)
                                            or m.sla_p99_s <= 0):
                raise ValueError(
                    f"model {m.arch!r} sla_p99_s must be a positive "
                    f"number when set, got {m.sla_p99_s!r}")
        names = [m.arch for m in self.models]
        if len(set(names)) != len(names):
            raise ValueError(
                f"fleet models must have distinct arch names, got {names}")
        fleet = len(self.models) > 1
        if fleet and w.arrival == "trace":
            raise ValueError(
                "fleet specs derive one arrival process per model; a "
                "shared timestamp trace cannot be split by rate share "
                "(use linear/poisson/bursty)")
        for ev in self.events:
            if isinstance(ev, SetWorkload) and ev.model is not None:
                if ev.model not in names:
                    raise ValueError(
                        f"set_workload targets unknown model "
                        f"{ev.model!r} (fleet: {names})")
                if ev.gap_s is not None:
                    raise ValueError(
                        "a model-scoped set_workload may not set gap_s "
                        "— move per-model rate with shift_traffic")
            elif isinstance(ev, ShiftTraffic):
                if not fleet:
                    raise ValueError(
                        "shift_traffic needs a multi-model fleet spec")
                for nm in (ev.from_model, ev.to_model):
                    if nm not in names:
                        raise ValueError(
                            f"shift_traffic targets unknown model "
                            f"{nm!r} (fleet: {names})")
        if fleet:
            # simulate the shift chain: no model's share may go negative
            total = sum(m.rate_share for m in self.models)
            shares = {m.arch: m.rate_share / total for m in self.models}
            for ev in sort_events([e for e in self.events
                                   if isinstance(e, ShiftTraffic)]):
                shares[ev.from_model] -= ev.share
                shares[ev.to_model] += ev.share
                if shares[ev.from_model] < -1e-12:
                    raise ValueError(
                        f"shift_traffic @{ev.time_s:g}s moves "
                        f"{ev.share:g} share from {ev.from_model!r}, "
                        f"which only holds "
                        f"{shares[ev.from_model] + ev.share:g} there")
        validate_events(self.events, t.m_mn)


def _model_ref_dict(m: ModelRef) -> Dict[str, Any]:
    """Serde form of one fleet member: single-model defaults
    (rate_share 1.0, no per-model SLA) stay out of the JSON so legacy
    single-model files keep their historical shape."""
    d: Dict[str, Any] = {"arch": m.arch, "reduced": m.reduced,
                         "init_seed": m.init_seed}
    if m.rate_share != 1.0:
        d["rate_share"] = m.rate_share
    if m.sla_p99_s is not None:
        d["sla_p99_s"] = m.sla_p99_s
    return d


def _build(cls, d: Dict[str, Any], section: str):
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - names)
    if unknown:
        raise ValueError(f"unknown {section} field(s): {', '.join(unknown)}")
    return cls(**d)


# --------------------------------------------------- workload planning
@dataclass
class PhasePlan:
    """One resolved workload phase: the distribution in force over a
    contiguous rid range of the generated stream."""
    index: int
    t_start: float
    mean_size: float
    sigma: float
    max_size: int
    alpha: float
    gap_s: float
    rid_start: int = 0
    rid_end: int = 0

    @property
    def requests(self) -> int:
        return self.rid_end - self.rid_start


def plan_workload(spec: ScenarioSpec, model_cfg
                  ) -> Tuple[List[Request], List[PhasePlan]]:
    """Build the scenario's request stream, honoring ``SetWorkload``
    phase changes.

    Arrivals come from the workload's :class:`~repro.data.queries.
    ArrivalProcess` (``linear`` | ``poisson`` | ``bursty`` | ``trace``),
    realigned to each phase's declared start: when a ``SetWorkload``
    fires at ``time_s``, the process restarts from exactly ``time_s``
    under the new ``gap_s`` — for ``linear`` the first post-event
    arrival lands *on* the phase start and subsequent arrivals are
    spaced at the new gap.  (Historical bug, fixed here: the old
    planner re-based on the stale-gap-extrapolated candidate arrival
    instead of the event's ``time_s``, so every later arrival drifted
    by the extrapolation overshoot and the first post-event arrival
    still used the old phase's gap.  No bitwise-compat shim is needed:
    the legacy-parity grid never crosses a phase boundary, and
    single-phase streams are unaffected.)  A request's phase is the one
    whose ``SetWorkload`` fired at or before its arrival.

    One ``np.random.RandomState(workload.seed)`` drives sizes and
    payloads, with sizes sampled per phase chunk, and the arrival
    process draws from a *separate* derived RNG — a single-phase
    ``linear`` scenario therefore reproduces
    ``data.queries.dlrm_request_stream(cfg, n, seed, dist, gap_s)``
    byte-for-byte (payloads AND timestamps), which is what keeps
    legacy-kwarg runs bitwise-equal to their spec equivalents; the
    stochastic processes move only the timestamps.
    """
    w = spec.workload
    sw = sort_events([e for e in spec.events if isinstance(e, SetWorkload)])
    cur = {"mean_size": w.mean_size, "sigma": w.sigma,
           "max_size": w.max_size, "alpha": w.alpha, "gap_s": w.gap_s}
    phases = [PhasePlan(index=0, t_start=0.0, **cur)]
    arrivals: List[float] = []
    pids: List[int] = []
    proc = ArrivalProcess(
        w.arrival, w.gap_s, seed=w.seed, burstiness=w.burstiness,
        trace=(load_trace(w.trace_path) if w.arrival == "trace" else None))
    k = 0
    for i in range(w.requests):
        t = proc.next()
        # a phase change at or before the candidate arrival realigns the
        # process to the event's declared start — the candidate was
        # generated under the stale phase and is discarded
        while k < len(sw) and sw[k].time_s <= t:
            ev = sw[k]
            k += 1
            for name in ("mean_size", "sigma", "max_size", "alpha",
                         "gap_s"):
                v = getattr(ev, name)
                if v is not None:
                    cur[name] = v
            proc.realign(ev.time_s, cur["gap_s"])
            phases.append(PhasePlan(index=len(phases), t_start=ev.time_s,
                                    rid_start=i, rid_end=i, **cur))
            t = proc.next()
        arrivals.append(t)
        pids.append(len(phases) - 1)

    rng = np.random.RandomState(w.seed)
    reqs: List[Request] = []
    i = 0
    n = w.requests
    while i < n:
        j = i
        while j < n and pids[j] == pids[i]:
            j += 1
        ph = phases[pids[i]]
        qd = QueryDist(mean_size=ph.mean_size, sigma=ph.sigma,
                       max_size=ph.max_size, alpha=ph.alpha)
        sizes = qd.sample(rng, j - i)
        for s, a in zip(sizes, arrivals[i:j]):
            b = dlrm_batch(model_cfg, int(s), rng, alpha=ph.alpha)
            reqs.append(Request(len(reqs),
                                {"dense": b["dense"],
                                 "indices": b["indices"]},
                                int(s), a))
        ph.rid_end = j
        i = j
    return reqs, phases


# --------------------------------------------------------- the report
@dataclass
class PhaseStats:
    """Per-workload-phase serving stats (latencies over the phase's
    contiguous rid range)."""
    index: int
    t_start: float
    alpha: float
    gap_s: float
    mean_size: float
    requests: int
    completed: int
    mean_latency: float
    p50: float
    p95: float
    p99: float


@dataclass
class ScenarioReport:
    """Structured result of :func:`run_scenario`: cluster-wide stats,
    per-phase stats, and the dispatcher's per-event audit trail
    (``stats.events``: event, fire time, resulting pool shape)."""
    name: str
    completed: int
    total: int
    final_n_cn: int
    final_m_mn: int
    mn_types: Tuple[str, ...]
    stats: ClusterStats
    phases: List[PhaseStats]
    latency_model: Dict[str, float]
    results: List[Result] = field(repr=False, default_factory=list)
    engine: Any = field(repr=False, compare=False, default=None)

    def bitwise_equal(self, other: "ScenarioReport") -> bool:
        """Score parity between two runs of the same workload: both
        complete, and every query's outputs bitwise-identical.  The
        single comparison the benches and examples assert when claiming
        an event timeline never changes values."""
        if not (self.completed == self.total
                and other.completed == other.total
                and self.total == other.total):
            return False
        want = {r.rid: r.outputs for r in other.results}
        return all(r.rid in want and np.array_equal(r.outputs, want[r.rid])
                   for r in self.results)

    def to_dict(self) -> Dict[str, Any]:
        st = dataclasses.asdict(self.stats)
        st.pop("events")
        # keep each event's type discriminator (dataclasses.asdict drops
        # the ClassVar `kind`, leaving a FailMN and a RecoverMN on the
        # same MN indistinguishable)
        events = [{"event": r.event.to_dict(), "time_s": r.time_s,
                   "n_cn": r.n_cn, "m_mn": r.m_mn, "dead": list(r.dead),
                   "applied": r.applied} for r in self.stats.events]
        return {
            "name": self.name,
            "completed": self.completed,
            "total": self.total,
            "final_pool": {"n_cn": self.final_n_cn,
                           "m_mn": self.final_m_mn,
                           "mn_types": list(self.mn_types)},
            "phases": [dataclasses.asdict(p) for p in self.phases],
            "events": events,
            "stats": st,
            "latency_model": dict(self.latency_model),
        }

    def summary(self) -> List[str]:
        st = self.stats
        lines = [
            f"[scenario] {self.name}: {self.completed}/{self.total} "
            f"queries completed; final pool {{{self.final_n_cn} CN, "
            f"{self.final_m_mn} MN [{','.join(self.mn_types)}]}}",
            f"[scenario] p50 {st.p50 * 1e3:.3f}ms "
            f"p95 {st.p95 * 1e3:.3f}ms p99 {st.p99 * 1e3:.3f}ms  "
            f"MN imbalance {st.imbalance:.3f}  "
            f"failures={st.failures} recoveries={st.recoveries} "
            f"resizes={st.resizes} reroutes={st.reroutes} "
            f"reinits={st.reinits} reissues={st.reissues}",
            f"[scenario] queueing delay (arrival -> admission): "
            f"mean {st.queue_wait_mean * 1e3:.3f}ms "
            f"p99 {st.queue_wait_p99 * 1e3:.3f}ms",
        ]
        if len(st.per_model) > 1:
            for name, ms in st.per_model.items():
                lines.append(
                    f"[scenario] model {name}: {ms.completed}/"
                    f"{ms.queries} completed, p99 {ms.p99 * 1e3:.3f}ms, "
                    f"queue-wait p99 {ms.queue_wait_p99 * 1e3:.3f}ms, "
                    f"{ms.cache_hits} cache hits "
                    f"({ms.cache_bytes_saved / 1e6:.2f}MB saved), "
                    f"{ms.sla_actions} SLA action(s)")
        if st.hedges or st.degrades:
            lines.append(
                f"[scenario] straggler mitigation: {st.degrades} "
                f"degrade events, {st.hedges} hedged scans "
                f"({st.hedge_wins} won by the hedge)")
        if st.sla_actions:
            lines.append(
                f"[scenario] SLA feedback: controller emitted "
                f"{st.sla_actions} resize action(s) "
                f"({st.sla_actions_cn} CN-dim, {st.sla_actions_mn} "
                f"MN-dim)")
        if not st.sla_window_filled:
            lines.append(
                "[scenario] SLA feedback: warning — the p99 window "
                "never filled (run shorter than the controller window; "
                "no action could fire)")
        mem = sum(st.mn_access_bytes) + st.retired_access_bytes
        gat = sum(st.mn_gather_bytes) + st.retired_gather_bytes
        if any("nmp" in t for t in self.mn_types) and mem:
            lines.append(
                f"[scenario] NMP near-memory pooling: scanned "
                f"{mem / 1e6:.2f}MB on-node, shipped {gat / 1e6:.2f}MB "
                f"over the fabric ({100 * (1 - gat / mem):.1f}% gather "
                f"bytes saved vs raw rows)")
        for ph in self.phases:
            lines.append(
                f"[scenario] phase {ph.index} @{ph.t_start * 1e3:.0f}ms "
                f"(alpha={ph.alpha:g}, gap={ph.gap_s * 1e3:g}ms, "
                f"mean_size={ph.mean_size:g}): "
                f"{ph.completed}/{ph.requests} completed, "
                f"p95 {ph.p95 * 1e3:.3f}ms")
        for rec in st.events:
            ev = rec.event
            extra = {k: v for k, v in ev.to_dict().items()
                     if k not in ("type", "time_s")}
            note = "" if rec.applied else " (no-op)"
            lines.append(
                f"[scenario] event @{rec.time_s * 1e3:.1f}ms "
                f"{ev.kind}{extra or ''}{note} -> pool "
                f"{{{rec.n_cn} CN, {rec.m_mn} MN}}, dead={list(rec.dead)}")
        if st.cache_hits + st.cache_misses:
            hr = st.cache_hits / (st.cache_hits + st.cache_misses)
            lines.append(
                f"[scenario] hot-row cache: {100 * hr:.1f}% hit rate, "
                f"{st.cache_bytes_saved / 1e6:.2f}MB gather bytes saved, "
                f"{st.cache_invalidations} coherence invalidations")
        if st.migration_bytes:
            lines.append(
                f"[scenario] shard migration: "
                f"{st.migration_bytes / 1e6:.3f}MB drained/topped-up "
                f"across {st.resizes} resizes")
        v = self.latency_model
        lines.append(
            f"[scenario] latency model cross-check: engine/analytic = "
            f"{v['ratio']:.2f} (MN stage {v['mn_stage_ratio']:.2f})")
        return lines


def nearest_rank(values, q: float) -> float:
    """Documented nearest-rank percentile: the ``ceil(q/100 * n)``-th
    smallest observation (1-indexed) — always an *actual* sample.

    ``np.percentile``'s default linear interpolation made p95/p99
    depend on the sample count in surprising ways at smoke scale (a
    32-sample p99 was an invented point 99% of the way between the two
    largest observations); nearest-rank is the standard tail-SLA
    convention (a measured latency some query actually saw) and is what
    every serving-layer percentile in this repo now means.  Empty input
    returns nan, matching the ``mean_latency`` contract."""
    a = np.sort(np.asarray(values, dtype=float))
    n = a.size
    if n == 0:
        return float("nan")
    k = max(int(math.ceil(q / 100.0 * n)), 1) - 1
    return float(a[min(k, n - 1)])


def _lat_stats(lats: List[float]) -> Tuple[float, float, float, float]:
    if not lats:
        nan = float("nan")
        return nan, nan, nan, nan
    a = np.sort(np.asarray(lats, dtype=float))
    return (float(a.mean()), nearest_rank(a, 50),
            nearest_rank(a, 95), nearest_rank(a, 99))


def run_scenario(spec: ScenarioSpec, model=None, params=None, stream=None
                 ) -> ScenarioReport:
    """The serving stack's single front door: validate the spec, build
    the model (unless one is handed in), plan the phased request stream,
    serve it through ``ClusterEngine`` with the spec's event timeline,
    and fold the outcome into a :class:`ScenarioReport`.

    ``stream`` is an optional pre-planned ``(requests, phases)`` pair
    from :func:`plan_workload` — a caching hook for sweeps that serve
    the *same* workload under many topologies (e.g. the cache bench's
    alpha x cache_mb grid), so the seeded stream is built once instead
    of once per point.  The caller owns the invariant that it was
    planned from an identical workload + ``SetWorkload`` timeline.

    Fleet specs (more than one entry in ``spec.models``) are delegated
    to :func:`repro.serving.fleet.run_fleet`; a one-model fleet IS a
    single-model spec (``__post_init__`` normalization) and takes this
    path unchanged — that is the bitwise-parity guarantee."""
    spec.validate()
    if len(spec.models) > 1:
        if model is not None or params is not None or stream is not None:
            raise ValueError(
                "fleet specs build their own models and streams; the "
                "model/params/stream caching hooks are single-model only")
        from repro.serving.fleet import run_fleet
        return run_fleet(spec)
    if model is None:
        from repro import configs
        from repro.models import registry
        cfg = (configs.get_reduced(spec.model.arch) if spec.model.reduced
               else configs.get_config(spec.model.arch))
        model = registry.build(cfg)
    if params is None:
        params = model.init(spec.model.init_seed)
    reqs, phases = (plan_workload(spec, model.cfg) if stream is None
                    else stream)
    engine = ClusterEngine(
        model, params, spec.topology.cluster_config(seed=spec.workload.seed))
    controller = None
    if spec.sla_p99_s is not None:
        from repro.serving.autoscaler import (SLAController,
                                              SLAControllerConfig)
        controller = SLAController(
            SLAControllerConfig(sla_p99_s=spec.sla_p99_s,
                                mode=spec.sla_mode),
            n_cn=spec.topology.n_cn, m_mn=spec.topology.m_mn)
    results, stats = engine.serve(reqs, events=spec.events,
                                  controller=controller)
    by_rid = {r.rid: r for r in results}
    phase_stats = []
    for ph in phases:
        lats = [by_rid[r].latency for r in range(ph.rid_start, ph.rid_end)
                if r in by_rid]
        mean, p50, p95, p99 = _lat_stats(lats)
        phase_stats.append(PhaseStats(
            index=ph.index, t_start=ph.t_start, alpha=ph.alpha,
            gap_s=ph.gap_s, mean_size=ph.mean_size, requests=ph.requests,
            completed=len(lats), mean_latency=mean, p50=p50, p95=p95,
            p99=p99))
    return ScenarioReport(
        name=spec.name, completed=stats.completed, total=len(reqs),
        final_n_cn=engine.n_cn, final_m_mn=engine.m_mn,
        mn_types=tuple(engine.mn_types), stats=stats, phases=phase_stats,
        latency_model=engine.validate_latency_model(), results=results,
        engine=engine)


# ------------------------------------------------------------- presets
def smoke_topology(**overrides) -> Topology:
    """The canonical smoke cluster every bench/example topology derives
    from: :class:`Topology`'s defaults ARE the smoke shape ({2 CN,
    4 MN, batch 32, 2x replicas} — one source of truth), and this
    helper names the intent at the 7+ call sites that used to
    hand-roll ``ClusterConfig(...)`` across ``benchmarks/`` and
    ``examples/``."""
    return Topology(**overrides)


def _preset_failover_storm() -> ScenarioSpec:
    return ScenarioSpec(
        name="failover_storm",
        description=(
            "Two failure/recovery cycles sweep the MN pool mid-stream: "
            "each death re-routes to surviving replicas (fast path), each "
            "timed recovery rebuilds routing over the healed pool — "
            "scores stay bitwise-identical to a failure-free run "
            "(paper §IV-A/§IV-D, Fig. 9)."),
        topology=smoke_topology(),
        workload=Workload(requests=32, seed=1),
        events=(
            FailMN(0.012, mn=1),
            RecoverMN(0.024, mn=1),
            FailMN(0.036, mn=3),
            RecoverMN(0.048, mn=3),
        ),
    )


def _preset_diurnal_elastic() -> ScenarioSpec:
    from repro.serving.autoscaler import Autoscaler, AutoscalerConfig
    span = 32 * 0.002
    toy = Autoscaler(AutoscalerConfig(
        qps_per_cn=1.0, qps_per_mn=0.5, min_cn=1, min_mn=2,
        max_cn=3, max_mn=6))
    events = tuple(Resize(e.time_s, n_cn=e.n_cn, m_mn=e.m_mn)
                   for e in toy.plan(peak_load=3.0, duration_s=span,
                                     steps=8))
    return ScenarioSpec(
        name="diurnal_elastic",
        description=(
            "One diurnal day mapped onto the stream: both pools follow "
            "the load curve down to the trough and back via timed "
            "resizes, shard migration draining to survivors — scores "
            "bitwise-identical to the fixed {3 CN, 6 MN} peak pool "
            "(paper §III, Fig. 2b/11)."),
        topology=smoke_topology(n_cn=3, m_mn=6),
        workload=Workload(requests=32, seed=0),
        events=events,
    )


def _preset_skew_drift() -> ScenarioSpec:
    return ScenarioSpec(
        name="skew_drift",
        description=(
            "Row-popularity skew drifts across the stream — uniform, "
            "then Zipf alpha=1.05, then 1.2 — while a small per-CN "
            "hot-row cache adapts and a final replan re-places tables "
            "from measured hotness (Gupta et al. skew; FlexEMR-style "
            "caching).  No legacy kwarg can express this."),
        topology=smoke_topology(cache_mb=0.05),
        workload=Workload(requests=36, seed=7),
        events=(
            SetWorkload(0.024, alpha=1.05),
            SetWorkload(0.048, alpha=1.2, gap_s=0.001),
            ReplanPlacement(0.06),
        ),
    )


def _preset_mixed_ddr_nmp() -> ScenarioSpec:
    return ScenarioSpec(
        name="mixed_ddr_nmp",
        description=(
            "Heterogeneous memory pool (2 DDR + 2 NMP): a DDR node dies "
            "and its tables ride their NMP replicas, it recovers, and "
            "the pool then grows with two more NMP nodes — bitwise-"
            "identical scores throughout, strictly fewer gather bytes "
            "than all-DDR (paper §NMP, Fig. 14)."),
        topology=smoke_topology(
            mn_types=("ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn")),
        workload=Workload(requests=32, seed=3),
        events=(
            FailMN(0.016, mn=0),
            RecoverMN(0.032, mn=0),
            Resize(0.048, m_mn=6, mn_type="nmp_mn"),
        ),
    )


def _preset_pipeline_burst() -> ScenarioSpec:
    return ScenarioSpec(
        name="pipeline_burst",
        description=(
            "A backlogged burst (every request at t=0) served with four "
            "batches in flight: MN scans of batch k+1 hide behind the "
            "gather/dense of batch k, so throughput tracks the "
            "bottleneck resource instead of the stage sum (DisaggRec "
            "§IV; FlexEMR overlapped gets).  Scores are bitwise-"
            "identical to the same spec at inflight_depth=1 — only the "
            "clock changes, never the math."),
        topology=smoke_topology(inflight_depth=4, max_wait_s=2e-5),
        workload=Workload(requests=64, gap_s=0.0, seed=5),
    )


def _preset_flash_crowd() -> ScenarioSpec:
    return ScenarioSpec(
        name="flash_crowd",
        description=(
            "Poisson traffic spikes 10x mid-stream and recedes: queueing "
            "delay (arrival -> admission) piles into the tail while the "
            "SLA feedback controller watches the measured p99 against "
            "sla_p99_s and emits Resize scale-ups through the live "
            "timeline, then the pool returns to steady state (Gupta et "
            "al. bursty production traffic; paper Fig. 2b).  Runs on a "
            "compressed virtual timescale (per-batch service is ~7us at "
            "smoke scale): the pool starts at its {1 CN, 2 MN} floor, "
            "the crowd overloads it ~3x, and the controller rides "
            "measured p99 up to 4x capacity and back down to the floor."),
        topology=smoke_topology(n_cn=1, m_mn=2, inflight_depth=4,
                                max_wait_s=2e-5),
        workload=Workload(requests=960, gap_s=4e-6, arrival="poisson",
                          seed=11),
        sla_p99_s=6e-5,
        events=(
            SetWorkload(1e-4, gap_s=7e-7),
            SetWorkload(5e-4, gap_s=4e-6),
        ),
    )


def _preset_spike_plus_failure() -> ScenarioSpec:
    return ScenarioSpec(
        name="spike_plus_failure",
        description=(
            "Bursty arrivals, then a traffic spike with an MN failure "
            "landing mid-spike: re-route rides the surviving replicas "
            "while the SLA controller scales the pool against the "
            "compound tail, the MN heals, and traffic recedes — the "
            "paper's reliability story under its worst-case load "
            "(§IV-A/§IV-D + Fig. 2b, via the typed timeline).  Same "
            "compressed virtual timescale as flash_crowd, with an "
            "on-scale mn_recovery_s so the mid-stage re-issue stall "
            "stays commensurate with the traffic."),
        topology=smoke_topology(n_cn=1, m_mn=2, inflight_depth=4,
                                max_wait_s=2e-5, mn_recovery_s=2e-5),
        workload=Workload(requests=1024, gap_s=2e-6, arrival="bursty",
                          burstiness=4.0, seed=13),
        sla_p99_s=6e-5,
        events=(
            SetWorkload(1e-4, gap_s=3.5e-7),
            FailMN(1.5e-4, mn=1),
            RecoverMN(2.5e-4, mn=1),
            SetWorkload(4e-4, gap_s=2e-6),
        ),
    )


def _preset_fleet_shift() -> ScenarioSpec:
    return ScenarioSpec(
        name="fleet_shift",
        description=(
            "RM1 and RM2 share one disaggregated pool: each model keeps "
            "its own ingress batcher and SLA accounting while their "
            "embedding tables are co-placed on the single MN pool "
            "(per-model hotness attribution, per-model cache budget "
            "partitions).  Mid-stream a shift_traffic event moves 30% "
            "of the aggregate rate from RM1 to RM2 — the paper's "
            "fast-evolving-workloads story (Fig. 1/14 fleet view) as a "
            "timeline event; a model-scoped set_workload then skews "
            "RM2's rows without touching RM1's stream."),
        models=(ModelRef(arch="rm1", rate_share=0.5),
                ModelRef(arch="rm2", rate_share=0.5)),
        topology=smoke_topology(cache_mb=0.05),
        workload=Workload(requests=48, seed=9),
        events=(
            ShiftTraffic(0.032, from_model="rm1", to_model="rm2",
                         share=0.3),
            SetWorkload(0.056, alpha=1.05, model="rm2"),
        ),
    )


PRESETS = {
    "failover_storm": _preset_failover_storm,
    "diurnal_elastic": _preset_diurnal_elastic,
    "skew_drift": _preset_skew_drift,
    "mixed_ddr_nmp": _preset_mixed_ddr_nmp,
    "pipeline_burst": _preset_pipeline_burst,
    "flash_crowd": _preset_flash_crowd,
    "spike_plus_failure": _preset_spike_plus_failure,
    "fleet_shift": _preset_fleet_shift,
}


def preset(name: str) -> ScenarioSpec:
    """Build a named scenario preset (the source of truth behind
    ``examples/scenarios/<name>.json``)."""
    if name not in PRESETS:
        raise KeyError(f"unknown scenario preset {name!r} "
                       f"(known: {sorted(PRESETS)})")
    return PRESETS[name]()


# ----------------------------------------------------------- lint CLI
def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="Lint (and optionally run) scenario spec files.")
    p.add_argument("paths", nargs="*", help="scenario .json files")
    p.add_argument("--run", action="store_true",
                   help="execute each linted scenario via run_scenario")
    p.add_argument("--write-presets", metavar="DIR", default=None,
                   help="re-emit the named preset library into DIR")
    p.add_argument("--format", choices=["text", "json"], default="text",
                   help="lint report format: text (default; defects "
                        "raise, preserving the historical CLI contract) "
                        "or json (defects become findings in the shared "
                        "repro.analysis report schema; exit 1 if any)")
    args = p.parse_args(argv)
    if args.write_presets:
        import os
        os.makedirs(args.write_presets, exist_ok=True)
        for name in sorted(PRESETS):
            path = os.path.join(args.write_presets, f"{name}.json")
            preset(name).save(path)
            print(f"[scenario] wrote {path}")
        return 0
    if not args.paths:
        p.error("no scenario files given")
    if args.format == "json":
        # one lint-report schema across the repo: the scenario lint
        # emits repro.analysis findings, so CI parses a single shape
        # regardless of which linter produced it
        if args.run:
            p.error("--format json is lint-only (drop --run)")
        from repro.analysis.report import Finding, LintResult, render_json
        result = LintResult()
        for path in args.paths:
            result.files_checked += 1
            try:
                spec = ScenarioSpec.load(path)
                spec.validate()
                rt = ScenarioSpec.from_json(spec.to_json())
                if rt != spec:
                    raise AssertionError(
                        "serde round-trip changed the spec")
            except Exception as e:
                result.findings.append(Finding(
                    file=path, line=0, rule="scenario-lint",
                    message=f"{type(e).__name__}: {e}"))
        sys.stdout.write(render_json(result, tool="scenario-lint"))
        return result.exit_code()
    models = {}     # (arch, reduced, init_seed) -> (model, params):
    for path in args.paths:  # presets share one reduced rm1 — build once
        spec = ScenarioSpec.load(path)
        spec.validate()
        rt = ScenarioSpec.from_json(spec.to_json())
        if rt != spec:
            raise AssertionError(f"{path}: serde round-trip changed the spec")
        print(f"[scenario-lint] ok {path}: {spec.name!r} "
              f"({len(spec.events)} events, {spec.workload.requests} "
              f"requests on {{{spec.topology.n_cn} CN, "
              f"{spec.topology.m_mn} MN}})")
        if args.run:
            if len(spec.models) > 1:
                # fleet specs build their own model set (run_fleet);
                # the single-model cache below doesn't apply
                rep = run_scenario(spec)
                for line in rep.summary():
                    print(line)
                if rep.completed != rep.total:
                    raise AssertionError(
                        f"{path}: {rep.completed}/{rep.total} completed")
                continue
            key = (spec.model.arch, spec.model.reduced,
                   spec.model.init_seed)
            if key not in models:
                from repro import configs
                from repro.models import registry
                mcfg = (configs.get_reduced(spec.model.arch)
                        if spec.model.reduced
                        else configs.get_config(spec.model.arch))
                model = registry.build(mcfg)
                models[key] = (model, model.init(spec.model.init_seed))
            model, params = models[key]
            rep = run_scenario(spec, model=model, params=params)
            for line in rep.summary():
                print(line)
            if rep.completed != rep.total:
                raise AssertionError(
                    f"{path}: {rep.completed}/{rep.total} completed")
    return 0


if __name__ == "__main__":
    # `python -m repro.serving.scenario` executes this file as
    # ``__main__`` while the serving package imports it again under its
    # canonical name — two parallel class hierarchies whose isinstance
    # checks never match.  Delegate to the canonical module so every
    # event the CLI builds is the class the dispatcher tests against.
    from repro.serving.scenario import main as _canonical_main
    sys.exit(_canonical_main())
