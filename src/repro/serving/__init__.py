"""Serving layer: analytic model consumers at three fidelities.

simulator.ClusterSim  — discrete-event simulator (queueing, policies)
engine.*ServingEngine — real-JAX single-unit engines
cluster.ClusterEngine — real-JAX multi-unit engine with replica routing
autoscaler.Autoscaler — diurnal elastic-resize policy for the engine
cache.RowCache        — per-CN hot-row embedding cache (LRU/LFU)
"""
from repro.serving.autoscaler import (Autoscaler,  # noqa: F401
                                      AutoscalerConfig, ResizeEvent)
from repro.serving.cache import CacheStats, RowCache  # noqa: F401
from repro.serving.cluster import (ClusterConfig, ClusterEngine,  # noqa: F401
                                   ClusterStats)
from repro.serving.engine import (DLRMServingEngine,  # noqa: F401
                                  LMServingEngine, Request, Result)
from repro.serving.simulator import ClusterSim, SimConfig  # noqa: F401
