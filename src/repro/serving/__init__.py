"""Serving layer: analytic model consumers at three fidelities.

simulator.ClusterSim  — discrete-event simulator (queueing, policies)
engine.*ServingEngine — real-JAX single-unit engines
cluster.ClusterEngine — real-JAX multi-unit engine with replica routing
autoscaler.Autoscaler — diurnal elastic-resize policy for the engine
cache.RowCache        — per-CN hot-row embedding cache (LRU/LFU)
scenario.ScenarioSpec — declarative scenarios: typed event timelines,
                        JSON serde, presets, run_scenario front door
timeline.TimelineDispatcher — serve()'s unified event-queue executor
pipeline.ResourceClock — per-resource FIFO timelines + depth-d
                        admission for pipelined batch overlap
"""
from repro.serving.autoscaler import (Autoscaler,  # noqa: F401
                                      AutoscalerConfig, ResizeEvent)
from repro.serving.cache import CacheStats, RowCache  # noqa: F401
from repro.serving.cluster import (ClusterConfig, ClusterEngine,  # noqa: F401
                                   ClusterStats)
from repro.serving.engine import (DLRMServingEngine,  # noqa: F401
                                  LMServingEngine, Request, Result)
from repro.serving.scenario import (FailMN, ModelRef,  # noqa: F401
                                    RecoverMN, ReloadParams,
                                    ReplanPlacement, Resize,
                                    ScenarioReport, ScenarioSpec,
                                    SetWorkload, Topology, Workload,
                                    preset, run_scenario, smoke_topology)
from repro.serving.pipeline import (AdmissionWindow,  # noqa: F401
                                    BatchTrace, ResourceClock)
from repro.serving.simulator import ClusterSim, SimConfig  # noqa: F401
from repro.serving.timeline import (EventRecord,  # noqa: F401
                                    TimelineDispatcher)
