"""Cluster-scale disaggregated serving engine (paper §IV, Fig. 6/7/9).

This is the real-JAX layer of the three-layer validation story:

  analytic ``core.serving_unit.ServingUnitModel``   (closed-form stages)
      <->  DES ``serving.simulator.ClusterSim``      (queueing behavior)
      <->  ``ClusterEngine``                         (this module)

One engine serves a cluster of {n CNs, m MNs}: queries enter a shared
ingress ``Batcher`` (large queries split, small queries fused — Fig. 3a),
each batch lands on the least-loaded CN, and that CN's task id selects the
rows of the MemAccess routing table (``core.embedding_manager``) that
scatter its table lookups over the MN pool.  Every MN holds a replica
shard — the stacked tables the allocator placed on it — and the pool may
mix node types (paper §NMP, Fig. 14):

- **DDR MN**: passive remote memory — the shard's raw rows stream back to
  the owning CN (``rows x D`` gather bytes), which pools them with the
  fused CN-side bag (``kernels.embedding_bag.embedding_bag_fused_flat``).
- **NMP MN**: pools *on the memory node* with the near-memory kernel
  (``kernels.embedding_bag.embedding_bag_nmp_flat``) at NMP bandwidth;
  only pooled (B, T_j, D) Fsum vectors cross the fabric (``tables x D``
  gather bytes) and the CN skips its pooling stage for that shard.

Both paths accumulate pooling slots in the same ascending order, so a
mixed DDR+NMP deployment scores bitwise-identically to the all-DDR
baseline while moving strictly fewer gather bytes.  Placement is
node-type-aware (``core.embedding_manager.allocate_heterogeneous``: hot
tables on DDR, capacity tables on NMP, replicas spanning both classes)
and routing weighs replicas by per-node bandwidth.

Failures (§IV-A/§IV-D): ``fail_mn`` marks an MN dead and rebuilds routing
over the surviving replicas (fast path) or re-initializes the allocation
when a table lost every replica.  ``serve`` accepts timed failure events;
a failure landing inside a batch's MN stage re-issues that batch's lookups
on the survivors — no query is ever dropped.

Elasticity (§III, Fig. 2b/11): ``resize(n_cn, m_mn)`` grows or shrinks
either pool independently while the engine keeps serving.  MN resizes go
through the incremental migration planner
(``core.embedding_manager.allocate_incremental`` / ``plan_migration``):
surviving placements stay put, a departing MN drains its shard copies to
the survivors, a joining MN is topped up with replicas — and only the
tables whose placement changed cross the fabric.  ``serve`` consumes
timed resize events alongside failure events, charging the migration
bytes to the virtual clock as a background stream that fair-shares the
gather NIC path with the G_S stage.  Because pooling accumulates slots
in the same ascending order on every node, scores before, during, and
after any resize are bitwise-identical to a fixed-pool run.

Hot-row caching (FlexEMR; Gupta et al.): with ``cache_mb > 0`` every CN
carves a byte budget out of its HBM for a ``serving.cache.RowCache`` and
splits each MemAccess into cache **hits** — served locally, zero memory-
bus and gather bytes on the virtual clock — and **misses**, routed to
the MN pool exactly as before (miss rows are admitted on return,
LRU/LFU under ``cache_policy``, with measured hot tables outranking
cold ones at eviction time).  The numeric pooling path is unchanged:
cached rows are bitwise copies of the authoritative shard rows, and the
fused bag accumulates the merged hit+miss row set in the same ascending
slot order, so a cached engine scores **bitwise-identically** to the
uncached baseline — the cache moves bytes and time, never values.
Coherence: whenever a CN's authoritative serving copy of a table moves
(``fail_mn`` / ``recover_mn`` re-route, ``resize`` migration, a reinit's
fresh allocation), exactly that table's rows are invalidated in that
CN's cache; ``reload_params`` (DLRM weight reload) flushes everything.
NMP-routed lookups bypass the cache — their rows never cross the fabric
to begin with, which is why measured-hotness placement steers hot
tables toward DDR where the cache can capture them.

Latency accounting is wall-clock-free: a virtual clock driven by the
analytic unit model's stage times (G_P, scatter, G_S + gather from
*measured* per-MN access/gather bytes at *per-node-type* bandwidths,
G_D), so per-query latencies can be cross-validated against
``ServingUnitModel.stage_times`` and the DES (``validate_latency_model``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding_manager as em
from repro.core import failure as fail_mod
from repro.core import hardware as hw
from repro.core.hardware import NODE_TYPES
from repro.core.scheduler import Batch, Batcher, Query
from repro.core.serving_unit import ServingUnitModel, UnitSpec
from repro.serving.cache import CacheStats, RowCache
from repro.serving.engine import Request, Result


def _fit(arr: np.ndarray, n: int, fill: float = 0.0) -> np.ndarray:
    """Resize a per-node accounting/clock array to `n` entries: growth
    appends `fill`, shrink drops the departing tail."""
    if len(arr) >= n:
        return arr[:n].copy()
    return np.concatenate([arr, np.full(n - len(arr), fill)])


def _validate_mn_types(types: Sequence[str], m_mn: int) -> List[str]:
    if len(types) != m_mn:
        raise ValueError(f"{len(types)} MN types for a pool of {m_mn}")
    for t in types:
        if t not in NODE_TYPES or NODE_TYPES[t].kind != "mn":
            raise ValueError(f"unknown memory-node type {t!r}")
    return list(types)


def parse_mn_types(spec: str, m_mn: int) -> List[str]:
    """Parse a CLI memory-pool spec into a per-MN node-type list.

    Accepts a single type (``"nmp_mn"`` — the whole pool), an explicit
    comma list (``"ddr_mn,ddr_mn,nmp_mn,nmp_mn"``), or counted groups
    (``"2xddr_mn+2xnmp_mn"``).  The expansion must match the pool size.
    """
    types: List[str] = []
    for part in spec.replace("+", ",").split(","):
        part = part.strip()
        if "x" in part and part.split("x", 1)[0].isdigit():
            count, name = part.split("x", 1)
            types += [name.strip()] * int(count)
        elif part:
            types.append(part)
    if len(types) == 1:
        types = types * m_mn
    return _validate_mn_types(types, m_mn)


@dataclass
class ClusterConfig:
    n_cn: int = 2                 # serving-unit compute nodes (= tasks)
    m_mn: int = 4                 # memory-node pool
    batch_size: int = 64
    max_wait_s: float = 0.002     # ingress batcher flush deadline
    n_replicas: int = 2           # embedding replication factor
    use_kernel: bool = True       # Pallas bag kernels on the hot path
    cn_type: str = "cn_1g"
    mn_type: str = "ddr_mn"       # default type for the whole pool
    mn_types: Optional[Sequence[str]] = None   # per-MN override, len m_mn
    mn_recovery_s: float = fail_mod.recovery_cost_s("mn")
    cache_mb: float = 0.0         # per-CN hot-row cache budget (CN HBM)
    cache_policy: str = "lru"     # lru | lfu
    seed: int = 0                 # the stream seed this engine serves
                                  # (dlrm_request_stream convention); the
                                  # serving path itself holds no RNG, so
                                  # same-seed runs give identical stats

    def resolved_mn_types(self) -> List[str]:
        types = (list(self.mn_types) if self.mn_types is not None
                 else [self.mn_type] * self.m_mn)
        return _validate_mn_types(types, self.m_mn)


@dataclass
class ClusterStats:
    completed: int
    mean_latency: float           # nan when no query completed
    p50: float
    p95: float
    failures: int
    reroutes: int
    reinits: int
    mn_access_bytes: List[float]  # memory-bus bytes scanned per MN
    mn_gather_bytes: List[float]  # bytes each MN shipped to CNs (fabric)
    mn_types: List[str]
    imbalance: float              # max/mean access over surviving MNs
    recoveries: int = 0           # MNs brought back via recover_mn
    resizes: int = 0              # elastic resize events applied
    migration_bytes: float = 0.0  # shard bytes moved by resizes
    retired_access_bytes: float = 0.0   # departed (shrunk-away) MNs' scans
    retired_gather_bytes: float = 0.0   # ... and their shipped bytes
    p99: float = float("nan")     # tail latency (nan when nothing completed)
    reissues: int = 0             # batches re-executed after in-flight MN loss
    cache_hits: int = 0           # CN hot-row cache counters (0 = no cache)
    cache_misses: int = 0
    cache_evictions: int = 0
    cache_invalidations: int = 0  # rows dropped by coherence events
    cache_bytes_saved: float = 0.0      # gather bytes hits kept off the NIC


class ClusterEngine:
    """Serve a DLRM over {n CN, m MN} with replica-aware routing."""

    def __init__(self, model, params, cfg: Optional[ClusterConfig] = None,
                 unit_model: Optional[ServingUnitModel] = None):
        assert model.cfg.family == "dlrm"
        self.model = model
        self.params = params
        self.cfg = cfg or ClusterConfig()
        r = model.cfg.dlrm
        self.T, self.R, self.D = (r.num_tables, r.rows_per_table,
                                  r.embed_dim)
        self.tables = [em.TableInfo(t, self.R, self.D, float(r.avg_pooling))
                       for t in range(self.T)]
        # live pool sizes — cfg keeps the initial provisioning, these move
        # with resize()
        self.n_cn = self.cfg.n_cn
        self.m_mn = self.cfg.m_mn
        # heterogeneous pool: one node type per MN (all cfg.mn_type when
        # no per-MN override is given)
        self.mn_types = self.cfg.resolved_mn_types()
        self.mn_nmp = [NODE_TYPES[t].nmp for t in self.mn_types]
        self.mn_bw = [NODE_TYPES[t].mem_bw for t in self.mn_types]
        self._route_w = [max(self.mn_bw) / bw for bw in self.mn_bw]
        self.capacities = self._pool_capacities(self.m_mn)
        self.alloc = em.allocate_heterogeneous(
            self.tables, self.capacities, self.mn_types,
            n_replicas=self.cfg.n_replicas)
        self.dead: Set[int] = set()
        self.routing = em.route_greedy(self.tables, self.alloc,
                                       self.n_cn, self.m_mn,
                                       mn_weights=self._route_w)
        self._build_shards()
        self.unit_model = unit_model or ServingUnitModel(
            model.cfg, UnitSpec(self.n_cn, self.cfg.cn_type,
                                self.m_mn, self.cfg.mn_type,
                                mn_types=tuple(self.mn_types)))
        self._dense_step = jax.jit(
            lambda p, d, pooled: jax.nn.sigmoid(
                model.dense_forward(p, d, pooled)))
        # measured per-table hotness: feeds cache admission priorities
        # and re-allocation (reinit / replan) hot/cold classification
        self.hotness = em.HotnessCounter(self.T)
        # per-CN hot-row caches + the routes their entries were fetched
        # over (the coherence protocol diffs these on every rebuild)
        self.caches: List[RowCache] = self._make_caches(self.n_cn)
        self._cache_routes: List[Dict[int, int]] = []
        self._retired_cache = CacheStats()     # departed CNs' counters
        self.cache_bytes_saved = 0.0
        self._batch_cache_s = 0.0              # last batch's probe+hit time
        self._sync_caches()
        # counters / accounting
        self.failures = 0
        self.reroutes = 0
        self.reinits = 0
        self.reissues = 0
        self.recoveries = 0
        self.resizes = 0
        self.migration_bytes = 0.0
        self.mn_access_bytes = np.zeros(self.m_mn)
        self.mn_gather_bytes = np.zeros(self.m_mn)
        self.mn_stage_s = np.zeros(self.m_mn)       # modeled G_S per MN
        self.retired_access_bytes = 0.0             # departed MNs' totals
        self.retired_gather_bytes = 0.0
        self._mn_stage_max_sum = 0.0                # per-batch gating stage
        self._n_batches = 0

    def _pool_capacities(self, m_mn: int) -> List[int]:
        """Per-MN shard budget at pool size `m_mn`: the requested
        replication factor fits, with one table of slack per MN for
        greedy placement skew.  The elastic pool re-provisions this
        budget at every size, so a shrink's survivors can always absorb
        the departing shards."""
        total = sum(t.size_bytes for t in self.tables)
        cap = (math.ceil(self.cfg.n_replicas * total / m_mn)
               + self.tables[0].size_bytes)
        return [cap] * m_mn

    # ------------------------------------------------------------- shards
    def _build_shards(self) -> None:
        """Materialize each MN's replica shard: the tables the allocator
        placed on it, flattened row-wise for the fused kernel."""
        embed = self.params["embed"]                      # (T, R, D)
        self._shard_tids: List[List[int]] = []
        self._shard_slot: List[Dict[int, int]] = []
        self._shard_flat: List[jax.Array] = []
        for j in range(self.m_mn):
            tids = sorted(t for t, reps in self.alloc.replicas.items()
                          if j in reps)
            self._shard_tids.append(tids)
            self._shard_slot.append({t: s for s, t in enumerate(tids)})
            if tids:
                flat = jnp.reshape(embed[jnp.asarray(tids)],
                                   (len(tids) * self.R, self.D))
            else:
                flat = jnp.zeros((0, self.D), embed.dtype)
            self._shard_flat.append(flat)

    # ------------------------------------------------------------- caching
    def _make_caches(self, n_cn: int) -> List[RowCache]:
        if self.cfg.cache_mb <= 0:
            return []
        budget = int(self.cfg.cache_mb * 1e6)
        return [RowCache(budget, self.D * 4, self.cfg.cache_policy)
                for _ in range(n_cn)]

    def _sync_caches(self) -> None:
        """Coherence: after any routing rebuild, invalidate in each CN's
        cache exactly the tables whose authoritative serving copy (the
        MN this CN's lookups route to) moved — rows of unmoved tables
        survive.  Also refreshes the measured hot-table admission set."""
        if not self.caches:
            return
        hot = self.hotness.hot_tables(self.tables)
        for task, cache in enumerate(self.caches):
            new = {tid: self.routing.routes[(task, tid)]
                   for tid in range(self.T)}
            old = (self._cache_routes[task]
                   if task < len(self._cache_routes) else {})
            for tid in range(self.T):
                if old.get(tid) != new[tid]:
                    cache.invalidate_table(tid)
            if task < len(self._cache_routes):
                self._cache_routes[task] = new
            else:
                self._cache_routes.append(new)
            cache.set_hot_tables(hot)

    def _refresh_hot_tables(self) -> None:
        """Install the current measured hot-table classification into
        every CN cache.  Runs on coherence syncs AND periodically during
        healthy serving (`run_batch`), so the admission priority tracks
        the live workload instead of waiting for a failure/resize."""
        if not self.caches:
            return
        hot = self.hotness.hot_tables(self.tables)
        for cache in self.caches:
            cache.set_hot_tables(hot)

    def _cache_serve(self, cache: RowCache, tids: Sequence[int],
                     sub: np.ndarray) -> int:
        """Probe one DDR shard's lookup stream through a CN cache in
        deterministic order (table-ascending, then batch-row-major slot
        order); misses are admitted fetch-on-miss.  Returns hits."""
        hits = 0
        lookup = cache.lookup
        for k, tid in enumerate(tids):
            rows = sub[:, k, :].ravel()
            for row in rows[rows >= 0].tolist():
                if lookup(tid, row):
                    hits += 1
        return hits

    def cache_stats(self) -> CacheStats:
        """Aggregate cache counters over live CNs + retired (shrunk-away)
        CN caches."""
        cs = CacheStats()
        for c in self.caches:
            cs.absorb(c.stats)
        cs.absorb(self._retired_cache)
        return cs

    def reload_params(self, params) -> None:
        """DLRM weight reload: every authoritative row changed, so the
        MN shards re-materialize and every CN cache flushes."""
        self.params = params
        self._build_shards()
        for cache in self.caches:
            cache.flush()

    def replan_placement(self) -> None:
        """Re-run node-type-aware placement with *measured* hotness (the
        serve-path counters) instead of the assumed ``avg_pooling``
        profile: hot tables migrate toward DDR MNs — where the CN cache
        can capture their traffic — and cold capacity tables toward NMP.
        Placement only targets live MNs (a replica parked on a dead node
        would silently shrink the effective replication factor), and
        routing rebuilds / caches invalidate per the moved routes."""
        live = [j for j in range(self.m_mn) if j not in self.dead]
        sub = em.allocate_heterogeneous(
            self.tables,
            [self.capacities[j] for j in live],
            [self.mn_types[j] for j in live],
            n_replicas=min(self.cfg.n_replicas, len(live)),
            access_bytes=self.hotness.measured_access_bytes(self.tables))
        mn_used = [0] * self.m_mn
        for i, j in enumerate(live):
            mn_used[j] = sub.mn_used[i]
        self.alloc = em.Allocation(
            replicas={tid: sorted(live[i] for i in reps)
                      for tid, reps in sub.replicas.items()},
            mn_used=mn_used, n_replicas=sub.n_replicas)
        self.routing = em.route_greedy(self.tables, self.alloc,
                                       self.n_cn, self.m_mn,
                                       exclude=sorted(self.dead),
                                       mn_weights=self._route_w)
        self._build_shards()
        self._sync_caches()

    # ------------------------------------------------------------ failure
    def fail_mn(self, j: int) -> None:
        """Kill MN `j`: re-route to surviving replicas, or re-initialize
        the shard allocation if some table lost its last replica."""
        if not 0 <= j < self.m_mn:
            raise ValueError(f"MN id {j} outside pool of {self.m_mn}")
        if j in self.dead:
            return
        self.dead.add(j)
        self.failures += 1
        lost = any(all(r in self.dead for r in self.alloc.replicas[t.tid])
                   for t in self.tables)
        if lost:
            # §IV-A re-initialization: some table lost its last replica, so
            # standby backup MNs take over the failed slots and replicas
            # are restored from the parameter store — the pool returns to
            # full strength under a fresh allocation
            self.reinits += 1
            self.dead.clear()
            self.alloc = em.allocate_heterogeneous(
                self.tables, self.capacities, self.mn_types,
                n_replicas=self.cfg.n_replicas,
                access_bytes=self.hotness.measured_access_bytes(self.tables))
            self.routing = em.route_greedy(self.tables, self.alloc,
                                           self.n_cn, self.m_mn,
                                           mn_weights=self._route_w)
            self._build_shards()
        else:
            self.reroutes += 1
            self.routing = em.route_greedy(self.tables, self.alloc,
                                           self.n_cn, self.m_mn,
                                           exclude=sorted(self.dead),
                                           mn_weights=self._route_w)
        self._sync_caches()

    def recover_mn(self, j: int) -> None:
        """Bring a failed MN back: its shard is still materialized (or was
        rebuilt by a reinit), so recovery is a routing rebuild only."""
        if not 0 <= j < self.m_mn:
            raise ValueError(f"MN id {j} outside pool of {self.m_mn}")
        if j not in self.dead:
            return
        self.dead.discard(j)
        self.recoveries += 1
        self.routing = em.route_greedy(self.tables, self.alloc,
                                       self.n_cn, self.m_mn,
                                       exclude=sorted(self.dead),
                                       mn_weights=self._route_w)
        self._sync_caches()

    # --------------------------------------------------------- elasticity
    def resize(self, n_cn: Optional[int] = None, m_mn: Optional[int] = None,
               mn_type: Optional[str] = None) -> em.MigrationPlan:
        """Grow/shrink either pool independently (paper §III, Fig. 2b/11).

        MN grow: the joining MNs (of `mn_type`, default the config's pool
        type) start empty and the incremental allocator tops replicas up
        onto them.  MN shrink: the highest-numbered MNs depart, draining
        their shard copies to the survivors first (the migration plan's
        moves) so no table ever loses availability.  CN resize holds no
        embedding state — it only rebalances the routing rows across the
        new task count.  Scores are bitwise-invariant across any resize:
        placement decides WHERE a table pools, never the slot
        accumulation order.

        Returns the migration plan; `serve` charges its bytes to the
        virtual clock as a background stream contending with the G_S
        gather path.
        """
        new_n = self.n_cn if n_cn is None else int(n_cn)
        new_m = self.m_mn if m_mn is None else int(m_mn)
        if new_n < 1 or new_m < 1:
            raise ValueError(
                f"cannot resize to {{n_cn={new_n}, m_mn={new_m}}}")
        if (new_n, new_m) == (self.n_cn, self.m_mn):
            return em.MigrationPlan(moves=[], dropped=[], bytes_moved=0)
        plan = em.MigrationPlan(moves=[], dropped=[], bytes_moved=0)
        if new_m != self.m_mn:
            if new_m > self.m_mn:
                add = mn_type or self.cfg.mn_type
                new_types = self.mn_types + [add] * (new_m - self.m_mn)
            else:
                new_types = self.mn_types[:new_m]
            new_types = _validate_mn_types(new_types, new_m)
            caps = self._pool_capacities(new_m)
            dead = {j for j in self.dead if j < new_m}
            new_alloc = em.allocate_incremental(
                self.tables, caps, new_types, prev=self.alloc,
                n_replicas=self.cfg.n_replicas, exclude=sorted(dead))
            plan = em.plan_migration(self.alloc, new_alloc, self.tables)
            if new_m < self.m_mn:
                # departing MNs retire their accumulated byte counters
                self.retired_access_bytes += float(
                    self.mn_access_bytes[new_m:].sum())
                self.retired_gather_bytes += float(
                    self.mn_gather_bytes[new_m:].sum())
            self.mn_access_bytes = _fit(self.mn_access_bytes, new_m)
            self.mn_gather_bytes = _fit(self.mn_gather_bytes, new_m)
            self.mn_stage_s = _fit(self.mn_stage_s, new_m)
            self.alloc = new_alloc
            self.mn_types = new_types
            self.mn_nmp = [NODE_TYPES[t].nmp for t in new_types]
            self.mn_bw = [NODE_TYPES[t].mem_bw for t in new_types]
            self._route_w = [max(self.mn_bw) / bw for bw in self.mn_bw]
            self.capacities = caps
            self.dead = dead
            self.m_mn = new_m
            self._build_shards()
        if new_n != self.n_cn and self.caches:
            if new_n < self.n_cn:
                # a departing CN retires its cache with its counters
                for cache in self.caches[new_n:]:
                    self._retired_cache.absorb(cache.stats)
                self.caches = self.caches[:new_n]
                self._cache_routes = self._cache_routes[:new_n]
            else:
                self.caches += self._make_caches(new_n - self.n_cn)
        self.n_cn = new_n
        self.routing = em.route_greedy(self.tables, self.alloc,
                                       self.n_cn, self.m_mn,
                                       exclude=sorted(self.dead),
                                       mn_weights=self._route_w)
        self._sync_caches()
        self.unit_model = ServingUnitModel(
            self.model.cfg, UnitSpec(self.n_cn, self.cfg.cn_type,
                                     self.m_mn, self.cfg.mn_type,
                                     mn_types=tuple(self.mn_types)))
        self.resizes += 1
        self.migration_bytes += plan.bytes_moved
        return plan

    # ------------------------------------------------------ real compute
    def _mn_pool(self, j: int, tids: Sequence[int],
                 idx_sub: np.ndarray) -> jax.Array:
        """Pool MN j's routed tables — on-node for NMP, CN-side for DDR.

        An NMP MN reduces each bag locally with the near-memory kernel
        and ships only pooled vectors; a DDR MN ships raw rows, which
        the owning CN pools with the fused multi-table bag.  Both
        accumulate slots in ascending order, so the scores are bitwise
        independent of the pool's node-type mix.
        """
        slots = np.asarray([self._shard_slot[j][t] for t in tids], np.int32)
        if self.cfg.use_kernel:
            from repro.kernels import ops
            offsets = jnp.asarray(slots * self.R)
            bag = (ops.embedding_bag_nmp_flat if self.mn_nmp[j]
                   else ops.embedding_bag_fused_flat)
            return bag(self._shard_flat[j], offsets, jnp.asarray(idx_sub))
        from repro.models.dlrm import embedding_bag_ref
        stack = self._shard_flat[j].reshape(-1, self.R, self.D)[
            jnp.asarray(slots)]
        return embedding_bag_ref(stack, jnp.asarray(idx_sub))

    def _execute(self, task: int, dense: np.ndarray, idx: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Scatter -> per-MN pooling -> gather -> DenseNet.

        Returns (scores, per-MN memory-bus bytes scanned, per-MN gather
        bytes shipped to the CN).  For a DDR MN the two are equal (raw
        rows cross the fabric); an NMP MN scans the same rows locally
        but ships only ``valid rows x T_j x D`` pooled bytes.

        With a CN cache, each DDR MemAccess splits into hits — served
        from the CN's resident copy, charged to neither the MN bus nor
        the fabric — and misses, routed (and admitted) as before.  The
        pooling math is untouched: cache rows are bitwise copies, so
        the fused bag over the merged hit+miss set in ascending slot
        order IS the uncached computation, and only the byte/time
        accounting moves."""
        shards = em.shard_assignment(self.alloc, self.routing, self.T,
                                     self.m_mn, task)
        B = dense.shape[0]
        pooled = np.zeros((B, self.T, self.D), np.float32)
        mem_j = np.zeros(self.m_mn)
        gat_j = np.zeros(self.m_mn)
        row_b = self.D * 4
        cache = self.caches[task] if self.caches else None
        batch_probes = 0
        batch_hit_bytes = 0.0
        for j, tids in enumerate(shards):
            if not tids:
                continue
            if j in self.dead:          # stale routing — never expected
                raise LookupError(f"routing targets dead MN {j}")
            sub = idx[:, tids, :]
            pooled[:, tids, :] = np.asarray(self._mn_pool(j, tids, sub))
            per_table = (sub >= 0).sum(axis=(0, 2))
            self.hotness.update(tids, per_table)
            nvalid = int(per_table.sum())
            if cache is not None and not self.mn_nmp[j]:
                hits = self._cache_serve(cache, tids, sub)
                mem_j[j] = float(nvalid - hits) * row_b
                gat_j[j] = mem_j[j]
                self.cache_bytes_saved += float(hits) * row_b
                batch_probes += nvalid
                batch_hit_bytes += float(hits) * row_b
            elif self.mn_nmp[j]:
                mem_j[j] = float(nvalid) * row_b
                live_rows = int((sub >= 0).any(axis=(1, 2)).sum())
                gat_j[j] = float(live_rows * len(tids)) * row_b
            else:
                mem_j[j] = float(nvalid) * row_b
                gat_j[j] = mem_j[j]
        # probe tags + hit rows stream from CN HBM on the virtual clock
        self._batch_cache_s = ((batch_probes * hw.CACHE_TAG_BYTES
                                + batch_hit_bytes) / hw.CN_HBM_BW)
        scores = np.asarray(self._dense_step(self.params,
                                             jnp.asarray(dense),
                                             jnp.asarray(pooled)))
        return scores, mem_j, gat_j

    # ---------------------------------------------------------- serving
    def serve(self, requests: List[Request],
              failures: Sequence[Tuple[float, int]] = (),
              resizes: Sequence[Tuple[float, int, int]] = ()
              ) -> Tuple[List[Result], ClusterStats]:
        """Serve a request stream; `failures` is [(time_s, mn_id), ...]
        and `resizes` is [(time_s, n_cn, m_mn), ...] — timed elastic
        resize events (e.g. from ``serving.autoscaler``), applied in
        global time order with the failures at batch boundaries on the
        virtual clock.  A resize's migration bytes stream in the
        background and contend with the G_S gather path.

        Execution is real JAX; time is a virtual clock advanced with the
        analytic stage model, so latencies are deterministic and
        comparable to ServingUnitModel / ClusterSim."""
        cfg = self.cfg
        batcher = Batcher(cfg.batch_size, cfg.max_wait_s)
        self._refresh_hot_tables()     # hotness measured by prior serving
        fail_q = sorted(failures)
        for _, j in fail_q:
            # ids refer to the pool at serve start; an id only becomes a
            # no-op if a scheduled shrink retires that MN before it fires
            if not 0 <= j < self.m_mn:
                raise ValueError(f"failure event targets MN {j} outside "
                                 f"the serving pool of {self.m_mn}")
        resize_q = sorted(resizes)
        payload = {r.rid: r.payload for r in requests}
        arrival = {r.rid: r.arrival for r in requests}
        row_cursor: Dict[int, int] = {r.rid: 0 for r in requests}
        pieces: Dict[int, List[np.ndarray]] = {r.rid: [] for r in requests}
        rows_left = {r.rid: r.size for r in requests}
        results: List[Result] = []
        latencies: List[float] = []

        st = self.unit_model.stage_times(cfg.batch_size)
        mn_bw = np.asarray(self.mn_bw)
        cn_pre_free = np.zeros(self.n_cn)
        cn_gpu_free = np.zeros(self.n_cn)
        mn_barrier = 0.0              # sequential lock-step over the pool
        mig_end = 0.0                 # background migration busy-until

        def mn_stage(mem_j: np.ndarray, gat_j: np.ndarray,
                     cache_s: float = 0.0) -> Tuple[np.ndarray, float]:
            """G_S + gather time for one batch: every MN scans (and, for
            NMP, pools — a bandwidth-bound streaming reduction) locally
            in parallel at its own memory bandwidth, then the batch's
            gather bytes serialize into the owning CN's back-end NIC.
            The CN-side cache probe + hit service overlaps the remote
            scans (hits never wait on the fabric), so it widens the
            stage only if it outlasts the slowest MN.
            Returns (per-MN stage contributions, batch gating time)."""
            stage_j = mem_j / mn_bw + gat_j / hw.NIC_BW
            gate = float(max((mem_j / mn_bw).max(), cache_s)
                         + gat_j.sum() / hw.NIC_BW)
            return stage_j, gate

        def inject(upto: float) -> None:
            """Apply failure and resize events in global time order.
            Resizes take effect at batch boundaries; a resize stamped
            inside a batch's MN stage applies before the next batch."""
            nonlocal st, mn_bw, cn_pre_free, cn_gpu_free, mig_end
            while True:
                t_f = fail_q[0][0] if fail_q else math.inf
                t_r = resize_q[0][0] if resize_q else math.inf
                if min(t_f, t_r) > upto:
                    return
                if t_f <= t_r:
                    _, j = fail_q.pop(0)
                    if j < self.m_mn:   # an MN that shrank away can't fail
                        self.fail_mn(j)
                    continue
                t, nn, mm = resize_q.pop(0)
                plan = self.resize(nn, mm)
                st = self.unit_model.stage_times(cfg.batch_size)
                mn_bw = np.asarray(self.mn_bw)
                # joining CNs are idle from the resize instant; a
                # departing CN's queue retires with it (batches are
                # placed by argmin over the live pool)
                cn_pre_free = _fit(cn_pre_free, self.n_cn, t)
                cn_gpu_free = _fit(cn_gpu_free, self.n_cn, t)
                # migration bytes stream over the fabric in the
                # background, starting when the resize fires
                mig_end = max(mig_end, t) + plan.bytes_moved / hw.NIC_BW

        def run_batch(b: Batch, now: float) -> None:
            nonlocal mn_barrier, mig_end
            # assemble real rows from each member query's payload
            dense_rows, idx_rows = [], []
            for q, nrows in b.parts:
                c = row_cursor[q.qid]
                dense_rows.append(payload[q.qid]["dense"][c:c + nrows])
                idx_rows.append(payload[q.qid]["indices"][c:c + nrows])
                row_cursor[q.qid] = c + nrows
            dense = np.concatenate(dense_rows)
            idx = np.concatenate(idx_rows)
            pad = cfg.batch_size - dense.shape[0]
            if pad > 0:
                dense = np.concatenate(
                    [dense, np.zeros_like(dense[:1]).repeat(pad, 0)])
                idx = np.concatenate(
                    [idx, -np.ones_like(idx[:1]).repeat(pad, 0)])

            scale = b.size / cfg.batch_size
            task = int(np.argmin(cn_pre_free))
            pre_done = max(now, cn_pre_free[task]) + st.t_pre * scale
            cn_pre_free[task] = pre_done
            mn_start = max(pre_done + st.t_comm_in * scale, mn_barrier)

            # MNs that died during G_P/scatter are gone before this batch's
            # MN stage begins: re-route first, then execute
            inject(mn_start)
            # a CN shrink landing inside the G_P/scatter window may have
            # retired the chosen CN: hand the batch off to a survivor and
            # redo its pre stage there
            while task >= len(cn_pre_free):
                task = int(np.argmin(cn_pre_free))
                pre_done = max(now, cn_pre_free[task]) + st.t_pre * scale
                cn_pre_free[task] = pre_done
                mn_start = max(pre_done + st.t_comm_in * scale, mn_barrier)
                inject(mn_start)
            scores, mem_j, gat_j = self._execute(task, dense, idx)
            stage_j, t_mn = mn_stage(mem_j, gat_j, self._batch_cache_s)

            # a failure landing inside this batch's MN stage hits packets
            # in flight: rebuild routing, re-issue on the survivors
            while (fail_q and mn_start < fail_q[0][0] <= mn_start + t_mn):
                t_fail, j = fail_q.pop(0)
                if j >= self.m_mn:      # departed via an earlier shrink
                    continue
                hit = mem_j[j] > 0
                self.fail_mn(j)
                if hit:
                    # the aborted scan's traffic was already on the wire
                    # and the bus — charge the wasted first pass before
                    # re-issuing on the survivors
                    self.reissues += 1
                    self.mn_access_bytes += mem_j
                    self.mn_gather_bytes += gat_j
                    self.mn_stage_s += stage_j
                    scores, mem_j, gat_j = self._execute(task, dense, idx)
                    stage_j, t_mn = mn_stage(mem_j, gat_j,
                                             self._batch_cache_s)
                    mn_start = t_fail + cfg.mn_recovery_s
            # an in-flight shard migration fair-shares the gather NIC
            # path with this batch: each stream extends by the other's
            # demand for the overlap
            if mn_start < mig_end and gat_j.sum() > 0:
                extra = float(gat_j.sum()) / hw.NIC_BW
                t_mn += extra
                mig_end += extra
            mn_done = mn_start + t_mn
            mn_barrier = mn_done
            self.mn_access_bytes += mem_j
            self.mn_gather_bytes += gat_j
            self.mn_stage_s += stage_j
            self._mn_stage_max_sum += t_mn
            self._n_batches += 1
            # keep admission priorities tracking the live workload even
            # on an event-free run (deterministic: a pure function of
            # the stream prefix served so far)
            if self.caches and self._n_batches % 8 == 0:
                self._refresh_hot_tables()

            g_start = max(mn_done, cn_gpu_free[task])
            done = g_start + st.t_dense * scale
            cn_gpu_free[task] = done

            o = 0
            for q, nrows in b.parts:
                pieces[q.qid].append(scores[o:o + nrows])
                o += nrows
                rows_left[q.qid] -= nrows
                if rows_left[q.qid] == 0:
                    lat = done - arrival[q.qid]
                    latencies.append(lat)
                    results.append(Result(
                        q.qid, np.concatenate(pieces[q.qid]), lat))

        def drain_due(upto: Optional[float]) -> None:
            """Form every batch whose flush deadline has passed."""
            while True:
                dl = batcher.next_deadline()
                if dl is None or (upto is not None and dl > upto):
                    return
                inject(dl)
                out = batcher.flush(dl)
                if not out:
                    return
                for b in out:
                    run_batch(b, dl)

        for req in sorted(requests, key=lambda r: r.arrival):
            drain_due(req.arrival)
            inject(req.arrival)
            q = Query(req.rid, req.arrival, req.size)
            for b in batcher.offer(q, req.arrival):
                run_batch(b, req.arrival)
        drain_due(None)

        if latencies:
            lats = np.asarray(latencies)
            mean_lat = float(lats.mean())
            p50 = float(np.percentile(lats, 50))
            p95 = float(np.percentile(lats, 95))
            p99 = float(np.percentile(lats, 99))
        else:       # nothing completed: report nan, not a fabricated 0.0
            mean_lat = p50 = p95 = p99 = float("nan")
        live = [a for j, a in enumerate(self.mn_access_bytes)
                if j not in self.dead]
        cs = self.cache_stats()
        stats = ClusterStats(
            completed=len(results),
            mean_latency=mean_lat,
            p50=p50,
            p95=p95,
            failures=self.failures,
            reroutes=self.reroutes,
            reinits=self.reinits,
            mn_access_bytes=list(self.mn_access_bytes),
            mn_gather_bytes=list(self.mn_gather_bytes),
            mn_types=list(self.mn_types),
            imbalance=em.imbalance(live),
            recoveries=self.recoveries,
            resizes=self.resizes,
            migration_bytes=self.migration_bytes,
            retired_access_bytes=self.retired_access_bytes,
            retired_gather_bytes=self.retired_gather_bytes,
            p99=p99,
            reissues=self.reissues,
            cache_hits=cs.hits,
            cache_misses=cs.misses,
            cache_evictions=cs.evictions,
            cache_invalidations=cs.invalidations,
            cache_bytes_saved=self.cache_bytes_saved,
        )
        results.sort(key=lambda r: r.rid)
        return results, stats

    # ------------------------------------------------------- validation
    def validate_latency_model(self) -> Dict[str, float]:
        """Unloaded single-batch latency: engine clock vs analytic model.

        The engine's virtual clock uses the analytic stage times for
        G_P/comm-in/G_D but *measured* per-MN access + gather bytes at
        per-node-type bandwidths for the G_S + gather stage, so the
        ratio engine/analytic isolates how far the observed pooling,
        routing imbalance, and node-type mix sit from the analytic
        model's uniform near-memory-reduction assumption (~1 when the
        workload matches cfg.avg_pooling on a homogeneous pool; > 1 on
        DDR pools, whose raw-row gather the analytic Fsum-only comm
        model undercounts — by construction the very bytes an NMP pool
        saves).  `engine_mn_stage_s` vs `analytic_mn_stage_s` compares
        the memory+gather stage in isolation (the NMP regression tests
        pin this band)."""
        st = self.unit_model.stage_times(self.cfg.batch_size)
        analytic = st.total()
        analytic_mn = st.t_sparse + st.t_comm_out
        mn_measured = (self._mn_stage_max_sum / self._n_batches
                       if self._n_batches else 0.0)
        engine = st.t_pre + st.t_comm_in + mn_measured + st.t_dense
        return {"analytic_s": analytic, "engine_s": engine,
                "ratio": engine / analytic if analytic else 1.0,
                "engine_mn_stage_s": mn_measured,
                "analytic_mn_stage_s": analytic_mn,
                "mn_stage_ratio": (mn_measured / analytic_mn
                                   if analytic_mn else 1.0)}

    @property
    def batches_seen(self) -> int:
        return self._n_batches
