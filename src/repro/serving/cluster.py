"""Cluster-scale disaggregated serving engine (paper §IV, Fig. 6/7/9).

This is the real-JAX layer of the three-layer validation story:

  analytic ``core.serving_unit.ServingUnitModel``   (closed-form stages)
      <->  DES ``serving.simulator.ClusterSim``      (queueing behavior)
      <->  ``ClusterEngine``                         (this module)

One engine serves a cluster of {n CNs, m MNs}: queries enter a shared
ingress ``Batcher`` (large queries split, small queries fused — Fig. 3a),
each batch lands on the least-loaded CN, and that CN's task id selects the
rows of the MemAccess routing table (``core.embedding_manager``) that
scatter its table lookups over the MN pool.  Every MN holds a replica
shard — the stacked tables the allocator placed on it — and the pool may
mix node types (paper §NMP, Fig. 14):

- **DDR MN**: passive remote memory — the shard's raw rows stream back to
  the owning CN (``rows x D`` gather bytes), which pools them with the
  fused CN-side bag (``kernels.embedding_bag.embedding_bag_fused_flat``).
- **NMP MN**: pools *on the memory node* with the near-memory kernel
  (``kernels.embedding_bag.embedding_bag_nmp_flat``) at NMP bandwidth;
  only pooled (B, T_j, D) Fsum vectors cross the fabric (``tables x D``
  gather bytes) and the CN skips its pooling stage for that shard.

Both paths accumulate pooling slots in the same ascending order, so a
mixed DDR+NMP deployment scores bitwise-identically to the all-DDR
baseline while moving strictly fewer gather bytes.  Placement is
node-type-aware (``core.embedding_manager.allocate_heterogeneous``: hot
tables on DDR, capacity tables on NMP, replicas spanning both classes)
and routing weighs replicas by per-node bandwidth.

Failures (§IV-A/§IV-D): ``fail_mn`` marks an MN dead and rebuilds routing
over the surviving replicas (fast path) or re-initializes the allocation
when a table lost every replica.  ``serve`` accepts timed failure events;
a failure landing inside a batch's MN stage re-issues that batch's lookups
on the survivors — no query is ever dropped.

Scenarios: ``serve`` consumes a typed event timeline — ``FailMN``,
``RecoverMN`` (timed recoveries), ``Resize``, ``ReloadParams``,
``ReplanPlacement``, ``SetWorkload`` — dispatched in global time order
by ``serving.timeline``; the declarative front door is
``serving.scenario.run_scenario(spec)``, and the legacy ``failures=`` /
``resizes=`` kwargs are bitwise-identical shims over the same queue.

Elasticity (§III, Fig. 2b/11): ``resize(n_cn, m_mn)`` grows or shrinks
either pool independently while the engine keeps serving.  MN resizes go
through the incremental migration planner
(``core.embedding_manager.allocate_incremental`` / ``plan_migration``):
surviving placements stay put, a departing MN drains its shard copies to
the survivors, a joining MN is topped up with replicas — and only the
tables whose placement changed cross the fabric.  ``serve`` consumes
timed resize events alongside failure events, charging the migration
bytes to the virtual clock as a background stream that fair-shares the
gather NIC path with the G_S stage.  Because pooling accumulates slots
in the same ascending order on every node, scores before, during, and
after any resize are bitwise-identical to a fixed-pool run.

Hot-row caching (FlexEMR; Gupta et al.): with ``cache_mb > 0`` every CN
carves a byte budget out of its HBM for a ``serving.cache.RowCache`` and
splits each MemAccess into cache **hits** — served locally, zero memory-
bus and gather bytes on the virtual clock — and **misses**, routed to
the MN pool exactly as before (miss rows are admitted on return,
LRU/LFU under ``cache_policy``, with measured hot tables outranking
cold ones at eviction time).  The numeric pooling path is unchanged:
cached rows are bitwise copies of the authoritative shard rows, and the
fused bag accumulates the merged hit+miss row set in the same ascending
slot order, so a cached engine scores **bitwise-identically** to the
uncached baseline — the cache moves bytes and time, never values.
Coherence: whenever a CN's authoritative serving copy of a table moves
(``fail_mn`` / ``recover_mn`` re-route, ``resize`` migration, a reinit's
fresh allocation), exactly that table's rows are invalidated in that
CN's cache; ``reload_params`` (DLRM weight reload) flushes everything.
NMP-routed lookups bypass the cache — their rows never cross the fabric
to begin with, which is why measured-hotness placement steers hot
tables toward DDR where the cache can capture them.

Latency accounting is wall-clock-free: a virtual clock driven by the
analytic unit model's stage times (G_P, scatter, G_S + gather from
*measured* per-MN access/gather bytes at *per-node-type* bandwidths,
G_D), so per-query latencies can be cross-validated against
``ServingUnitModel.stage_times`` and the DES (``validate_latency_model``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Dict, List, Optional, Sequence, Set,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embedding_manager as em
from repro.core import failure as fail_mod
from repro.core import hardware as hw
from repro.core.hardware import NODE_TYPES
from repro.core.serving_unit import ServingUnitModel, UnitSpec
from repro.serving.cache import CacheStats, RowCache
from repro.serving.engine import Request, Result

if TYPE_CHECKING:   # timeline imports cluster; annotation-only reverse dep
    from repro.serving.timeline import EventRecord


def _fit(arr: np.ndarray, n: int, fill: float = 0.0) -> np.ndarray:
    """Resize a per-node accounting/clock array to `n` entries: growth
    appends `fill`, shrink drops the departing tail."""
    if len(arr) >= n:
        return arr[:n].copy()
    return np.concatenate([arr, np.full(n - len(arr), fill)])


def _validate_mn_types(types: Sequence[str], m_mn: int) -> List[str]:
    if len(types) != m_mn:
        raise ValueError(f"{len(types)} MN types for a pool of {m_mn}")
    for t in types:
        if t not in NODE_TYPES or NODE_TYPES[t].kind != "mn":
            raise ValueError(f"unknown memory-node type {t!r}")
    return list(types)


def parse_mn_types(spec: str, m_mn: int) -> List[str]:
    """Parse a CLI memory-pool spec into a per-MN node-type list.

    Accepts a single type (``"nmp_mn"`` — the whole pool), an explicit
    comma list (``"ddr_mn,ddr_mn,nmp_mn,nmp_mn"``), or counted groups
    (``"2xddr_mn+2xnmp_mn"``).  The expansion must match the pool size.
    """
    types: List[str] = []
    for part in spec.replace("+", ",").split(","):
        part = part.strip()
        if "x" in part and part.split("x", 1)[0].isdigit():
            count, name = part.split("x", 1)
            types += [name.strip()] * int(count)
        elif part:
            types.append(part)
    if len(types) == 1:
        types = types * m_mn
    return _validate_mn_types(types, m_mn)


#: batch -> CN placement policies (ClusterConfig.cn_router /
#: topology.cn_router / --cn-router); cpu_free is the bitwise-parity
#: legacy default
CN_ROUTERS = ("cpu_free", "pipeline_free", "least_outstanding")


@dataclass
class ClusterConfig:
    n_cn: int = 2                 # serving-unit compute nodes (= tasks)
    m_mn: int = 4                 # memory-node pool
    batch_size: int = 64
    max_wait_s: float = 0.002     # ingress batcher flush deadline
    n_replicas: int = 2           # embedding replication factor
    use_kernel: bool = True       # Pallas bag kernels on the hot path
    cn_type: str = "cn_1g"
    mn_type: str = "ddr_mn"       # default type for the whole pool
    mn_types: Optional[Sequence[str]] = None   # per-MN override, len m_mn
    mn_recovery_s: float = fail_mod.recovery_cost_s("mn")
    cache_mb: float = 0.0         # per-CN hot-row cache budget (CN HBM)
    cache_policy: str = "lru"     # lru | lfu
    inflight_depth: int = 1       # max batches concurrently inside the MN
                                  # stage (scans + gather) pool-wide; 1 =
                                  # the sequential clock (bitwise parity
                                  # with the pre-pipeline engine), >1 =
                                  # pipelined overlap on per-resource
                                  # FIFO queues (serving.pipeline)
    cn_router: str = "cpu_free"   # batch -> CN placement policy
                                  # (serving.timeline._route_cn):
                                  # cpu_free = earliest-free preprocess
                                  # core (legacy, bitwise parity);
                                  # pipeline_free = earliest drain of
                                  # the CN's whole cpu/nic/gpu pipeline;
                                  # least_outstanding = fewest
                                  # uncommitted bookings (JSQ).  Ties
                                  # break to the lowest index everywhere.
    hedge_multiplier: float = 0.0  # straggler mitigation (FlexEMR
                                  # optimistic get): a scan projected to
                                  # exceed hedge_multiplier x its nominal
                                  # (degradation-free) duration is
                                  # re-issued on the fastest live replica
                                  # at the detection instant — both
                                  # issues charged, first finisher wins.
                                  # 0 disables (the parity default).
    seed: int = 0                 # the stream seed this engine serves
                                  # (dlrm_request_stream convention); the
                                  # serving path itself holds no RNG, so
                                  # same-seed runs give identical stats

    def resolved_mn_types(self) -> List[str]:
        types = (list(self.mn_types) if self.mn_types is not None
                 else [self.mn_type] * self.m_mn)
        return _validate_mn_types(types, self.m_mn)


@dataclass
class ModelStats:
    """Per-model slice of a fleet run's ClusterStats (keyed by model
    name in ``ClusterStats.per_model``).  Single-model runs carry one
    entry; percentiles are nearest-rank like the cluster-wide ones."""
    queries: int
    completed: int
    p99: float                    # nan when the model completed nothing
    queue_wait_p99: float         # arrival -> admission tail, per model
    cache_hits: int               # hot-row cache hits on this model's tables
    cache_bytes_saved: float      # gather bytes those hits kept off the NIC
    sla_actions: int = 0          # Resize events this model's controller emitted


@dataclass
class ClusterStats:
    completed: int
    mean_latency: float           # nan when no query completed
    p50: float
    p95: float
    failures: int
    reroutes: int
    reinits: int
    mn_access_bytes: List[float]  # memory-bus bytes scanned per MN
    mn_gather_bytes: List[float]  # bytes each MN shipped to CNs (fabric)
    mn_types: List[str]
    imbalance: float              # max/mean access over surviving MNs
    recoveries: int = 0           # MNs brought back via recover_mn
    resizes: int = 0              # elastic resize events applied
    migration_bytes: float = 0.0  # shard bytes moved by resizes
    retired_access_bytes: float = 0.0   # departed (shrunk-away) MNs' scans
    retired_gather_bytes: float = 0.0   # ... and their shipped bytes
    p99: float = float("nan")     # tail latency (nan when nothing completed)
    reissues: int = 0             # batches re-executed after in-flight MN loss
    cache_hits: int = 0           # CN hot-row cache counters (0 = no cache)
    cache_misses: int = 0
    cache_evictions: int = 0
    cache_invalidations: int = 0  # rows dropped by coherence events
    cache_bytes_saved: float = 0.0      # gather bytes hits kept off the NIC
    # pipelined execution (serving.pipeline): per-resource timelines.
    # Resource keys are "cn_cpu:i" (G_P), "cn_nic:i" (gather),
    # "cn_gpu:i" (G_D), "mn_bus:j" (scans); a retired (shrunk-away)
    # node's clock folds into its slot's totals.
    inflight_depth: int = 1       # the depth this run was served at
    makespan_s: float = 0.0       # last batch completion on the clock
    throughput_qps: float = float("nan")   # completed / makespan
    admission_wait_s: float = 0.0  # MN-stage admission stall, all batches
    # per-query queueing delay (arrival -> batch admission, i.e. the
    # first resource start of the query's first batch).  Nearest-rank
    # p99; nan when nothing completed (the mean_latency contract).
    queue_wait_mean: float = float("nan")
    queue_wait_p99: float = float("nan")
    # straggler mitigation (hedged re-issue of slow MN scans)
    degrades: int = 0             # DegradeMN events applied
    hedges: int = 0               # scans re-issued on an alternate replica
    hedge_wins: int = 0           # hedges that finished before the original
    # SLA feedback control (serving.autoscaler.SLAController)
    sla_actions: int = 0          # Resize events the controller emitted
    sla_actions_cn: int = 0       # ... that resized the CN pool
    sla_actions_mn: int = 0       # ... that resized the MN pool
    sla_window_filled: bool = True   # False only when a controller was
                                  # attached but its p99 window never
                                  # filled (run shorter than cfg.window:
                                  # the controller silently saw nothing)
    resource_busy_s: Dict[str, float] = field(default_factory=dict)
    resource_queue_s: Dict[str, float] = field(default_factory=dict)
    resource_util: Dict[str, float] = field(default_factory=dict)
    resource_occupancy: Dict[str, float] = field(default_factory=dict)
    # multi-model fleet serving: per-model breakdown keyed by model
    # name (one entry for single-model runs — the whole-cluster numbers
    # restricted to that model's stream)
    per_model: Dict[str, ModelStats] = field(default_factory=dict)
    # per-event audit trail: serving.timeline.EventRecord entries in
    # fire order — event, fire time, resulting pool shape.  Recoveries,
    # resizes, reloads, and replans all appear here with real virtual-
    # clock timestamps instead of being untimed method calls.
    events: List["EventRecord"] = field(default_factory=list)


class ClusterEngine:
    """Serve a DLRM over {n CN, m MN} with replica-aware routing.

    Fleet serving: ``fleet`` is an optional ``[(name, model, params),
    ...]`` list (first entry = the primary ``model``/``params`` pair)
    whose members share this engine's CN and MN pools.  Every model's
    tables map into one global table-id space — model k's local table
    ``t`` is global tid ``_tbl_off[k] + t`` — so placement, routing,
    shards, hedging, and the caches all run unchanged over the union;
    only hot/cold classification and cache budgets are attributed per
    model.  The shared pool needs a uniform table shape ``(rows, dim)``
    across members (table *counts* and pooling factors may differ).  A
    fleet of one is exactly the historical single-model engine."""

    def __init__(self, model, params, cfg: Optional[ClusterConfig] = None,
                 unit_model: Optional[ServingUnitModel] = None,
                 fleet: Optional[Sequence[Tuple[str, object, object]]] = None):
        assert model.cfg.family == "dlrm"
        self.model = model
        self.cfg = cfg or ClusterConfig()
        self.fleet = (list(fleet) if fleet is not None
                      else [(model.cfg.name, model, params)])
        if fleet is not None and (not self.fleet
                                  or self.fleet[0][1] is not model):
            raise ValueError("fleet[0] must be the engine's primary "
                             "(model, params) pair")
        self.model_names = [n for n, _, _ in self.fleet]
        self.n_models = len(self.fleet)
        r = model.cfg.dlrm
        self.R, self.D = r.rows_per_table, r.embed_dim
        self._tbl_off: List[int] = []
        self._tbl_count: List[int] = []
        self._tbl_owner: List[int] = []
        self.tables = []
        for k, (name, m, _) in enumerate(self.fleet):
            assert m.cfg.family == "dlrm"
            rk = m.cfg.dlrm
            if (rk.rows_per_table, rk.embed_dim) != (self.R, self.D):
                raise ValueError(
                    f"fleet model {name!r} has table shape "
                    f"({rk.rows_per_table}, {rk.embed_dim}); the shared "
                    f"MN pool needs the uniform shape "
                    f"({self.R}, {self.D})")
            off = len(self.tables)
            self._tbl_off.append(off)
            self._tbl_count.append(rk.num_tables)
            self._tbl_owner += [k] * rk.num_tables
            self.tables += [em.TableInfo(off + t, self.R, self.D,
                                         float(rk.avg_pooling))
                            for t in range(rk.num_tables)]
        self.T = len(self.tables)
        self._fleet_params = [p for _, _, p in self.fleet]
        self.params = (params if self.n_models == 1
                       else self._fleet_embed())
        # live pool sizes — cfg keeps the initial provisioning, these move
        # with resize()
        self.n_cn = self.cfg.n_cn
        self.m_mn = self.cfg.m_mn
        # heterogeneous pool: one node type per MN (all cfg.mn_type when
        # no per-MN override is given)
        self.mn_types = self.cfg.resolved_mn_types()
        self.mn_nmp = [NODE_TYPES[t].nmp for t in self.mn_types]
        self.mn_bw = [NODE_TYPES[t].mem_bw for t in self.mn_types]
        # per-MN bandwidth degradation (DegradeMN straggler injection):
        # MN j scans at mem_bw / mn_slow[j]; 1.0 = nominal, and a
        # multiply by 1.0 is float-exact so an all-ones pool is bitwise-
        # identical to the pre-degrade engine
        self.mn_slow = [1.0] * self.m_mn
        self._route_w = [max(self.mn_bw) / bw for bw in self.mn_bw]
        self.capacities = self._pool_capacities(self.m_mn)
        self.alloc = self._allocate(self.tables, self.capacities,
                                    self.mn_types,
                                    n_replicas=self.cfg.n_replicas)
        self.dead: Set[int] = set()
        self.routing = em.route_greedy(self.tables, self.alloc,
                                       self.n_cn, self.m_mn,
                                       mn_weights=self._route_w)
        self._build_shards()
        self.unit_model = unit_model or ServingUnitModel(
            model.cfg, UnitSpec(self.n_cn, self.cfg.cn_type,
                                self.m_mn, self.cfg.mn_type,
                                mn_types=tuple(self.mn_types)))
        self._dense_steps = [
            jax.jit(lambda p, d, pooled, _m=m: jax.nn.sigmoid(
                _m.dense_forward(p, d, pooled)))
            for _, m, _ in self.fleet]
        self._dense_step = self._dense_steps[0]
        # measured per-table hotness: feeds cache admission priorities
        # and re-allocation (reinit / replan) hot/cold classification.
        # Under a fleet the counter is owner-scoped, so one model's
        # traffic cannot demote another model's hot tables.
        self.hotness = em.HotnessCounter(
            self.T, owners=(self._tbl_owner if self.n_models > 1
                            else None))
        # per-CN hot-row caches + the routes their entries were fetched
        # over (the coherence protocol diffs these on every rebuild)
        self.caches: List[RowCache] = self._make_caches(self.n_cn)
        self._cache_routes: List[Dict[int, int]] = []
        self._retired_cache = CacheStats()     # departed CNs' counters
        self.cache_bytes_saved = 0.0
        # per-model cache attribution (index = fleet position)
        self.fleet_cache_hits = [0] * self.n_models
        self.fleet_cache_bytes_saved = [0.0] * self.n_models
        self._batch_cache_s = 0.0              # last batch's probe+hit time
        self._sync_caches()
        # counters / accounting
        self.failures = 0
        self.reroutes = 0
        self.reinits = 0
        self.reissues = 0
        self.recoveries = 0
        self.resizes = 0
        self.degrades = 0
        self.hedges = 0
        self.hedge_wins = 0
        # per-MN (tid, bytes) split of the most recent _execute's scans:
        # the hedging planner re-issues a straggler's tables on their
        # fastest live alternate replicas from this
        self._last_scan: Dict[int, List[Tuple[int, float]]] = {}
        self.migration_bytes = 0.0
        self.mn_access_bytes = np.zeros(self.m_mn)
        self.mn_gather_bytes = np.zeros(self.m_mn)
        self.mn_stage_s = np.zeros(self.m_mn)       # modeled G_S per MN
        self.retired_access_bytes = 0.0             # departed MNs' totals
        self.retired_gather_bytes = 0.0
        self._mn_stage_max_sum = 0.0                # per-batch gating stage
        self._n_batches = 0
        # pipelined-execution introspection: the most recent serve()
        # call's per-batch trace and resource clocks (serving.pipeline)
        self.last_trace: List = []
        self.last_resources: List = []

    def _fleet_embed(self) -> Dict[str, jnp.ndarray]:
        """Concatenate the fleet members' embedding banks along the table
        axis, in fleet order — global tid `_tbl_off[k] + t` indexes model
        k's local table t directly."""
        return {"embed": jnp.concatenate(
            [p["embed"] for p in self._fleet_params], axis=0)}

    def _allocate(self, tables, capacities, mn_types, n_replicas,
                  access_bytes=None):
        """Placement dispatch: owner-scoped `allocate_fleet` for a
        multi-model pool, the historical `allocate_heterogeneous` call
        (bit-for-bit) for a single model."""
        if self.n_models > 1:
            return em.allocate_fleet(
                tables, capacities, mn_types,
                [self._tbl_owner[t.tid] for t in tables],
                n_replicas=n_replicas, access_bytes=access_bytes)
        return em.allocate_heterogeneous(
            tables, capacities, mn_types, n_replicas=n_replicas,
            access_bytes=access_bytes)

    def _pool_capacities(self, m_mn: int) -> List[int]:
        """Per-MN shard budget at pool size `m_mn`: the requested
        replication factor fits, with one table of slack per MN for
        greedy placement skew.  The elastic pool re-provisions this
        budget at every size, so a shrink's survivors can always absorb
        the departing shards."""
        total = sum(t.size_bytes for t in self.tables)
        cap = (math.ceil(self.cfg.n_replicas * total / m_mn)
               + self.tables[0].size_bytes)
        return [cap] * m_mn

    # ------------------------------------------------------------- shards
    def _build_shards(self) -> None:
        """Materialize each MN's replica shard: the tables the allocator
        placed on it, flattened row-wise for the fused kernel."""
        embed = self.params["embed"]                      # (T, R, D)
        self._shard_tids: List[List[int]] = []
        self._shard_slot: List[Dict[int, int]] = []
        self._shard_flat: List[jax.Array] = []
        for j in range(self.m_mn):
            tids = sorted(t for t, reps in self.alloc.replicas.items()
                          if j in reps)
            self._shard_tids.append(tids)
            self._shard_slot.append({t: s for s, t in enumerate(tids)})
            if tids:
                flat = jnp.reshape(embed[jnp.asarray(tids)],
                                   (len(tids) * self.R, self.D))
            else:
                flat = jnp.zeros((0, self.D), embed.dtype)
            self._shard_flat.append(flat)

    # ------------------------------------------------------------- caching
    def _make_caches(self, n_cn: int) -> List[RowCache]:
        if self.cfg.cache_mb <= 0:
            return []
        budget = int(self.cfg.cache_mb * 1e6)
        caches = [RowCache(budget, self.D * 4, self.cfg.cache_policy)
                  for _ in range(n_cn)]
        if self.n_models > 1:
            owner_of = {tid: o for tid, o in enumerate(self._tbl_owner)}
            budgets = self._cache_budgets(budget)
            for c in caches:
                c.set_partitions(owner_of, budgets)
        return caches

    def _cache_budgets(self, budget: int) -> Dict[int, int]:
        """Split one CN's cache byte budget across fleet members in
        proportion to their measured access bytes (equal split on a cold
        counter).  The remainder after integer division goes to model 0."""
        totals = self.hotness.owner_totals(self.tables)
        grand = sum(totals.values())
        if grand <= 0.0:
            budgets = {k: budget // self.n_models
                       for k in range(self.n_models)}
        else:
            budgets = {k: int(budget * (totals.get(k, 0.0) / grand))
                       for k in range(self.n_models)}
        budgets[0] += budget - sum(budgets.values())
        return budgets

    def rebalance_cache_budgets(self) -> int:
        """Re-split every CN cache's partition budgets to the current
        per-model traffic mix; returns rows evicted to fit the new
        budgets.  No-op for a single-model engine."""
        if self.n_models <= 1 or not self.caches:
            return 0
        budgets = self._cache_budgets(int(self.cfg.cache_mb * 1e6))
        return sum(c.rebalance(budgets) for c in self.caches)

    def _sync_caches(self) -> None:
        """Coherence: after any routing rebuild, invalidate in each CN's
        cache exactly the tables whose authoritative serving copy (the
        MN this CN's lookups route to) moved — rows of unmoved tables
        survive.  Also refreshes the measured hot-table admission set."""
        if not self.caches:
            return
        hot = self.hotness.hot_tables(self.tables)
        for task, cache in enumerate(self.caches):
            new = {tid: self.routing.routes[(task, tid)]
                   for tid in range(self.T)}
            old = (self._cache_routes[task]
                   if task < len(self._cache_routes) else {})
            for tid in range(self.T):
                if old.get(tid) != new[tid]:
                    cache.invalidate_table(tid)
            if task < len(self._cache_routes):
                self._cache_routes[task] = new
            else:
                self._cache_routes.append(new)
            cache.set_hot_tables(hot)

    def _refresh_hot_tables(self) -> None:
        """Install the current measured hot-table classification into
        every CN cache.  Runs on coherence syncs AND periodically during
        healthy serving (`run_batch`), so the admission priority tracks
        the live workload instead of waiting for a failure/resize."""
        if not self.caches:
            return
        hot = self.hotness.hot_tables(self.tables)
        for cache in self.caches:
            cache.set_hot_tables(hot)

    def _cache_serve(self, cache: RowCache, tids: Sequence[int],
                     sub: np.ndarray) -> int:
        """Probe one DDR shard's lookup stream through a CN cache in
        deterministic order (table-ascending, then batch-row-major slot
        order); misses are admitted fetch-on-miss.  Returns hits."""
        hits = 0
        lookup = cache.lookup
        for k, tid in enumerate(tids):
            rows = sub[:, k, :].ravel()
            for row in rows[rows >= 0].tolist():
                if lookup(tid, row):
                    hits += 1
        return hits

    def cache_stats(self) -> CacheStats:
        """Aggregate cache counters over live CNs + retired (shrunk-away)
        CN caches."""
        cs = CacheStats()
        for c in self.caches:
            cs.absorb(c.stats)
        cs.absorb(self._retired_cache)
        return cs

    def reload_params(self, params) -> None:
        """DLRM weight reload: every authoritative row changed, so the
        MN shards re-materialize and every CN cache flushes."""
        self.params = params
        if self.n_models == 1:
            self._fleet_params = [params]
        self._build_shards()
        for cache in self.caches:
            cache.flush()

    def reload_seed(self, seed: Optional[int]) -> None:
        """Seeded weight reload (the ReloadParams event): re-initialize
        every fleet member's parameters from `seed` (None keeps current
        weights but still forces the shard rebuild + cache flush)."""
        if seed is None:
            self.reload_params(self.params)
        elif self.n_models == 1:
            self.reload_params(self.model.init(seed))
        else:
            self._fleet_params = [m.init(seed) for _, m, _ in self.fleet]
            self.reload_params(self._fleet_embed())

    def replan_placement(self) -> None:
        """Re-run node-type-aware placement with *measured* hotness (the
        serve-path counters) instead of the assumed ``avg_pooling``
        profile: hot tables migrate toward DDR MNs — where the CN cache
        can capture their traffic — and cold capacity tables toward NMP.
        Placement only targets live MNs (a replica parked on a dead node
        would silently shrink the effective replication factor), and
        routing rebuilds / caches invalidate per the moved routes."""
        live = [j for j in range(self.m_mn) if j not in self.dead]
        sub = self._allocate(
            self.tables,
            [self.capacities[j] for j in live],
            [self.mn_types[j] for j in live],
            n_replicas=min(self.cfg.n_replicas, len(live)),
            access_bytes=self.hotness.measured_access_bytes(self.tables))
        mn_used = [0] * self.m_mn
        for i, j in enumerate(live):
            mn_used[j] = sub.mn_used[i]
        self.alloc = em.Allocation(
            replicas={tid: sorted(live[i] for i in reps)
                      for tid, reps in sub.replicas.items()},
            mn_used=mn_used, n_replicas=sub.n_replicas)
        self.routing = em.route_greedy(self.tables, self.alloc,
                                       self.n_cn, self.m_mn,
                                       exclude=sorted(self.dead),
                                       mn_weights=self._route_w)
        self._build_shards()
        self._sync_caches()
        # a replan is also the natural moment to re-split the per-model
        # cache byte budgets to the measured traffic mix (no-op single)
        self.rebalance_cache_budgets()

    # ------------------------------------------------------------ failure
    def fail_mn(self, j: int) -> None:
        """Kill MN `j`: re-route to surviving replicas, or re-initialize
        the shard allocation if some table lost its last replica."""
        if not 0 <= j < self.m_mn:
            raise ValueError(f"MN id {j} outside pool of {self.m_mn}")
        if j in self.dead:
            return
        self.dead.add(j)
        self.failures += 1
        lost = any(all(r in self.dead for r in self.alloc.replicas[t.tid])
                   for t in self.tables)
        if lost:
            # §IV-A re-initialization: some table lost its last replica, so
            # standby backup MNs take over the failed slots and replicas
            # are restored from the parameter store — the pool returns to
            # full strength under a fresh allocation
            self.reinits += 1
            self.dead.clear()
            self.alloc = self._allocate(
                self.tables, self.capacities, self.mn_types,
                n_replicas=self.cfg.n_replicas,
                access_bytes=self.hotness.measured_access_bytes(self.tables))
            self.routing = em.route_greedy(self.tables, self.alloc,
                                           self.n_cn, self.m_mn,
                                           mn_weights=self._route_w)
            self._build_shards()
        else:
            self.reroutes += 1
            self.routing = em.route_greedy(self.tables, self.alloc,
                                           self.n_cn, self.m_mn,
                                           exclude=sorted(self.dead),
                                           mn_weights=self._route_w)
        self._sync_caches()

    def recover_mn(self, j: int) -> None:
        """Bring a failed MN back: its shard is still materialized (or was
        rebuilt by a reinit), so recovery is a routing rebuild only."""
        if not 0 <= j < self.m_mn:
            raise ValueError(f"MN id {j} outside pool of {self.m_mn}")
        if j not in self.dead:
            return
        self.dead.discard(j)
        self.recoveries += 1
        self.routing = em.route_greedy(self.tables, self.alloc,
                                       self.n_cn, self.m_mn,
                                       exclude=sorted(self.dead),
                                       mn_weights=self._route_w)
        self._sync_caches()

    def degrade_mn(self, j: int, factor: float = 1.0) -> bool:
        """Slow MN `j`'s memory bus by `factor` (>= 1.0; 1.0 restores
        nominal speed) — straggler injection for the hedged re-issue
        path.  Routing, placement, and scores are untouched: only the
        virtual clock's scan durations move.  Returns whether the
        slowdown state actually changed (an identity degrade is a
        recorded no-op, mirroring identity resizes)."""
        if not 0 <= j < self.m_mn:
            raise ValueError(f"MN id {j} outside pool of {self.m_mn}")
        if factor < 1.0:
            raise ValueError(f"degrade factor must be >= 1.0, "
                             f"got {factor!r}")
        changed = float(factor) != self.mn_slow[j]
        self.mn_slow[j] = float(factor)
        if changed:
            self.degrades += 1
        return changed

    # --------------------------------------------------------- elasticity
    def resize(self, n_cn: Optional[int] = None, m_mn: Optional[int] = None,
               mn_type: Optional[str] = None) -> em.MigrationPlan:
        """Grow/shrink either pool independently (paper §III, Fig. 2b/11).

        MN grow: the joining MNs (of `mn_type`, default the config's pool
        type) start empty and the incremental allocator tops replicas up
        onto them.  MN shrink: the highest-numbered MNs depart, draining
        their shard copies to the survivors first (the migration plan's
        moves) so no table ever loses availability.  CN resize holds no
        embedding state — it only rebalances the routing rows across the
        new task count.  Scores are bitwise-invariant across any resize:
        placement decides WHERE a table pools, never the slot
        accumulation order.

        Returns the migration plan; `serve` charges its bytes to the
        virtual clock as a background stream contending with the G_S
        gather path.
        """
        new_n = self.n_cn if n_cn is None else int(n_cn)
        new_m = self.m_mn if m_mn is None else int(m_mn)
        if new_n < 1 or new_m < 1:
            raise ValueError(
                f"cannot resize to {{n_cn={new_n}, m_mn={new_m}}}")
        if (new_n, new_m) == (self.n_cn, self.m_mn):
            return em.MigrationPlan(moves=[], dropped=[], bytes_moved=0)
        plan = em.MigrationPlan(moves=[], dropped=[], bytes_moved=0)
        if new_m != self.m_mn:
            if new_m > self.m_mn:
                add = mn_type or self.cfg.mn_type
                new_types = self.mn_types + [add] * (new_m - self.m_mn)
            else:
                new_types = self.mn_types[:new_m]
            new_types = _validate_mn_types(new_types, new_m)
            caps = self._pool_capacities(new_m)
            dead = {j for j in self.dead if j < new_m}
            new_alloc = em.allocate_incremental(
                self.tables, caps, new_types, prev=self.alloc,
                n_replicas=self.cfg.n_replicas, exclude=sorted(dead))
            plan = em.plan_migration(self.alloc, new_alloc, self.tables)
            if new_m < self.m_mn:
                # departing MNs retire their accumulated byte counters
                self.retired_access_bytes += float(
                    self.mn_access_bytes[new_m:].sum())
                self.retired_gather_bytes += float(
                    self.mn_gather_bytes[new_m:].sum())
            self.mn_access_bytes = _fit(self.mn_access_bytes, new_m)
            self.mn_gather_bytes = _fit(self.mn_gather_bytes, new_m)
            self.mn_stage_s = _fit(self.mn_stage_s, new_m)
            self.alloc = new_alloc
            self.mn_types = new_types
            self.mn_nmp = [NODE_TYPES[t].nmp for t in new_types]
            self.mn_bw = [NODE_TYPES[t].mem_bw for t in new_types]
            # joining MNs scan at nominal speed; a departing MN takes
            # its slowdown with it
            self.mn_slow = (self.mn_slow[:new_m]
                            + [1.0] * (new_m - len(self.mn_slow)))
            self._route_w = [max(self.mn_bw) / bw for bw in self.mn_bw]
            self.capacities = caps
            self.dead = dead
            self.m_mn = new_m
            self._build_shards()
        if new_n != self.n_cn and self.caches:
            if new_n < self.n_cn:
                # a departing CN retires its cache with its counters
                for cache in self.caches[new_n:]:
                    self._retired_cache.absorb(cache.stats)
                self.caches = self.caches[:new_n]
                self._cache_routes = self._cache_routes[:new_n]
            else:
                self.caches += self._make_caches(new_n - self.n_cn)
        self.n_cn = new_n
        self.routing = em.route_greedy(self.tables, self.alloc,
                                       self.n_cn, self.m_mn,
                                       exclude=sorted(self.dead),
                                       mn_weights=self._route_w)
        self._sync_caches()
        self.unit_model = ServingUnitModel(
            self.model.cfg, UnitSpec(self.n_cn, self.cfg.cn_type,
                                     self.m_mn, self.cfg.mn_type,
                                     mn_types=tuple(self.mn_types)))
        self.resizes += 1
        self.migration_bytes += plan.bytes_moved
        return plan

    # ------------------------------------------------------ real compute
    def _mn_pool(self, j: int, tids: Sequence[int],
                 idx_sub: np.ndarray) -> jax.Array:
        """Pool MN j's routed tables — on-node for NMP, CN-side for DDR.

        An NMP MN reduces each bag locally with the near-memory kernel
        and ships only pooled vectors; a DDR MN ships raw rows, which
        the owning CN pools with the fused multi-table bag.  Both
        accumulate slots in ascending order, so the scores are bitwise
        independent of the pool's node-type mix.
        """
        slots = np.asarray([self._shard_slot[j][t] for t in tids], np.int32)
        if self.cfg.use_kernel:
            from repro.kernels import ops
            offsets = jnp.asarray(slots * self.R)
            bag = (ops.embedding_bag_nmp_flat if self.mn_nmp[j]
                   else ops.embedding_bag_fused_flat)
            return bag(self._shard_flat[j], offsets, jnp.asarray(idx_sub))
        from repro.models.dlrm import embedding_bag_ref
        stack = self._shard_flat[j].reshape(-1, self.R, self.D)[
            jnp.asarray(slots)]
        return embedding_bag_ref(stack, jnp.asarray(idx_sub))

    def _execute(self, task: int, dense: np.ndarray, idx: np.ndarray,
                 model: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Scatter -> per-MN pooling -> gather -> DenseNet.

        Returns (scores, per-MN memory-bus bytes scanned, per-MN gather
        bytes shipped to the CN).  For a DDR MN the two are equal (raw
        rows cross the fabric); an NMP MN scans the same rows locally
        but ships only ``valid rows x T_j x D`` pooled bytes.

        With a CN cache, each DDR MemAccess splits into hits — served
        from the CN's resident copy, charged to neither the MN bus nor
        the fabric — and misses, routed (and admitted) as before.  The
        pooling math is untouched: cache rows are bitwise copies, so
        the fused bag over the merged hit+miss set in ascending slot
        order IS the uncached computation, and only the byte/time
        accounting moves.

        `model` selects the fleet member the batch belongs to: `idx` is
        indexed by the model's *local* table ids, its lookups touch only
        the model's global-tid slice, and the dense step runs that
        member's parameters.  Model 0 of a single-model engine is the
        historical path bit-for-bit (the slice is the whole pool)."""
        off = self._tbl_off[model]
        Tm = self._tbl_count[model]
        shards = em.shard_assignment(self.alloc, self.routing, self.T,
                                     self.m_mn, task)
        B = dense.shape[0]
        pooled = np.zeros((B, Tm, self.D), np.float32)
        mem_j = np.zeros(self.m_mn)
        gat_j = np.zeros(self.m_mn)
        row_b = self.D * 4
        cache = self.caches[task] if self.caches else None
        batch_probes = 0
        batch_hit_bytes = 0.0
        self._last_scan = {}
        for j, tids in enumerate(shards):
            # restrict this MN's shard slice to the owning model's tables
            mtids = [t for t in tids if off <= t < off + Tm]
            if not mtids:
                continue
            if j in self.dead:          # stale routing — never expected
                raise LookupError(f"routing targets dead MN {j}")
            cols = [t - off for t in mtids]
            sub = idx[:, cols, :]
            pooled[:, cols, :] = np.asarray(self._mn_pool(j, mtids, sub))
            per_table = (sub >= 0).sum(axis=(0, 2))
            self._last_scan[j] = [(int(t), float(pt) * row_b) for t, pt
                                  in zip(mtids, per_table.tolist())]
            self.hotness.update(mtids, per_table)
            nvalid = int(per_table.sum())
            if cache is not None and not self.mn_nmp[j]:
                hits = self._cache_serve(cache, mtids, sub)
                mem_j[j] = float(nvalid - hits) * row_b
                gat_j[j] = mem_j[j]
                self.cache_bytes_saved += float(hits) * row_b
                # every tid in mtids belongs to `model`, so the whole
                # shard's hits attribute to it without a per-tid split
                self.fleet_cache_hits[model] += hits
                self.fleet_cache_bytes_saved[model] += float(hits) * row_b
                batch_probes += nvalid
                batch_hit_bytes += float(hits) * row_b
            elif self.mn_nmp[j]:
                mem_j[j] = float(nvalid) * row_b
                live_rows = int((sub >= 0).any(axis=(1, 2)).sum())
                gat_j[j] = float(live_rows * len(mtids)) * row_b
            else:
                mem_j[j] = float(nvalid) * row_b
                gat_j[j] = mem_j[j]
        # probe tags + hit rows stream from CN HBM on the virtual clock
        self._batch_cache_s = ((batch_probes * hw.CACHE_TAG_BYTES
                                + batch_hit_bytes) / hw.CN_HBM_BW)
        scores = np.asarray(self._dense_steps[model](
            self._fleet_params[model], jnp.asarray(dense),
            jnp.asarray(pooled)))
        return scores, mem_j, gat_j

    # ---------------------------------------------------------- serving
    def serve(self, requests: List[Request],
              failures: Sequence[Tuple[float, int]] = (),
              resizes: Sequence[Tuple[float, int, int]] = (),
              events: Sequence = (),
              controller=None,
              controllers=None,
              ) -> Tuple[List[Result], ClusterStats]:
        """Serve a request stream under a typed event timeline.

        ``events`` is a sequence of ``serving.scenario`` events
        (``FailMN``, ``RecoverMN``, ``Resize``, ``ReloadParams``,
        ``ReplanPlacement``, ``SetWorkload``) consumed in global time
        order by ``serving.timeline.TimelineDispatcher`` — see that
        module for the ordering and batch-boundary/mid-stage semantics,
        and ``serving.scenario.run_scenario`` for the declarative front
        door that also builds the stream.

        The legacy kwargs are thin shims kept bitwise-identical:
        ``failures=[(time_s, mn_id), ...]`` becomes ``FailMN`` events
        and ``resizes=[(time_s, n_cn, m_mn), ...]`` becomes ``Resize``
        events (failures first at equal times — the historical
        tie-break).  Failure/recovery ids are validated against the
        schedule-aware *maximum* pool, so a failure scheduled after a
        timed grow is accepted.

        ``controller`` is an optional SLA feedback controller
        (``serving.autoscaler.SLAController``): the dispatcher feeds it
        every completion (virtual finish time, measured latency) and
        enqueues whatever ``Resize`` events it emits into the live
        timeline — the declarative front door builds one when
        ``ScenarioSpec.sla_p99_s`` is set.  ``controllers`` is the fleet
        form — a ``{model_index: SLAController}`` dict giving each fleet
        member its own latency window and SLA target over the shared
        pool (mutually exclusive with ``controller``).

        Execution is real JAX; time is a virtual clock advanced with the
        analytic stage model, so latencies are deterministic and
        comparable to ServingUnitModel / ClusterSim."""
        from repro.serving.timeline import TimelineDispatcher, legacy_events
        evs = legacy_events(failures, resizes) + list(events or ())
        return TimelineDispatcher(self, requests, evs,
                                  controller=controller,
                                  controllers=controllers).run()

    # ------------------------------------------------------- validation
    def validate_latency_model(self) -> Dict[str, float]:
        """Unloaded single-batch latency: engine clock vs analytic model.

        The engine's virtual clock uses the analytic stage times for
        G_P/comm-in/G_D but *measured* per-MN access + gather bytes at
        per-node-type bandwidths for the G_S + gather stage, so the
        ratio engine/analytic isolates how far the observed pooling,
        routing imbalance, and node-type mix sit from the analytic
        model's uniform near-memory-reduction assumption (~1 when the
        workload matches cfg.avg_pooling on a homogeneous pool; > 1 on
        DDR pools, whose raw-row gather the analytic Fsum-only comm
        model undercounts — by construction the very bytes an NMP pool
        saves).  `engine_mn_stage_s` vs `analytic_mn_stage_s` compares
        the memory+gather stage in isolation (the NMP regression tests
        pin this band)."""
        st = self.unit_model.stage_times(self.cfg.batch_size)
        analytic = st.total()
        analytic_mn = st.t_sparse + st.t_comm_out
        mn_measured = (self._mn_stage_max_sum / self._n_batches
                       if self._n_batches else 0.0)
        # the analytic cross-check models an UNLOADED single batch: no
        # query waits for admission, so the queue-wait term is exactly
        # 0.0 by construction.  The assert pins that contract — if the
        # queueing-delay accounting ever leaks a nonzero term into this
        # path, the engine/analytic ratio would silently shift.
        queue_wait_s = 0.0
        assert queue_wait_s == 0.0, (
            "validate_latency_model assumes zero queueing; the "
            "unloaded-path queue-wait term must be exactly 0.0")
        engine = (st.t_pre + st.t_comm_in + queue_wait_s + mn_measured
                  + st.t_dense)
        return {"analytic_s": analytic, "engine_s": engine,
                "ratio": engine / analytic if analytic else 1.0,
                "engine_mn_stage_s": mn_measured,
                "analytic_mn_stage_s": analytic_mn,
                "queue_wait_s": queue_wait_s,
                "mn_stage_ratio": (mn_measured / analytic_mn
                                   if analytic_mn else 1.0)}

    @property
    def batches_seen(self) -> int:
        return self._n_batches
