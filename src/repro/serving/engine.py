"""Real-JAX serving engine: batched request execution with the paper's
sequential (lock-step) semantics — a pjit'd step over the serving unit's
mesh IS lock-step query processing; the engine adds the ingress batcher,
the DLRM/LM execution paths, and MN-failure recovery hooks.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import Batcher, Query
from repro.distributed import sharding as shd


@dataclass
class Request:
    rid: int
    payload: Dict[str, np.ndarray]      # per-sample model inputs
    size: int
    arrival: float
    # owning model index under fleet serving (0 for single-model streams)
    model: int = 0


@dataclass
class Result:
    rid: int
    outputs: np.ndarray
    latency: float


class DLRMServingEngine:
    """Batched CTR scoring over a (possibly sharded) DLRM."""

    def __init__(self, model, params, batch_size: int = 128, mesh=None,
                 rules=None, use_kernel: bool = False):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.mesh = mesh
        self.rules = rules
        self.use_kernel = use_kernel
        self._step = jax.jit(
            lambda p, b: model.serve_step(p, b, use_kernel=use_kernel))
        self._clock = 0.0

    def _pad_concat(self, reqs: List[Request]) -> Dict[str, np.ndarray]:
        dense = np.concatenate([r.payload["dense"] for r in reqs])
        idx = np.concatenate([r.payload["indices"] for r in reqs])
        pad = self.batch_size - dense.shape[0]
        if pad > 0:
            dense = np.concatenate([dense, np.zeros_like(dense[:1]).repeat(pad, 0)])
            idx = np.concatenate([idx, -np.ones_like(idx[:1]).repeat(pad, 0)])
        return {"dense": jnp.asarray(dense), "indices": jnp.asarray(idx)}

    def serve(self, requests: List[Request]) -> List[Result]:
        """Sequential query processing: requests are executed in complete
        batches, in arrival order; one query's lookups never interleave
        with another's inside the step."""
        out: List[Result] = []
        ctx = (shd.use_mesh(self.mesh, self.rules)
               if self.mesh is not None else _null_ctx())
        with ctx:
            i = 0
            while i < len(requests):
                group: List[Request] = []
                n = 0
                while i < len(requests) and n + requests[i].size <= self.batch_size:
                    group.append(requests[i])
                    n += requests[i].size
                    i += 1
                if not group:           # oversized request: split
                    r = requests[i]
                    i += 1
                    scores = []
                    for s0 in range(0, r.size, self.batch_size):
                        chunk = {k: v[s0:s0 + self.batch_size]
                                 for k, v in r.payload.items()}
                        sub = Request(r.rid, chunk,
                                      min(self.batch_size, r.size - s0),
                                      r.arrival)
                        batch = self._pad_concat([sub])
                        scores.append(np.asarray(
                            self._step(self.params, batch))[:sub.size])
                    out.append(Result(r.rid, np.concatenate(scores), 0.0))
                    continue
                batch = self._pad_concat(group)
                scores = np.asarray(self._step(self.params, batch))
                o = 0
                for r in group:
                    out.append(Result(r.rid, scores[o:o + r.size], 0.0))
                    o += r.size
        return out


class LMServingEngine:
    """Prefill+decode serving for the LM archs (greedy sampling)."""

    def __init__(self, model, params, cache_len: int = 256):
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len))
        self._decode = jax.jit(model.decode_step)

    def generate(self, tokens: np.ndarray, steps: int = 16,
                 extra: Optional[Dict[str, Any]] = None) -> np.ndarray:
        batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, cache = self._prefill(self.params, batch)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, {"tokens": tok})
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return np.concatenate(out, axis=1)


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
