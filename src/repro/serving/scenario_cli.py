"""Scenario lint/run CLI: ``python -m repro.serving.scenario_cli``.

A thin wrapper so the command-line entry point is a module the serving
package does NOT import: running ``-m repro.serving.scenario`` directly
executes that file a second time as ``__main__`` (runpy warns, and the
``__main__`` copy's event classes would fail the dispatcher's
isinstance checks — ``scenario.py`` guards against the latter by
delegating, but the dual execution and the warning remain).  This
module exists only in ``sys.modules`` as itself, so the scenario module
loads exactly once, under its canonical name.

  PYTHONPATH=src python -m repro.serving.scenario_cli \
      examples/scenarios/*.json [--run] [--write-presets DIR] \
      [--format text|json]

``--format json`` renders the lint outcome in the shared
``repro.analysis.report`` schema (byte-stable, machine-diffable) and
exits nonzero on findings instead of raising.
"""
import sys

from repro.serving.scenario import main

if __name__ == "__main__":
    sys.exit(main())
