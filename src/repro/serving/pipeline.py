"""Per-resource virtual timelines: the pipelined execution model.

The sequential virtual clock serialized the whole MN stage pool-wide
(one global barrier), so modeled throughput was the *sum* of stages
rather than the *bottleneck* stage — the opposite of how a production
disaggregated rack behaves (DisaggRec §IV; FlexEMR's overlapped
optimistic-get path).  This module supplies the primitives that make
pipelined overlap first-class:

:class:`ResourceClock`
    One independent FIFO queue per physical resource — a CN's
    preprocess core (``cn_cpu:i``), its back-end gather NIC
    (``cn_nic:i``), its GPU (``cn_gpu:i``), and each MN's memory bus
    (``mn_bus:j``).  A batch *books* busy intervals on the resources it
    touches; a booking starts no earlier than the resource's
    ``free_at`` (FIFO, no preemption) and the clock accumulates busy
    time, queueing delay, and the full interval list for the
    correctness battery (``tests/test_pipeline.py``).

:class:`AdmissionWindow`
    The ``ClusterConfig.inflight_depth`` gate: at most ``depth``
    batches may be inside their MN stage (scans + gather) at once.
    Admission is an order statistic over completed-stage times — batch
    i may start once at most ``depth - 1`` of the previously admitted
    batches are still in flight — which degenerates to the legacy
    global barrier at ``depth=1`` (the floor is then the max previous
    stage-done time, i.e. exactly the old ``mn_barrier``).

:class:`BatchTrace`
    One per-batch record of every interval the dispatcher booked —
    the raw material for the causality/conservation invariants.

**Depth-1 bitwise parity.**  At ``inflight_depth=1`` every resource is
idle by the time a batch reaches it (the admission floor is the
previous batch's stage-done time, which upper-bounds every bus/NIC
``free_at``), so the dispatcher takes its *wait-free* commit path: the
stage-done time is computed with the sequential clock's closed-form
gate — ``max(max_j scan_j, cache_s) + gather`` — in the same
floating-point operation order.  Parity with the pre-pipeline clock is
therefore by construction, not by rounding luck; the queued general
path only engages when a resource actually makes a batch wait, which
cannot happen at depth 1.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis import clocksan


@dataclass(frozen=True)
class Interval:
    """One booked busy interval on a resource.  ``aborted`` marks the
    wasted first pass of a batch re-issued after an in-flight MN
    failure (charged up to the failure instant)."""
    start: float
    end: float
    tag: int = -1               # batch id (-1 = untagged)
    aborted: bool = False


class ResourceClock:
    """A single FIFO resource timeline.

    ``book`` records an interval the caller planned (the dispatcher
    plans a whole MN stage before committing, so a mid-stage failure
    can abort it without corrupting the clock); ``reserve`` is the
    plan-free convenience for the strictly serial stages (pre, dense).
    Causality is enforced, never silently repaired: a booking that
    starts before ``free_at`` is a dispatcher bug.
    """

    def __init__(self, name: str, free_at: float = 0.0):
        self.name = name
        self.free_at = free_at
        self.busy_s = 0.0
        self.queue_s = 0.0          # time bookings waited behind the queue
        self.bookings = 0
        self.intervals: List[Interval] = []

    def peek(self, ready_s: float) -> float:
        """Earliest start for work becoming ready at ``ready_s`` —
        without booking anything."""
        return ready_s if ready_s >= self.free_at else self.free_at

    def book(self, ready_s: float, start_s: float, end_s: float,
             tag: int = -1, aborted: bool = False) -> None:
        """Commit a planned busy interval.  ``ready_s`` is when the
        work *could* have started (start - ready is queueing delay)."""
        if clocksan.enabled():
            # pure observer, checked before any mutation: enabling the
            # sanitizer cannot perturb the simulated timeline
            clocksan.check_book(self, ready_s, start_s, end_s, tag,
                                aborted)
        if start_s < self.free_at or start_s < ready_s or end_s < start_s:
            raise AssertionError(
                f"{self.name}: booking [{start_s}, {end_s}) violates "
                f"FIFO causality (free_at={self.free_at}, "
                f"ready={ready_s})")
        self.queue_s += start_s - ready_s
        self.busy_s += end_s - start_s
        self.free_at = end_s
        self.bookings += 1
        self.intervals.append(Interval(start_s, end_s, tag, aborted))

    def reserve(self, ready_s: float, duration_s: float,
                tag: int = -1) -> Tuple[float, float]:
        """Book ``duration_s`` of work at the earliest FIFO slot;
        returns (start, end).  end = start + duration in the same
        floating-point order as the sequential clock's chain."""
        start = self.peek(ready_s)
        end = start + duration_s
        self.book(ready_s, start, end, tag)
        return start, end

    def charge_abort(self, start_s: float, upto_s: float,
                     tag: int = -1) -> None:
        """Charge the in-flight prefix of an aborted planned interval:
        the resource was genuinely busy from ``start_s`` until the
        failure at ``upto_s``.  A no-op if the work never started."""
        if upto_s <= start_s:
            return
        self.book(start_s, start_s, upto_s, tag, aborted=True)

    def __repr__(self) -> str:          # pragma: no cover - debug aid
        return (f"ResourceClock({self.name!r}, free_at={self.free_at:g}, "
                f"busy={self.busy_s:g}, queue={self.queue_s:g}, "
                f"n={self.bookings})")


class AdmissionWindow:
    """Depth-``d`` MN-stage admission: a batch may start its MN stage
    only when at most ``d - 1`` previously admitted batches are still
    inside theirs.

    The floor for the (i+1)-th batch is the (i+1-d)-th smallest of the
    previous stage-done times — an order statistic, *not* the d-th most
    recent completion, because at depth > 1 batches complete out of
    admission order.  At ``depth=1`` the floor is the max previous
    stage-done time: exactly the legacy global ``mn_barrier``.
    """

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"inflight_depth must be >= 1, got {depth}")
        self.depth = depth
        self.wait_s = 0.0           # total admission stall across batches
        self._done: List[float] = []

    def floor(self) -> float:
        """Earliest instant the next batch may start its MN stage."""
        k = len(self._done)
        if k < self.depth:
            return 0.0
        return self._done[k - self.depth]

    def complete(self, done_s: float) -> None:
        bisect.insort(self._done, done_s)


@dataclass(frozen=True)
class BatchTrace:
    """Every interval one batch booked, for the correctness battery."""
    bid: int
    task: int                       # owning CN
    size: int                       # real (unpadded) rows
    pre: Tuple[float, float]        # G_P on cn_cpu:task
    chain_ready: float              # pre done + scatter: earliest MN start
    mn_start: float                 # after admission (+ recovery stalls)
    scans: Tuple[Tuple[int, float, float], ...]   # (mn, start, end)
    gather: Tuple[float, float]     # on cn_nic:task (start == end: none)
    mn_done: float
    dense: Tuple[float, float]      # G_D on cn_gpu:task
    done: float
    reissues: int                   # in-flight MN losses this batch ate
    qids: Tuple[int, ...]           # member queries
    hedges: Tuple["HedgeIssue", ...] = ()   # straggler re-issues


@dataclass(frozen=True)
class HedgeIssue:
    """One hedged re-issue of a straggling MN scan (FlexEMR's
    optimistic get): the scan's tables re-issued on an alternate
    replica's bus at the detection instant.  Both the original and the
    hedge are charged to their buses; the batch proceeds at the first
    finisher."""
    src_mn: int                     # the straggling MN
    alt_mn: int                     # the replica bus the hedge runs on
    detect_s: float                 # when the straggle was detected
    start_s: float                  # hedge start on the alternate bus
    dur_s: float                    # hedge scan duration
    bytes_b: float                  # bytes the hedge moved (charged to alt)
    won: bool                       # hedge finished before the original

    @property
    def end_s(self) -> float:
        return self.start_s + self.dur_s


@dataclass
class MNPlan:
    """A batch's planned (not yet committed) MN stage.

    ``queued`` is True when any bus or the gather NIC would make this
    batch wait — only then does the stage-done time come from the
    general per-resource chain; otherwise it is the sequential clock's
    closed-form gate (``mn_start + t_gate``), preserving depth-1
    bitwise parity (see module docstring).  ``hedges`` (always empty
    when ``ClusterConfig.hedge_multiplier`` is 0) lists the straggler
    re-issues; a plan with hedges is always ``queued`` — the closed-
    form gate knows nothing about alternate buses.
    """
    mn_start: float
    scans: List[Tuple[int, float, float]]   # (mn, start, duration)
    t_gate: float                   # max(max scan, cache_s) + gather
    gather_ready: float             # scans done and cache probe drained
    gather_start: float
    gather_dur: float
    queued: bool
    end: float                      # planned stage-done time
    hedges: Tuple[HedgeIssue, ...] = ()


def fit_clocks(clocks: List[ResourceClock], n: int, prefix: str,
               fill: float, registry: Optional[List[ResourceClock]] = None
               ) -> List[ResourceClock]:
    """Resize a per-node clock list across an elastic resize: joining
    nodes are idle from the resize instant (``fill``); a departing
    node's clock retires with its accumulated stats (it stays in
    ``registry`` for end-of-run aggregation, mirroring how departed
    MNs retire their byte counters)."""
    if len(clocks) >= n:
        return clocks[:n]
    out = list(clocks)
    for i in range(len(clocks), n):
        c = ResourceClock(f"{prefix}:{i}", free_at=fill)
        if registry is not None:
            registry.append(c)
        out.append(c)
    return out


def summarize_resources(clocks: List[ResourceClock], makespan_s: float
                        ) -> Tuple[Dict[str, float], Dict[str, float],
                                   Dict[str, float], Dict[str, float]]:
    """Fold every clock ever created (live + retired) into per-resource
    stats keyed by name: busy seconds, queueing-delay seconds,
    utilization (busy / makespan), and occupancy ((busy + queued) /
    makespan).  A re-grown node's clock shares its predecessor's name
    and their stats sum — the name identifies the slot, not the
    incarnation.

    The returned dicts are key-sorted: accumulation runs in clock
    creation order (so the floating-point sums are reproducible against
    the per-clock fold), but the emitted mappings iterate in sorted-key
    order so serialized reports are byte-stable run to run."""
    busy: Dict[str, float] = {}
    queue: Dict[str, float] = {}
    for c in clocks:
        busy[c.name] = float(busy.get(c.name, 0.0) + c.busy_s)
        queue[c.name] = float(queue.get(c.name, 0.0) + c.queue_s)
    names = sorted(busy)
    busy = {k: busy[k] for k in names}
    queue = {k: queue[k] for k in names}
    if makespan_s > 0:
        util = {k: busy[k] / makespan_s for k in names}
        occ = {k: (busy[k] + queue[k]) / makespan_s for k in names}
    else:
        util = {k: 0.0 for k in names}
        occ = {k: 0.0 for k in names}
    return busy, queue, util, occ
