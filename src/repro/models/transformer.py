"""Decoder-only transformer LM (dense / MoE / VLM) with:

- scan-over-layers + configurable remat (compile-time + memory sanity at
  48L/512-device scale),
- rule-driven sharding (head-TP, FSDP, or decode layouts — see
  registry.make_rules),
- flash-style chunked attention for train/prefill,
- sequence-sharded KV cache decode (DisaggRec Fsum pattern).

The class exposes the framework-wide Model API:
  init / param_specs / param_shapes / loss / prefill / decode_step /
  input_specs / cache_specs.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import params as pm
from repro.models.params import Spec


def padded_vocab(v: int) -> int:
    return -(-v // 128) * 128


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def pad_cache(kv, cache_len: Optional[int], axis: int = 2):
    """Pad a stacked (L,B,S,...) prefill cache out to cache_len slots."""
    if cache_len is None or cache_len <= kv.shape[axis]:
        return kv
    pad = [(0, 0)] * kv.ndim
    pad[axis] = (0, cache_len - kv.shape[axis])
    return jnp.pad(kv, pad)


def cross_entropy(logits, labels, vocab_real: int):
    """Stable CE with padded-vocab masking. logits fp32 (..., Vp)."""
    logits = logits.astype(jnp.float32)
    Vp = logits.shape[-1]
    if Vp > vocab_real:
        logits = jnp.where(jnp.arange(Vp) < vocab_real, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    # one-hot reduce (not take_along_axis): partitions over a vocab-sharded
    # logits dim without an all-gather
    hit = jnp.arange(Vp) == labels[..., None]
    ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    return lse - ll


class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.vp = padded_vocab(cfg.vocab_size)

    # ------------------------------------------------------------ params
    def _layer_table(self) -> dict:
        cfg = self.cfg
        t = {
            "ln1": L.norm_table(cfg.d_model),
            "attn": L.attn_table(cfg),
            "ln2": L.norm_table(cfg.d_model),
        }
        if cfg.moe is not None:
            t["moe"] = moe_mod.moe_table(cfg)
        else:
            t["mlp"] = L.mlp_table(cfg.d_model, cfg.d_ff)
        return t

    def _top_table(self) -> dict:
        cfg = self.cfg
        t = {
            "embed": L.embed_table(self.vp, cfg.d_model),
            "final_norm": L.norm_table(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            t["head"] = L.head_table(self.vp, cfg.d_model)
        if cfg.family == "vlm":
            d = cfg.d_model
            t["mm_proj"] = {
                "w1": Spec((d, d), ("embed", None)),
                "b1": Spec((d,), (None,), "zeros"),
                "w2": Spec((d, d), (None, "embed")),
                "b2": Spec((d,), ("embed",), "zeros"),
            }
        return t

    def init(self, seed: int = 0):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        params = pm.init_table(k1, self._top_table(), dt)
        params["layers"] = pm.init_stacked(
            k2, self._layer_table(), cfg.num_layers, dt)
        return params

    def param_specs(self):
        specs = pm.table_specs(self._top_table())
        specs["layers"] = pm.table_specs(self._layer_table(), prefix=("layers",))
        return specs

    def param_shapes(self, dtype=None):
        dt = dtype or jnp.dtype(self.cfg.param_dtype)
        shapes = pm.eval_shape_tree(self._top_table(), dtype=dt)
        shapes["layers"] = pm.eval_shape_tree(
            self._layer_table(), stack=self.cfg.num_layers, dtype=dt)
        return shapes

    def param_count(self) -> int:
        n = pm.table_size(self._top_table())
        n += pm.table_size(self._layer_table()) * self.cfg.num_layers
        return n

    # ----------------------------------------------------------- forward
    def _attention(self, lp, x, pos):
        cfg = self.cfg
        wq = shd.lsc(lp["wq"], "attn_din_c", "heads", "head_dim")
        wk = shd.lsc(lp["wk"], "attn_din_c", "kv_heads", "head_dim")
        wv = shd.lsc(lp["wv"], "attn_din_c", "kv_heads", "head_dim")
        wo = shd.lsc(lp["wo"], "heads", "head_dim", "attn_dout_c")
        p = dict(lp, wq=wq, wk=wk, wv=wv, wo=wo)
        q, k, v = L._project_qkv(p, x, cfg, pos)
        q = shd.lsc(q, "batch", "seq", "heads", "head_dim")
        kv = (k, v)
        # GQA + head-TP: expand kv to full (padded) heads so the flash
        # grouping reshape never splits a sharded head dim across shards
        G = cfg.padded_heads // cfg.num_kv_heads
        if G > 1 and shd.resolve(("heads",)) != shd.resolve((None,)):
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
            k = shd.lsc(k, "batch", "seq", "heads", "head_dim")
            v = shd.lsc(v, "batch", "seq", "heads", "head_dim")
        else:
            k = shd.lsc(k, "batch", "seq", "kv_heads", "head_dim")
            v = shd.lsc(v, "batch", "seq", "kv_heads", "head_dim")
        mesh = shd.current_mesh()
        if L.use_context_parallel(mesh, q.shape[1]):
            # FSDP-mode heads: shard q-sequence instead of replicating
            # the whole attention across the model axis (16x dedup)
            o = L.context_parallel_attention(q, k, v, mesh, causal=True)
            o = shd.lsc(o, "batch", "seq_sp", "heads", "head_dim")
        else:
            o = L.flash_attention_jnp(q, k, v, causal=True,
                                      q_block=min(512, q.shape[1]),
                                      kv_block=min(1024, k.shape[1]))
            o = shd.lsc(o, "batch", "seq", "heads", "head_dim")
        mask = L.head_mask(cfg, o.dtype)
        if mask is not None:
            o = o * mask[None, None, :, None]
        out = jnp.einsum("...hk,hkd->...d", o, wo)
        return out, kv

    def _layer(self, lp, x, pos):
        cfg = self.cfg
        h, kv = self._attention(
            lp["attn"], L.rmsnorm(x, lp["ln1"], cfg.norm_eps), pos)
        # Megatron-SP: constrain each block's row-parallel output to the
        # sequence-sharded layout BEFORE the residual add — GSPMD then
        # emits reduce-scatter (1x payload) instead of all-reduce (2x)
        h = shd.lsc(h, "batch", "seq_sp", "embed")
        x = x + h
        hn = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            hn = shd.lsc(hn, "batch", "seq", "embed")
            h2, aux = moe_mod.moe_apply(lp["moe"], hn, cfg)
        else:
            h2 = shd.lsc(L.mlp_apply(lp["mlp"], hn),
                         "batch", "seq_sp", "embed")
            aux = 0.0
        x = shd.lsc(x + h2, "batch", "seq_sp", "embed")
        return x, kv, aux

    def _embed_inputs(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"])
        if cfg.family == "vlm":
            mp = params["mm_proj"]
            img = batch["images"].astype(x.dtype)
            img = jnp.tanh(img @ mp["w1"] + mp["b1"]) @ mp["w2"] + mp["b2"]
            x = jnp.concatenate([img, x], axis=1)
        x = shd.lsc(x, "batch", "seq_sp", "embed")
        pos = jnp.arange(x.shape[1])
        return x, pos

    def forward(self, params, batch):
        cfg = self.cfg
        x, pos = self._embed_inputs(params, batch)

        def body(x, lp):
            y, _, aux = self._layer(lp, x, pos)
            return y, aux

        x, auxs = jax.lax.scan(_remat(body, cfg.remat), x, params["layers"])
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.sum(auxs) if cfg.moe is not None else 0.0

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            logits = L.unembed(x, params["embed"], tied=True)
        else:
            logits = L.unembed(x, params["head"], tied=False)
        return shd.lsc(logits, "batch", "seq", "vocab")

    def loss(self, params, batch):
        cfg = self.cfg
        x, aux = self.forward(params, batch)
        labels, mask = batch["labels"], batch.get("loss_mask")
        if cfg.family == "vlm":  # loss only over text positions
            x = x[:, -labels.shape[1]:]

        # vocab-chunked CE over seq to bound fp32 logits memory
        S = x.shape[1]
        chunk = min(1024, S)
        nc = S // chunk if S % chunk == 0 else 1
        if nc > 1:
            xs = x.reshape(x.shape[0], nc, chunk, x.shape[-1]).swapaxes(0, 1)
            ls = labels.reshape(labels.shape[0], nc, chunk).swapaxes(0, 1)

            def ce_chunk(_, xl):
                xc, lc = xl
                return None, cross_entropy(
                    self._logits(params, xc), lc, cfg.vocab_size)

            # remat per chunk: fp32 logits otherwise stack across chunks
            _, ces = jax.lax.scan(jax.checkpoint(ce_chunk), None, (xs, ls))
            ce = ces.swapaxes(0, 1).reshape(labels.shape)
        else:
            ce = cross_entropy(self._logits(params, x), labels, cfg.vocab_size)
        if mask is not None:
            ce = ce * mask
            denom = jnp.maximum(mask.sum(), 1.0)
        else:
            denom = ce.size
        total = ce.sum() / denom
        if cfg.moe is not None:
            total = total + cfg.moe.router_aux_loss * aux
        return total

    # ----------------------------------------------------------- serving
    def prefill(self, params, batch, cache_len: Optional[int] = None):
        """Full-sequence forward; returns (last_logits, cache).

        cache_len pads the emitted KV cache beyond the prompt so decode
        steps have room (defaults to prompt length, the dry-run shape).
        """
        cfg = self.cfg
        x, pos = self._embed_inputs(params, batch)

        def body(x, lp):
            y, (k, v), _ = self._layer(lp, x, pos)
            return y, (k.astype(jnp.dtype(cfg.dtype)),
                       v.astype(jnp.dtype(cfg.dtype)))

        x, (ks, vs) = jax.lax.scan(_remat(body, "none"), x, params["layers"])
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1:, :])
        ks = pad_cache(ks, cache_len)
        vs = pad_cache(vs, cache_len)
        ks = shd.lsc(ks, "layers", "batch", "kv_seq", "cache_heads", "head_dim")
        vs = shd.lsc(vs, "layers", "batch", "kv_seq", "cache_heads", "head_dim")
        cache = {"k": ks, "v": vs,
                 "pos": jnp.full((), x.shape[1] - 1, jnp.int32)}
        return logits, cache

    def _decode_attention(self, lp, x, pos, kc, vc):
        """x: (B,1,d); kc/vc: (B,T,kv,D) (seq-sharded under a mesh)."""
        cfg = self.cfg
        q, k, v = L._project_qkv(dict(lp), x, cfg, pos[None])
        q, k, v = q[:, 0], k[:, 0], v[:, 0]          # (B,H,D)/(B,kv,D)
        mesh = shd.current_mesh()
        if mesh is not None and "model" in mesh.shape and mesh.shape["model"] > 1:
            o, kc, vc = L.sharded_decode_attention(
                q, kc, vc, k, v, pos, mesh)
        else:
            o, kc, vc = L.decode_attention_unsharded(q, kc, vc, k, v, pos)
        mask = L.head_mask(cfg, o.dtype)
        if mask is not None:
            o = o * mask[None, :, None]
        out = jnp.einsum("bhk,hkd->bd", o, lp["wo"])[:, None, :]
        return out, kc, vc

    def decode_step(self, params, cache, batch):
        """One token for the whole batch. batch: {"tokens": (B,1)}.

        The stacked cache rides the scan CARRY with per-layer
        dynamic-slice/update — one live buffer (aliased via donation),
        not the xs->ys double copy."""
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"])
        x = shd.lsc(x, "batch", "seq", "embed")
        pos = cache["pos"] + 1

        def body(carry, lp):
            x, ks, vs, i = carry
            kc = jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False)
            h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            h, kc, vc = self._decode_attention(lp["attn"], h, pos, kc, vc)
            ks = jax.lax.dynamic_update_index_in_dim(ks, kc, i, 0)
            vs = jax.lax.dynamic_update_index_in_dim(vs, vc, i, 0)
            x = x + h
            hn = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
            if cfg.moe is not None:
                h2, _ = moe_mod.moe_apply(lp["moe"], hn, cfg)
            else:
                h2 = L.mlp_apply(lp["mlp"], hn)
            x = shd.lsc(x + h2, "batch", "seq", "embed")
            return (x, ks, vs, i + 1), None

        (x, ks, vs, _), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"], jnp.zeros((), jnp.int32)),
            params["layers"])
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits, {"k": ks, "v": vs, "pos": pos}

    # ------------------------------------------------------------- specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
        if shape.kind == "train":
            n_img = cfg.vlm.num_patches if cfg.family == "vlm" else 0
            spec = {"tokens": tok((B, S - n_img)), "labels": tok((B, S - n_img))}
            if n_img:
                spec["images"] = jax.ShapeDtypeStruct(
                    (B, n_img, cfg.d_model), jnp.dtype(cfg.dtype))
            return spec
        if shape.kind == "prefill":
            n_img = cfg.vlm.num_patches if cfg.family == "vlm" else 0
            spec = {"tokens": tok((B, S - n_img))}
            if n_img:
                spec["images"] = jax.ShapeDtypeStruct(
                    (B, n_img, cfg.d_model), jnp.dtype(cfg.dtype))
            return spec
        return {"tokens": tok((B, 1))}

    def input_logical(self, shape: ShapeConfig) -> Dict[str, Tuple]:
        out = {"tokens": ("batch", None)}
        if shape.kind == "train":
            out["labels"] = ("batch", None)
        if self.cfg.family == "vlm" and shape.kind in ("train", "prefill"):
            out["images"] = ("batch", None, None)
        return out

    def cache_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B, T = shape.global_batch, shape.seq_len
        kv, D = cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        s = jax.ShapeDtypeStruct((cfg.num_layers, B, T, kv, D), dt)
        return {"k": s, "v": s, "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_logical(self, shape: ShapeConfig):
        kvspec = ("layers", "batch", "kv_seq", "cache_heads", "head_dim")
        return {"k": kvspec, "v": kvspec, "pos": ()}

    def init_cache(self, shape: ShapeConfig):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_specs(shape))
