"""Expert-parallel MoE FFN.

DisaggRec mapping: experts are the "memory nodes" — large parameter pools
touched sparsely per token. Expert weights shard over the ``model`` mesh
axis (EP); activations stay replicated across that axis, each shard
computes only its local experts' contribution for every token, and the
combine is a single psum — the near-memory-reduction / Fsum pattern
(expert outputs are reduced *at the expert shard* before crossing the
network; only (T, d) crosses, never (T, k, d) per-expert outputs).

Routing uses capacity-bounded greedy dispatch: position-in-expert via
one-hot cumsum, drop beyond capacity — the software analogue of the
paper's MemAccess routing table balancing accesses across MNs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models.params import Spec


def moe_table(cfg) -> dict:
    m = cfg.moe
    E = m.padded_experts
    d = cfg.d_model
    t = {
        "router": Spec((d, E), ("embed", None), "normal:0.02"),
        "wi_gate": Spec((E, d, m.d_ff_expert), ("experts", "embed", "expert_ffn")),
        "wi_up": Spec((E, d, m.d_ff_expert), ("experts", "embed", "expert_ffn")),
        "wo": Spec((E, m.d_ff_expert, d), ("experts", "expert_ffn", "embed")),
    }
    if m.num_shared_experts:
        t["shared"] = {
            "wi_gate": Spec((d, m.d_ff_shared), ("embed", "ffn")),
            "wi_up": Spec((d, m.d_ff_shared), ("embed", "ffn")),
            "wo": Spec((m.d_ff_shared, d), ("ffn", "embed")),
            "gate": Spec((d, 1), ("embed", None), "zeros"),
        }
    return t


def _route(x2d, router, cfg):
    """Router logits -> (weights, ids, aux_loss). Padding experts masked."""
    m = cfg.moe
    E, Ep = m.num_experts, m.padded_experts
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router.astype(jnp.float32))
    if Ep > E:
        logits = jnp.where(jnp.arange(Ep) < E, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style) over real experts
    density = jnp.mean(jax.nn.one_hot(ids, Ep), axis=(0, 1))[:E]
    mean_prob = jnp.mean(probs[:, :E], axis=0)
    aux = E * jnp.sum(density * mean_prob)
    return w.astype(x2d.dtype), ids, aux


def _expert_compute(xbuf, wg, wu, wo):
    """xbuf: (E_loc, C, d) -> (E_loc, C, d) through SwiGLU experts."""
    g = jnp.einsum("ecd,edf->ecf", xbuf, wg)
    u = jnp.einsum("ecd,edf->ecf", xbuf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xbuf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _moe_local(x2d, w, ids, wg, wu, wo, *, e_off, E_loc, capacity, cfg,
               axis: Optional[str]):
    """Dispatch local tokens to local experts, compute, combine, psum."""
    T, d = x2d.shape
    k = cfg.moe.top_k
    Ep = cfg.moe.padded_experts
    C = capacity

    fid = ids.reshape(T * k)
    fw = w.reshape(T * k)
    tok = jnp.arange(T * k, dtype=jnp.int32) // k
    # position of each (token, expert) pair within its expert's queue
    onehot = jax.nn.one_hot(fid, Ep, dtype=jnp.float32)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1).astype(jnp.int32) - 1
    keep = pos < C
    local = (fid >= e_off) & (fid < e_off + E_loc) & keep
    slot = (fid - e_off) * C + jnp.clip(pos, 0, C - 1)
    slot = jnp.where(local, slot, E_loc * C)           # dump row

    # scatter SCALAR token ids into slots, then gather rows once — the
    # payload never materializes at (T*k, d)
    tok_of = jnp.full((E_loc * C + 1,), T, jnp.int32).at[slot].set(tok)
    w_of = jnp.zeros((E_loc * C + 1,), fw.dtype).at[slot].set(fw)
    xpad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    xbuf = jnp.take(xpad, tok_of[: E_loc * C], axis=0)
    out = _expert_compute(xbuf.reshape(E_loc, C, d), wg, wu, wo)
    out = out.reshape(E_loc * C, d)

    y = jnp.zeros((T + 1, d), x2d.dtype).at[tok_of[: E_loc * C]].add(
        out * w_of[: E_loc * C, None])[:T]
    if axis is not None:
        y = jax.lax.psum(y, axis)                      # Fsum combine
    return y


def moe_apply(p, x, cfg, *, capacity_factor: Optional[float] = None):
    """MoE FFN. x: (B, S, d) (or (B, 1, d) decode). Returns (y, aux)."""
    B, S, d = x.shape
    m = cfg.moe
    if capacity_factor is None:
        capacity_factor = m.capacity_factor
    x2d = x.reshape(B * S, d)
    w, ids, aux = _route(x2d, p["router"], cfg)

    mesh = shd.current_mesh()
    ep = shd.axis_size("model") if mesh is not None else 1
    # only use EP when the experts rule actually maps to the mesh
    use_ep = (
        mesh is not None and ep > 1
        and shd.resolve(("experts",)) == P("model")
        and m.padded_experts % ep == 0
    )
    T_tok = B * S
    if use_ep:
        from repro.models.layers import batch_pspec_entry
        E_loc = m.padded_experts // ep
        bspec = batch_pspec_entry(T_tok, mesh)
        baxes = () if bspec is None else (
            (bspec,) if isinstance(bspec, str) else tuple(bspec))
        nshards = 1
        for a in baxes:
            nshards *= mesh.shape[a]
        t_loc = T_tok // nshards
        cap = max(8, int((t_loc * m.top_k / m.num_experts) * capacity_factor))
        cap = -(-cap // 8) * 8

        def f(x2d, w, ids, wg, wu, wo):
            e_off = jax.lax.axis_index("model") * E_loc
            return _moe_local(x2d, w, ids, wg, wu, wo, e_off=e_off,
                              E_loc=E_loc, capacity=cap, cfg=cfg, axis="model")

        y = shard_map(
            f, mesh=mesh,
            in_specs=(P(bspec, None), P(bspec, None), P(bspec, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=P(bspec, None),
            check_rep=False,
        )(x2d, w, ids, p["wi_gate"], p["wi_up"], p["wo"])
    else:
        cap = max(8, int((T_tok * m.top_k / m.num_experts) * capacity_factor))
        y = _moe_local(x2d, w, ids, p["wi_gate"], p["wi_up"], p["wo"],
                       e_off=0, E_loc=m.padded_experts, capacity=cap,
                       cfg=cfg, axis=None)

    if m.num_shared_experts:
        s = p["shared"]
        g = jnp.einsum("td,df->tf", x2d, s["wi_gate"])
        u = jnp.einsum("td,df->tf", x2d, s["wi_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x2d.dtype) * u
        sh = jnp.einsum("tf,fd->td", h, s["wo"])
        gate = jax.nn.sigmoid(
            jnp.einsum("td,dz->tz", x2d.astype(jnp.float32),
                       s["gate"].astype(jnp.float32)))
        y = y + sh * gate.astype(y.dtype)

    return y.reshape(B, S, d), aux
