"""Mamba2 (SSD) block + Zamba2 hybrid stack.

Zamba2 structure: groups of 6 Mamba2 layers, one *shared* attention+MLP
block applied after each group (weights reused across all 13 applications,
as in the paper's shared-block design), plus a tail of leftover Mamba2
layers (81 = 13*6 + 3).

Sharding: d_inner (x/z projections, conv, heads) shards over ``model``
(112 heads / 16 = 7 local heads, head_dim 64 stays MXU-aligned); B/C/dt
are small and replicated; out_proj is row-parallel (one psum). SSD uses
the chunked algorithm — O(S·Q) memory, scalar-per-head decay.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import params as pm
from repro.models import transformer as tfm
from repro.models.params import Spec


# --------------------------------------------------------------- tables


def mamba2_table(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    return {
        "norm": L.norm_table(d),
        "in_x": Spec((d, di), ("embed", "ffn")),
        "in_z": Spec((d, di), ("embed", "ffn")),
        "in_bc": Spec((d, 2 * s.d_state), ("embed", None)),
        "in_dt": Spec((d, nh), ("embed", "mamba_heads")),
        "conv_x": Spec((s.conv_width, di), ("conv", "ffn"), "normal:0.5"),
        "conv_bc": Spec((s.conv_width, 2 * s.d_state), ("conv", None), "normal:0.5"),
        "A_log": Spec((nh,), ("mamba_heads",), "zeros"),
        "D": Spec((nh,), ("mamba_heads",), "ones"),
        "dt_bias": Spec((nh,), ("mamba_heads",), "zeros"),
        "gnorm": Spec((di,), ("ffn",), "zeros"),
        "out": Spec((di, d), ("ffn", "embed")),
    }


def _causal_conv(u, w, state=None):
    """Depthwise causal conv. u: (B,S,C), w: (W,C). Returns (y, new_state)
    where state carries the last W-1 inputs for decode."""
    W = w.shape[0]
    if state is None:
        pads = [jnp.zeros_like(u[:, :1]).repeat(W - 1, axis=1)]
        ext = jnp.concatenate(pads + [u], axis=1)
    else:
        ext = jnp.concatenate([state, u], axis=1)
    y = sum(ext[:, i:i + u.shape[1]] * w[i] for i in range(W))
    return y, ext[:, -(W - 1):]


def _segsum(a):
    """a: (..., Q). Returns (..., Q, Q) lower-tri pairwise sums
    cum[t]-cum[s] for s<=t (exclusive of a[s], inclusive of a[t])."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """SSD (Mamba2) chunked scan.

    x: (B,S,H,P); dt: (B,S,H); A: (H,) negative; Bm/Cm: (B,S,N).
    Returns (y: (B,S,H,P), h_final: (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = L.pick_block(S, chunk)
    nc = S // Q

    xr = x.reshape(Bsz, nc, Q, H, P)
    dtr = dt.reshape(Bsz, nc, Q, H)
    Br = Bm.reshape(Bsz, nc, Q, N)
    Cr = Cm.reshape(Bsz, nc, Q, N)
    a = dtr * A                                    # (B,nc,Q,H) negative
    xdt = xr * dtr[..., None]

    cum = jnp.cumsum(a, axis=2)                    # (B,nc,Q,H)
    # intra-chunk
    Lm = jnp.exp(_segsum(a.transpose(0, 1, 3, 2)))         # (B,nc,H,Q,Q)
    att = jnp.einsum("bcqn,bcsn,bchqs->bchqs", Cr, Br, Lm)
    y = jnp.einsum("bchqs,bcshp->bcqhp", att, xdt)
    # chunk -> state
    decay_st = jnp.exp(cum[:, :, -1:, :] - cum)            # (B,nc,Q,H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Br, decay_st, xdt)
    # inter-chunk scan
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)

    def step(h, sd):
        s_c, dec = sd                              # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + s_c
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_fin, h_prevs = jax.lax.scan(
        step, h0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)             # (B,nc,H,P,N)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cr,
                       h_prevs.astype(Cr.dtype), jnp.exp(cum))
    y = (y + y_off).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), h_fin


def mamba2_apply(p, x, cfg, *, ssm_state=None, conv_state=None):
    """Full-sequence (train/prefill) or single-step (decode) Mamba2.

    Decode when x has S==1 and states are provided.
    """
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    B, S, _ = x.shape

    h = L.rmsnorm(x, p["norm"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, p["in_x"])
    z = jnp.einsum("bsd,de->bse", h, p["in_z"])
    bc = jnp.einsum("bsd,de->bse", h, p["in_bc"])
    dt_raw = jnp.einsum("bsd,dh->bsh", h, p["in_dt"])

    xz, conv_state_x = _causal_conv(
        xz, p["conv_x"], None if conv_state is None else conv_state["x"])
    bc, conv_state_bc = _causal_conv(
        bc, p["conv_bc"], None if conv_state is None else conv_state["bc"])
    xz = jax.nn.silu(xz.astype(jnp.float32)).astype(x.dtype)
    bc = jax.nn.silu(bc.astype(jnp.float32)).astype(x.dtype)
    Bm, Cm = bc[..., :s.d_state], bc[..., s.d_state:]

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    xh = xz.reshape(B, S, nh, s.head_dim)

    if S == 1 and ssm_state is not None:
        # recurrent decode step
        a = jnp.exp(dt[:, 0] * A)                          # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, 0],
                         dt[:, 0], xh[:, 0].astype(jnp.float32))
        h_new = ssm_state * a[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], h_new.astype(Cm.dtype))
        y = y[:, None].reshape(B, 1, nh, s.head_dim)
        h_fin = h_new
    else:
        y, h_fin = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk, h0=ssm_state)

    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                  p["gnorm"], cfg.norm_eps)
    y = shd.lsc(y, "batch", "seq", "ffn")
    out = jnp.einsum("bse,ed->bsd", y, p["out"])
    new_conv = {"x": conv_state_x, "bc": conv_state_bc}
    res = shd.lsc(x + out, "batch", "seq_sp", "embed")
    return res, h_fin, new_conv


# --------------------------------------------------------------- zamba2


class Zamba2Model:
    """Hybrid: 13 groups of (6 mamba + shared attn/mlp block) + 3 mamba."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.vp = tfm.padded_vocab(cfg.vocab_size)
        k = cfg.ssm.attn_every
        self.n_groups = cfg.num_layers // k if k else 0
        self.group = k
        self.tail = cfg.num_layers - self.n_groups * k
        self._lm = tfm.DecoderLM(cfg)   # reuse attention/mlp/loss pieces

    # params -----------------------------------------------------------
    def _attn_block_table(self):
        cfg = self.cfg
        return {
            "ln1": L.norm_table(cfg.d_model),
            "attn": L.attn_table(cfg),
            "ln2": L.norm_table(cfg.d_model),
            "mlp": L.mlp_table(cfg.d_model, cfg.d_ff),
        }

    def _top_table(self):
        return {
            "embed": L.embed_table(self.vp, self.cfg.d_model),
            "final_norm": L.norm_table(self.cfg.d_model),
            "head": L.head_table(self.vp, self.cfg.d_model),
        }

    def init(self, seed: int = 0):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        params = pm.init_table(ks[0], self._top_table(), dt)
        mt = mamba2_table(cfg)
        grp = pm.init_stacked(ks[1], mt, self.n_groups * self.group, dt)
        params["groups"] = jax.tree.map(
            lambda a: a.reshape((self.n_groups, self.group) + a.shape[1:]), grp)
        params["tail"] = pm.init_stacked(ks[2], mt, self.tail, dt)
        params["shared_attn"] = pm.init_table(ks[3], self._attn_block_table(), dt)
        return params

    def param_specs(self):
        mt = mamba2_table(self.cfg)
        specs = pm.table_specs(self._top_table())
        specs["groups"] = pm.table_specs(mt, prefix=("layers", "layers"))
        specs["tail"] = pm.table_specs(mt, prefix=("layers",))
        specs["shared_attn"] = pm.table_specs(self._attn_block_table())
        return specs

    def param_shapes(self, dtype=None):
        dt = dtype or jnp.dtype(self.cfg.param_dtype)
        mt = mamba2_table(self.cfg)
        shapes = pm.eval_shape_tree(self._top_table(), dtype=dt)
        g = pm.eval_shape_tree(mt, stack=self.group, dtype=dt)
        shapes["groups"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.n_groups,) + s.shape, dt), g)
        shapes["tail"] = pm.eval_shape_tree(mt, stack=self.tail, dtype=dt)
        shapes["shared_attn"] = pm.eval_shape_tree(
            self._attn_block_table(), dtype=dt)
        return shapes

    def param_count(self):
        n = pm.table_size(self._top_table())
        n += pm.table_size(mamba2_table(self.cfg)) * self.cfg.num_layers
        n += pm.table_size(self._attn_block_table())
        return n

    # forward ----------------------------------------------------------
    def _attn_block(self, ap, x, pos):
        cfg = self.cfg
        h, kv = self._lm._attention(
            ap["attn"], L.rmsnorm(x, ap["ln1"], cfg.norm_eps), pos)
        x = x + h
        x = x + L.mlp_apply(ap["mlp"], L.rmsnorm(x, ap["ln2"], cfg.norm_eps))
        return shd.lsc(x, "batch", "seq_sp", "embed"), kv

    def forward(self, params, batch):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"])
        x = shd.lsc(x, "batch", "seq_sp", "embed")
        pos = jnp.arange(x.shape[1])

        def mamba_scan(x, stacked):
            def body(x, lp):
                y, _, _ = mamba2_apply(lp, x, cfg)
                return y, None
            y, _ = jax.lax.scan(tfm._remat(body, cfg.remat), x, stacked)
            return y

        def group_body(x, gp):
            x = mamba_scan(x, gp)
            x, _ = self._attn_block(params["shared_attn"], x, pos)
            return x, None

        x, _ = jax.lax.scan(tfm._remat(group_body, cfg.remat),
                            x, params["groups"])
        x = mamba_scan(x, params["tail"])
        return L.rmsnorm(x, params["final_norm"], cfg.norm_eps), 0.0

    def loss(self, params, batch):
        x, _ = self.forward(params, batch)
        logits_fn = lambda xc: shd.lsc(
            L.unembed(xc, params["head"], tied=False), "batch", "seq", "vocab")
        ce = tfm.cross_entropy(logits_fn(x), batch["labels"], self.cfg.vocab_size)
        return ce.mean()

    # serving ----------------------------------------------------------
    def prefill(self, params, batch, cache_len=None):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"])
        pos = jnp.arange(x.shape[1])
        S = x.shape[1]

        def mamba_scan(x, stacked):
            def body(x, lp):
                y, h_fin, conv = mamba2_apply(lp, x, cfg)
                return y, (h_fin, conv)
            return jax.lax.scan(body, x, stacked)

        def group_body(x, gp):
            x, st = mamba_scan(x, gp)
            x, (k, v) = self._attn_block(params["shared_attn"], x, pos)
            return x, (st, (k.astype(jnp.dtype(cfg.dtype)),
                            v.astype(jnp.dtype(cfg.dtype))))

        x, (g_states, (ks, vs)) = jax.lax.scan(group_body, x, params["groups"])
        x, t_states = mamba_scan(x, params["tail"])
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(x[:, -1:], params["head"], tied=False)
        ks = tfm.pad_cache(ks, cache_len)
        vs = tfm.pad_cache(vs, cache_len)
        cache = {
            "attn_k": shd.lsc(ks, "layers", "batch", "kv_seq", "cache_heads", "head_dim"),
            "attn_v": shd.lsc(vs, "layers", "batch", "kv_seq", "cache_heads", "head_dim"),
            "group_ssm": g_states[0], "group_conv": g_states[1],
            "tail_ssm": t_states[0], "tail_conv": t_states[1],
            "pos": jnp.full((), S - 1, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"])
        pos = cache["pos"] + 1

        def mamba_step_scan(x, stacked, ssm, conv):
            def body(x, lc):
                lp, h0, cv = lc
                y, h_fin, cv2 = mamba2_apply(lp, x, cfg, ssm_state=h0,
                                             conv_state=cv)
                return y, (h_fin, cv2)
            return jax.lax.scan(body, x, (stacked, ssm, conv))

        def group_body(carry, gkv):
            x, ks, vs, i = carry
            gp, ssm, conv = gkv
            kc = jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False)
            x, st = mamba_step_scan(x, gp, ssm, conv)
            ap = params["shared_attn"]
            h = L.rmsnorm(x, ap["ln1"], cfg.norm_eps)
            h, kc, vc = self._lm._decode_attention(ap["attn"], h, pos, kc, vc)
            ks = jax.lax.dynamic_update_index_in_dim(ks, kc, i, 0)
            vs = jax.lax.dynamic_update_index_in_dim(vs, vc, i, 0)
            x = x + h
            x = x + L.mlp_apply(ap["mlp"], L.rmsnorm(x, ap["ln2"], cfg.norm_eps))
            return (x, ks, vs, i + 1), st

        (x, ks, vs, _), g_st = jax.lax.scan(
            group_body,
            (x, cache["attn_k"], cache["attn_v"], jnp.zeros((), jnp.int32)),
            (params["groups"], cache["group_ssm"], cache["group_conv"]))
        x, t_st = mamba_step_scan(x, params["tail"], cache["tail_ssm"],
                                  cache["tail_conv"])
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(x, params["head"], tied=False)
        new_cache = {
            "attn_k": ks, "attn_v": vs,
            "group_ssm": g_st[0], "group_conv": g_st[1],
            "tail_ssm": t_st[0], "tail_conv": t_st[1],
            "pos": pos,
        }
        return logits, new_cache

    # specs -------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
        if shape.kind == "train":
            return {"tokens": tok((B, S)), "labels": tok((B, S))}
        if shape.kind == "prefill":
            return {"tokens": tok((B, S))}
        return {"tokens": tok((B, 1))}

    def input_logical(self, shape: ShapeConfig):
        out = {"tokens": ("batch", None)}
        if shape.kind == "train":
            out["labels"] = ("batch", None)
        return out

    def cache_specs(self, shape: ShapeConfig):
        cfg, s = self.cfg, self.cfg.ssm
        B, T = shape.global_batch, shape.seq_len
        di = s.expand * cfg.d_model
        nh = di // s.head_dim
        kv, D = cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        f32 = jnp.float32
        ssm = lambda lead: jax.ShapeDtypeStruct(
            lead + (B, nh, s.head_dim, s.d_state), f32)
        conv_x = lambda lead: jax.ShapeDtypeStruct(
            lead + (B, s.conv_width - 1, di), dt)
        conv_bc = lambda lead: jax.ShapeDtypeStruct(
            lead + (B, s.conv_width - 1, 2 * s.d_state), dt)
        g = (self.n_groups, self.group)
        t = (self.tail,)
        return {
            "attn_k": jax.ShapeDtypeStruct((self.n_groups, B, T, kv, D), dt),
            "attn_v": jax.ShapeDtypeStruct((self.n_groups, B, T, kv, D), dt),
            "group_ssm": ssm(g), "group_conv": {"x": conv_x(g), "bc": conv_bc(g)},
            "tail_ssm": ssm(t), "tail_conv": {"x": conv_x(t), "bc": conv_bc(t)},
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def cache_logical(self, shape: ShapeConfig):
        kvspec = ("layers", "batch", "kv_seq", "cache_heads", "head_dim")
        return {
            "attn_k": kvspec, "attn_v": kvspec,
            "group_ssm": ("layers", "layers", "batch", "mamba_heads", None, None),
            "group_conv": {"x": ("layers", "layers", "batch", None, "ffn"),
                           "bc": ("layers", "layers", "batch", None, None)},
            "tail_ssm": ("layers", "batch", "mamba_heads", None, None),
            "tail_conv": {"x": ("layers", "batch", None, "ffn"),
                          "bc": ("layers", "batch", None, None)},
            "pos": (),
        }

    def init_cache(self, shape: ShapeConfig):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_specs(shape))
