"""Model registry + mode-dependent sharding rules.

``build(cfg)`` returns the model object for any config (assigned archs +
RM1/RM2). ``make_rules(cfg, mesh, mode)`` resolves the logical-axis rule
set for a given mesh and program kind:

train/prefill:
  - head-TP (Megatron) when num_heads divides the model axis;
  - FSDP-over-data for attention-ish weights otherwise (qwen2.5 40H,
    whisper 20H, smollm 9H, rwkv6 40H do not divide 16) — stored sharded
    on the contracting dim over ``data``, all-gathered per layer inside
    the scan (GSPMD turns the matching grads into reduce-scatters);
decode:
  - attention weights shard on the contracting/output d_model dims over
    ``model`` (universal divisibility), heads replicated, KV cache
    sequence-sharded over ``model`` with shard-local partial softmax.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DEFAULT_RULES


def build(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import DecoderLM
        return DecoderLM(cfg)
    if cfg.family == "hybrid":
        from repro.models.mamba2 import Zamba2Model
        return Zamba2Model(cfg)
    if cfg.family == "ssm":
        from repro.models.rwkv6 import RWKV6Model
        return RWKV6Model(cfg)
    if cfg.family == "audio":
        from repro.models.whisper import WhisperModel
        return WhisperModel(cfg)
    if cfg.family == "dlrm":
        from repro.models.dlrm import DLRMModel
        return DLRMModel(cfg)
    raise ValueError(cfg.family)


def make_rules(cfg: ModelConfig, mesh, mode: str,
               overrides: Optional[Dict] = None) -> Dict:
    """Logical-axis rules for (arch, mesh, mode). mode: train|prefill|decode."""
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    rules = dict(DEFAULT_RULES)

    heads_div = tp > 1 and cfg.padded_heads % tp == 0
    kv_div = tp > 1 and cfg.num_kv_heads % tp == 0

    if mode == "decode":
        rules.update({
            "attn_din": ("model",), "attn_din_c": ("model",),
            "attn_dout": ("model",), "attn_dout_c": ("model",),
            "heads": None, "kv_heads": None,
            "kv_seq": ("model",), "seq_sp": None,
        })
    elif heads_div:
        rules.update({
            "attn_din": None, "attn_din_c": None,
            "attn_dout": None, "attn_dout_c": None,
            "heads": ("model",),
            "kv_heads": ("model",) if kv_div else None,
            "kv_seq": ("model",), "seq_sp": ("model",),
        })
    else:
        # FSDP: weights live sharded over data, gathered at use
        rules.update({
            "attn_din": ("data",), "attn_din_c": None,
            "attn_dout": None, "attn_dout_c": None,
            "heads": None, "kv_heads": None,
            "kv_seq": ("model",), "seq_sp": ("model",),
        })

    # large MoE: expert FFN dim additionally shards over data at rest
    # (ZeRO-3-style); shard_map's in_specs gather it per layer at use.
    # Decode keeps weights resident (per-token gathers would swamp ICI).
    if cfg.moe is not None and mode != "decode":
        if cfg.param_count() * 2 / 16 > 4e9:   # >4GB/device resident
            rules["expert_ffn"] = ("data",)

    # mamba heads (d_inner/head_dim) shard over model when divisible
    if cfg.ssm is not None:
        di = cfg.ssm.expand * cfg.d_model
        nh = di // cfg.ssm.head_dim
        rules["mamba_heads"] = ("model",) if (tp > 1 and nh % tp == 0) else None

    # DLRM: TB-scale tables shard 2D (tables x rows)
    if cfg.family == "dlrm":
        rules["table_rows"] = ("data",)

    if overrides:
        rules.update(overrides)
    return rules


def mode_for_shape(shape) -> str:
    return {"train": "train", "prefill": "prefill", "decode": "decode"}[shape.kind]
