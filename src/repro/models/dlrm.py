"""DLRM-style recommendation model (the paper's RM1/RM2).

Pipeline (paper Fig. 1a): preprocessing G_P (hashing, done in the data
layer) -> SparseNet G_S (embedding bags: gather + pooling) -> DenseNet G_D
(bottom MLP, pairwise interaction, top MLP).

DisaggRec mapping: the stacked embedding tables shard table-wise over the
``model`` mesh axis (the MN pool; assignment computed by
core/embedding_manager's greedy allocator) and — for TB-scale generations —
row-wise over ``data`` as well, since one pod's HBM per model-group is
smaller than a DRAM memory node. Pooling (the Fsum reduction) happens
*shard-local* before any cross-device traffic: only (B, T, D) pooled
vectors cross the network, never (B, T, P, D) raw rows. That is the
paper's near-memory reduction, realized on TPU as a VMEM-local reduction
(see kernels/embedding_bag for the Pallas version).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import params as pm
from repro.models.params import Spec


def _mlp_tables(dims, prefix_names=("embed", None)):
    t = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        t[f"w{i}"] = Spec((a, b), (None, None))
        t[f"b{i}"] = Spec((b,), (None,), "zeros")
    return t


def _mlp_apply(t, x, n):
    for i in range(n):
        x = x @ t[f"w{i}"] + t[f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def embedding_bag_ref(tables, idx):
    """tables: (T, R, D); idx: (B, T, P) -> pooled (B, T, D).

    Shard-local gather+sum; -1 indices are padding (masked out).
    """
    valid = (idx >= 0)[..., None]
    safe = jnp.maximum(idx, 0)

    def per_table(table, ix):              # (R, D), (B, P)
        return jnp.take(table, ix, axis=0)  # (B, P, D)

    rows = jax.vmap(per_table, in_axes=(0, 1), out_axes=1)(tables, safe)
    return jnp.where(valid, rows, 0.0).sum(axis=2)


class DLRMModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        r = cfg.dlrm
        self.num_feats = r.interaction_proj + 1
        self.inter = self.num_feats * (self.num_feats - 1) // 2

    def _tables(self):
        r = self.cfg.dlrm
        bot = (r.num_dense_features,) + r.bottom_mlp
        top = (r.bottom_mlp[-1] + self.inter,) + r.top_mlp
        return {
            "embed": Spec((r.num_tables, r.rows_per_table, r.embed_dim),
                          ("table_shard", "table_rows", None), "normal:0.01"),
            "proj": Spec((r.num_tables, r.interaction_proj), (None, None),
                         "normal:0.05"),
            "bottom": _mlp_tables(bot),
            "top": _mlp_tables(top),
        }

    def init(self, seed: int = 0):
        # DLRM tables are served fp32 (as in the paper's production stack)
        return pm.init_table(jax.random.PRNGKey(seed), self._tables(),
                             jnp.float32)

    def param_specs(self):
        return pm.table_specs(self._tables())

    def param_shapes(self, dtype=None):
        return pm.eval_shape_tree(self._tables(), dtype=dtype or jnp.float32)

    def param_count(self):
        return pm.table_size(self._tables())

    # ------------------------------------------------------------ forward
    def pool_embeddings(self, params, idx, use_kernel: bool = False):
        """SparseNet G_S: gather+pool all tables -> (B, T, D).

        `use_kernel=True` runs the fused multi-table Pallas embedding-bag
        (one call for the whole table stack); otherwise the jnp reference.
        This is the shard-local half of the query path — the ClusterEngine
        calls it per MN shard with that shard's table subset.
        """
        if use_kernel:
            from repro.kernels import ops
            return ops.embedding_bag_fused(params["embed"], idx)
        return embedding_bag_ref(params["embed"], idx)

    def dense_forward(self, params, dense, pooled):
        """DenseNet G_D on already-pooled embeddings (the CN-side half:
        what runs after the Fsum gather returns from the MN pool)."""
        r = self.cfg.dlrm
        bot = _mlp_apply(params["bottom"], dense, len(r.bottom_mlp))
        pooled = shd.lsc(pooled, "batch", None, None)           # Fsum gather
        pooled = jnp.einsum("btd,tk->bkd", pooled.astype(bot.dtype),
                            params["proj"])
        z = jnp.concatenate([bot[:, None, :], pooled], axis=1)  # (B,K+1,D)
        zz = jnp.einsum("bfd,bgd->bfg", z, z)
        iu = jnp.triu_indices(self.num_feats, k=1)
        inter = zz[:, iu[0], iu[1]]                             # (B, F(F-1)/2)
        x = jnp.concatenate([bot, inter], axis=-1)
        return _mlp_apply(params["top"], x, len(r.top_mlp))[..., 0]

    def forward(self, params, batch, use_kernel: bool = False):
        pooled = self.pool_embeddings(params, batch["indices"],
                                      use_kernel=use_kernel)
        return self.dense_forward(params, batch["dense"], pooled)

    def loss(self, params, batch):
        logit = self.forward(params, batch)
        y = batch["labels"].astype(jnp.float32)
        z = logit.astype(jnp.float32)
        # stable BCE-with-logits
        return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))

    def serve_step(self, params, batch, use_kernel: bool = False):
        return jax.nn.sigmoid(self.forward(params, batch,
                                           use_kernel=use_kernel))

    # -------------------------------------------------------------- specs
    def input_specs(self, shape_or_batch):
        r = self.cfg.dlrm
        if isinstance(shape_or_batch, ShapeConfig):
            B = shape_or_batch.global_batch
            kind = shape_or_batch.kind
        else:
            B, kind = shape_or_batch, "train"
        spec = {
            "dense": jax.ShapeDtypeStruct((B, r.num_dense_features),
                                          jnp.float32),
            "indices": jax.ShapeDtypeStruct(
                (B, r.num_tables, r.avg_pooling), jnp.int32),
        }
        if kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        return spec

    def input_logical(self, shape=None):
        return {"dense": ("batch", None), "indices": ("batch", None, None),
                "labels": ("batch",)}
