"""Whisper-large-v3 backbone: encoder-decoder transformer.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, 1500, d_model). The decoder uses
RoPE instead of the original 448-entry learned position table so the
assignment's 32k decode shape is expressible (noted in DESIGN.md).
Cross-attention KV is computed once at prefill and cached.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import params as pm
from repro.models import transformer as tfm


def _enc_layer_table(cfg):
    return {
        "ln1": L.norm_table(cfg.d_model),
        "attn": L.attn_table(cfg),
        "ln2": L.norm_table(cfg.d_model),
        "mlp": L.mlp_table(cfg.d_model, cfg.d_ff),
    }


def _dec_layer_table(cfg):
    return {
        "ln1": L.norm_table(cfg.d_model),
        "self_attn": L.attn_table(cfg),
        "ln_x": L.norm_table(cfg.d_model),
        "cross_attn": L.attn_table(cfg),
        "ln2": L.norm_table(cfg.d_model),
        "mlp": L.mlp_table(cfg.d_model, cfg.d_ff),
    }


def _sinusoid(S: int, d: int):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.vp = tfm.padded_vocab(cfg.vocab_size)
        self._lm = tfm.DecoderLM(cfg)

    def _top_table(self):
        return {
            "embed": L.embed_table(self.vp, self.cfg.d_model),
            "enc_norm": L.norm_table(self.cfg.d_model),
            "final_norm": L.norm_table(self.cfg.d_model),
        }

    def init(self, seed: int = 0):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        params = pm.init_table(ks[0], self._top_table(), dt)
        params["enc_layers"] = pm.init_stacked(
            ks[1], _enc_layer_table(cfg), cfg.encdec.num_encoder_layers, dt)
        params["dec_layers"] = pm.init_stacked(
            ks[2], _dec_layer_table(cfg), cfg.num_layers, dt)
        return params

    def param_specs(self):
        specs = pm.table_specs(self._top_table())
        specs["enc_layers"] = pm.table_specs(_enc_layer_table(self.cfg),
                                             prefix=("layers",))
        specs["dec_layers"] = pm.table_specs(_dec_layer_table(self.cfg),
                                             prefix=("layers",))
        return specs

    def param_shapes(self, dtype=None):
        dt = dtype or jnp.dtype(self.cfg.param_dtype)
        shapes = pm.eval_shape_tree(self._top_table(), dtype=dt)
        shapes["enc_layers"] = pm.eval_shape_tree(
            _enc_layer_table(self.cfg),
            stack=self.cfg.encdec.num_encoder_layers, dtype=dt)
        shapes["dec_layers"] = pm.eval_shape_tree(
            _dec_layer_table(self.cfg), stack=self.cfg.num_layers, dtype=dt)
        return shapes

    def param_count(self):
        cfg = self.cfg
        return (pm.table_size(self._top_table())
                + pm.table_size(_enc_layer_table(cfg)) * cfg.encdec.num_encoder_layers
                + pm.table_size(_dec_layer_table(cfg)) * cfg.num_layers)

    # --------------------------------------------------------------- enc
    def encode(self, params, frames):
        cfg = self.cfg
        x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)
        x = shd.lsc(x, "batch", "seq", "embed")

        def body(x, lp):
            h, _ = self._attn(lp["attn"], L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
                              causal=False)
            x = x + h
            x = x + L.mlp_apply(lp["mlp"],
                                L.rmsnorm(x, lp["ln2"], cfg.norm_eps))
            return shd.lsc(x, "batch", "seq_sp", "embed"), None

        x, _ = jax.lax.scan(tfm._remat(body, cfg.remat), x,
                            params["enc_layers"])
        return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    def _attn(self, ap, x, causal, kv_src=None, pos=None):
        """Self or cross attention (kv_src = encoder output for cross)."""
        cfg = self.cfg
        wq = shd.lsc(ap["wq"], "attn_din_c", "heads", "head_dim")
        wk = shd.lsc(ap["wk"], "attn_din_c", "kv_heads", "head_dim")
        wv = shd.lsc(ap["wv"], "attn_din_c", "kv_heads", "head_dim")
        wo = shd.lsc(ap["wo"], "heads", "head_dim", "attn_dout_c")
        src = x if kv_src is None else kv_src
        q = jnp.einsum("...d,dhk->...hk", x, wq)
        k = jnp.einsum("...d,dhk->...hk", src, wk)
        v = jnp.einsum("...d,dhk->...hk", src, wv)
        if pos is not None:
            q = L.rope(q, pos, cfg.rope_theta)
            k = L.rope(k, pos, cfg.rope_theta)
        mesh = shd.current_mesh()
        if L.use_context_parallel(mesh, q.shape[1]):
            o = L.context_parallel_attention(q, k, v, mesh, causal=causal)
            o = shd.lsc(o, "batch", "seq_sp", "heads", "head_dim")
        else:
            o = L.flash_attention_jnp(
                q, k, v, causal=causal,
                q_block=min(512, q.shape[1]), kv_block=min(1024, k.shape[1]))
        out = jnp.einsum("...hk,hkd->...d", o, wo)
        return out, (k, v)

    # --------------------------------------------------------------- dec
    def _dec_layer(self, lp, x, enc, pos):
        cfg = self.cfg
        h, kv = self._attn(lp["self_attn"],
                           L.rmsnorm(x, lp["ln1"], cfg.norm_eps),
                           causal=True, pos=pos)
        x = x + h
        h, cross_kv = self._attn(lp["cross_attn"],
                                 L.rmsnorm(x, lp["ln_x"], cfg.norm_eps),
                                 causal=False, kv_src=enc)
        x = x + h
        x = x + L.mlp_apply(lp["mlp"], L.rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return shd.lsc(x, "batch", "seq_sp", "embed"), kv, cross_kv

    def forward(self, params, batch):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        # gather the seq-sharded encoder output ONCE before the decoder
        # scan — otherwise every decoder layer re-gathers it (32x AG/CP)
        enc = shd.lsc(enc, "batch", "seq", "embed")
        x = L.embed_lookup(params["embed"], batch["tokens"])
        x = shd.lsc(x, "batch", "seq", "embed")
        pos = jnp.arange(x.shape[1])

        def body(x, lp):
            y, _, _ = self._dec_layer(lp, x, enc, pos)
            return y, None

        x, _ = jax.lax.scan(tfm._remat(body, cfg.remat), x,
                            params["dec_layers"])
        return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        x = self.forward(params, batch)
        logits = shd.lsc(L.unembed(x, params["embed"], tied=True),
                         "batch", "seq", "vocab")
        return tfm.cross_entropy(logits, batch["labels"],
                                 self.cfg.vocab_size).mean()

    def prefill(self, params, batch, cache_len=None):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        enc = shd.lsc(enc, "batch", "seq", "embed")
        x = L.embed_lookup(params["embed"], batch["tokens"])
        S = x.shape[1]
        pos = jnp.arange(S)

        def body(x, lp):
            y, (k, v), (ck, cv) = self._dec_layer(lp, x, enc, pos)
            dt = jnp.dtype(cfg.dtype)
            return y, (k.astype(dt), v.astype(dt), ck.astype(dt), cv.astype(dt))

        x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"])
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(x[:, -1:], params["embed"], tied=True)
        ks = tfm.pad_cache(ks, cache_len)
        vs = tfm.pad_cache(vs, cache_len)
        cache = {
            "k": shd.lsc(ks, "layers", "batch", "kv_seq", "cache_heads", "head_dim"),
            "v": shd.lsc(vs, "layers", "batch", "kv_seq", "cache_heads", "head_dim"),
            "cross_k": cks, "cross_v": cvs,
            "pos": jnp.full((), S - 1, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"])
        pos = cache["pos"] + 1

        def body(carry, lp_cross):
            x, ks, vs, i = carry
            lp, ck, cv = lp_cross
            kc = jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False)
            h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            h, kc, vc = self._lm._decode_attention(lp["self_attn"], h, pos,
                                                   kc, vc)
            ks = jax.lax.dynamic_update_index_in_dim(ks, kc, i, 0)
            vs = jax.lax.dynamic_update_index_in_dim(vs, vc, i, 0)
            x = x + h
            # cross attention: static encoder kv (B, 1500, kv, D)
            h = L.rmsnorm(x, lp["ln_x"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])[:, 0]
            o, l, m = L.decode_attention_local(q, ck, cv, ck.shape[1])
            o = L.combine_partials(o, l, m, None)
            h = jnp.einsum("bhk,hkd->bd", o, lp["cross_attn"]["wo"])[:, None]
            x = x + h
            x = x + L.mlp_apply(lp["mlp"],
                                L.rmsnorm(x, lp["ln2"], cfg.norm_eps))
            return (x, ks, vs, i + 1), None

        (x, ks, vs, _), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"], jnp.zeros((), jnp.int32)),
            (params["dec_layers"], cache["cross_k"], cache["cross_v"]))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(x, params["embed"], tied=True)
        return logits, dict(cache, k=ks, v=vs, pos=pos)

    # ------------------------------------------------------------- specs
    def input_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        E = cfg.encdec.encoder_seq
        tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
        frames = jax.ShapeDtypeStruct((B, E, cfg.d_model), jnp.dtype(cfg.dtype))
        if shape.kind == "train":
            return {"frames": frames, "tokens": tok((B, S)),
                    "labels": tok((B, S))}
        if shape.kind == "prefill":
            return {"frames": frames, "tokens": tok((B, S))}
        return {"tokens": tok((B, 1))}

    def input_logical(self, shape: ShapeConfig):
        out = {"tokens": ("batch", None)}
        if shape.kind in ("train", "prefill"):
            out["frames"] = ("batch", None, None)
        if shape.kind == "train":
            out["labels"] = ("batch", None)
        return out

    def cache_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B, T = shape.global_batch, shape.seq_len
        kv, D = cfg.num_kv_heads, cfg.resolved_head_dim
        E = cfg.encdec.encoder_seq
        dt = jnp.dtype(cfg.dtype)
        s = jax.ShapeDtypeStruct((cfg.num_layers, B, T, kv, D), dt)
        c = jax.ShapeDtypeStruct((cfg.num_layers, B, E, kv, D), dt)
        return {"k": s, "v": s, "cross_k": c, "cross_v": c,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def cache_logical(self, shape: ShapeConfig):
        kvspec = ("layers", "batch", "kv_seq", "cache_heads", "head_dim")
        cspec = ("layers", "batch", None, "kv_heads", "head_dim")
        return {"k": kvspec, "v": kvspec, "cross_k": cspec,
                "cross_v": cspec, "pos": ()}

    def init_cache(self, shape: ShapeConfig):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_specs(shape))
