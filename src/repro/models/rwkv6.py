"""RWKV6 ("Finch") — attention-free LM with data-dependent decay.

Recurrence (per head, K=V=head_dim):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (diag(u) k_t v_t^T + S_{t-1})

Train/prefill uses an outer chunk scan (remat per chunk bounds residual
memory) with an inner sequential scan; decode carries (S, prev-x) state —
O(1) per token, which is why this arch runs the long_500k shape.

DisaggRec applicability (DESIGN.md §Arch-applicability): the recurrent
core has no gather/Fsum structure; the paper's technique applies to this
arch only via embedding/LM-head sharding and the serving/allocation layer.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import params as pm
from repro.models import transformer as tfm
from repro.models.params import Spec

_LORA = 32


def rwkv6_table(cfg: ModelConfig) -> dict:
    d, dff = cfg.d_model, cfg.d_ff
    return {
        "ln1": L.norm_table(d),
        "ln2": L.norm_table(d),
        "tm": {  # time mix
            "x_maa": Spec((d,), ("embed",), "zeros"),
            "maa": Spec((5, d), (None, "embed"), "zeros"),
            "maa_w1": Spec((d, 5 * _LORA), ("embed", None), "normal:0.02"),
            "maa_w2": Spec((5, _LORA, d), (None, None, "embed"), "normal:0.02"),
            "decay": Spec((d,), ("embed",), "const:-6.0"),
            "decay_w1": Spec((d, _LORA), ("embed", None), "normal:0.02"),
            "decay_w2": Spec((_LORA, d), (None, "embed"), "normal:0.02"),
            "u": Spec((d,), ("embed",), "zeros"),
            "wr": Spec((d, d), ("attn_din", "rwkv_out")),
            "wk": Spec((d, d), ("attn_din", "rwkv_out")),
            "wv": Spec((d, d), ("attn_din", "rwkv_out")),
            "wg": Spec((d, d), ("attn_din", "rwkv_out")),
            "wo": Spec((d, d), ("attn_din", "rwkv_out")),
            "ln_x_w": Spec((d,), ("embed",), "zeros"),
            "ln_x_b": Spec((d,), ("embed",), "zeros"),
        },
        "cm": {  # channel mix
            "k_maa": Spec((d,), ("embed",), "zeros"),
            "r_maa": Spec((d,), ("embed",), "zeros"),
            "wk": Spec((d, dff), ("embed", "ffn")),
            "wv": Spec((dff, d), ("ffn", "embed")),
            "wr": Spec((d, d), ("attn_din", "rwkv_out")),
        },
    }


def _wkv_scan(r, k, v, w, u, state0):
    """Sequential WKV. r,k,v,w: (B,S,H,K); u: (H,K); state: (B,H,K,K).
    Returns (y: (B,S,H,K), final state)."""
    def step(S, rkvw):
        rt, kt, vt, wt = rkvw                       # (B,H,K)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    Sf, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), Sf


def wkv_chunked(r, k, v, w, u, state0, chunk: int, sub: int = 16):
    """Chunked WKV: outer remat'd scan over chunks; within a chunk a
    second remat level over sub-chunks bounds AD state-stacking to
    O(sub + chunk/sub) per-step states instead of O(chunk)."""
    B, S, H, K = r.shape
    Q = L.pick_block(S, chunk)
    nc = S // Q
    Qs = L.pick_block(Q, sub)
    ns = Q // Qs

    def sub_body(state, xs):
        ys, Sf = _wkv_scan(*xs, u, state)
        return Sf, ys

    def body(state, xs):
        xs_sub = tuple(t.reshape(B, ns, Qs, H, K).transpose(1, 0, 2, 3, 4)
                       for t in xs)
        state, ys = jax.lax.scan(jax.checkpoint(sub_body), state, xs_sub)
        return state, ys.transpose(1, 0, 2, 3, 4).reshape(B, Q, H, K)

    xs = tuple(t.reshape(B, nc, Q, H, K).transpose(1, 0, 2, 3, 4)
               for t in (r, k, v, w))
    Sf, ys = jax.lax.scan(jax.checkpoint(body), state0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, K)
    return y, Sf


def _token_shift(x, prev):
    """prev-token mix. x: (B,S,d); prev: (B,d) carry from decode or zeros."""
    if x.shape[1] == 1:
        return prev[:, None, :]
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)
    return shifted


def time_mix(p, x, cfg, prev_x, state0):
    B, S, d = x.shape
    H = cfg.num_heads
    K = cfg.resolved_head_dim
    xx = _token_shift(x, prev_x)
    sx = xx - x
    xxx = x + sx * p["x_maa"]
    m = jnp.tanh(xxx @ p["maa_w1"]).reshape(B, S, 5, _LORA)
    m = jnp.einsum("bsfl,fld->bsfd", m, p["maa_w2"])
    xw, xk, xv, xr, xg = [
        x + sx * (p["maa"][i] + m[:, :, i]) for i in range(5)]

    wr = shd.lsc(p["wr"], "attn_din_c", "rwkv_out_c")
    wk_ = shd.lsc(p["wk"], "attn_din_c", "rwkv_out_c")
    wv_ = shd.lsc(p["wv"], "attn_din_c", "rwkv_out_c")
    wg_ = shd.lsc(p["wg"], "attn_din_c", "rwkv_out_c")
    wo_ = shd.lsc(p["wo"], "attn_din_c", "rwkv_out_c")

    r = (xr @ wr).reshape(B, S, H, K)
    kk = (xk @ wk_).reshape(B, S, H, K)
    vv = (xv @ wv_).reshape(B, S, H, K)
    g = jax.nn.silu((xg @ wg_).astype(jnp.float32)).astype(x.dtype)

    dec = p["decay"] + jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(B, S, H, K)
    u = p["u"].reshape(H, K).astype(jnp.float32)

    y, Sf = wkv_chunked(r.astype(jnp.float32), kk.astype(jnp.float32),
                        vv.astype(jnp.float32), w, u, state0,
                        cfg.ssm.chunk)
    y = y.reshape(B, S, d)
    # per-head group norm
    yh = y.reshape(B, S, H, K)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, d) * (1.0 + p["ln_x_w"]) + p["ln_x_b"]
    out = (y.astype(x.dtype) * g) @ wo_
    return out, x[:, -1], Sf


def channel_mix(p, x, prev_x):
    xx = _token_shift(x, prev_x)
    sx = xx - x
    xk = x + sx * p["k_maa"]
    xr = x + sx * p["r_maa"]
    wr = shd.lsc(p["wr"], "attn_din_c", "rwkv_out_c")
    k = jnp.square(jax.nn.relu((xk @ p["wk"]).astype(jnp.float32)))
    k = shd.lsc(k.astype(x.dtype), "batch", "seq", "ffn")
    v = k @ p["wv"]
    r = jax.nn.sigmoid((xr @ wr).astype(jnp.float32)).astype(x.dtype)
    return r * v, x[:, -1]


class RWKV6Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.vp = tfm.padded_vocab(cfg.vocab_size)

    def _top_table(self):
        return {
            "embed": L.embed_table(self.vp, self.cfg.d_model),
            "final_norm": L.norm_table(self.cfg.d_model),
            "head": L.head_table(self.vp, self.cfg.d_model),
        }

    def init(self, seed: int = 0):
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        params = pm.init_table(k1, self._top_table(), dt)
        params["layers"] = pm.init_stacked(
            k2, rwkv6_table(cfg), cfg.num_layers, dt)
        return params

    def param_specs(self):
        specs = pm.table_specs(self._top_table())
        specs["layers"] = pm.table_specs(rwkv6_table(self.cfg),
                                         prefix=("layers",))
        return specs

    def param_shapes(self, dtype=None):
        dt = dtype or jnp.dtype(self.cfg.param_dtype)
        shapes = pm.eval_shape_tree(self._top_table(), dtype=dt)
        shapes["layers"] = pm.eval_shape_tree(
            rwkv6_table(self.cfg), stack=self.cfg.num_layers, dtype=dt)
        return shapes

    def param_count(self):
        return (pm.table_size(self._top_table())
                + pm.table_size(rwkv6_table(self.cfg)) * self.cfg.num_layers)

    def _layer(self, lp, x, tm_state, tm_prev, cm_prev):
        cfg = self.cfg
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        dt_, tm_prev_new, tm_state_new = time_mix(
            lp["tm"], h, cfg, tm_prev, tm_state)
        x = x + dt_
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        dc, cm_prev_new = channel_mix(lp["cm"], h, cm_prev)
        x = shd.lsc(x + dc, "batch", "seq_sp", "embed")
        return x, tm_state_new, tm_prev_new, cm_prev_new

    def _zero_states(self, B):
        cfg = self.cfg
        H, K = cfg.num_heads, cfg.resolved_head_dim
        tm_state = jnp.zeros((cfg.num_layers, B, H, K, K), jnp.float32)
        tm_prev = jnp.zeros((cfg.num_layers, B, cfg.d_model),
                            jnp.dtype(cfg.dtype))
        cm_prev = jnp.zeros_like(tm_prev)
        return tm_state, tm_prev, cm_prev

    def forward(self, params, batch, states=None):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"])
        x = shd.lsc(x, "batch", "seq", "embed")
        B = x.shape[0]
        if states is None:
            states = self._zero_states(B)
        tm_state, tm_prev, cm_prev = states

        def body(x, lp_st):
            lp, st, tp, cp = lp_st
            y, st2, tp2, cp2 = self._layer(lp, x, st, tp, cp)
            return y, (st2, tp2, cp2)

        x, new_states = jax.lax.scan(
            tfm._remat(body, cfg.remat), x,
            (params["layers"], tm_state, tm_prev, cm_prev))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, new_states

    def loss(self, params, batch):
        x, _ = self.forward(params, batch)
        logits = shd.lsc(L.unembed(x, params["head"], tied=False),
                         "batch", "seq", "vocab")
        return tfm.cross_entropy(logits, batch["labels"],
                                 self.cfg.vocab_size).mean()

    def prefill(self, params, batch, cache_len=None):
        # recurrent state is O(1): cache_len is irrelevant (accepted for
        # the uniform Model API)
        x, (tm_state, tm_prev, cm_prev) = self.forward(params, batch)
        logits = L.unembed(x[:, -1:], params["head"], tied=False)
        cache = {"tm_state": tm_state, "tm_prev": tm_prev,
                 "cm_prev": cm_prev,
                 "pos": jnp.full((), batch["tokens"].shape[1] - 1, jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache, batch):
        states = (cache["tm_state"], cache["tm_prev"], cache["cm_prev"])
        x, (st, tp, cp) = self.forward(params, batch, states=states)
        logits = L.unembed(x, params["head"], tied=False)
        return logits, {"tm_state": st, "tm_prev": tp, "cm_prev": cp,
                        "pos": cache["pos"] + 1}

    # specs --------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
        if shape.kind == "train":
            return {"tokens": tok((B, S)), "labels": tok((B, S))}
        if shape.kind == "prefill":
            return {"tokens": tok((B, S))}
        return {"tokens": tok((B, 1))}

    def input_logical(self, shape: ShapeConfig):
        out = {"tokens": ("batch", None)}
        if shape.kind == "train":
            out["labels"] = ("batch", None)
        return out

    def cache_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B = shape.global_batch
        H, K = cfg.num_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        return {
            "tm_state": jax.ShapeDtypeStruct(
                (cfg.num_layers, B, H, K, K), jnp.float32),
            "tm_prev": jax.ShapeDtypeStruct(
                (cfg.num_layers, B, cfg.d_model), dt),
            "cm_prev": jax.ShapeDtypeStruct(
                (cfg.num_layers, B, cfg.d_model), dt),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def cache_logical(self, shape: ShapeConfig):
        return {
            "tm_state": ("layers", "batch", None, None, None),
            "tm_prev": ("layers", "batch", "embed"),
            "cm_prev": ("layers", "batch", "embed"),
            "pos": (),
        }

    def init_cache(self, shape: ShapeConfig):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_specs(shape))
