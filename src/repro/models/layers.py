"""Shared transformer building blocks (pure-JAX, sharding-annotated).

Attention modes
---------------
train/prefill:  flash-style chunked causal attention (online softmax over
                KV blocks via lax.scan) — O(S·block) activation memory, so
                the 32k prefill dry-run provably fits HBM without a Pallas
                dependency on the CPU backend. The Pallas kernel
                (`repro.kernels.flash_attention`) is the TPU fast path.
decode:         sequence-sharded KV cache over the ``model`` mesh axis
                ("memory-node pool"): each shard attends over its local
                cache slice and only (max, sum, partial-V) cross the
                network — DisaggRec's near-memory reduction (Fsum) applied
                to LM serving.

Weight sharding is *rule-driven* (see distributed/sharding.py): the same
logical names resolve to head-TP, FSDP-over-data, or decode contracting-dim
sharding depending on the active rule set.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models.params import Spec

# ---------------------------------------------------------------- norms


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def norm_table(d: int) -> Spec:
    return Spec((d,), ("embed",), "zeros")   # scale stored as (1 + s)


# ---------------------------------------------------------------- rope


def rope(x, pos, theta: float):
    """x: (..., S, H, D) or (..., H, D) with pos broadcastable to S."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.arange(0, half, dtype=jnp.float32)
    inv = theta ** (-freqs / half)
    ang = pos[..., None].astype(jnp.float32) * inv          # (..., S, half)
    ang = ang[..., None, :]                                 # broadcast heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- mlp


def mlp_table(d: int, f: int) -> dict:
    return {
        "wi_gate": Spec((d, f), ("embed", "ffn")),
        "wi_up": Spec((d, f), ("embed", "ffn")),
        "wo": Spec((f, d), ("ffn", "embed")),
    }


def mlp_apply(p, x):
    gate = jnp.einsum("...d,df->...f", x, p["wi_gate"])
    up = jnp.einsum("...d,df->...f", x, p["wi_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    h = shd.lsc(h, "batch", "seq", "ffn")
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------- attention


def attn_table(cfg) -> dict:
    hd = cfg.resolved_head_dim
    Hp = cfg.padded_heads
    t = {
        "wq": Spec((cfg.d_model, Hp, hd),
                   ("attn_din", "heads", "head_dim")),
        "wk": Spec((cfg.d_model, cfg.num_kv_heads, hd),
                   ("attn_din", "kv_heads", "head_dim")),
        "wv": Spec((cfg.d_model, cfg.num_kv_heads, hd),
                   ("attn_din", "kv_heads", "head_dim")),
        "wo": Spec((Hp, hd, cfg.d_model),
                   ("heads", "head_dim", "attn_dout")),
    }
    if cfg.attn_bias:
        t["bq"] = Spec((Hp, hd), ("heads", "head_dim"), "zeros")
        t["bk"] = Spec((cfg.num_kv_heads, hd), ("kv_heads", "head_dim"), "zeros")
        t["bv"] = Spec((cfg.num_kv_heads, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        t["q_norm"] = Spec((hd,), ("head_dim",), "zeros")
        t["k_norm"] = Spec((hd,), ("head_dim",), "zeros")
    return t


def head_mask(cfg, dtype):
    """(Hp,) mask zeroing padded heads' output path (and, via the chain
    rule, their weight grads). Padding is laid out WITHIN each kv group —
    group g holds H/kv real heads then pad slots — so the GQA q->kv
    mapping of the real heads is unchanged."""
    Hp, H, kv = cfg.padded_heads, cfg.num_heads, cfg.num_kv_heads
    if Hp == H:
        return None
    gp, g = Hp // kv, H // kv
    return ((jnp.arange(Hp) % gp) < g).astype(dtype)


def _project_qkv(p, x, cfg, pos):
    q = jnp.einsum("...d,dhk->...hk", x, p["wq"])
    k = jnp.einsum("...d,dhk->...hk", x, p["wk"])
    v = jnp.einsum("...d,dhk->...hk", x, p["wv"])
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if pos is not None:  # rope (None for whisper encoder/cross paths)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def pick_block(S: int, target: int) -> int:
    """Largest divisor of S that is <= target (block-size helper)."""
    b = min(S, target)
    while S % b:
        b -= 1
    return b


def flash_attention_jnp(q, k, v, *, causal: bool, q_offset=0,
                        q_block: int = 512, kv_block: int = 1024,
                        kv_len: Optional[jax.Array] = None):
    """Blocked online-softmax attention. q: (B,S,H,D), k/v: (B,T,Hkv,D).

    GQA via head grouping; O(block) memory; optional running-length mask
    (kv_len) for decode-style use. Returns (B,S,H,D).
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = D ** -0.5
    qb = pick_block(S, q_block)
    kb = pick_block(T, kv_block)
    nq, nk = S // qb, T // kb

    qg = q.reshape(B, nq, qb, Hkv, G, D).transpose(1, 0, 3, 4, 2, 5)
    kg = k.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)
    vg = v.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)

    # Block positions come from loop-CARRIED counters, not scan indices:
    # index-derived masks are pure functions of the induction variable and
    # XLA hoists them, materializing per-(i,j) penalty tensors at s's full
    # shape across all steps (GBs at 32k seq / many heads).
    def q_step(iq, qblk):                              # (B,Hkv,G,qb,D)
        q_pos = q_offset + iq * qb + jnp.arange(qb)

        def kv_step(carry, kv_blk):
            m, l, acc, jk = carry
            kblk, vblk = kv_blk                        # (B,Hkv,kb,D)
            kpos = jk * kb + jnp.arange(kb)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            penalty = jnp.zeros((qb, kb), jnp.float32)
            if causal:
                penalty += jnp.where(q_pos[:, None] >= kpos[None, :],
                                     0.0, -1e30)
            if kv_len is not None:
                penalty += jnp.where(kpos[None, :] < kv_len, 0.0, -1e30)
            s = s + penalty
            # clamp: keeps fully-masked blocks nan-free (p and corr -> 0)
            m_new = jnp.maximum(jnp.maximum(m, s.max(-1)), -1e30)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk)
            return (m_new, l_new, acc_new, jk + 1), None

        m0 = jnp.full((B, Hkv, G, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, D), jnp.float32)
        (m, l, acc, _), _ = jax.lax.scan(
            kv_step, (m0, l0, a0, jnp.zeros((), jnp.int32)), (kg, vg))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        return iq + 1, out.astype(q.dtype)

    # checkpoint per q-block: AD otherwise stacks every (q,kv) block's
    # score/prob tensors across both scan levels (GBs at 32k)
    _, outs = jax.lax.scan(jax.checkpoint(q_step),
                           jnp.zeros((), jnp.int32), qg)
    # outs: (nq, B, Hkv, G, qb, D) -> (B, S, H, D)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, D)


def full_attention_ref(q, k, v, *, causal: bool, q_offset=0):
    """Unblocked reference (tests only)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * D ** -0.5
    if causal:
        qp = q_offset + jnp.arange(S)
        kp = jnp.arange(k.shape[1])
        s = jnp.where(qp[:, None] >= kp[None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, S, H, D)


def context_parallel_attention(q, k, v, mesh, *, causal: bool = True,
                               axis: str = "model",
                               q_block: int = 512, kv_block: int = 1024):
    """Context-parallel attention for head-counts that cannot shard over
    the model axis (smollm 9H, whisper 20H): shard the QUERY sequence over
    `axis` — each rank runs flash over its S/n q rows against full KV —
    instead of replicating the whole attention 16x (found by the roofline:
    16x duplicated FLOPs in FSDP mode). KV is replicated (it fits; a KV
    ring is the next step at longer contexts).

    q: (B,S,H,D) logically global; k/v: (B,T,Hkv,D). Returns (B,S,H,D)
    sharded on S over `axis`.
    """
    from jax.experimental.shard_map import shard_map

    n = mesh.shape[axis]
    B, S, H, D = q.shape
    s_loc = S // n
    bspec = batch_pspec_entry(B, mesh)

    def local(q_loc, k, v):
        off = jax.lax.axis_index(axis) * s_loc
        return flash_attention_jnp(
            q_loc, k, v, causal=causal, q_offset=off,
            q_block=min(q_block, s_loc), kv_block=min(kv_block, k.shape[1]))

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, axis, None, None), P(bspec, None, None, None),
                  P(bspec, None, None, None)),
        out_specs=P(bspec, axis, None, None),
        check_rep=False,
    )(q, k, v)


def use_context_parallel(mesh, seq_len: int, axis: str = "model") -> bool:
    """CP applies when heads are NOT sharded (FSDP mode), the mesh has a
    model axis, and the sequence divides it (train/prefill only)."""
    if mesh is None or axis not in mesh.shape or mesh.shape[axis] <= 1:
        return False
    if shd.resolve(("heads",)) != shd.resolve((None,)):
        return False
    return seq_len > 1 and seq_len % mesh.shape[axis] == 0


# ------------------------------------------------- decode (seq-sharded KV)


def batch_pspec_entry(batch: int, mesh):
    """PartitionSpec entry for the batch dim under the active 'batch' rule,
    dropping axes the batch size cannot divide (e.g. global_batch=1)."""
    entry = shd.resolve(("batch",))[0]
    if entry is None:
        return None
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    keep = []
    prod = 1
    for a in axes:
        if batch % (prod * mesh.shape[a]) == 0:
            keep.append(a)
            prod *= mesh.shape[a]
    if not keep:
        return None
    return keep[0] if len(keep) == 1 else tuple(keep)


def decode_attention_local(q, k_cache, v_cache, pos, kv_offset=0):
    """Partial attention over a local cache slice.

    q: (B,H,D); caches: (B,T_loc,Hkv,D); pos: scalar current position
    (global); kv_offset: global position of this slice's first row.
    Returns partial (o, l, m) for cross-shard combination — the Fsum
    pattern: only (B,H,D)+(B,H)+(B,H) leave the shard.
    """
    B, H, D = q.shape
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache,
                   preferred_element_type=jnp.float32) * D ** -0.5
    t = kv_offset + jnp.arange(k_cache.shape[1])
    s = jnp.where((t <= pos)[None, None, None, :], s, -jnp.inf)
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    # rows may be fully masked on non-owner shards -> p=0, l=0 (safe)
    p = jnp.where(jnp.isfinite(m)[..., None], p, 0.0)
    l = p.sum(-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, H, D), l.reshape(B, H // Hkv * Hkv), m.reshape(B, H)


def combine_partials(o, l, m, axis_name: Optional[str]):
    """Combine flash-decode partials across a mesh axis (or locally)."""
    if axis_name is None:
        return (o / jnp.maximum(l, 1e-37)[..., None]).astype(o.dtype)
    m_glob = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis_name)
    o_glob = jax.lax.psum(o * corr[..., None].astype(o.dtype), axis_name)
    return o_glob / jnp.maximum(l_glob, 1e-37)[..., None].astype(o.dtype)


def sharded_decode_attention(q, k_cache, v_cache, k_new, v_new, pos,
                             mesh, axis: str = "model"):
    """Decode attention over a sequence-sharded KV cache.

    The new token's KV is written with a plain dynamic_update_slice on the
    sharded cache (GSPMD masks the write to the owning shard and the
    buffer aliases in place — no cache copy); the attention itself is a
    shard_map with shard-local partial softmax + one psum of (o, l, m) —
    the Fsum pattern.
    """
    from jax.experimental.shard_map import shard_map

    T = k_cache.shape[1]
    n_shards = mesh.shape[axis]
    t_loc = T // n_shards
    bspec = batch_pspec_entry(q.shape[0], mesh)

    from jax.sharding import NamedSharding

    k_cache = jax.lax.dynamic_update_index_in_dim(k_cache, k_new, pos, 1)
    v_cache = jax.lax.dynamic_update_index_in_dim(v_cache, v_new, pos, 1)
    cspec = P(bspec, axis, None, None)
    k_cache = jax.lax.with_sharding_constraint(
        k_cache, NamedSharding(mesh, cspec))
    v_cache = jax.lax.with_sharding_constraint(
        v_cache, NamedSharding(mesh, cspec))

    def local_fn(q, kc, vc, pos):
        pos = pos.reshape(())
        off = jax.lax.axis_index(axis) * t_loc
        o, l, m = decode_attention_local(q, kc, vc, pos, kv_offset=off)
        return (combine_partials(o, l, m, axis),)

    qspec = P(bspec, None, None)
    (out,) = shard_map(
        local_fn, mesh=mesh,
        in_specs=(qspec, cspec, cspec, P()),
        out_specs=(qspec,),
        check_rep=False,
    )(q, k_cache, v_cache, pos)
    return out, k_cache, v_cache


def decode_attention_unsharded(q, k_cache, v_cache, k_new, v_new, pos):
    """Single-host path (tests / no-mesh)."""
    kc = jax.lax.dynamic_update_index_in_dim(k_cache, k_new, pos, 1)
    vc = jax.lax.dynamic_update_index_in_dim(v_cache, v_new, pos, 1)
    o, l, m = decode_attention_local(q, kc, vc, pos)
    return combine_partials(o, l, m, None), kc, vc


# ---------------------------------------------------------------- embed


def embed_table(vocab: int, d: int) -> Spec:
    return Spec((vocab, d), ("vocab", "embed"), "normal:0.02")


def embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table_or_head, tied: bool):
    if tied:
        return jnp.einsum("...d,vd->...v", x, table_or_head)
    return jnp.einsum("...d,dv->...v", x, table_or_head)


def head_table(vocab: int, d: int) -> Spec:
    return Spec((d, vocab), ("embed", "vocab"))
