"""Declarative parameter tables.

A *table* is a nested dict whose leaves are ``Spec(shape, names, init)``.
From one table we derive: initialized arrays (optionally vmapped/stacked
for scan-over-layers), logical sharding specs, and analytic sizes — so the
full-size dry-run never materializes parameters.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    names: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | const:<v> | normal:<scale>

    def __post_init__(self):
        assert len(self.shape) == len(self.names), (self.shape, self.names)


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def _init_leaf(key, spec: Spec, dtype) -> jax.Array:
    kind = spec.init
    if kind == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if kind == "ones":
        return jnp.ones(spec.shape, dtype)
    if kind.startswith("const:"):
        return jnp.full(spec.shape, float(kind.split(":")[1]), dtype)
    if kind.startswith("normal:"):
        scale = float(kind.split(":")[1])
    else:
        fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[-1], 1)
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def init_table(key, table, dtype) -> Dict:
    """Initialize a (nested) table of Specs into arrays."""
    leaves, treedef = jax.tree.flatten(table, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def init_stacked(key, table, num: int, dtype) -> Dict:
    """Initialize `num` copies stacked on axis 0 (for lax.scan layers)."""
    keys = jax.random.split(key, num)
    return jax.vmap(lambda k: init_table(k, table, dtype))(keys)


def table_specs(table, prefix: Tuple[Optional[str], ...] = ()) -> Dict:
    """Logical-name tuples tree matching the table's array tree."""
    return jax.tree.map(lambda s: tuple(prefix) + tuple(s.names), table,
                        is_leaf=_is_spec)


def table_shapes(table, stack: int = 0) -> Dict:
    def f(s: Spec):
        shape = ((stack,) + s.shape) if stack else s.shape
        return shape
    return jax.tree.map(f, table, is_leaf=_is_spec)


def table_size(table, stack: int = 1) -> int:
    n = 0
    for s in jax.tree.leaves(table, is_leaf=_is_spec):
        n += math.prod(s.shape)
    return n * max(stack, 1)


def eval_shape_tree(table, stack: int = 0, dtype=jnp.bfloat16):
    """ShapeDtypeStructs without allocation (dry-run path)."""
    def f(s: Spec):
        shape = ((stack,) + s.shape) if stack else s.shape
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.tree.map(f, table, is_leaf=_is_spec)
