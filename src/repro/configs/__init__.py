"""Config registry: ``--arch <id>`` resolution for every assigned
architecture plus the paper's own RM1/RM2 models."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    MULTI_POD, SHAPES, SINGLE_POD, DLRMConfig, EncDecConfig, MeshConfig,
    ModelConfig, MoEConfig, ShapeConfig, SSMConfig, VLMConfig,
    shape_applicable,
)

# arch id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen3-4b": "qwen3_4b",
    "smollm-135m": "smollm_135m",
    "llama3-8b": "llama3_8b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "qwen2-moe-a2.7b": "qwen2_moe",
    "zamba2-7b": "zamba2_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "whisper-large-v3": "whisper_large_v3",
    "rwkv6-3b": "rwkv6_3b",
    "rm1": "rm1",
    "rm2": "rm2",
}

ASSIGNED_ARCHS: List[str] = [a for a in _ARCH_MODULES if a not in ("rm1", "rm2")]


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).REDUCED


def get_generation(arch: str, v: int) -> ModelConfig:
    """RM1/RM2 evolution generations V0..V5 (paper Fig. 1)."""
    return _module(arch).generation(v)


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)
