"""RM1 — the paper's memory-intensive recommendation model (Fig. 1).

SparseNet is the growth driver: model size 1.4 TB (V0) -> 7.8 TB (V5)
over six generations / three years. Dense compute grows mildly.
Sizes are synthetic-projection endpoints from the paper; intermediate
generations interpolate geometrically (x~1.41/gen).
"""
from repro.configs.base import DLRMConfig, ModelConfig

_EMBED_DIM = 128
_BYTES = 4  # fp32 tables, as served in the paper's production stack

# (num_tables, mean_rows, avg_pooling) per generation V0..V5;
# chosen so tables*rows*dim*4B hits the Fig.1(b) curve 1.4 -> 7.8 TB.
_GENS = [
    (800,  3_417_969, 80),    # V0: 1.40 TB
    (900,  4_305_004, 90),    # V1: ~1.98 TB
    (1000, 5_464_438, 100),   # V2: ~2.80 TB
    (1200, 6_442_020, 110),   # V3: ~3.96 TB
    (1400, 7_812_500, 125),   # V4: ~5.60 TB
    (1600, 9_536_743, 140),   # V5: 7.81 TB
]

_BOTTOM = (512, 256, 128)
_TOP = (1024, 1024, 512, 256, 1)


def generation(v: int) -> ModelConfig:
    tables, rows, pooling = _GENS[v]
    return ModelConfig(
        name=f"rm1.v{v}",
        family="dlrm",
        num_layers=0, num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=0,
        d_model=_EMBED_DIM,
        dlrm=DLRMConfig(
            num_tables=tables, rows_per_table=rows, embed_dim=_EMBED_DIM,
            avg_pooling=pooling, num_dense_features=256,
            bottom_mlp=_BOTTOM, top_mlp=_TOP,
        ),
    )


def size_bytes(v: int) -> int:
    tables, rows, _ = _GENS[v]
    return tables * rows * _EMBED_DIM * _BYTES


CONFIG = generation(0)
GENERATIONS = [generation(v) for v in range(6)]

REDUCED = CONFIG.replace(
    name="rm1-reduced",
    dlrm=DLRMConfig(num_tables=8, rows_per_table=1000, embed_dim=16,
                    avg_pooling=10, num_dense_features=16,
                    bottom_mlp=(32, 16), top_mlp=(64, 32, 1)),
)
