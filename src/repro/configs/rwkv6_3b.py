"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,              # wkv heads = d_model / head_dim
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    ssm=SSMConfig(d_state=64, head_dim=64, chunk=256),
)

REDUCED = CONFIG.replace(
    name="rwkv6-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=224, vocab_size=256, head_dim=16,
    ssm=SSMConfig(d_state=16, head_dim=16, chunk=16),
)
