"""zamba2-7b [hybrid] — Mamba2 stack + shared attention blocks.
[arXiv:2411.15242; unverified]

81 layers of Mamba2; a single shared attention+MLP block is interleaved
every 6 layers (weights shared across uses, as in the paper's "shared
attention" design). ssm_state=64.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_width=4,
                  chunk=256, attn_every=6, shared_attn_params=True),
)

REDUCED = CONFIG.replace(
    name="zamba2-7b-reduced", num_layers=7, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
    ssm=SSMConfig(d_state=16, expand=2, head_dim=32, conv_width=4,
                  chunk=32, attn_every=3, shared_attn_params=True),
)
