"""Analytic parameter counts.

These formulas mirror `repro.models.*` init exactly; tests assert equality
against real pytrees on reduced configs, so the full-size counts used for
roofline MODEL_FLOPS are trustworthy without materializing 14B params.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig


def _attn_params(cfg: ModelConfig, kv_heads: int | None = None) -> int:
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads if kv_heads is None else kv_heads
    n = cfg.d_model * cfg.num_heads * hd          # q
    n += 2 * cfg.d_model * kv * hd                # k, v
    n += cfg.num_heads * hd * cfg.d_model         # o
    if cfg.attn_bias:
        n += (cfg.num_heads + 2 * kv) * hd        # qkv bias (no o bias, qwen2)
    if cfg.qk_norm:
        n += 2 * hd                               # per-head-dim rmsnorm scales
    return n


def _mlp_params(d_model: int, d_ff: int) -> int:
    return 3 * d_model * d_ff                     # gate, up, down


def _moe_params(cfg: ModelConfig) -> int:
    m = cfg.moe
    n = cfg.d_model * m.num_experts               # router
    n += m.num_experts * _mlp_params(cfg.d_model, m.d_ff_expert)
    if m.num_shared_experts:
        n += _mlp_params(cfg.d_model, m.d_ff_shared)
        n += cfg.d_model                          # shared-expert gate
    return n


def _mamba2_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    n = cfg.d_model * (2 * d_inner + 2 * s.d_state + nheads)   # in_proj
    n += s.conv_width * (d_inner + 2 * s.d_state)              # conv1d
    n += 3 * nheads                                            # A_log, D, dt_bias
    n += d_inner                                               # gated norm scale
    n += d_inner * cfg.d_model                                 # out_proj
    n += cfg.d_model                                           # pre-norm
    return n


def _rwkv6_params(cfg: ModelConfig) -> int:
    d, dff = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    lora = 32
    n = 0
    # time-mix block
    n += 6 * d                       # x_maa base + (w,k,v,r,g) lerps
    n += d * (5 * lora) + 5 * lora * d   # maa lora (w1, w2)
    n += d * lora + lora * d + d     # decay lora + decay base
    n += d                           # u ("time_faaaa" bonus)
    n += 4 * d * d                   # r, k, v, g projections
    n += d * d                       # output projection
    n += 2 * d                       # per-head group-norm scale+bias
    # channel-mix block
    n += 2 * d                       # x_maa lerp (k, r)
    n += d * dff + dff * d + d * d   # k, v, receptance
    n += 2 * d                       # two pre-norms
    return n


def _dense_layer_params(cfg: ModelConfig) -> int:
    return _attn_params(cfg) + _mlp_params(cfg.d_model, cfg.d_ff) + 2 * cfg.d_model


def param_count(cfg: ModelConfig) -> int:
    if cfg.family == "dlrm":
        return _dlrm_params(cfg)

    V, d = cfg.vocab_size, cfg.d_model
    n = V * d                                     # embedding
    if not cfg.tie_embeddings:
        n += V * d                                # lm head
    n += d                                        # final norm

    if cfg.family in ("dense", "vlm"):
        n += cfg.num_layers * _dense_layer_params(cfg)
        if cfg.family == "vlm":
            n += 2 * d * d + 2 * d                # mm projector (2-layer MLP)
    elif cfg.family == "moe":
        per = _attn_params(cfg) + _moe_params(cfg) + 2 * d
        n += cfg.num_layers * per
    elif cfg.family == "hybrid":
        n += cfg.num_layers * _mamba2_params(cfg)
        if cfg.ssm.attn_every:
            # one shared attention+MLP block reused at every attn_every layers
            n += _attn_params(cfg) + _mlp_params(d, cfg.d_ff) + 2 * d
    elif cfg.family == "ssm":
        n += cfg.num_layers * _rwkv6_params(cfg)
    elif cfg.family == "audio":
        enc_layer = _attn_params(cfg) + _mlp_params(d, cfg.d_ff) + 2 * d
        dec_layer = 2 * _attn_params(cfg) + _mlp_params(d, cfg.d_ff) + 3 * d
        n += cfg.encdec.num_encoder_layers * enc_layer
        n += cfg.num_layers * dec_layer
        n += d                                    # encoder final norm
    else:
        raise ValueError(cfg.family)
    return n


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k + shared experts only)."""
    if cfg.family != "moe":
        return param_count(cfg)
    m = cfg.moe
    V, d = cfg.vocab_size, cfg.d_model
    n = V * d + (0 if cfg.tie_embeddings else V * d) + d
    per = _attn_params(cfg) + 2 * d
    per += cfg.d_model * m.num_experts            # router always runs
    per += m.top_k * _mlp_params(d, m.d_ff_expert)
    if m.num_shared_experts:
        per += _mlp_params(d, m.d_ff_shared) + d
    n += cfg.num_layers * per
    return n


def dlrm_dense_flops(cfg: ModelConfig) -> int:
    """DenseNet FLOPs per sample (bottom MLP + proj + interaction + top)."""
    r = cfg.dlrm
    f = 0
    dims = (r.num_dense_features,) + r.bottom_mlp
    for a, b in zip(dims[:-1], dims[1:]):
        f += 2 * a * b
    f += 2 * r.num_tables * r.interaction_proj * r.embed_dim
    nf = r.interaction_proj + 1
    f += 2 * nf * nf * r.embed_dim
    inter = nf * (nf - 1) // 2
    dims = (r.bottom_mlp[-1] + inter,) + r.top_mlp
    for a, b in zip(dims[:-1], dims[1:]):
        f += 2 * a * b
    return f


def dlrm_sparse_bytes(cfg: ModelConfig) -> float:
    """SparseNet bytes touched per sample (sum over tables of pooling x row)."""
    r = cfg.dlrm
    return r.num_tables * r.avg_pooling * r.embed_dim * 4


def dlrm_size_bytes(cfg: ModelConfig) -> int:
    r = cfg.dlrm
    return r.num_tables * r.rows_per_table * r.embed_dim * 4


def _dlrm_params(cfg: ModelConfig) -> int:
    r = cfg.dlrm
    n = r.num_tables * r.rows_per_table * r.embed_dim
    n += r.num_tables * r.interaction_proj        # interaction projection
    dims = (r.num_dense_features,) + r.bottom_mlp
    for a, b in zip(dims[:-1], dims[1:]):
        n += a * b + b
    f = r.interaction_proj + 1
    inter = f * (f - 1) // 2
    dims = (r.bottom_mlp[-1] + inter,) + r.top_mlp
    for a, b in zip(dims[:-1], dims[1:]):
        n += a * b + b
    return n
