"""llama3-8b [dense] — GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
)

REDUCED = CONFIG.replace(
    name="llama3-8b-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
)
