"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

60 experts do not divide the 16-way model axis: experts are padded to 64
for expert-parallelism (6.7% padded-expert waste, recorded in the roofline
notes; padding experts are masked out of routing).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,                 # per-expert FFN width
    vocab_size=151936,
    head_dim=128,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        num_experts=60, top_k=4, d_ff_expert=1408,
        num_shared_experts=4, d_ff_shared=5632,   # 4 x 1408 fused shared expert
        ep_pad_to=64,
    ),
)

REDUCED = CONFIG.replace(
    name="qwen2-moe-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=64, vocab_size=256, head_dim=16,
    moe=MoEConfig(num_experts=6, top_k=2, d_ff_expert=64,
                  num_shared_experts=1, d_ff_shared=128, ep_pad_to=8),
)
