"""llava-next-mistral-7b [vlm] — mistral backbone, anyres tiling stub.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (batch, num_patches, d_model); a learned
2-layer MM projector maps them into the LM embedding space.
"""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1_000_000.0,
    vlm=VLMConfig(num_patches=576),
)

REDUCED = CONFIG.replace(
    name="llava-next-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    vlm=VLMConfig(num_patches=16),
)
