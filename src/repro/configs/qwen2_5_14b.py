"""qwen2.5-14b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B family; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    head_dim=128,
    attn_bias=True,
    rope_theta=1_000_000.0,
    # 40 heads don't divide the 16-way model axis: pad to 48 (masked,
    # zero-contribution heads) to get Megatron head-TP; ~20% extra attn
    # compute, recorded in the roofline notes
    pad_heads_to=48,
)

REDUCED = CONFIG.replace(
    name="qwen2.5-14b-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    pad_heads_to=6,   # exercise masked head padding in the smoke tests
)
