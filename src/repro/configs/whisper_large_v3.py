"""whisper-large-v3 [audio] — enc-dec backbone; conv frontend STUB.
[arXiv:2212.04356; unverified]

``input_specs()`` provides precomputed frame embeddings
(batch, 1500, d_model). Decoder uses RoPE in this backbone (the original's
learned 448-position table cannot cover the assignment's 32k decode shape;
noted in DESIGN.md as a changed assumption).
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,             # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    tie_embeddings=True,
    encdec=EncDecConfig(num_encoder_layers=32, encoder_seq=1500),
)

REDUCED = CONFIG.replace(
    name="whisper-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
    encdec=EncDecConfig(num_encoder_layers=2, encoder_seq=24),
)
