"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,                 # per-expert FFN width
    vocab_size=32064,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400),
)

REDUCED = CONFIG.replace(
    name="phi3.5-moe-reduced", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=96, vocab_size=256, head_dim=16,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=96),
)
