"""Config system: model / shape / mesh / run configs.

Every assigned architecture is a `ModelConfig` instance in its own module
(one file per arch).  `reduced()` derives the small smoke-test variant of
the same family.  Shapes are the assignment's four (seq_len, global_batch)
cells; which step each shape lowers (train_step / prefill / decode) is a
property of the shape, not the arch.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    # expert-parallel padding: pad num_experts up to a multiple of the model
    # axis so EP divides evenly (qwen2-moe: 60 -> 64).
    ep_pad_to: Optional[int] = None
    router_aux_loss: float = 0.001
    capacity_factor: float = 1.25

    @property
    def padded_experts(self) -> int:
        return self.ep_pad_to or self.num_experts


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD parameters (zamba2) or RWKV6 parameters."""
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 256          # chunked-scan block length
    # zamba2 hybrid: one (shared) attention block every `attn_every` layers.
    attn_every: int = 0       # 0 = pure SSM stack
    shared_attn_params: bool = True


@dataclass(frozen=True)
class EncDecConfig:
    num_encoder_layers: int = 32
    encoder_seq: int = 1500   # whisper: 30s of audio -> 1500 frames (stub)


@dataclass(frozen=True)
class VLMConfig:
    num_patches: int = 576    # anyres base tile, 24x24 patches (stub embeds)


@dataclass(frozen=True)
class DLRMConfig:
    """Paper's own recommendation models (RM1/RM2, Fig. 1)."""
    num_tables: int = 64
    rows_per_table: int = 1_000_000      # mean; tables drawn heterogeneous
    embed_dim: int = 128
    avg_pooling: int = 80                # profiled average pooling factor
    num_dense_features: int = 256
    bottom_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    # pooled features are projected to this many interaction channels
    # before the pairwise-dot interaction (DLRM-v2/DCN-style compression;
    # keeps DenseNet realistic at hundreds of tables)
    interaction_proj: int = 64
    # generation scaling handled by rm1/rm2 config modules


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | vlm | audio | ssm | dlrm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    attn_bias: bool = False           # qwen2.5: QKV projection bias
    # pad query heads to this count for head-TP divisibility (padded heads
    # are masked out of the output path: zero contribution + zero grads)
    pad_heads_to: Optional[int] = None
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    dlrm: Optional[DLRMConfig] = None
    # lowering strategy
    scan_layers: bool = True          # scan over layers (compile-time sanity)
    remat: str = "full"               # none | dots | full
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def padded_heads(self) -> int:
        return self.pad_heads_to or self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / linear-attn)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytic parameter counts (for roofline MODEL_FLOPS) ----
    def param_count(self) -> int:
        from repro.configs import counting
        return counting.param_count(self)

    def active_param_count(self) -> int:
        from repro.configs import counting
        return counting.active_param_count(self)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "SKIP(full-attention): long_500k needs sub-quadratic attention"
    return True, ""


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))
