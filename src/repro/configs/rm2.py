"""RM2 — the paper's compute-intensive recommendation model (Fig. 1).

DenseNet is the growth driver: FC depth/width scale until FLOPs reach
18.9x V0 at V5 (Fig. 1(c)). SparseNet grows mildly (0.8 -> 1.8 TB).
"""
from repro.configs.base import DLRMConfig, ModelConfig

_EMBED_DIM = 128
_BYTES = 4

# (num_tables, mean_rows, avg_pooling, width_mult) per V0..V5. DenseNet
# is GFLOP-class (the paper's compute-intensive regime); widths scale so
# dense FLOPs/sample hit ~18.9x V0 at V5 (Fig. 1c).
_BASE_BOTTOM = (2048, 2048, 128)
_BASE_TOP = (16384, 16384, 8192, 4096, 1)
_W = [1.0, 1.34, 1.82, 2.45, 3.27, 4.35]   # sqrt of target flops ratios
_GENS = [
    (400, 3_906_250, 40),
    (440, 4_261_363, 44),
    (480, 4_882_812, 48),
    (560, 5_580_357, 52),
    (640, 6_103_515, 56),
    (720, 6_781_684, 60),
]


def _scale(dims, w, last_fixed):
    out = []
    for i, d in enumerate(dims):
        if d == 1 or (last_fixed and i == len(dims) - 1):
            out.append(d)
        else:
            out.append(max(128, int(round(d * w / 128)) * 128))
    return tuple(out)


def generation(v: int) -> ModelConfig:
    tables, rows, pooling = _GENS[v]
    bottom = _scale(_BASE_BOTTOM, _W[v], last_fixed=True)
    top = _scale(_BASE_TOP, _W[v], last_fixed=False)
    return ModelConfig(
        name=f"rm2.v{v}",
        family="dlrm",
        num_layers=0, num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=0,
        d_model=_EMBED_DIM,
        dlrm=DLRMConfig(
            num_tables=tables, rows_per_table=rows, embed_dim=_EMBED_DIM,
            avg_pooling=pooling, num_dense_features=256,
            bottom_mlp=bottom, top_mlp=top,
        ),
    )


def size_bytes(v: int) -> int:
    tables, rows = _GENS[v][0], _GENS[v][1]
    return tables * rows * _EMBED_DIM * _BYTES


def dense_flops(v: int) -> int:
    """FLOPs per sample through bottom MLP + interaction + top MLP."""
    cfg = generation(v).dlrm
    f = 0
    dims = (cfg.num_dense_features,) + cfg.bottom_mlp
    for a, b in zip(dims[:-1], dims[1:]):
        f += 2 * a * b
    nf = cfg.num_tables + 1
    f += 2 * nf * nf * cfg.embed_dim          # pairwise interaction
    inter = nf * (nf - 1) // 2
    dims = (cfg.bottom_mlp[-1] + inter,) + cfg.top_mlp
    for a, b in zip(dims[:-1], dims[1:]):
        f += 2 * a * b
    return f


CONFIG = generation(0)
GENERATIONS = [generation(v) for v in range(6)]

REDUCED = CONFIG.replace(
    name="rm2-reduced",
    dlrm=DLRMConfig(num_tables=8, rows_per_table=1000, embed_dim=16,
                    avg_pooling=10, num_dense_features=16,
                    bottom_mlp=(32, 16), top_mlp=(64, 32, 1)),
)
