"""Logical-axis sharding rules (MaxText-style) mapping model-space axis
names to mesh axes, plus helpers to build NamedShardings for pjit.

The DisaggRec mapping lives here: the ``model`` mesh axis is the "memory
node pool" (embedding tables, experts, KV-cache sequence shards), the
``data``(+``pod``) axes are the "compute node pool" (batch replicas).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> mesh axis (or tuple of mesh axes, or None=replicated).
# Axes absent from the active mesh are dropped at resolution time, so one
# rule set serves both the single-pod and multi-pod meshes.
DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "ffn": ("model",),
    "vocab": ("model",),
    "experts": ("model",),       # expert parallelism (MN pool)
    "expert_ffn": None,
    "table_shard": ("model",),   # DLRM embedding-table shards (MN pool)
    "kv_seq": ("model",),        # sequence-sharded KV cache at decode
    "layers": None,
    "conv": None,
    "ssm_state": None,
    "opt_shard": ("data",),      # ZeRO-1 optimizer-state sharding
    "qlen": None,
    # Megatron-SP: the residual stream between blocks is sequence-sharded
    # over `model`; blocks gather/reduce-scatter at their boundaries
    "seq_sp": None,
    "mamba_heads": None,
    "table_rows": None,
    # rwkv square (d,d) projections: output dim never shards (the input
    # dim carries attn_din's mode-dependent sharding)
    "rwkv_out": None,
    "rwkv_out_c": None,
    # KV-cache head dim: never sharded (kv_seq carries the model axis)
    "cache_heads": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Optional[Tuple[str, ...]]] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict] = None):
    """Activate a mesh + logical rules for lsc()/make_sharding()."""
    old_mesh, old_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    if rules is not None:
        merged = dict(DEFAULT_RULES)
        merged.update(rules)
        _CTX.rules = merged
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = old_mesh, old_rules


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def axis_size(name: str) -> int:
    m = _CTX.mesh
    if m is None or name not in m.shape:
        return 1
    return m.shape[name]


def resolve(names: Sequence[Optional[str]]) -> P:
    """Logical axis names -> PartitionSpec under the active mesh+rules."""
    mesh = _CTX.mesh
    out = []
    for n in names:
        if n is None:
            out.append(None)
            continue
        target = _CTX.rules.get(n)
        if target is None:
            out.append(None)
            continue
        if isinstance(target, str):
            target = (target,)
        present = tuple(a for a in target if mesh is None or a in mesh.shape)
        out.append(present if len(present) > 1 else (present[0] if present else None))
    # PartitionSpec trailing Nones are harmless; keep explicit for clarity
    return P(*out)


def make_sharding(names: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    mesh = _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(names))


def lsc(x, *names):
    """Logical sharding constraint; no-op without an active mesh."""
    if _CTX.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, resolve(names)))


def tree_shardings(spec_tree):
    """Map a pytree of logical-name tuples to NamedShardings (or None)."""
    return jax.tree.map(
        lambda names: make_sharding(names),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def resolve_for_shape(names: Sequence[Optional[str]], shape) -> P:
    """resolve(), but drop mesh axes a dimension cannot divide (e.g.
    global_batch=1 under a 16-way data axis)."""
    mesh = _CTX.mesh
    base = resolve(names)
    if mesh is None:
        return base
    out = []
    for dim, entry in zip(shape, tuple(base) + (None,) * (len(shape) - len(base))):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        keep, prod = [], 1
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
        out.append(keep[0] if len(keep) == 1 else (tuple(keep) or None))
    return P(*out)


def tree_shardings_for_shapes(spec_tree, shape_tree):
    """Shape-aware tree_shardings: divisibility-filtered per leaf."""
    mesh = _CTX.mesh

    def f(names, s):
        if mesh is None:
            return None
        return NamedSharding(mesh, resolve_for_shape(tuple(names), s.shape))

    return jax.tree.map(f, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple))
