"""Elastic scaling: re-shard a running job onto a different mesh.

Node failures shrink the healthy device set; DisaggRec's failure handling
(§IV-A) maps at training/serving time to: checkpoint -> rebuild mesh from
survivors -> restore with the new mesh's shardings -> rebuild routing
(embedding_manager.rebuild_after_failure). On a single host this is
exercised by re-sharding across host-device subsets (tests).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.distributed import sharding as shd


def healthy_mesh(axes: Dict[str, int], failed_fraction: float = 0.0,
                 devices=None) -> Mesh:
    """Build the largest mesh with the requested axis RATIOS from the
    surviving device pool (drops whole data-parallel slices first —
    failures cost DP replicas, never TP shards)."""
    devices = list(devices if devices is not None else jax.devices())
    n_ok = int(len(devices) * (1.0 - failed_fraction))
    model = axes.get("model", 1)
    data = max(1, n_ok // model)
    # shrink data-parallel dim to fit the survivors
    use = data * model
    dev = np.asarray(devices[:use]).reshape(data, model)
    return Mesh(dev, ("data", "model"))


def reshard_tree(tree, spec_tree, mesh, rules=None):
    """device_put every leaf with the new mesh's shardings."""
    with shd.use_mesh(mesh, rules):
        shardings = shd.tree_shardings(spec_tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        tree, shardings)


def elastic_restore(ckpt_dir: str, model, opt_cfg, mesh, rules=None):
    """Restore the latest checkpoint re-sharded onto `mesh`."""
    from repro.train import checkpoint as ckpt
    from repro.train import optimizer as opt_mod

    params_tpl = model.init(0)
    opt_tpl = opt_mod.init_state(opt_cfg, params_tpl)
    out = ckpt.try_restore(ckpt_dir, params_tpl, opt_tpl)
    if out is None:
        return None
    params, opt_state, step = out
    params = reshard_tree(params, model.param_specs(), mesh, rules)
    opt_state = reshard_tree(
        opt_state, opt_mod.state_specs(opt_cfg, model.param_specs()),
        mesh, rules)
    return params, opt_state, step
