"""Frozen-spec hygiene: ``object.__setattr__`` stays in ``__post_init__``.

The declarative layer (``ScenarioSpec``, events, ``UnitSpec``) is built
from frozen dataclasses precisely so a spec in flight cannot drift.  The
single sanctioned escape hatch is ``object.__setattr__`` inside
``__post_init__`` (dataclasses' own idiom for derived fields).  Anywhere
else it silently un-freezes an object that every downstream consumer
assumes immutable.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import Project, register
from repro.analysis.report import Finding

_SCOPE = ("src/",)


@register("frozen-setattr",
          "object.__setattr__ only inside __post_init__",
          scope=_SCOPE)
def check_frozen_setattr(project: Project) -> Iterable[Finding]:
    for mod in project.scoped(_SCOPE):
        # lexical walk tracking the innermost enclosing function name
        def visit(node: ast.AST, fn_name: str):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn_name = node.name
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "__setattr__"
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "object"
                    and fn_name != "__post_init__"):
                yield Finding(
                    mod.rel, node.lineno, "frozen-setattr",
                    "object.__setattr__ outside __post_init__ mutates a "
                    "frozen spec — construct a new instance "
                    "(dataclasses.replace) instead")
            for child in ast.iter_child_nodes(node):
                yield from visit(child, fn_name)

        yield from visit(mod.tree, "<module>")
