"""clocksan: the opt-in runtime sanitizer for the per-resource clocks.

The depth-d pipelined execution model (``serving.pipeline``) stakes its
correctness on invariants no single call site can see whole: bookings on
a :class:`ResourceClock` are FIFO and causal, committed busy time is
conserved (``busy_s`` is exactly the sum of the committed intervals,
aborted prefixes included), and every fired timeline event lands in the
``ClusterStats.events`` audit trail.  clocksan is the race-detector
analogue: with ``REPRO_CLOCKSAN=1`` in the environment,

- :func:`check_book` runs inside every ``ResourceClock.book`` *before*
  the clock mutates — catching time-travel, starts before ready,
  FIFO/overlap violations against the actual interval list (so a
  desynced ``free_at`` cannot mask one), double-commits of an identical
  planned interval, and out-of-band mutation of the clock's accumulators
  between bookings (via a shadow copy of every counter);
- :func:`verify_run` runs post-hoc over every clock a dispatch created
  (live and retired) — re-deriving ``busy_s`` from the interval list in
  the same accumulation order (so the conservation comparison is exact,
  not epsilon), re-checking FIFO/overlap globally, cross-checking the
  per-resource dicts on ``ClusterStats``, and asserting audit-trail
  completeness (initial events + dynamically enqueued == recorded).

The sanitizer is a pure observer: it never mutates a clock and adds no
floating-point operations to the simulated timeline, so enabling it
cannot perturb the depth-1 bitwise-parity claims it exists to guard.
Violations raise :class:`ClockSanError` (an ``AssertionError`` subclass,
so existing "clock discipline is asserted" expectations hold).
"""
from __future__ import annotations

import os
import weakref
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

ENV_VAR = "REPRO_CLOCKSAN"


class ClockSanError(AssertionError):
    """A clock-discipline invariant was violated at runtime."""


def enabled() -> bool:
    """Read the gate dynamically so tests can flip it per-run."""
    return os.environ.get(ENV_VAR, "") == "1"


@dataclass
class _Shadow:
    """Sanitizer-private replica of one clock's accumulators, updated in
    lock-step with every sanitized booking.  Divergence between shadow
    and clock means something mutated the clock outside ``book``."""
    free_at: float
    busy_s: float
    queue_s: float
    bookings: int
    committed: Set[Tuple[float, float, int]] = field(default_factory=set)


_shadows: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def reset() -> None:
    """Drop all shadow state (test isolation)."""
    _shadows.clear()


def check_book(clock, ready_s: float, start_s: float, end_s: float,
               tag: int, aborted: bool) -> None:
    """Validate one booking against the clock's visible state and the
    sanitizer's shadow, *before* the clock mutates.  Raises
    :class:`ClockSanError`; on success, advances the shadow."""
    sh = _shadows.get(clock)
    if sh is None:
        sh = _Shadow(free_at=clock.free_at, busy_s=clock.busy_s,
                     queue_s=clock.queue_s, bookings=clock.bookings)
        _shadows[clock] = sh
    problems: List[str] = []
    if end_s < start_s:
        problems.append(
            f"time-travel: interval [{start_s}, {end_s}) ends before "
            f"it starts")
    if start_s < ready_s:
        problems.append(
            f"causality: start {start_s} precedes ready {ready_s} — "
            f"work began before its inputs existed")
    if start_s < clock.free_at:
        problems.append(
            f"FIFO: start {start_s} precedes free_at {clock.free_at} — "
            f"the resource is still busy")
    if clock.intervals and start_s < clock.intervals[-1].end:
        problems.append(
            f"overlap: start {start_s} lands inside the last committed "
            f"interval (ends {clock.intervals[-1].end}) — free_at has "
            f"desynced from the interval list")
    if not aborted and (start_s, end_s, tag) in sh.committed:
        problems.append(
            f"double-commit: interval [{start_s}, {end_s}) tag={tag} "
            f"was already committed on this clock")
    # the comparisons below are identity checks on values the sanitizer
    # itself stored — exact equality is the point, not an epsilon bug
    if clock.free_at != sh.free_at:
        problems.append(
            f"out-of-band mutation: free_at={clock.free_at} but the "
            f"shadow recorded {sh.free_at} after the last booking")
    if ((clock.busy_s, clock.queue_s, clock.bookings)
            != (sh.busy_s, sh.queue_s, sh.bookings)):
        problems.append(
            f"out-of-band mutation: (busy_s, queue_s, bookings)="
            f"({clock.busy_s}, {clock.queue_s}, {clock.bookings}) vs "
            f"shadow ({sh.busy_s}, {sh.queue_s}, {sh.bookings})")
    if problems:
        raise ClockSanError(
            f"clocksan[{clock.name}]: " + "; ".join(problems))
    if not aborted:
        sh.committed.add((start_s, end_s, tag))
    sh.free_at = end_s
    sh.busy_s = sh.busy_s + (end_s - start_s)
    sh.queue_s = sh.queue_s + (start_s - ready_s)
    sh.bookings += 1


def _fold_resources(clocks) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Recompute the per-resource busy/queue folds in the same clock
    order and accumulation order as ``pipeline.summarize_resources``,
    so the conservation comparison against ``ClusterStats`` is exact."""
    busy: Dict[str, float] = {}
    queue: Dict[str, float] = {}
    for c in clocks:
        busy[c.name] = float(busy.get(c.name, 0.0) + c.busy_s)
        queue[c.name] = float(queue.get(c.name, 0.0) + c.queue_s)
    return busy, queue


def verify_run(clocks, stats=None, audit=None,
               n_audit_expected: Optional[int] = None) -> None:
    """Post-hoc verification over every clock a dispatch created.

    ``clocks`` must be the dispatcher's creation-order registry (live
    and retired) — the same list ``summarize_resources`` folded — so the
    recomputed per-resource sums are bitwise-comparable to the ones on
    ``stats``.  Raises :class:`ClockSanError` listing every violation.
    """
    problems: List[str] = []
    for c in clocks:
        busy = 0.0
        prev_end: Optional[float] = None
        for i, iv in enumerate(c.intervals):
            if iv.end < iv.start:
                problems.append(
                    f"{c.name}: interval #{i} [{iv.start}, {iv.end}) "
                    f"ends before it starts")
            if prev_end is not None and iv.start < prev_end:
                problems.append(
                    f"{c.name}: interval #{i} starts at {iv.start}, "
                    f"inside its predecessor (ends {prev_end}) — "
                    f"FIFO/overlap violation")
            prev_end = iv.end
            busy = busy + (iv.end - iv.start)
        # conservation: busy_s accumulated one (end - start) per booking
        # in commit order; `busy` above re-adds in the identical order,
        # so equality is exact by construction, not by epsilon
        if busy != c.busy_s:  # disagglint: disable=clock-eq -- conservation recomputation in identical fp order; inequality means busy_s was mutated outside book()
            problems.append(
                f"{c.name}: busy_s={c.busy_s} but the committed "
                f"intervals (aborted prefixes included) sum to {busy} — "
                f"busy time is not conserved")
        if c.intervals and c.free_at != c.intervals[-1].end:
            problems.append(
                f"{c.name}: free_at={c.free_at} != last interval end "
                f"{c.intervals[-1].end}")
        sh = _shadows.get(c)
        if sh is not None and (
                (c.busy_s, c.queue_s, c.free_at, c.bookings)
                != (sh.busy_s, sh.queue_s, sh.free_at, sh.bookings)):
            problems.append(
                f"{c.name}: clock diverged from its shadow — "
                f"out-of-band mutation between bookings")
    # a batch's pre stage commits on exactly one CN cpu incarnation: a
    # CN shrink hands the pre off to a survivor, and the superseded
    # booking on the retired clock must be charged as an abort — a
    # second non-aborted commit of the same tag means retired busy time
    # is double-counted (phantom booking).  Scoped to cn_cpu: bus/NIC
    # clocks legitimately re-book a tag (hedges, failure re-issues).
    pre_commit: Dict[int, str] = {}
    for c in clocks:
        if not c.name.startswith("cn_cpu"):
            continue
        for iv in c.intervals:
            if iv.aborted or iv.tag < 0:
                continue
            prev = pre_commit.get(iv.tag)
            if prev is not None:
                problems.append(
                    f"{c.name}: pre stage of batch tag={iv.tag} already "
                    f"committed on {prev} — phantom booking on a "
                    f"retired CN (busy time not conserved)")
            else:
                pre_commit[iv.tag] = c.name
    if stats is not None:
        busy_f, queue_f = _fold_resources(clocks)
        if dict(stats.resource_busy_s) != busy_f:
            problems.append(
                "stats.resource_busy_s does not equal the fold of the "
                "committed intervals over all clocks (live + retired)")
        if dict(stats.resource_queue_s) != queue_f:
            problems.append(
                "stats.resource_queue_s does not equal the fold of the "
                "booked queueing delays over all clocks")
    if n_audit_expected is not None and audit is not None:
        if len(audit) != n_audit_expected:
            problems.append(
                f"audit trail has {len(audit)} records but "
                f"{n_audit_expected} events were fired (initial queue + "
                f"dynamically enqueued) — an event vanished without a "
                f"record")
    if problems:
        raise ClockSanError("clocksan: " + "\n  ".join(problems))
