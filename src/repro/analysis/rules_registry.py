"""Cross-module sync rules: event registry, stats drift, CLI drift.

Three places in this repo form implicit contracts between files that no
single-module check can see:

- ``registry-sync``: every ``ScenarioEvent`` subclass needs its serde
  tag (a ``kind`` ClassVar + membership in ``EVENT_TYPES``) *and* a
  dispatch arm (an ``isinstance`` check) inside ``TimelineDispatcher``.
  A subclass missing any leg round-trips through JSON but silently
  no-ops at dispatch, or vice versa.
- ``stats-drift``: every ``ClusterStats`` field must reach the
  serialization site (passed as a keyword at some ``ClusterStats(...)``
  call) and the docs table (``docs/architecture.md``).  A field that
  exists but is never populated reports a default forever.
- ``cli-sync``: every argparse flag in ``launch/`` must be consumed as
  ``args.<dest>``, and keywords passed to the spec constructors
  (``ScenarioSpec``/``Topology``/``Workload``/``ModelRef``/
  ``ClusterConfig``) must name real fields.

All anchors are located by NAME project-wide, never by path, so fixture
trees with toy look-alikes exercise the rules end to end.  Each check
degrades to silence when its anchors are absent from the lint set
(linting ``tests/`` alone should not fail for lack of ``scenario.py``).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.engine import Module, Project, register
from repro.analysis.report import Finding

SPEC_CLASSES = ("ScenarioSpec", "Topology", "Workload", "ModelRef",
                "ClusterConfig")


def _class_field_names(project: Project, cls: ast.ClassDef,
                       mod: Module, depth: int = 0) -> Set[str]:
    """Annotated field names of a (data)class, walking name-resolvable
    base classes project-wide."""
    fields: Set[str] = set()
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(
                stmt.target, ast.Name):
            fields.add(stmt.target.id)
    if depth < 4:
        for base in cls.bases:
            name = base.id if isinstance(base, ast.Name) else (
                base.attr if isinstance(base, ast.Attribute) else None)
            if not name:
                continue
            for bmod, bcls in project.find_classes(name):
                fields |= _class_field_names(project, bcls, bmod,
                                             depth + 1)
    return fields


def _subclasses_of(project: Project, base_name: str
                   ) -> List[Tuple[Module, ast.ClassDef]]:
    out = []
    for mod in project.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for b in node.bases:
                if (isinstance(b, ast.Name) and b.id == base_name) or (
                        isinstance(b, ast.Attribute)
                        and b.attr == base_name):
                    out.append((mod, node))
    return out


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _isinstance_targets(cls: ast.ClassDef) -> Set[str]:
    """Class names tested via isinstance(...) anywhere in the class
    body — the dispatch arms."""
    targets: Set[str] = set()
    for node in ast.walk(cls):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "isinstance"
                and len(node.args) == 2):
            targets |= _names_in(node.args[1])
    return targets


@register("registry-sync",
          "every ScenarioEvent subclass has a kind tag, an EVENT_TYPES "
          "entry, and a TimelineDispatcher arm")
def check_registry_sync(project: Project) -> Iterable[Finding]:
    if not project.find_classes("ScenarioEvent"):
        return
    subclasses = _subclasses_of(project, "ScenarioEvent")

    registry_names: Optional[Set[str]] = None
    for mod in project.modules:
        for node in ast.walk(mod.tree):
            target = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                target, value = node.targets[0], node.value
            elif isinstance(node, ast.AnnAssign) and node.value:
                target, value = node.target, node.value
            else:
                continue
            if isinstance(target, ast.Name) and target.id == "EVENT_TYPES":
                registry_names = _names_in(value)

    dispatch_names: Optional[Set[str]] = None
    for _, cls in project.find_classes("TimelineDispatcher"):
        dispatch_names = (dispatch_names or set()) | _isinstance_targets(cls)

    for mod, cls in subclasses:
        has_kind = any(
            (isinstance(s, ast.AnnAssign)
             and isinstance(s.target, ast.Name) and s.target.id == "kind")
            or (isinstance(s, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "kind"
                for t in s.targets))
            for s in cls.body)
        if not has_kind:
            yield Finding(
                mod.rel, cls.lineno, "registry-sync",
                f"ScenarioEvent subclass {cls.name} has no 'kind' "
                f"ClassVar — it cannot round-trip through "
                f"to_dict/from_dict")
        if registry_names is not None and cls.name not in registry_names:
            yield Finding(
                mod.rel, cls.lineno, "registry-sync",
                f"{cls.name} is missing from EVENT_TYPES — "
                f"from_dict cannot deserialize it")
        if dispatch_names is not None and cls.name not in dispatch_names:
            yield Finding(
                mod.rel, cls.lineno, "registry-sync",
                f"{cls.name} has no isinstance dispatch arm in "
                f"TimelineDispatcher — firing it would silently no-op")


STATS_CLASSES = ("ClusterStats", "ModelStats")


@register("stats-drift",
          "every ClusterStats/ModelStats field reaches serialization "
          "and the docs table")
def check_stats_drift(project: Project) -> Iterable[Finding]:
    for stats_cls in STATS_CLASSES:
        hits = project.find_classes(stats_cls)
        if not hits:
            continue
        mod, cls = hits[0]
        fields = [s.target.id for s in cls.body
                  if isinstance(s, ast.AnnAssign)
                  and isinstance(s.target, ast.Name)]

        # serialization check: union of keywords over all
        # <StatsClass>(...) call sites (timeline.run populates every
        # field explicitly)
        kw_union: Set[str] = set()
        call_sites = 0
        for m in project.modules:
            for node in ast.walk(m.tree):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)
                        and node.func.id == stats_cls
                        and node.keywords):
                    call_sites += 1
                    kw_union |= {k.arg for k in node.keywords if k.arg}
        if call_sites:
            for f in fields:
                if f not in kw_union:
                    yield Finding(
                        mod.rel, cls.lineno, "stats-drift",
                        f"{stats_cls}.{f} is never passed at any "
                        f"{stats_cls}(...) call site — the field would "
                        f"report its default forever")

        docs = project.root / "docs" / "architecture.md"
        if docs.is_file():
            text = docs.read_text()
            for f in fields:
                if not re.search(rf"\b{re.escape(f)}\b", text):
                    yield Finding(
                        mod.rel, cls.lineno, "stats-drift",
                        f"{stats_cls}.{f} is missing from the "
                        f"docs/architecture.md field table")


def _add_argument_dests(mod: Module) -> List[Tuple[int, str]]:
    dests: List[Tuple[int, str]] = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            continue
        dest = None
        for kw in node.keywords:
            if kw.arg == "dest" and isinstance(kw.value, ast.Constant):
                dest = kw.value.value
        if dest is None:
            opts = [a.value for a in node.args
                    if isinstance(a, ast.Constant)
                    and isinstance(a.value, str)]
            longs = [o for o in opts if o.startswith("--")]
            if longs:
                dest = longs[0].lstrip("-").replace("-", "_")
            elif opts and not opts[0].startswith("-"):
                dest = opts[0]
        if dest and dest != "help":
            dests.append((node.lineno, dest))
    return dests


@register("cli-sync",
          "argparse flags in launch/ are consumed and spec-constructor "
          "keywords name real fields",
          scope=("src/repro/launch/",))
def check_cli_sync(project: Project) -> Iterable[Finding]:
    spec_fields: Dict[str, Set[str]] = {}
    for name in SPEC_CLASSES:
        for cmod, cls in project.find_classes(name):
            spec_fields.setdefault(name, set()).update(
                _class_field_names(project, cls, cmod))

    for mod in project.scoped(("src/repro/launch/",)):
        consumed = {node.attr for node in ast.walk(mod.tree)
                    if isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "args"}
        for lineno, dest in _add_argument_dests(mod):
            if dest not in consumed:
                yield Finding(
                    mod.rel, lineno, "cli-sync",
                    f"argparse flag with dest '{dest}' is never read as "
                    f"args.{dest} — dead flag or typo'd consumer")
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in spec_fields):
                continue
            fields = spec_fields[node.func.id]
            for kw in node.keywords:
                if kw.arg is not None and kw.arg not in fields:
                    yield Finding(
                        mod.rel, node.lineno, "cli-sync",
                        f"{node.func.id}(...) is passed unknown keyword "
                        f"'{kw.arg}' — not a declared field")
