"""``python -m repro.analysis`` — the disagglint CLI entry point."""
import sys

from repro.analysis.engine import main

if __name__ == "__main__":
    sys.exit(main())
