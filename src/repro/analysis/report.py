"""Findings and reporters — shared by disagglint and the scenario lint.

A :class:`Finding` is one rule violation anchored at ``file:line``.  The
two reporters render a uniform result shape:

- :func:`render_text` — one ``file:line: severity: [rule] message`` line
  per finding plus a summary, the human-facing default.
- :func:`render_json` — a byte-stable JSON document (sorted findings,
  sorted keys) suitable for CI artifacts and machine diffing.

``repro.serving.scenario``'s lint CLI reuses these for its
``--format json`` mode instead of growing a private serializer, so a CI
job consuming lint output parses one schema regardless of which linter
produced it.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List

SEVERITIES = ("error", "warning")


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at ``file:line``.

    ``file`` is the path relative to the lint root (posix separators),
    so reports are byte-stable regardless of where the tree is checked
    out.  The field order doubles as the sort order: findings group by
    file, then line, then rule.
    """
    file: str
    line: int
    rule: str
    message: str
    severity: str = "error"

    def to_dict(self) -> Dict[str, Any]:
        return {"file": self.file, "line": self.line, "rule": self.rule,
                "message": self.message, "severity": self.severity}

    def render(self) -> str:
        return (f"{self.file}:{self.line}: {self.severity}: "
                f"[{self.rule}] {self.message}")


@dataclass
class LintResult:
    """The outcome of one lint run: surviving findings plus the
    bookkeeping a CI gate wants (files checked, suppression count)."""
    findings: List[Finding] = field(default_factory=list)
    files_checked: int = 0
    suppressed: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings

    def exit_code(self) -> int:
        return 0 if self.ok else 1


def render_text(result: LintResult, tool: str = "disagglint") -> str:
    lines = [f.render() for f in sorted(result.findings)]
    n = len(result.findings)
    lines.append(
        f"[{tool}] {result.files_checked} file(s) checked: "
        f"{n} finding(s), {result.suppressed} suppressed"
        + (" — clean" if n == 0 else ""))
    return "\n".join(lines)


def render_json(result: LintResult, tool: str = "disagglint") -> str:
    doc = {
        "tool": tool,
        "files_checked": result.files_checked,
        "suppressed": result.suppressed,
        "findings": [f.to_dict() for f in sorted(result.findings)],
        "ok": result.ok,
    }
    return json.dumps(doc, indent=2, sort_keys=True) + "\n"
