"""Pallas kernel hygiene.

The embedding-bag kernels are written to one discipline: control flow
stays on-device (``pl.when``/``lax`` primitives, never Python ``if`` on
a value loaded from a Ref), block shapes are static, and every
``pallas_call`` site plumbs ``interpret=`` so the CPU CI path exists.
This rule checks all three, content-gated on modules that actually
import pallas:

- ``pallas_call(...)`` without an ``interpret=`` keyword;
- Python ``if``/``while`` inside a kernel whose test reads a kernel
  parameter (a Ref) via subscript or ``pl.load`` — data-dependent
  Python branching traces only one side;
- ``BlockSpec`` shape tuples containing non-static elements (calls,
  subscripts) — block shapes must be compile-time constants.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set

from repro.analysis.engine import Module, Project, register
from repro.analysis.report import Finding

STATIC_SHAPE_NODES = (ast.Constant, ast.Name, ast.Attribute, ast.BinOp,
                      ast.UnaryOp)


def _imports_pallas(mod: Module) -> bool:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            if any("pallas" in a.name for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            if node.module and "pallas" in node.module:
                return True
            if any("pallas" in a.name for a in node.names):
                return True
    return False


def _pallas_call_sites(mod: Module) -> List[ast.Call]:
    return [node for node in ast.walk(mod.tree)
            if isinstance(node, ast.Call)
            and ((isinstance(node.func, ast.Attribute)
                  and node.func.attr == "pallas_call")
                 or (isinstance(node.func, ast.Name)
                     and node.func.id == "pallas_call"))]


def _kernel_names(calls: List[ast.Call]) -> Set[str]:
    names = set()
    for c in calls:
        if c.args and isinstance(c.args[0], ast.Name):
            names.add(c.args[0].id)
    return names


def _reads_param(test: ast.AST, params: Set[str]) -> bool:
    """Does this branch test read a kernel parameter (Ref) — via
    ``ref[...]`` subscript or ``pl.load(ref, ...)``?"""
    for node in ast.walk(test):
        if (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Name)
                and node.value.id in params):
            return True
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "load"
                and node.args and isinstance(node.args[0], ast.Name)
                and node.args[0].id in params):
            return True
    return False


@register("pallas-hygiene",
          "pallas_call plumbs interpret=, no Python branching on Ref "
          "loads, static BlockSpec shapes")
def check_pallas(project: Project) -> Iterable[Finding]:
    for mod in project.modules:
        if not _imports_pallas(mod):
            continue
        calls = _pallas_call_sites(mod)
        for c in calls:
            if not any(kw.arg == "interpret" for kw in c.keywords):
                yield Finding(
                    mod.rel, c.lineno, "pallas-hygiene",
                    "pallas_call without interpret= — the CPU CI path "
                    "needs interpret mode plumbed through")
        kernel_names = _kernel_names(calls)
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.FunctionDef)
                    and node.name in kernel_names):
                params = {a.arg for a in node.args.args}
                for sub in ast.walk(node):
                    if (isinstance(sub, (ast.If, ast.While))
                            and _reads_param(sub.test, params)):
                        yield Finding(
                            mod.rel, sub.lineno, "pallas-hygiene",
                            "data-dependent Python branch on a Ref load "
                            "inside a kernel — trace-time control flow "
                            "sees one side only; use pl.when/lax.cond")
            if (isinstance(node, ast.Call)
                    and ((isinstance(node.func, ast.Name)
                          and node.func.id == "BlockSpec")
                         or (isinstance(node.func, ast.Attribute)
                             and node.func.attr == "BlockSpec"))):
                shapes = [a for a in node.args
                          if isinstance(a, ast.Tuple)]
                shapes += [kw.value for kw in node.keywords
                           if kw.arg == "block_shape"
                           and isinstance(kw.value, ast.Tuple)]
                for tup in shapes:
                    for el in tup.elts:
                        if not isinstance(el, STATIC_SHAPE_NODES):
                            yield Finding(
                                mod.rel, el.lineno, "pallas-hygiene",
                                "non-static BlockSpec shape element — "
                                "block shapes must be compile-time "
                                "constants")
