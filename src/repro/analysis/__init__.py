"""``repro.analysis`` — determinism & clock-discipline tooling (disagglint).

Every correctness claim this repo makes — bitwise parity to a baseline,
seeded-only RNG, a serde-complete event registry, FIFO/conservation
discipline in the per-resource virtual clocks — is an *invariant by
convention*.  This package makes them machine-checked:

- **Static half** (``engine`` + ``rules_*``): an AST-based rule engine
  with repo-specific rules — wall-clock bans, seeded-RNG discipline,
  set-iteration ordering hazards, frozen-spec hygiene, the
  ``ScenarioEvent`` registry/dispatcher cross-module sync, ``ClusterStats``
  serialization/docs drift, argparse <-> spec-field sync, Pallas kernel
  hygiene, and exact float comparison on ``*_s`` time values.  Run it
  with ``python -m repro.analysis [paths] [--format json]``; suppress a
  finding with ``# disagglint: disable=<rule> -- <reason>`` (the reason
  is mandatory).

- **Runtime half** (``clocksan``): an opt-in clock sanitizer — the
  race-detector analogue for the depth-d pipelined virtual clock.  With
  ``REPRO_CLOCKSAN=1``, every ``ResourceClock`` booking is checked for
  causality/overlap/double-commit at commit time and the whole run is
  verified post-hoc for FIFO order, busy-time conservation (aborted
  prefixes included), and audit-trail completeness (every fired event
  lands in ``ClusterStats.events``).

The package imports only the standard library at module scope, so the
lint CLI starts without pulling JAX.
"""
from repro.analysis.engine import (LintResult, lint_paths,  # noqa: F401
                                   load_rules, main)
from repro.analysis.report import (Finding, render_json,  # noqa: F401
                                   render_text)
