"""Determinism rules: wall-clock bans, seeded-RNG discipline, set-iteration.

The simulator's headline claims are bitwise: depth-1 pipelining equals
the sequential clock, event timelines equal their event-free baselines.
Anything that injects host entropy — wall-clock reads, process-global
RNG state, hash-randomized set ordering feeding the virtual clock —
breaks those claims non-locally.  Three rules police it:

- ``wallclock``  (src/): no ``time.time``/``perf_counter``/
  ``datetime.now`` & co. — simulated time comes from the virtual
  clocks, never the host.
- ``global-rng`` (src/): no module-level ``random.*`` or
  ``np.random.<fn>`` draws; randomness must flow through an explicitly
  seeded ``RandomState``/``default_rng``/``Random``/``PRNGKey``.
- ``set-iter``   (src/repro/serving/): no bare iteration over sets in
  the serving stack, where iteration order feeds clocks or stats —
  wrap in ``sorted(...)``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set

from repro.analysis.engine import Module, Project, register
from repro.analysis.report import Finding

WALL_TIME_FNS = {"time", "time_ns", "monotonic", "monotonic_ns",
                 "perf_counter", "perf_counter_ns", "process_time"}
WALL_DATETIME_FNS = {"now", "utcnow", "today"}
# Seeded-generator constructors: allowed entry points into numpy
# randomness, provided they are handed an explicit seed.
NP_RANDOM_CTORS = {"RandomState", "default_rng", "Generator",
                   "SeedSequence", "PCG64", "Philox", "MT19937",
                   "BitGenerator"}


def _import_map(tree: ast.Module) -> Dict[str, str]:
    """Map local names to the dotted module they are bound to, for the
    imports this rule set cares about."""
    bound: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                bound[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                bound[a.asname or a.name] = f"{node.module}.{a.name}"
    return bound


def _dotted(node: ast.AST) -> List[str]:
    """``np.random.rand`` -> ["np", "random", "rand"]; [] if not a pure
    name/attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def _resolve(chain: List[str], imports: Dict[str, str]) -> str:
    """Rewrite the chain head through the import map and return the
    dotted path: ["np", "random", "rand"] -> "numpy.random.rand"."""
    if not chain:
        return ""
    head = imports.get(chain[0], chain[0])
    return ".".join([head] + chain[1:])


@register("wallclock",
          "no host wall-clock reads — simulated time only",
          scope=("src/", "examples/"))
def check_wallclock(project: Project) -> Iterable[Finding]:
    for mod in project.scoped(("src/", "examples/")):
        imports = _import_map(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for a in node.names:
                    if a.name in WALL_TIME_FNS:
                        yield Finding(
                            mod.rel, node.lineno, "wallclock",
                            f"import of time.{a.name}: wall-clock reads "
                            f"are banned in src/ — simulated time comes "
                            f"from the virtual clocks")
            if not isinstance(node, ast.Call):
                continue
            path = _resolve(_dotted(node.func), imports)
            if path.startswith("time.") and path.split(".")[1] in WALL_TIME_FNS:
                yield Finding(
                    mod.rel, node.lineno, "wallclock",
                    f"call to {path}: wall-clock reads are banned in "
                    f"src/ — simulated time comes from the virtual "
                    f"clocks")
            elif (path.startswith("datetime.")
                  and path.split(".")[-1] in WALL_DATETIME_FNS):
                yield Finding(
                    mod.rel, node.lineno, "wallclock",
                    f"call to {path}: wall-clock reads are banned in "
                    f"src/ — pass timestamps in explicitly")


@register("global-rng",
          "no process-global RNG draws — use a seeded generator",
          scope=("src/", "examples/"))
def check_global_rng(project: Project) -> Iterable[Finding]:
    for mod in project.scoped(("src/", "examples/")):
        imports = _import_map(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ImportFrom):
                if node.module == "random":
                    for a in node.names:
                        if a.name != "Random":
                            yield Finding(
                                mod.rel, node.lineno, "global-rng",
                                f"import of random.{a.name}: draws from "
                                f"the process-global RNG — construct a "
                                f"seeded random.Random(seed) instead")
                elif node.module == "numpy.random":
                    for a in node.names:
                        if a.name not in NP_RANDOM_CTORS:
                            yield Finding(
                                mod.rel, node.lineno, "global-rng",
                                f"import of numpy.random.{a.name}: "
                                f"draws from the global numpy RNG — "
                                f"use a seeded RandomState/default_rng")
            if not isinstance(node, ast.Call):
                continue
            path = _resolve(_dotted(node.func), imports)
            parts = path.split(".")
            if parts[0] == "random" and len(parts) == 2:
                if parts[1] != "Random":
                    yield Finding(
                        mod.rel, node.lineno, "global-rng",
                        f"call to {path}: draws from the process-global "
                        f"RNG — construct a seeded random.Random(seed)")
                elif not node.args and not node.keywords:
                    yield Finding(
                        mod.rel, node.lineno, "global-rng",
                        "random.Random() without a seed is "
                        "entropy-seeded — pass an explicit seed")
            elif (len(parts) >= 3 and parts[0] == "numpy"
                  and parts[1] == "random"):
                fn = parts[2]
                if fn not in NP_RANDOM_CTORS:
                    yield Finding(
                        mod.rel, node.lineno, "global-rng",
                        f"call to {path}: draws from the global numpy "
                        f"RNG — route through a seeded "
                        f"RandomState/default_rng")
                elif (fn in ("RandomState", "default_rng")
                      and not node.args and not node.keywords):
                    yield Finding(
                        mod.rel, node.lineno, "global-rng",
                        f"{path}() without a seed is entropy-seeded — "
                        f"pass an explicit seed")


def _set_names(tree: ast.Module) -> Set[str]:
    """Names assigned a set literal / set() call / Set annotation — the
    cheap local type inference behind set-iter."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and _is_set_expr(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Name):
            ann = node.annotation
            base = ann.value if isinstance(ann, ast.Subscript) else ann
            if (isinstance(base, ast.Name)
                    and base.id in ("Set", "set", "FrozenSet",
                                    "frozenset")):
                names.add(node.target.id)
    return names


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


@register("set-iter",
          "no bare set iteration where order feeds clocks/stats — "
          "wrap in sorted()",
          scope=("src/repro/serving/",))
def check_set_iter(project: Project) -> Iterable[Finding]:
    for mod in project.scoped(("src/repro/serving/",)):
        set_names = _set_names(mod.tree)
        iters = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.For):
                iters.append((node.lineno, node.iter))
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.DictComp, ast.GeneratorExp)):
                for gen in node.generators:
                    iters.append((node.lineno, gen.iter))
        for lineno, it in iters:
            offending = None
            if _is_set_expr(it):
                offending = "a set expression"
            elif isinstance(it, ast.Name) and it.id in set_names:
                offending = f"set-typed name '{it.id}'"
            if offending:
                yield Finding(
                    mod.rel, lineno, "set-iter",
                    f"iteration over {offending}: set order is "
                    f"hash-randomized and feeds the clock/stats path — "
                    f"iterate over sorted(...) instead")
