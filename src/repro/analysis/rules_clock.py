"""Clock discipline: no bare float ``==`` on ``*_s`` time values.

Simulated timestamps and durations are floats named with an ``_s``
suffix by repo convention.  Comparing them with ``==``/``!=`` outside an
``assert`` is almost always a latent epsilon bug — two causally-equal
times can differ in the last ulp once they flow through different
accumulation orders.  ``assert`` statements are exempt because the
repo's bitwise-parity claims are *intentionally* exact (depth-1 clock
parity, event-free baselines); an exact comparison inside an assert is
a declared invariant, not an accident.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.engine import Project, register
from repro.analysis.report import Finding

_SCOPE = ("src/repro/",)


def _time_named(node: ast.AST) -> str:
    if isinstance(node, ast.Name) and node.id.endswith("_s"):
        return node.id
    if isinstance(node, ast.Attribute) and node.attr.endswith("_s"):
        return node.attr
    return ""


@register("clock-eq",
          "no bare float ==/!= on *_s time values outside assert",
          scope=_SCOPE)
def check_clock_eq(project: Project) -> Iterable[Finding]:
    for mod in project.scoped(_SCOPE):
        in_assert = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assert):
                for sub in ast.walk(node):
                    in_assert.add(id(sub))
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Compare) or id(node) in in_assert:
                continue
            if not any(isinstance(op, (ast.Eq, ast.NotEq))
                       for op in node.ops):
                continue
            sides = [node.left] + list(node.comparators)
            named = next((n for n in map(_time_named, sides) if n), "")
            if named:
                yield Finding(
                    mod.rel, node.lineno, "clock-eq",
                    f"exact ==/!= on time value '{named}': float "
                    f"equality on *_s values is epsilon-unsafe outside "
                    f"a declared-parity assert — compare with a "
                    f"tolerance or restructure")
