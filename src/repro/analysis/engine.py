"""disagglint rule engine: project model, registry, suppressions, CLI.

The engine parses every ``.py`` file under the given paths into a
:class:`Project` (one AST + source per :class:`Module`), runs every
registered :class:`Rule` over it, and filters the findings through
line-level suppressions.

**Rules** are project-scoped: each rule sees the whole :class:`Project`
and yields :class:`~repro.analysis.report.Finding` objects, which lets
cross-module rules (event-registry sync, stats drift, CLI sync) relate
declarations in one file to their consumers in another.  Rules declare a
``scope`` of root-relative path prefixes; a module outside every prefix
is invisible to that rule, which is how e.g. the wall-clock ban applies
to ``src/`` but not to ``benchmarks/`` (whose whole point is wall-clock
timing).  Fixture tests exploit the same mechanism by laying out tiny
trees that mirror the scoped structure (``<tmp>/src/repro/serving/…``).

**Suppressions** are per-line comments with a mandatory reason::

    risky_line()   # disagglint: disable=rule-id -- why this is safe

Multiple rules separate with commas.  A suppression without a reason is
itself a finding (``bad-suppression``) — the policy is that every
exception to an invariant carries its justification in the diff.
Comments are extracted with :mod:`tokenize`, so the directive inside a
string literal (docs, fixtures) is inert.

CLI::

    python -m repro.analysis [paths...] [--format text|json] [--root DIR]

Exit status is 0 iff no unsuppressed finding survived — the CI gate.
"""
from __future__ import annotations

import argparse
import ast
import io
import re
import sys
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.report import (Finding, LintResult, render_json,
                                   render_text)

SUPPRESS_RE = re.compile(
    r"#\s*disagglint:\s*disable=(?P<rules>[\w\-, ]+?)"
    r"(?:\s*--\s*(?P<reason>.*\S))?\s*$")


@dataclass
class Suppression:
    line: int
    rules: Tuple[str, ...]
    reason: Optional[str]


@dataclass
class Module:
    """One parsed source file."""
    path: Path                  # absolute
    rel: str                    # posix path relative to the lint root
    source: str
    tree: ast.Module
    suppressions: List[Suppression] = field(default_factory=list)

    def suppression_at(self, line: int) -> Optional[Suppression]:
        for s in self.suppressions:
            if s.line == line:
                return s
        return None


@dataclass
class Project:
    """Everything one lint run can see: the root (for path scoping and
    sibling artifacts like ``docs/architecture.md``) plus the parsed
    modules."""
    root: Path
    modules: List[Module] = field(default_factory=list)

    def in_scope(self, module: Module, scope: Tuple[str, ...]) -> bool:
        if not scope:
            return True
        return any(module.rel.startswith(p) for p in scope)

    def scoped(self, scope: Tuple[str, ...]) -> List[Module]:
        return [m for m in self.modules if self.in_scope(m, scope)]

    def find_classes(self, name: str) -> List[Tuple[Module, ast.ClassDef]]:
        """Every class definition with this name, project-wide — how the
        cross-module rules locate ``ScenarioEvent``/``ClusterStats``/
        ``TimelineDispatcher`` without hard-coding file paths (so
        fixture trees exercise them with toy look-alikes)."""
        out = []
        for m in self.modules:
            for node in ast.walk(m.tree):
                if isinstance(node, ast.ClassDef) and node.name == name:
                    out.append((m, node))
        return out


# ------------------------------------------------------------- registry
@dataclass(frozen=True)
class Rule:
    """One registered rule: id, one-line doc (the rule catalog), the
    root-relative path prefixes it applies to (empty = everywhere), and
    the check callable ``(project) -> iterable of findings``."""
    rule_id: str
    doc: str
    scope: Tuple[str, ...]
    check: Callable[[Project], Iterable[Finding]]


RULES: Dict[str, Rule] = {}


def register(rule_id: str, doc: str, scope: Tuple[str, ...] = ()):
    """Decorator: register ``fn(project) -> Iterable[Finding]`` under
    ``rule_id``.  Re-registration replaces (idempotent reloads)."""
    def deco(fn: Callable[[Project], Iterable[Finding]]):
        RULES[rule_id] = Rule(rule_id, doc, scope, fn)
        return fn
    return deco


def load_rules() -> Dict[str, Rule]:
    """Import every rule module (side effect: registration) and return
    the registry.  Deferred so ``engine`` <-> ``rules_*`` imports never
    cycle at module load."""
    from repro.analysis import (rules_clock, rules_determinism,  # noqa: F401
                                rules_frozen, rules_pallas,
                                rules_registry)
    return RULES


# --------------------------------------------------------- suppressions
def parse_suppressions(source: str) -> Tuple[List[Suppression],
                                             List[Tuple[int, str]]]:
    """Extract ``# disagglint: disable=`` directives from COMMENT tokens
    only (a directive inside a string literal is inert).  Returns
    (suppressions, problems) where each problem is a (line, message)
    for a malformed/reasonless directive."""
    sups: List[Suppression] = []
    problems: List[Tuple[int, str]] = []
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string) for t in toks
                    if t.type == tokenize.COMMENT]
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return sups, problems
    for line, text in comments:
        if "disagglint" not in text:
            continue
        m = SUPPRESS_RE.search(text)
        if not m:
            problems.append(
                (line, "malformed disagglint directive (expected "
                       "'# disagglint: disable=<rule>[,<rule>] -- "
                       "<reason>')"))
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        reason = m.group("reason")
        if not reason:
            problems.append(
                (line, f"suppression of {', '.join(rules)} carries no "
                       f"reason — append ' -- <why this is safe>'"))
        sups.append(Suppression(line, rules, reason))
    return sups, problems


# -------------------------------------------------------------- loading
def _iter_py_files(paths: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(q for q in p.rglob("*.py")
                                if "__pycache__" not in q.parts
                                and not any(part.startswith(".")
                                            for part in q.parts)))
        elif p.suffix == ".py" or p.is_file():
            files.append(p)
    # de-dup while preserving order (overlapping path args)
    seen = set()
    out = []
    for f in files:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            out.append(f)
    return out


def _relpath(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def build_project(paths: Sequence[Path], root: Path
                  ) -> Tuple[Project, List[Finding]]:
    project = Project(root=root)
    findings: List[Finding] = []
    for f in _iter_py_files(paths):
        rel = _relpath(f, root)
        try:
            source = f.read_text()
        except OSError as e:
            findings.append(Finding(rel, 0, "parse-error",
                                    f"unreadable: {e}"))
            continue
        try:
            tree = ast.parse(source, filename=str(f))
        except SyntaxError as e:
            findings.append(Finding(rel, e.lineno or 0, "parse-error",
                                    f"syntax error: {e.msg}"))
            continue
        sups, problems = parse_suppressions(source)
        for line, msg in problems:
            findings.append(Finding(rel, line, "bad-suppression", msg))
        project.modules.append(Module(f, rel, source, tree, sups))
    return project, findings


# ------------------------------------------------------------- the run
def lint_paths(paths: Sequence[str], root: Optional[str] = None,
               only: Optional[Sequence[str]] = None) -> LintResult:
    """Run every registered rule over the ``.py`` files under ``paths``.

    ``root`` anchors rule scoping and relative paths in the report
    (default: the current working directory).  ``only`` restricts to a
    subset of rule ids (fixture tests isolate one rule at a time;
    ``bad-suppression``/``parse-error`` findings always survive)."""
    rules = load_rules()
    rootp = Path(root) if root is not None else Path.cwd()
    project, findings = build_project([Path(p) for p in paths], rootp)
    active = (rules.values() if only is None
              else [rules[r] for r in only])
    for rule in active:
        for f in rule.check(project):
            findings.append(f)
    # suppression filter: a finding on a line carrying a matching
    # disable directive is dropped (bad-suppression findings are not
    # themselves suppressible — the directive is the problem)
    by_rel = {m.rel: m for m in project.modules}
    kept: List[Finding] = []
    suppressed = 0
    for f in findings:
        mod = by_rel.get(f.file)
        sup = mod.suppression_at(f.line) if mod else None
        if (sup is not None and sup.reason
                and f.rule in sup.rules
                and f.rule not in ("bad-suppression", "parse-error")):
            suppressed += 1
            continue
        kept.append(f)
    return LintResult(findings=sorted(kept),
                      files_checked=len(project.modules),
                      suppressed=suppressed)


# ------------------------------------------------------------------ CLI
def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="disagglint: determinism & clock-discipline linter")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files/directories to lint (default: src)")
    p.add_argument("--format", choices=["text", "json"], default="text",
                   help="report format (json is byte-stable: sorted "
                        "findings, sorted keys)")
    p.add_argument("--root", default=None,
                   help="scoping root for rule path prefixes and "
                        "report-relative paths (default: cwd)")
    p.add_argument("--only", default=None,
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    args = p.parse_args(argv)
    if args.list_rules:
        for rid, rule in sorted(load_rules().items()):
            scope = ", ".join(rule.scope) if rule.scope else "everywhere"
            print(f"{rid:20s} [{scope}] {rule.doc}")
        return 0
    only = ([r.strip() for r in args.only.split(",") if r.strip()]
            if args.only else None)
    result = lint_paths(args.paths or ["src"], root=args.root, only=only)
    render = render_json if args.format == "json" else render_text
    sys.stdout.write(render(result)
                     if args.format == "json" else render(result) + "\n")
    return result.exit_code()
