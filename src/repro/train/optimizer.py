"""Functional optimizers with ZeRO-1 sharded state + gradient compression.

Adam for dense parameters, Adagrad for embedding tables (the production
choice for DLRM sparse tables). Optimizer state carries its own logical
sharding specs: every state tensor inherits the parameter's spec with the
``opt_shard`` ZeRO axis prepended on the first replicated dimension —
state shards over ``data`` even where weights are replicated.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    kind: str = "adam"            # adam | adagrad | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    # int8 gradient compression (error feedback) for the DP all-reduce
    compress_grads: bool = False


def init_state(cfg: OptConfig, params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    if cfg.kind == "adam":
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "err": (jax.tree.map(f32, params) if cfg.compress_grads else None),
        }
    if cfg.kind == "adagrad":
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(f32, params), "err": None}
    return {"step": jnp.zeros((), jnp.int32), "err": None}


def state_specs(cfg: OptConfig, param_specs, param_shapes=None):
    """Logical specs for the state tree: ZeRO-1 shards moment tensors over
    the data axis on the first dim that (a) resolves to no mesh axis under
    the active rules and (b) is divisible by the data-axis size."""
    from repro.distributed import sharding as shd

    data = shd.axis_size("data") * shd.axis_size("pod")

    opt_axes = shd.resolve(("opt_shard",))[0]
    opt_axes = (() if opt_axes is None else
                ((opt_axes,) if isinstance(opt_axes, str) else tuple(opt_axes)))

    def zero1(names, shape=None):
        names = tuple(names)
        out = list(names)
        # mesh axes already consumed by the parameter's own sharding
        used = set()
        for n in names:
            r = shd.resolve((n,))[0]
            if r is not None:
                used.update((r,) if isinstance(r, str) else tuple(r))
        if any(a in used for a in opt_axes):
            return names                      # param already spans ZeRO axes
        for i, n in enumerate(names):
            resolved = shd.resolve((n,))[0]
            if resolved is not None:
                continue
            if shape is not None and shape[i] % max(data, 1) != 0:
                continue
            out[i] = "opt_shard"
            break
        return tuple(out)

    if param_shapes is not None:
        moments = jax.tree.map(
            lambda names, s: zero1(names, s.shape), param_specs, param_shapes,
            is_leaf=lambda x: isinstance(x, tuple))
    else:
        moments = jax.tree.map(zero1, param_specs,
                               is_leaf=lambda x: isinstance(x, tuple))
    out = {"step": (), "err": None}
    if cfg.kind == "adam":
        out.update(m=moments, v=moments)
    elif cfg.kind == "adagrad":
        out.update(v=moments)
    if cfg.compress_grads:
        out["err"] = moments
    return out


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def compress_int8(g, err):
    """Error-feedback int8 quantization: returns (int8 payload, scale,
    new error). The all-reduce then moves 1/4 the bytes; the residual is
    re-injected next step (Karimireddy et al. style)."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g32 - deq


def apply_updates(cfg: OptConfig, params, grads, state):
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1

    if cfg.compress_grads:
        pairs = jax.tree.map(compress_int8, grads, state["err"])
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_err = state["err"]

    if cfg.kind == "adam":
        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * clip
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
            vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
            delta = cfg.lr * mh / (jnp.sqrt(vh) + cfg.eps)
            if cfg.weight_decay:
                delta += cfg.lr * cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"step": step, "m": new_m, "v": new_v, "err": new_err}

    if cfg.kind == "adagrad":
        def upd(p, g, v):
            g = g.astype(jnp.float32) * clip
            v = v + g * g
            delta = cfg.lr * g / (jnp.sqrt(v) + cfg.eps)
            return (p.astype(jnp.float32) - delta).astype(p.dtype), v

        out = jax.tree.map(upd, params, grads, state["v"])
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"step": step, "v": new_v, "err": new_err}

    # sgd
    def upd(p, g):
        return (p.astype(jnp.float32)
                - cfg.lr * g.astype(jnp.float32) * clip).astype(p.dtype)

    return jax.tree.map(upd, params, grads), {"step": step, "err": new_err}
