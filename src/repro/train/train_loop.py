"""Training step factory: microbatching (grad accumulation), remat-aware,
mesh/rule-driven shardings, fault-tolerant outer loop.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.train import optimizer as opt_mod
from repro.train.optimizer import OptConfig


def make_train_step(model, opt_cfg: OptConfig, microbatches: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state,
    metrics). Microbatching splits the batch on dim 0 and accumulates
    grads (the standard large-global-batch recipe)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grads_acc, grads)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, zeros), mbs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        params, opt_state = opt_mod.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": opt_mod.global_norm(grads)}
        return params, opt_state, metrics

    return step


def make_sharded_train_step(model, opt_cfg: OptConfig, mesh, rules,
                            shape, microbatches: int = 1):
    """jit with explicit in/out shardings for the production mesh."""
    step = make_train_step(model, opt_cfg, microbatches)
    with shd.use_mesh(mesh, rules):
        pspecs = shd.tree_shardings(model.param_specs())
        ospecs = shd.tree_shardings(
            opt_mod.state_specs(opt_cfg, model.param_specs()))
        ispecs = {k: shd.make_sharding(v)
                  for k, v in model.input_logical(shape).items()}
    jitted = jax.jit(
        step,
        in_shardings=(pspecs, ospecs, ispecs),
        out_shardings=(pspecs, ospecs, None),
        donate_argnums=(0, 1),
    )
    return jitted


@dataclass
class TrainLoopConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    max_failures: int = 3


def run_train_loop(model, opt_cfg: OptConfig, data_iter, cfg: TrainLoopConfig,
                   mesh=None, rules=None, params=None, opt_state=None,
                   fault_hook: Optional[Callable[[int], None]] = None,
                   log_fn=print):
    """Fault-tolerant outer loop: periodic checkpoints; on a (simulated or
    real) step failure, restore the last checkpoint and continue —
    the CN-failure recovery path of §IV-A at training time."""
    from repro.train import checkpoint as ckpt

    if params is None:
        params = model.init(0)
    if opt_state is None:
        opt_state = opt_mod.init_state(opt_cfg, params)

    step_fn = make_train_step(model, opt_cfg)
    if mesh is not None:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    start = 0
    if cfg.checkpoint_dir:
        restored = ckpt.try_restore(cfg.checkpoint_dir, params, opt_state)
        if restored is not None:
            params, opt_state, start = restored
            log_fn(f"[ckpt] resumed at step {start}")

    failures = 0
    history = []
    it = iter(data_iter)
    step = start
    while step < cfg.steps:
        batch = next(it)
        batch = jax.tree.map(jnp.asarray, batch)
        try:
            if fault_hook is not None:
                fault_hook(step)      # may raise to simulate a node loss
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        except RuntimeError as e:
            failures += 1
            if failures > cfg.max_failures or not cfg.checkpoint_dir:
                raise
            log_fn(f"[fault] step {step}: {e}; restoring checkpoint")
            params, opt_state, step = ckpt.try_restore(
                cfg.checkpoint_dir, params, opt_state)
            continue
        if step % cfg.log_every == 0:
            loss = float(metrics["loss"])
            history.append((step, loss))
            log_fn(f"step {step:5d} loss {loss:.4f}")
        step += 1
        if cfg.checkpoint_dir and step % cfg.checkpoint_every == 0:
            ckpt.save(cfg.checkpoint_dir, params, opt_state, step)
    if cfg.checkpoint_dir:
        ckpt.save(cfg.checkpoint_dir, params, opt_state, step)
    return params, opt_state, history
