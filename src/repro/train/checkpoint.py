"""Checkpointing: atomic save/restore + elastic resharding.

Format: one .npz with flattened leaf arrays (key = joined pytree path)
plus a msgpack sidecar (step, leaf order). Saves are atomic
(tmp+rename); `latest` tracks the newest complete checkpoint, so a crash
mid-save never corrupts restore state. `restore_resharded` device_puts
leaves with the shardings of a *different* mesh — the elastic-scaling
path (restore a 512-chip checkpoint onto 256 chips or vice versa).
"""
from __future__ import annotations

import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        a = np.asarray(leaf)
        if "bfloat16" in str(a.dtype) or a.dtype.kind == "V":
            a = a.astype(np.float32)   # npz-safe; restore casts back
        flat[key] = a
    return flat


def _unflatten_into(template, flat: dict):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, params, opt_state, step: int) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"ckpt_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp.npz")
    final = os.path.join(ckpt_dir, name + ".npz")
    flat = {f"p/{k}": v for k, v in _flatten(params).items()}
    flat.update({f"o/{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(tmp, **flat)
    os.rename(tmp, final)
    meta = {"step": step, "file": name + ".npz"}
    mtmp = os.path.join(ckpt_dir, "latest.tmp")
    with open(mtmp, "wb") as f:
        f.write(msgpack.packb(meta))
    os.rename(mtmp, os.path.join(ckpt_dir, "latest"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    try:
        with open(os.path.join(ckpt_dir, "latest"), "rb") as f:
            return msgpack.unpackb(f.read())["step"]
    except FileNotFoundError:
        return None


def try_restore(ckpt_dir: str, params_tpl, opt_tpl
                ) -> Optional[Tuple[Any, Any, int]]:
    meta_path = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(meta_path):
        return None
    with open(meta_path, "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(os.path.join(ckpt_dir, meta["file"]))
    flat = {k: data[k] for k in data.files}
    params = _unflatten_into(
        params_tpl, {k[2:]: v for k, v in flat.items() if k.startswith("p/")})
    opt = _unflatten_into(
        opt_tpl, {k[2:]: v for k, v in flat.items() if k.startswith("o/")})
    return params, opt, int(meta["step"])


def restore_resharded(ckpt_dir: str, params_tpl, opt_tpl, shardings=None):
    """Elastic restore: place leaves with the (new) mesh's shardings."""
    out = try_restore(ckpt_dir, params_tpl, opt_tpl)
    if out is None:
        return None
    params, opt, step = out
    if shardings is not None:
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            params, shardings)
    return params, opt, step
