"""Production mesh construction.

A function, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
import; tests and benches see the default single device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU demos)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
