import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init). Everything below is ordinary.
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.base import SHAPES, shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_program  # noqa: E402

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell
on the production mesh with 512 placeholder host devices; record memory
analysis, cost analysis and the collective traffic for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --arch all --mesh both --out results/dryrun
"""


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rule_overrides=None, microbatches: int = 1,
             dump_hlo: str = None) -> dict:
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    jitted, args, rules = build_program(
        cfg, shape, mesh, rule_overrides=rule_overrides,
        microbatches=microbatches)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()

    out = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "devices": mesh.devices.size,
    }
    try:
        out["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        }
        out["memory"]["total_per_device_bytes"] = (
            out["memory"]["argument_bytes"] + out["memory"]["output_bytes"]
            + out["memory"]["temp_bytes"] - out["memory"]["alias_bytes"])
    except Exception as e:  # pragma: no cover
        out["memory_error"] = str(e)
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    out["cost"] = {k: float(v) for k, v in (cost or {}).items()
                   if isinstance(v, (int, float)) and (
                       k in ("flops", "bytes accessed", "transcendentals")
                       or k.startswith("bytes accessed"))}

    # collective traffic + loop-scaled cost for the roofline (§Roofline)
    from benchmarks.roofline import collective_bytes_from_hlo, hlo_cost_scaled
    try:
        hlo = compiled.as_text()
        out["collectives"] = collective_bytes_from_hlo(hlo)
        out["hlo_scaled"] = hlo_cost_scaled(hlo)
        if dump_hlo:
            with open(dump_hlo, "w") as f:
                f.write(hlo)
    except Exception as e:  # pragma: no cover
        out["collectives_error"] = str(e)
    return out


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="all")
    p.add_argument("--shape", default="all")
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--out", default=None, help="directory for JSON records")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--dump-hlo", default=None)
    p.add_argument("--skip-existing", action="store_true")
    args = p.parse_args(argv)

    archs = configs.ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}|{shape}|{'multi' if multi else 'single'}"
                if args.skip_existing and args.out:
                    fn = (f"{arch.replace('.', '_')}__{shape}__"
                          f"{'multi' if multi else 'single'}.json")
                    path = os.path.join(args.out, fn)
                    if os.path.exists(path):
                        with open(path) as f:
                            old = json.load(f)
                        if old.get("status") in ("ok", "skip"):
                            print(f"[dryrun] {tag}: cached "
                                  f"({old['status']})")
                            continue
                try:
                    rec = run_cell(arch, shape, multi,
                                   microbatches=args.microbatches,
                                   dump_hlo=args.dump_hlo)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": str(e),
                           "trace": traceback.format_exc()[-2000:]}
                    failures += 1
                print(f"[dryrun] {tag}: {rec['status']}"
                      + (f" ({rec.get('reason', rec.get('error', ''))[:120]})"
                         if rec["status"] != "ok" else
                         f" mem/device={rec.get('memory', {}).get('total_per_device_bytes', 0)/2**30:.2f}GiB"
                         f" flops={rec.get('cost', {}).get('flops', 0):.3g}"))
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fn = f"{arch.replace('.', '_')}__{shape}__{rec['mesh']}.json"
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(rec, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
