"""Step builders: jit-with-shardings for train / prefill / decode.

Shared by the dry-run (lower+compile on the production mesh) and the
real drivers (train.py / serve.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import registry
from repro.train import optimizer as opt_mod
from repro.train.optimizer import OptConfig
from repro.train.train_loop import make_train_step


def build_program(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  opt_cfg: Optional[OptConfig] = None,
                  rule_overrides: Optional[Dict] = None,
                  microbatches: int = 1):
    """Returns (jitted_fn, example_args (ShapeDtypeStructs), rules).

    train  : step(params, opt_state, batch)
    prefill: fn(params, batch) -> (logits, cache)
    decode : fn(params, cache, batch) -> (logits, cache)
    """
    model = registry.build(cfg)
    mode = registry.mode_for_shape(shape)
    rules = registry.make_rules(cfg, mesh, mode, overrides=rule_overrides)
    opt_cfg = opt_cfg or OptConfig()

    from jax.sharding import NamedSharding

    with shd.use_mesh(mesh, rules):
        pshapes = model.param_shapes()
        pshard = shd.tree_shardings_for_shapes(model.param_specs(), pshapes)
        in_specs = model.input_specs(shape)
        in_logical = model.input_logical(shape)
        ishard = {k: (NamedSharding(mesh, shd.resolve_for_shape(
                          in_logical.get(k) or (None,) * len(v.shape),
                          v.shape)) if mesh is not None else None)
                  for k, v in in_specs.items()}

        if mode == "train":
            ostate_specs = opt_mod.state_specs(opt_cfg, model.param_specs(),
                                               pshapes)
            oshard = shd.tree_shardings(ostate_specs)
            oshapes = jax.eval_shape(
                lambda: opt_mod.init_state(
                    opt_cfg,
                    jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 pshapes)))
            raw = make_train_step(model, opt_cfg, microbatches=microbatches)

            def step(params, opt_state, batch):
                with shd.use_mesh(mesh, rules):
                    return raw(params, opt_state, batch)

            jitted = jax.jit(step,
                             in_shardings=(pshard, oshard, ishard),
                             out_shardings=(pshard, oshard, None),
                             donate_argnums=(0, 1))
            return jitted, (pshapes, oshapes, in_specs), rules

        if mode == "prefill":
            cshard = shd.tree_shardings_for_shapes(
                model.cache_logical(shape), model.cache_specs(shape))

            def fn(params, batch):
                with shd.use_mesh(mesh, rules):
                    return model.prefill(params, batch,
                                         cache_len=shape.seq_len)

            jitted = jax.jit(fn, in_shardings=(pshard, ishard),
                             out_shardings=(None, cshard))
            return jitted, (pshapes, in_specs), rules

        # decode
        cshapes = model.cache_specs(shape)
        cshard = shd.tree_shardings_for_shapes(
            model.cache_logical(shape), cshapes)

        def fn(params, cache, batch):
            with shd.use_mesh(mesh, rules):
                return model.decode_step(params, cache, batch)

        jitted = jax.jit(fn, in_shardings=(pshard, cshard, ishard),
                         out_shardings=(None, cshard),
                         donate_argnums=(1,))
        return jitted, (pshapes, cshapes, in_specs), rules
