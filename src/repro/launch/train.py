"""End-to-end training driver.

CPU-scale run (default): trains smollm-135m (the ~100M assigned arch) or
a reduced config on synthetic data with checkpoint/restart fault
tolerance. On a pod, the same driver runs the production mesh via
--mesh single|multi.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 256 --reduced
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro import configs
from repro.data.queries import ShardedLoader, dlrm_batch, lm_batch
from repro.models import registry
from repro.train.optimizer import OptConfig
from repro.train.train_loop import TrainLoopConfig, run_train_loop


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--reduced", action="store_true",
                   help="use the reduced smoke config")
    p.add_argument("--opt", default="adam", choices=["adam", "adagrad", "sgd"])
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    model = registry.build(cfg)
    opt_cfg = OptConfig(kind=args.opt, lr=args.lr,
                        compress_grads=args.compress_grads)

    if cfg.family == "dlrm":
        gen = lambda rng: dlrm_batch(cfg, args.batch, rng)
    else:
        vocab = cfg.vocab_size
        gen = lambda rng: lm_batch(vocab, args.batch, args.seq, rng)
    loader = ShardedLoader(gen, seed=args.seed)

    loop_cfg = TrainLoopConfig(
        steps=args.steps, log_every=args.log_every,
        checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt_dir)
    params, opt_state, history = run_train_loop(
        model, opt_cfg, loader, loop_cfg)
    if len(history) >= 2:
        print(f"[train] loss {history[0][1]:.4f} -> {history[-1][1]:.4f} "
              f"over {args.steps} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
