"""Serving driver: disaggregated DLRM scoring or LM generation.

  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --requests 64
  PYTHONPATH=src python -m repro.launch.serve \
      --scenario examples/scenarios/failover_storm.json   # declarative
  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --cluster \
      --cns 2 --mns 4 --fail-mn 1
  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --cluster \
      --mns 4 --mn-type "2xddr_mn+2xnmp_mn"        # heterogeneous pool
  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --cluster \
      --cns 3 --mns 6 --elastic              # diurnal resize schedule
  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --cluster \
      --alpha 1.05 --cache-mb 64             # skewed stream + CN row cache
  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --cluster \
      --arrival poisson --sla-p99-ms 60      # live traffic + SLA feedback
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced

Cluster serving goes through the declarative scenario API
(``serving.scenario.run_scenario``): ``--scenario path.json`` runs a
scenario file directly, and the legacy flag combinations are kept as a
preset builder (`spec_from_flags`) that assembles the equivalent
``ScenarioSpec`` — one front door either way.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro import configs
from repro.data.queries import QueryDist, dlrm_request_stream
from repro.models import registry
from repro.serving.autoscaler import Autoscaler, AutoscalerConfig
from repro.serving.cluster import parse_mn_types
from repro.serving.engine import DLRMServingEngine, LMServingEngine, Request
from repro.serving.scenario import (FailMN, ModelRef, Resize, ScenarioSpec,
                                    Topology, Workload, run_scenario)


def spec_from_flags(args) -> ScenarioSpec:
    """The legacy CLI flags, expressed as a ScenarioSpec — the ad-hoc
    flag combinations are now just a preset builder over the scenario
    API."""
    mn_types = tuple(parse_mn_types(args.mn_type, args.mns))
    if args.models:
        archs = [a.strip() for a in args.models.split(",") if a.strip()]
        models = tuple(ModelRef(arch=a, reduced=args.reduced,
                                init_seed=args.seed) for a in archs)
    else:
        models = (ModelRef(arch=args.arch, reduced=args.reduced,
                           init_seed=args.seed),)
    events = []
    if args.fail_mn is not None:
        events.append(FailMN(0.001 * args.requests / 2, mn=args.fail_mn))
    if args.elastic:
        # one diurnal day mapped onto the stream; the CLI pool sizes are
        # the peak the trough scales down from
        toy = Autoscaler(AutoscalerConfig(
            qps_per_cn=1.0 / args.cns, qps_per_mn=1.0 / args.mns,
            min_cn=1, min_mn=min(2, args.mns),
            max_cn=args.cns, max_mn=args.mns))
        events += [Resize(e.time_s, n_cn=e.n_cn, m_mn=e.m_mn)
                   for e in toy.plan(peak_load=0.95,
                                     duration_s=0.001 * args.requests,
                                     steps=8)]
    return ScenarioSpec(
        name="cli",
        description="scenario assembled from repro.launch.serve flags",
        models=models,
        topology=Topology(
            n_cn=args.cns, m_mn=args.mns, batch_size=args.batch,
            n_replicas=args.replicas, use_kernel=args.use_kernel,
            mn_types=mn_types, cache_mb=args.cache_mb,
            cache_policy=args.cache_policy,
            inflight_depth=args.inflight_depth,
            cn_router=args.cn_router,
            hedge_multiplier=args.hedge_multiplier),
        workload=Workload(requests=args.requests, mean_size=8.0,
                          max_size=4 * args.batch, alpha=args.alpha,
                          gap_s=0.001, seed=args.seed,
                          arrival=args.arrival,
                          burstiness=args.burstiness,
                          trace_path=args.trace),
        sla_p99_s=(args.sla_p99_ms / 1e3
                   if args.sla_p99_ms is not None else None),
        sla_mode=args.sla_mode,
        events=tuple(events),
    )


def _print_report(rep) -> None:
    """One renderer for both cluster entry points: the scenario report's
    own summary, prefixed by the scored-output line only the flags path
    has reason to surface."""
    if rep.results:
        scores = np.concatenate([r.outputs for r in rep.results])
        print(f"[serve] scored {rep.completed}/{rep.total} queries "
              f"({scores.size} samples), mean CTR {scores.mean():.4f}")
    else:
        print(f"[serve] scored 0/{rep.total} queries (empty stream)")
    for line in rep.summary():
        print(line)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="rm1")
    p.add_argument("--models", default=None, metavar="A,B",
                   help="comma list of archs to serve as a fleet on one "
                        "shared pool (cluster mode), e.g. 'rm1,rm2' — "
                        "overrides --arch; rates split evenly and "
                        "per-model stats report on the shared pool")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--full", dest="reduced", action="store_false")
    p.add_argument("--requests", type=int, default=32)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--decode-steps", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--scenario", default=None, metavar="PATH",
                   help="run a declarative scenario file "
                        "(examples/scenarios/*.json) through "
                        "run_scenario — ignores the other cluster flags")
    p.add_argument("--cluster", action="store_true",
                   help="serve across {n CN, m MN} via ClusterEngine")
    p.add_argument("--cns", type=int, default=2)
    p.add_argument("--mns", type=int, default=4)
    p.add_argument("--replicas", type=int, default=2)
    p.add_argument("--mn-type", default="ddr_mn",
                   help="memory-pool spec: one type for the whole pool "
                        "('nmp_mn'), a comma list, or counted groups "
                        "('2xddr_mn+2xnmp_mn')")
    p.add_argument("--fail-mn", type=int, default=None,
                   help="kill this MN mid-stream (cluster mode)")
    p.add_argument("--elastic", action="store_true",
                   help="follow a diurnal resize schedule mapped onto "
                        "the request stream (cluster mode): both pools "
                        "scale down toward the trough and back")
    p.add_argument("--alpha", type=float, default=0.0,
                   help="Zipf row-popularity skew of the query stream "
                        "(0 = uniform; production streams ~1.05)")
    p.add_argument("--cache-mb", type=float, default=0.0,
                   help="per-CN hot-row cache budget in MB (cluster mode; "
                        "0 disables)")
    p.add_argument("--inflight-depth", type=int, default=1,
                   help="max batches concurrently inside the MN stage "
                        "(1 = sequential clock, bitwise-identical to "
                        "the pre-pipeline model)")
    p.add_argument("--cache-policy", default="lru", choices=["lru", "lfu"],
                   help="hot-row cache eviction policy")
    p.add_argument("--cn-router", default="cpu_free",
                   choices=["cpu_free", "pipeline_free",
                            "least_outstanding"],
                   help="batch -> CN placement policy (cluster mode): "
                        "cpu_free routes on the preprocess core's "
                        "free_at (legacy, bitwise parity), pipeline_free "
                        "on the whole cpu/nic/gpu pipeline drain, "
                        "least_outstanding on fewest uncommitted "
                        "bookings")
    p.add_argument("--arrival", default="linear",
                   choices=["linear", "poisson", "bursty", "trace"],
                   help="arrival process of the request stream (cluster "
                        "mode; linear reproduces the historical evenly-"
                        "spaced stream byte-for-byte)")
    p.add_argument("--burstiness", type=float, default=4.0,
                   help="bursty arrivals: burst/lull rate swing factor "
                        "(>= 1; ignored by other processes)")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="JSON arrival-timestamp trace file "
                        "(requires --arrival trace)")
    p.add_argument("--sla-p99-ms", type=float, default=None,
                   help="p99 latency SLA in ms (cluster mode): enables "
                        "the feedback SLAController, which watches the "
                        "measured sliding-window p99 and emits live "
                        "Resize events to hold it under the target")
    p.add_argument("--sla-mode", default="coupled",
                   choices=["coupled", "decoupled"],
                   help="SLA controller scaling split (with --sla-p99-ms)"
                        ": coupled steps both pools in lockstep; "
                        "decoupled attributes each breach to the binding "
                        "pool and emits partial per-pool resizes")
    p.add_argument("--hedge-multiplier", type=float, default=0.0,
                   help="hedged re-issue of straggling MN scans: re-issue "
                        "on a replica once a scan exceeds this multiple "
                        "of its nominal time (0 disables)")
    p.add_argument("--no-kernel", dest="use_kernel", action="store_false",
                   default=True)
    args = p.parse_args(argv)

    if args.scenario:
        spec = ScenarioSpec.load(args.scenario)
        rep = run_scenario(spec)
        if spec.description:
            print(f"[serve] scenario {spec.name!r}: {spec.description}")
        _print_report(rep)
        return 0

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    model = registry.build(cfg)
    params = model.init(args.seed)
    rng = np.random.RandomState(args.seed)

    if cfg.family == "dlrm":
        if args.cluster:
            spec = spec_from_flags(args)
            if len(spec.models) > 1:
                # fleet specs build their own models (the single
                # prebuilt model/params pair can't cover the fleet)
                rep = run_scenario(spec)
            else:
                rep = run_scenario(spec, model=model, params=params)
            _print_report(rep)
        else:
            qd = QueryDist(mean_size=8.0, max_size=4 * args.batch,
                           alpha=args.alpha)
            reqs = [Request(*t) for t in
                    dlrm_request_stream(cfg, args.requests, seed=args.seed,
                                        dist=qd, gap_s=0.001)]
            engine = DLRMServingEngine(model, params, batch_size=args.batch,
                                       use_kernel=args.use_kernel)
            results = engine.serve(reqs)
            scores = np.concatenate([r.outputs for r in results])
            print(f"[serve] scored {len(results)} queries "
                  f"({scores.size} samples), mean CTR {scores.mean():.4f}")
    else:
        if args.cluster:
            print("[serve] --cluster only applies to dlrm archs; "
                  "running single-unit LM generation")
        engine = LMServingEngine(model, params, cache_len=128)
        toks = rng.randint(0, cfg.vocab_size, (2, 16)).astype(np.int32)
        extra = {}
        if cfg.family == "audio":
            extra["frames"] = rng.randn(
                2, cfg.encdec.encoder_seq, cfg.d_model).astype(np.float32)
        if cfg.family == "vlm":
            extra["images"] = rng.randn(
                2, cfg.vlm.num_patches, cfg.d_model).astype(np.float32)
        out = engine.generate(toks, steps=args.decode_steps, extra=extra)
        print(f"[serve] generated {out.shape[1]} tokens/seq for "
              f"{out.shape[0]} sequences: {out[0].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
