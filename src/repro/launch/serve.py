"""Serving driver: disaggregated DLRM scoring or LM generation.

  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --requests 64
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro import configs
from repro.data.queries import QueryDist, dlrm_batch
from repro.models import registry
from repro.serving.engine import DLRMServingEngine, LMServingEngine, Request


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="rm1")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--full", dest="reduced", action="store_false")
    p.add_argument("--requests", type=int, default=32)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--decode-steps", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    model = registry.build(cfg)
    params = model.init(args.seed)
    rng = np.random.RandomState(args.seed)

    if cfg.family == "dlrm":
        engine = DLRMServingEngine(model, params, batch_size=args.batch)
        qd = QueryDist(mean_size=8.0, max_size=4 * args.batch)
        sizes = qd.sample(rng, args.requests)
        reqs = []
        for i, s in enumerate(sizes):
            b = dlrm_batch(cfg, int(s), rng)
            reqs.append(Request(i, {"dense": b["dense"],
                                    "indices": b["indices"]},
                                int(s), float(i)))
        results = engine.serve(reqs)
        scores = np.concatenate([r.outputs for r in results])
        print(f"[serve] scored {len(results)} queries "
              f"({scores.size} samples), mean CTR {scores.mean():.4f}")
    else:
        engine = LMServingEngine(model, params, cache_len=128)
        toks = rng.randint(0, cfg.vocab_size, (2, 16)).astype(np.int32)
        extra = {}
        if cfg.family == "audio":
            extra["frames"] = rng.randn(
                2, cfg.encdec.encoder_seq, cfg.d_model).astype(np.float32)
        if cfg.family == "vlm":
            extra["images"] = rng.randn(
                2, cfg.vlm.num_patches, cfg.d_model).astype(np.float32)
        out = engine.generate(toks, steps=args.decode_steps, extra=extra)
        print(f"[serve] generated {out.shape[1]} tokens/seq for "
              f"{out.shape[0]} sequences: {out[0].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
