"""Serving driver: disaggregated DLRM scoring or LM generation.

  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --requests 64
  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --cluster \
      --cns 2 --mns 4 --fail-mn 1
  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --cluster \
      --mns 4 --mn-type "2xddr_mn+2xnmp_mn"        # heterogeneous pool
  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --cluster \
      --cns 3 --mns 6 --elastic              # diurnal resize schedule
  PYTHONPATH=src python -m repro.launch.serve --arch rm1 --cluster \
      --alpha 1.05 --cache-mb 64             # skewed stream + CN row cache
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro import configs
from repro.data.queries import QueryDist, dlrm_request_stream
from repro.models import registry
from repro.serving.autoscaler import Autoscaler, AutoscalerConfig
from repro.serving.cluster import (ClusterConfig, ClusterEngine,
                                   parse_mn_types)
from repro.serving.engine import DLRMServingEngine, LMServingEngine, Request


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="rm1")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--full", dest="reduced", action="store_false")
    p.add_argument("--requests", type=int, default=32)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--decode-steps", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--cluster", action="store_true",
                   help="serve across {n CN, m MN} via ClusterEngine")
    p.add_argument("--cns", type=int, default=2)
    p.add_argument("--mns", type=int, default=4)
    p.add_argument("--replicas", type=int, default=2)
    p.add_argument("--mn-type", default="ddr_mn",
                   help="memory-pool spec: one type for the whole pool "
                        "('nmp_mn'), a comma list, or counted groups "
                        "('2xddr_mn+2xnmp_mn')")
    p.add_argument("--fail-mn", type=int, default=None,
                   help="kill this MN mid-stream (cluster mode)")
    p.add_argument("--elastic", action="store_true",
                   help="follow a diurnal resize schedule mapped onto "
                        "the request stream (cluster mode): both pools "
                        "scale down toward the trough and back")
    p.add_argument("--alpha", type=float, default=0.0,
                   help="Zipf row-popularity skew of the query stream "
                        "(0 = uniform; production streams ~1.05)")
    p.add_argument("--cache-mb", type=float, default=0.0,
                   help="per-CN hot-row cache budget in MB (cluster mode; "
                        "0 disables)")
    p.add_argument("--cache-policy", default="lru", choices=["lru", "lfu"],
                   help="hot-row cache eviction policy")
    p.add_argument("--no-kernel", dest="use_kernel", action="store_false",
                   default=True)
    args = p.parse_args(argv)

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    model = registry.build(cfg)
    params = model.init(args.seed)
    rng = np.random.RandomState(args.seed)

    if cfg.family == "dlrm":
        qd = QueryDist(mean_size=8.0, max_size=4 * args.batch,
                       alpha=args.alpha)
        reqs = [Request(*t) for t in
                dlrm_request_stream(cfg, args.requests, seed=args.seed,
                                    dist=qd, gap_s=0.001)]
        if args.cluster:
            mn_types = parse_mn_types(args.mn_type, args.mns)
            engine = ClusterEngine(model, params, ClusterConfig(
                n_cn=args.cns, m_mn=args.mns, batch_size=args.batch,
                n_replicas=args.replicas, use_kernel=args.use_kernel,
                mn_types=mn_types, cache_mb=args.cache_mb,
                cache_policy=args.cache_policy, seed=args.seed))
            failures = ([] if args.fail_mn is None
                        else [(0.001 * args.requests / 2, args.fail_mn)])
            resizes = []
            if args.elastic:
                # one diurnal day mapped onto the stream; the CLI pool
                # sizes are the peak the trough scales down from
                toy = Autoscaler(AutoscalerConfig(
                    qps_per_cn=1.0 / args.cns, qps_per_mn=1.0 / args.mns,
                    min_cn=1, min_mn=min(2, args.mns),
                    max_cn=args.cns, max_mn=args.mns))
                resizes = toy.plan(peak_load=0.95,
                                   duration_s=0.001 * args.requests,
                                   steps=8)
            results, stats = engine.serve(reqs, failures=failures,
                                          resizes=resizes)
            scores = np.concatenate([r.outputs for r in results])
            pool = ",".join(mn_types)
            print(f"[serve] cluster {{{args.cns} CN, {args.mns} MN "
                  f"[{pool}]}} scored {stats.completed} queries "
                  f"({scores.size} samples), mean CTR {scores.mean():.4f}")
            print(f"[serve] p50 {stats.p50 * 1e3:.3f}ms "
                  f"p95 {stats.p95 * 1e3:.3f}ms  "
                  f"MN imbalance {stats.imbalance:.3f}  "
                  f"failures={stats.failures} reroutes={stats.reroutes}")
            mem = sum(stats.mn_access_bytes) + stats.retired_access_bytes
            gat = sum(stats.mn_gather_bytes) + stats.retired_gather_bytes
            if any(engine.mn_nmp):
                print(f"[serve] NMP near-memory pooling: scanned "
                      f"{mem / 1e6:.2f}MB on-node, shipped "
                      f"{gat / 1e6:.2f}MB over the fabric "
                      f"({100 * (1 - gat / max(mem, 1)):.1f}% gather "
                      f"bytes saved vs raw rows)")
            if args.cache_mb > 0:
                probes = stats.cache_hits + stats.cache_misses
                hr = stats.cache_hits / max(probes, 1)
                print(f"[serve] hot-row cache ({args.cache_policy}, "
                      f"{args.cache_mb:g}MB/CN): {100 * hr:.1f}% hit rate, "
                      f"{stats.cache_bytes_saved / 1e6:.2f}MB gather "
                      f"bytes saved, {stats.cache_evictions} evictions, "
                      f"{stats.cache_invalidations} coherence "
                      f"invalidations")
            if args.elastic:
                print(f"[serve] elastic: {stats.resizes} resizes applied, "
                      f"{stats.migration_bytes / 1e6:.2f}MB shard "
                      f"migration, pool now {{{engine.n_cn} CN, "
                      f"{engine.m_mn} MN}}")
            v = engine.validate_latency_model()
            print(f"[serve] latency model cross-check: engine/analytic "
                  f"= {v['ratio']:.2f} (MN stage {v['mn_stage_ratio']:.2f})")
        else:
            engine = DLRMServingEngine(model, params, batch_size=args.batch,
                                       use_kernel=args.use_kernel)
            results = engine.serve(reqs)
            scores = np.concatenate([r.outputs for r in results])
            print(f"[serve] scored {len(results)} queries "
                  f"({scores.size} samples), mean CTR {scores.mean():.4f}")
    else:
        if args.cluster:
            print("[serve] --cluster only applies to dlrm archs; "
                  "running single-unit LM generation")
        engine = LMServingEngine(model, params, cache_len=128)
        toks = rng.randint(0, cfg.vocab_size, (2, 16)).astype(np.int32)
        extra = {}
        if cfg.family == "audio":
            extra["frames"] = rng.randn(
                2, cfg.encdec.encoder_seq, cfg.d_model).astype(np.float32)
        if cfg.family == "vlm":
            extra["images"] = rng.randn(
                2, cfg.vlm.num_patches, cfg.d_model).astype(np.float32)
        out = engine.generate(toks, steps=args.decode_steps, extra=extra)
        print(f"[serve] generated {out.shape[1]} tokens/seq for "
              f"{out.shape[0]} sequences: {out[0].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
