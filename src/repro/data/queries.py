"""Query workload generation (paper Fig. 2).

- Heavy-tailed query-size distribution (Fig. 2a): lognormal, most queries
  small, a long tail of large ranking requests.
- Poisson arrivals modulated by the diurnal load curve (Fig. 2b).
- Preprocessing (G_P): hashing raw sparse features to table indices.
- Zipf-skewed row popularity (Gupta et al.: production embedding access
  streams concentrate on a small hot set): ``alpha > 0`` draws table
  indices from a truncated Zipf over the row space instead of uniform
  hashing, giving CN-side caches a hot set to exploit.

Everything is seeded and wall-clock-free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Tuple

import numpy as np


@dataclass(frozen=True)
class QueryDist:
    mean_size: float = 64.0
    sigma: float = 1.0          # lognormal shape: heavy tail
    max_size: int = 4096
    alpha: float = 0.0          # Zipf row-popularity skew (0 = uniform)

    def sample(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        mu = np.log(self.mean_size) - 0.5 * self.sigma ** 2
        s = rng.lognormal(mu, self.sigma, size=n)
        return np.clip(np.ceil(s), 1, self.max_size).astype(np.int64)


def poisson_arrivals(rate_qps: float, duration_s: float,
                     rng: np.random.RandomState) -> np.ndarray:
    """Arrival timestamps over [0, duration)."""
    n = rng.poisson(rate_qps * duration_s)
    return np.sort(rng.uniform(0.0, duration_s, size=n))


def hash_features(raw: np.ndarray, num_rows: int, salt: int = 0) -> np.ndarray:
    """G_P: map raw sparse ids to table row indices (multiplicative hash)."""
    x = raw.astype(np.uint64) * np.uint64(2654435761) + np.uint64(salt)
    x ^= x >> np.uint64(16)
    return (x % np.uint64(num_rows)).astype(np.int32)


# truncated-Zipf CDFs are pure functions of (num_rows, alpha): memoize so
# per-request batch generation doesn't recompute a row-space-sized cumsum
_ZIPF_CDF: Dict[Tuple[int, float], np.ndarray] = {}


def zipf_row_cdf(num_rows: int, alpha: float) -> np.ndarray:
    """CDF of a truncated Zipf over ranks 1..num_rows: P(k) ~ 1/k^alpha."""
    key = (int(num_rows), float(alpha))
    cdf = _ZIPF_CDF.get(key)
    if cdf is None:
        w = 1.0 / np.arange(1, num_rows + 1, dtype=np.float64) ** alpha
        cdf = np.cumsum(w)
        cdf /= cdf[-1]
        _ZIPF_CDF[key] = cdf
    return cdf


def zipf_indices(rng: np.random.RandomState, shape, num_rows: int,
                 alpha: float) -> np.ndarray:
    """Zipf-skewed row indices: rank k (0 = hottest row) drawn with
    probability ~ 1/(k+1)^alpha via inverse-CDF sampling.  Row id == rank,
    so the hot set of every table is its low row ids — a deterministic,
    seed-stable convention the cache/placement layers can be tested
    against."""
    u = rng.uniform(size=shape)
    return np.searchsorted(zipf_row_cdf(num_rows, alpha), u,
                           side="right").astype(np.int32)


def dlrm_batch(cfg, batch: int, rng: np.random.RandomState,
               pooling_sigma: float = 0.3, alpha: float = 0.0):
    """Synthetic click-log batch for a DLRM config: dense features,
    per-table pooled index lists (-1 padded), labels.

    ``alpha > 0`` switches index generation from uniform hashing to a
    truncated Zipf over each table's rows (the skewed production access
    pattern); ``alpha = 0`` keeps the exact uniform-hash RNG stream of
    earlier revisions, so seeded goldens are unaffected."""
    r = cfg.dlrm
    dense = rng.randn(batch, r.num_dense_features).astype(np.float32)
    P = r.avg_pooling
    if alpha > 0.0:
        idx = zipf_indices(rng, (batch, r.num_tables, P),
                           r.rows_per_table, alpha)
    else:
        raw = rng.randint(0, 1 << 31, size=(batch, r.num_tables, P))
        idx = hash_features(raw, r.rows_per_table)
    # variable pooling: mask out a lognormal-distributed tail per bag
    lens = np.clip(rng.lognormal(np.log(max(P * 0.7, 1.0)), pooling_sigma,
                                 size=(batch, r.num_tables)), 1, P)
    mask = np.arange(P)[None, None, :] < lens[..., None]
    idx = np.where(mask, idx, -1).astype(np.int32)
    labels = rng.binomial(1, 0.2, size=batch).astype(np.int32)
    return {"dense": dense, "indices": idx, "labels": labels}


def dlrm_request_stream(cfg, n: int, seed: int = 0,
                        dist: QueryDist = None,
                        gap_s: float = 0.002) -> List[Tuple]:
    """Standard seeded DLRM request stream: (rid, payload, size, arrival)
    tuples ready to splat into ``serving.engine.Request``.

    One explicit ``np.random.RandomState(seed)`` drives sizes and
    payloads — the single sanctioned way for benches/launchers to build
    engine workloads, so two builds from the same seed are identical
    (``ClusterConfig.seed`` threads the same convention through the
    engine).  ``dist.alpha`` selects the Zipf row-popularity skew."""
    rng = np.random.RandomState(seed)
    qd = dist or QueryDist(mean_size=8.0, max_size=64)
    sizes = qd.sample(rng, n)
    reqs = []
    for i, s in enumerate(sizes):
        b = dlrm_batch(cfg, int(s), rng, alpha=qd.alpha)
        reqs.append((i, {"dense": b["dense"], "indices": b["indices"]},
                     int(s), gap_s * i))
    return reqs


def lm_batch(vocab: int, batch: int, seq: int, rng: np.random.RandomState):
    """Synthetic token stream (zipf-ish unigram) for LM train smoke."""
    p = 1.0 / np.arange(1, vocab + 1) ** 1.1
    p /= p.sum()
    toks = rng.choice(vocab, size=(batch, seq + 1), p=p).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ShardedLoader:
    """Deterministic per-host data sharding: host i of k reads every k-th
    batch (the standard multi-pod input pipeline contract)."""

    def __init__(self, gen_fn, host_id: int = 0, num_hosts: int = 1,
                 seed: int = 0):
        self.gen = gen_fn
        self.host = host_id
        self.k = num_hosts
        self.seed = seed

    def __iter__(self) -> Iterator:
        step = 0
        while True:
            rng = np.random.RandomState(
                (self.seed * 9973 + step * self.k + self.host) % (1 << 31))
            yield self.gen(rng)
            step += 1
