"""Query workload generation (paper Fig. 2).

- Heavy-tailed query-size distribution (Fig. 2a): lognormal, most queries
  small, a long tail of large ranking requests.
- Poisson arrivals modulated by the diurnal load curve (Fig. 2b).
- Preprocessing (G_P): hashing raw sparse features to table indices.

Everything is seeded and wall-clock-free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Tuple

import numpy as np


@dataclass(frozen=True)
class QueryDist:
    mean_size: float = 64.0
    sigma: float = 1.0          # lognormal shape: heavy tail
    max_size: int = 4096

    def sample(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        mu = np.log(self.mean_size) - 0.5 * self.sigma ** 2
        s = rng.lognormal(mu, self.sigma, size=n)
        return np.clip(np.ceil(s), 1, self.max_size).astype(np.int64)


def poisson_arrivals(rate_qps: float, duration_s: float,
                     rng: np.random.RandomState) -> np.ndarray:
    """Arrival timestamps over [0, duration)."""
    n = rng.poisson(rate_qps * duration_s)
    return np.sort(rng.uniform(0.0, duration_s, size=n))


def hash_features(raw: np.ndarray, num_rows: int, salt: int = 0) -> np.ndarray:
    """G_P: map raw sparse ids to table row indices (multiplicative hash)."""
    x = raw.astype(np.uint64) * np.uint64(2654435761) + np.uint64(salt)
    x ^= x >> np.uint64(16)
    return (x % np.uint64(num_rows)).astype(np.int32)


def dlrm_batch(cfg, batch: int, rng: np.random.RandomState,
               pooling_sigma: float = 0.3):
    """Synthetic click-log batch for a DLRM config: dense features,
    per-table pooled index lists (-1 padded), labels."""
    r = cfg.dlrm
    dense = rng.randn(batch, r.num_dense_features).astype(np.float32)
    P = r.avg_pooling
    raw = rng.randint(0, 1 << 31, size=(batch, r.num_tables, P))
    idx = hash_features(raw, r.rows_per_table)
    # variable pooling: mask out a lognormal-distributed tail per bag
    lens = np.clip(rng.lognormal(np.log(max(P * 0.7, 1.0)), pooling_sigma,
                                 size=(batch, r.num_tables)), 1, P)
    mask = np.arange(P)[None, None, :] < lens[..., None]
    idx = np.where(mask, idx, -1).astype(np.int32)
    labels = rng.binomial(1, 0.2, size=batch).astype(np.int32)
    return {"dense": dense, "indices": idx, "labels": labels}


def lm_batch(vocab: int, batch: int, seq: int, rng: np.random.RandomState):
    """Synthetic token stream (zipf-ish unigram) for LM train smoke."""
    p = 1.0 / np.arange(1, vocab + 1) ** 1.1
    p /= p.sum()
    toks = rng.choice(vocab, size=(batch, seq + 1), p=p).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ShardedLoader:
    """Deterministic per-host data sharding: host i of k reads every k-th
    batch (the standard multi-pod input pipeline contract)."""

    def __init__(self, gen_fn, host_id: int = 0, num_hosts: int = 1,
                 seed: int = 0):
        self.gen = gen_fn
        self.host = host_id
        self.k = num_hosts
        self.seed = seed

    def __iter__(self) -> Iterator:
        step = 0
        while True:
            rng = np.random.RandomState(
                (self.seed * 9973 + step * self.k + self.host) % (1 << 31))
            yield self.gen(rng)
            step += 1
