"""Query workload generation (paper Fig. 2).

- Heavy-tailed query-size distribution (Fig. 2a): lognormal, most queries
  small, a long tail of large ranking requests.
- Poisson arrivals modulated by the diurnal load curve (Fig. 2b).
- Arrival processes (:class:`ArrivalProcess`): request streams may be
  ``linear`` (the historical evenly-spaced stream, byte-for-byte), or
  realistic — ``poisson`` (exponential inter-arrival gaps at mean
  ``gap_s``), ``bursty`` (a two-state burst/lull modulation of the
  Poisson stream, Gupta et al.'s production traffic shape), or ``trace``
  (replay absolute timestamps from a JSON file).
- Preprocessing (G_P): hashing raw sparse features to table indices.
- Zipf-skewed row popularity (Gupta et al.: production embedding access
  streams concentrate on a small hot set): ``alpha > 0`` draws table
  indices from a truncated Zipf over the row space instead of uniform
  hashing, giving CN-side caches a hot set to exploit.

Everything is seeded and wall-clock-free.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class QueryDist:
    mean_size: float = 64.0
    sigma: float = 1.0          # lognormal shape: heavy tail
    max_size: int = 4096
    alpha: float = 0.0          # Zipf row-popularity skew (0 = uniform)

    def sample(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        mu = np.log(self.mean_size) - 0.5 * self.sigma ** 2
        s = rng.lognormal(mu, self.sigma, size=n)
        return np.clip(np.ceil(s), 1, self.max_size).astype(np.int64)


def poisson_arrivals(rate_qps: float, duration_s: float,
                     rng: np.random.RandomState) -> np.ndarray:
    """Arrival timestamps over [0, duration)."""
    n = rng.poisson(rate_qps * duration_s)
    return np.sort(rng.uniform(0.0, duration_s, size=n))


# ------------------------------------------------------ arrival processes
ARRIVALS = ("linear", "poisson", "bursty", "trace")

# bursty process shape: geometric burst/lull episode lengths (in
# arrivals), mean episode length in arrivals
BURST_EPISODE_MEAN = 8.0


def _arrival_seed(seed: int) -> int:
    """Derive the arrival-stream seed from the workload seed.  The
    arrival RNG is a *separate* stream from the size/payload RNG so
    switching ``linear`` -> ``poisson`` never perturbs the sampled
    query contents (and ``linear``, which consumes no randomness,
    stays byte-for-byte identical to the historical streams)."""
    return int((int(seed) * 2654435761 + 0x9E37) % (1 << 31))


def load_trace(path: str) -> List[float]:
    """Load a JSON arrival trace: either a bare list of absolute
    timestamps or ``{"arrivals": [...]}``.  Timestamps are sorted and
    must be finite and >= 0."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict):
        data = data.get("arrivals")
    if not isinstance(data, list) or not all(
            isinstance(t, (int, float)) and not isinstance(t, bool)
            for t in data):
        raise ValueError(f"{path}: arrival trace must be a JSON list of "
                         f"timestamps (or {{'arrivals': [...]}})")
    out = sorted(float(t) for t in data)
    if out and (not np.isfinite(out[0]) or out[0] < 0
                or not np.isfinite(out[-1])):
        raise ValueError(f"{path}: trace timestamps must be finite and >= 0")
    return out


class ArrivalProcess:
    """Seeded per-phase arrival-time generator for the four processes:

    - ``linear``: evenly spaced at ``gap_s`` from the phase start (the
      historical stream; the first arrival of every phase lands exactly
      on the declared phase start).
    - ``poisson``: exponential inter-arrival gaps with mean ``gap_s``
      from the phase start.
    - ``bursty``: a two-state Markov-modulated Poisson stream — bursts
      draw gaps at ``gap_s / burstiness``, lulls at
      ``gap_s * burstiness``, with geometric episode lengths (mean
      ``BURST_EPISODE_MEAN`` arrivals), so the long-run mean rate stays
      near ``1 / gap_s`` while the short-run rate swings.
    - ``trace``: replay absolute timestamps (``trace`` list or a JSON
      file via :func:`load_trace`); ``realign`` is a no-op — a trace is
      absolute, phases only re-shape the query contents.  A trace
      shorter than the request count extends linearly at ``gap_s``
      past its last timestamp.

    ``realign(t_start, gap_s)`` starts a new phase: subsequent arrivals
    are generated from ``t_start`` under the new gap.  Callers pop one
    candidate with :meth:`next`; a candidate discarded because a phase
    change fired before it is simply regenerated after ``realign`` (the
    stochastic processes burn the discarded draw — deterministic either
    way, since everything hangs off one seeded ``RandomState``).
    """

    def __init__(self, kind: str, gap_s: float, seed: int = 0,
                 burstiness: float = 4.0,
                 trace: Optional[List[float]] = None):
        if kind not in ARRIVALS:
            raise ValueError(f"unknown arrival process {kind!r} "
                             f"(known: {ARRIVALS})")
        if kind == "trace" and trace is None:
            raise ValueError("trace arrivals need a trace "
                             "(list or loaded file)")
        if burstiness < 1.0:
            raise ValueError(f"burstiness must be >= 1.0, "
                             f"got {burstiness!r}")
        self.kind = kind
        self.gap_s = float(gap_s)
        self.burstiness = float(burstiness)
        self.trace = list(trace) if trace is not None else None
        self.rng = (np.random.RandomState(_arrival_seed(seed))
                    if kind in ("poisson", "bursty") else None)
        self._base_t = 0.0      # current phase start
        self._i = 0             # arrivals generated in this phase
        self._t = 0.0           # last generated arrival (stochastic)
        self._k = 0             # trace cursor
        self._burst = True      # bursty: current episode state
        self._left = 0          # bursty: arrivals left in the episode

    def realign(self, t_start: float, gap_s: float) -> None:
        """Start a new phase at ``t_start`` with inter-arrival ``gap_s``.

        For a trace the timestamps are absolute, so the clock doesn't
        move — but the caller's discard-and-regenerate protocol (a
        candidate popped before the phase change fired is thrown away
        and :meth:`next` called again) must not drop a trace arrival:
        the cursor rewinds one step so the pending candidate is
        re-delivered.  ``gap_s`` still updates (it shapes the past-end
        linear extension)."""
        self.gap_s = float(gap_s)
        if self.kind == "trace":
            self._k = max(0, self._k - 1)
            return
        self._base_t = float(t_start)
        self._t = float(t_start)
        self._i = 0

    def _episode_gap(self) -> float:
        """Bursty: the current episode's mean gap, advancing the
        two-state machine one arrival."""
        if self._left <= 0:
            self._burst = not self._burst
            self._left = 1 + int(self.rng.geometric(
                1.0 / BURST_EPISODE_MEAN))
        self._left -= 1
        return (self.gap_s / self.burstiness if self._burst
                else self.gap_s * self.burstiness)

    def next(self) -> float:
        """Generate the next arrival timestamp (non-decreasing within a
        phase; across phases, non-decreasing whenever ``realign`` targets
        a time at or after every arrival already emitted — which
        ``plan_workload`` guarantees by popping a phase change only once
        the candidate arrival reaches it)."""
        if self.kind == "linear":
            t = self._base_t + self.gap_s * self._i
            self._i += 1
            return t
        if self.kind == "trace":
            if self._k < len(self.trace):
                t = self.trace[self._k]
            else:       # past the trace end: extend linearly at gap_s
                last = self.trace[-1] if self.trace else 0.0
                t = last + self.gap_s * (self._k - len(self.trace) + 1)
            self._k += 1
            return t
        mean = (self._episode_gap() if self.kind == "bursty"
                else self.gap_s)
        self._t = self._t + (self.rng.exponential(mean) if mean > 0
                             else 0.0)
        return self._t


def hash_features(raw: np.ndarray, num_rows: int, salt: int = 0) -> np.ndarray:
    """G_P: map raw sparse ids to table row indices (multiplicative hash)."""
    x = raw.astype(np.uint64) * np.uint64(2654435761) + np.uint64(salt)
    x ^= x >> np.uint64(16)
    return (x % np.uint64(num_rows)).astype(np.int32)


# truncated-Zipf CDFs are pure functions of (num_rows, alpha): memoize so
# per-request batch generation doesn't recompute a row-space-sized cumsum
_ZIPF_CDF: Dict[Tuple[int, float], np.ndarray] = {}


def zipf_row_cdf(num_rows: int, alpha: float) -> np.ndarray:
    """CDF of a truncated Zipf over ranks 1..num_rows: P(k) ~ 1/k^alpha."""
    key = (int(num_rows), float(alpha))
    cdf = _ZIPF_CDF.get(key)
    if cdf is None:
        w = 1.0 / np.arange(1, num_rows + 1, dtype=np.float64) ** alpha
        cdf = np.cumsum(w)
        cdf /= cdf[-1]
        _ZIPF_CDF[key] = cdf
    return cdf


def zipf_indices(rng: np.random.RandomState, shape, num_rows: int,
                 alpha: float) -> np.ndarray:
    """Zipf-skewed row indices: rank k (0 = hottest row) drawn with
    probability ~ 1/(k+1)^alpha via inverse-CDF sampling.  Row id == rank,
    so the hot set of every table is its low row ids — a deterministic,
    seed-stable convention the cache/placement layers can be tested
    against."""
    u = rng.uniform(size=shape)
    return np.searchsorted(zipf_row_cdf(num_rows, alpha), u,
                           side="right").astype(np.int32)


def dlrm_batch(cfg, batch: int, rng: np.random.RandomState,
               pooling_sigma: float = 0.3, alpha: float = 0.0):
    """Synthetic click-log batch for a DLRM config: dense features,
    per-table pooled index lists (-1 padded), labels.

    ``alpha > 0`` switches index generation from uniform hashing to a
    truncated Zipf over each table's rows (the skewed production access
    pattern); ``alpha = 0`` keeps the exact uniform-hash RNG stream of
    earlier revisions, so seeded goldens are unaffected."""
    r = cfg.dlrm
    dense = rng.randn(batch, r.num_dense_features).astype(np.float32)
    P = r.avg_pooling
    if alpha > 0.0:
        idx = zipf_indices(rng, (batch, r.num_tables, P),
                           r.rows_per_table, alpha)
    else:
        raw = rng.randint(0, 1 << 31, size=(batch, r.num_tables, P))
        idx = hash_features(raw, r.rows_per_table)
    # variable pooling: mask out a lognormal-distributed tail per bag
    lens = np.clip(rng.lognormal(np.log(max(P * 0.7, 1.0)), pooling_sigma,
                                 size=(batch, r.num_tables)), 1, P)
    mask = np.arange(P)[None, None, :] < lens[..., None]
    idx = np.where(mask, idx, -1).astype(np.int32)
    labels = rng.binomial(1, 0.2, size=batch).astype(np.int32)
    return {"dense": dense, "indices": idx, "labels": labels}


def dlrm_request_stream(cfg, n: int, seed: int = 0,
                        dist: QueryDist = None,
                        gap_s: float = 0.002,
                        arrival: str = "linear",
                        burstiness: float = 4.0,
                        trace: Optional[List[float]] = None) -> List[Tuple]:
    """Standard seeded DLRM request stream: (rid, payload, size, arrival)
    tuples ready to splat into ``serving.engine.Request``.

    One explicit ``np.random.RandomState(seed)`` drives sizes and
    payloads — the single sanctioned way for benches/launchers to build
    engine workloads, so two builds from the same seed are identical
    (``ClusterConfig.seed`` threads the same convention through the
    engine).  ``dist.alpha`` selects the Zipf row-popularity skew;
    ``arrival`` selects the :class:`ArrivalProcess` (the arrival RNG is
    a separate derived stream, so every process yields byte-identical
    payloads — only the timestamps move, and ``linear`` reproduces the
    historical ``gap_s * i`` spacing bit-for-bit)."""
    rng = np.random.RandomState(seed)
    qd = dist or QueryDist(mean_size=8.0, max_size=64)
    proc = ArrivalProcess(arrival, gap_s, seed=seed,
                          burstiness=burstiness, trace=trace)
    sizes = qd.sample(rng, n)
    reqs = []
    for i, s in enumerate(sizes):
        b = dlrm_batch(cfg, int(s), rng, alpha=qd.alpha)
        reqs.append((i, {"dense": b["dense"], "indices": b["indices"]},
                     int(s), proc.next()))
    return reqs


def lm_batch(vocab: int, batch: int, seq: int, rng: np.random.RandomState):
    """Synthetic token stream (zipf-ish unigram) for LM train smoke."""
    p = 1.0 / np.arange(1, vocab + 1) ** 1.1
    p /= p.sum()
    toks = rng.choice(vocab, size=(batch, seq + 1), p=p).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ShardedLoader:
    """Deterministic per-host data sharding: host i of k reads every k-th
    batch (the standard multi-pod input pipeline contract)."""

    def __init__(self, gen_fn, host_id: int = 0, num_hosts: int = 1,
                 seed: int = 0):
        self.gen = gen_fn
        self.host = host_id
        self.k = num_hosts
        self.seed = seed

    def __iter__(self) -> Iterator:
        step = 0
        while True:
            rng = np.random.RandomState(
                (self.seed * 9973 + step * self.k + self.host) % (1 << 31))
            yield self.gen(rng)
            step += 1
