"""Hardware constants: paper Tables I/II + measured bandwidths (§III),
and the TPU v5e targets used for the roofline analysis.

All prices are the paper's public market prices; the MN ASIC price is not
given in the paper — we model it at $1.5K (documented assumption; its
power is the paper's 23.9 W figure).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

# ------------------------------------------------------------ paper Table II
DEVICE_PRICE = {                     # USD
    "icelake": 4_500.0,
    "cooperlake": 2_500.0,
    "a100": 13_500.0,
    "ddr4_16gb": 80.0,
    "ddr4_64gb": 350.0,
    "nmp_64gb": 700.0,               # assumed 2x DDR (paper Table II)
    "nic": 2_500.0,
    "mn_asic": 1_500.0,              # modeled (not in Table II)
}

DEVICE_TDP_W = {
    "icelake": 270.0,
    "cooperlake": 86.0,
    "a100": 400.0,
    "ddr4_16gb": 5.0,
    "ddr4_64gb": 24.0,
    "nmp_64gb": 24.0,
    "nic": 20.0,
    "mn_asic": 23.9,
}

# --------------------------------------------------- measured bandwidths §III
LOCAL_MEM_BW = 145e9                 # B/s per socket, peak
NUMA_LOCAL_BW = 93e9                 # B/s achieved local half (Fig. 4b)
NUMA_REMOTE_BW = 52e9                # B/s achieved via UPI (Fig. 4b)
UPI_BW = 55e9
NIC_BW = 25e9                        # back-end RDMA, ~200Gbps ConnectX-6
NMP_SPEEDUP = 4.0                    # DIMM- + rank-level parallelism
# CN-side hot-row cache lives in the accelerator's HBM (A100 40GB class);
# probe + hit service run at this bandwidth on the virtual clock
CN_HBM_BW = 1.555e12
CACHE_TAG_BYTES = 16                 # per-probe tag/metadata traffic
# sustained dense-MLP FLOP/s: ranking MLPs are low-arithmetic-intensity
# (batch <= a few hundred rows); ~8% of peak is typical (calibrated so
# RM2's DenseNet binds GPUs, reproducing Fig. 10/13's compute regime)
A100_EFF_FLOPS = 25e12
CPU_PREPROC_RATE = 1.0e8             # hash ops/s/core (calibrated, G_P)
ICELAKE_CORES = 40
COOPERLAKE_CORES = 26

ELECTRICITY_RATE = 0.10 / 3.6e6      # USD per Joule ($0.10/kWh)
LIFETIME_YEARS = 3.0

# daily machine failure rates (Fig. 9 / §VI-C)
FAIL_GPU_SERVER = 0.07               # monolithic (follows least-reliable part)
FAIL_CN = 0.07
FAIL_MN = 0.0004
LOAD_VARIANCE_R = 0.05               # R% over-provision for load variance

# ------------------------------------------------------------------- nodes


@dataclass(frozen=True)
class NodeType:
    name: str
    kind: str                        # mono | cn | mn
    cpus: Tuple[str, ...] = ()
    gpus: int = 0
    dimms: Dict[str, int] = field(default_factory=dict)
    nics: int = 1
    asic: bool = False
    nmp: bool = False                # near-memory processing (pools on-node)
    mem_bw: float = LOCAL_MEM_BW     # embedding-scan bandwidth
    mem_capacity: float = 0.0        # bytes usable for embeddings

    @property
    def capex(self) -> float:
        c = sum(DEVICE_PRICE[x] for x in self.cpus)
        c += self.gpus * DEVICE_PRICE["a100"]
        c += sum(n * DEVICE_PRICE[d] for d, n in self.dimms.items())
        c += self.nics * DEVICE_PRICE["nic"]
        if self.asic:
            c += DEVICE_PRICE["mn_asic"]
        return c

    @property
    def power(self) -> float:
        p = sum(DEVICE_TDP_W[x] for x in self.cpus)
        p += self.gpus * DEVICE_TDP_W["a100"]
        p += sum(n * DEVICE_TDP_W[d] for d, n in self.dimms.items())
        p += self.nics * DEVICE_TDP_W["nic"]
        if self.asic:
            p += DEVICE_TDP_W["mn_asic"]
        return p


TB = 1024 ** 4
GB = 1024 ** 3


def _mk(name, **kw) -> NodeType:
    return NodeType(name=name, **kw)


NODE_TYPES: Dict[str, NodeType] = {
    # monolithic scale-up: 2 sockets, 2TB, 8 GPUs
    "su2s": _mk("su2s", kind="mono", cpus=("icelake", "icelake"), gpus=8,
                dimms={"ddr4_64gb": 32}, nics=2,
                mem_bw=2 * LOCAL_MEM_BW, mem_capacity=1.8 * TB),
    # monolithic scale-out: 1 socket, 1TB, 1/2/4 GPUs
    "so1s_1g": _mk("so1s_1g", kind="mono", cpus=("icelake",), gpus=1,
                   dimms={"ddr4_64gb": 16}, nics=3,
                   mem_bw=LOCAL_MEM_BW, mem_capacity=0.9 * TB),
    "so1s_2g": _mk("so1s_2g", kind="mono", cpus=("icelake",), gpus=2,
                   dimms={"ddr4_64gb": 16}, nics=3,
                   mem_bw=LOCAL_MEM_BW, mem_capacity=0.9 * TB),
    "so1s_4g": _mk("so1s_4g", kind="mono", cpus=("icelake",), gpus=4,
                   dimms={"ddr4_64gb": 16}, nics=3,
                   mem_bw=LOCAL_MEM_BW, mem_capacity=0.9 * TB),
    # NMP variants of monolithic scale-out
    "so1s_1g_nmp": _mk("so1s_1g_nmp", kind="mono", cpus=("icelake",), gpus=1,
                       dimms={"nmp_64gb": 16}, nics=3, nmp=True,
                       mem_bw=NMP_SPEEDUP * LOCAL_MEM_BW, mem_capacity=0.9 * TB),
    "so1s_4g_nmp": _mk("so1s_4g_nmp", kind="mono", cpus=("icelake",), gpus=4,
                       dimms={"nmp_64gb": 16}, nics=3, nmp=True,
                       mem_bw=NMP_SPEEDUP * LOCAL_MEM_BW, mem_capacity=0.9 * TB),
    # disaggregated compute nodes
    "cn_1g": _mk("cn_1g", kind="cn", cpus=("cooperlake",), gpus=1,
                 dimms={"ddr4_16gb": 4}, nics=2, mem_capacity=0),
    "cn_4g": _mk("cn_4g", kind="cn", cpus=("cooperlake",), gpus=4,
                 dimms={"ddr4_16gb": 4}, nics=2, mem_capacity=0),
    # disaggregated memory nodes
    "ddr_mn": _mk("ddr_mn", kind="mn", asic=True,
                  dimms={"ddr4_64gb": 16}, nics=1,
                  mem_bw=LOCAL_MEM_BW, mem_capacity=0.95 * TB),
    "nmp_mn": _mk("nmp_mn", kind="mn", asic=True, nmp=True,
                  dimms={"nmp_64gb": 16}, nics=1,
                  mem_bw=NMP_SPEEDUP * LOCAL_MEM_BW, mem_capacity=0.95 * TB),
}

# -------------------------------------------------------- TPU v5e (roofline)
TPU_PEAK_FLOPS = 197e12              # bf16 per chip
TPU_HBM_BW = 819e9                   # B/s per chip
TPU_ICI_BW = 50e9                    # B/s per link
TPU_HBM_BYTES = 16 * GB
