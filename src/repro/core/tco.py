"""TCO accounting across model generations (paper §VI, Figs. 10-14)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core import hardware as hw
from repro.core.allocator import AllocationPlan, allocate_from_model, best_unit
from repro.core.serving_unit import ServingUnitModel, UnitSpec


def monolithic_candidates(max_servers: int = 16) -> List[UnitSpec]:
    out = []
    for n in range(1, max_servers + 1):
        for t in ("so1s_1g", "so1s_2g", "so1s_4g"):
            out.append(UnitSpec(n=n, cn_type=t, scheme="distributed"))
    out.append(UnitSpec(n=1, cn_type="su2s", scheme="su_numa"))
    out.append(UnitSpec(n=1, cn_type="su2s", scheme="su_naive"))
    return out


def monolithic_nmp_candidates(max_servers: int = 16) -> List[UnitSpec]:
    out = []
    for n in range(1, max_servers + 1):
        for t in ("so1s_1g_nmp", "so1s_4g_nmp"):
            out.append(UnitSpec(n=n, cn_type=t, scheme="distributed"))
    return out


def disagg_candidates(max_cn: int = 8, max_mn: int = 16,
                      mn_type: str = "ddr_mn") -> List[UnitSpec]:
    out = []
    for n in range(1, max_cn + 1):
        for m in range(1, max_mn + 1):
            for cn in ("cn_1g", "cn_4g"):
                out.append(UnitSpec(n=n, cn_type=cn, m=m, mn_type=mn_type,
                                    scheme="disagg"))
    return out


@dataclass
class GenerationResult:
    model_name: str
    plan: AllocationPlan
    tco: float


def evolution_study(generations: Sequence, candidates_fn, peak_load: float,
                    sla: float = 0.1) -> List[GenerationResult]:
    """Optimal unit per generation; returns per-generation TCO (Fig. 13/14)."""
    out = []
    for g in generations:
        plan, _ = best_unit(g, candidates_fn(), peak_load, sla=sla)
        out.append(GenerationResult(g.name, plan, plan.tco))
    return out


def idleness_breakdown(model, unit: UnitSpec, peak_load: float,
                       sla: float = 0.1) -> Dict[str, float]:
    """Paper Fig. 11: % of TCO wasted on (a) over-provisioned capacity for
    failures+diurnal gap, (b) unbalanced-pipeline idleness inside servers."""
    sm = ServingUnitModel(model, unit)
    qps, b = sm.latency_bounded_qps(sla=sla)
    plan = allocate_from_model(model, unit, peak_load, sla=sla)
    st = sm.stage_times(b or 256)
    bott = st.bottleneck()
    # fraction of each resource idle while pipeline is bottlenecked
    idle_pre = 1.0 - st.t_pre / bott
    idle_dense = 1.0 - st.t_dense / bott
    idle_sparse = 1.0 - st.t_sparse / bott
    # cost weights: CPU vs GPU vs memory share of the unit capex
    cn = unit.cn
    cpu_cost = sum(hw.DEVICE_PRICE[c] for c in cn.cpus) * unit.n
    gpu_cost = cn.gpus * hw.DEVICE_PRICE["a100"] * unit.n
    mem_cost = sum(nn * hw.DEVICE_PRICE[d] for d, nn in cn.dimms.items()) * unit.n
    if unit.scheme == "disagg":
        mn = unit.mn
        mem_cost += unit.m * mn.capex
    total_cost = unit.capex()
    idle_frac = (0.5 * cpu_cost * idle_pre + gpu_cost * idle_dense
                 + mem_cost * idle_sparse + 0.5 * cpu_cost * idle_sparse
                 ) / total_cost
    over_frac = plan.failure_units / max(plan.n_peak, 1)
    return {
        "pipeline_idle_tco_frac": idle_frac,
        "overprovision_tco_frac": over_frac,
        "batch": float(b),
        "qps": qps,
    }
