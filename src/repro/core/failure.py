"""Machine failure modeling (paper §IV-D, Fig. 9).

Four daily machine states: available all day, inaccessible all day,
recovers mid-day, fails mid-day. Backup machines absorb the fourth
category. Deterministic seeded generator (no wall-clock use).
"""
from __future__ import annotations

import random as _random
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.core import hardware as hw


@dataclass(frozen=True)
class FailureEvent:
    node_id: int
    kind: str          # "cn" | "mn" | "mono"
    time_s: float      # within-day failure time


class FailureTrace:
    """Daily failure sampling for a fleet."""

    def __init__(self, n_nodes: int, kind: str, daily_rate: float, seed: int = 0):
        self.n = n_nodes
        self.kind = kind
        self.rate = daily_rate
        self.rng = _random.Random(seed ^ hash(kind) & 0xFFFF)

    def sample_day(self) -> List[FailureEvent]:
        out = []
        for i in range(self.n):
            if self.rng.random() < self.rate:
                out.append(FailureEvent(i, self.kind,
                                        self.rng.random() * 86400.0))
        return sorted(out, key=lambda e: e.time_s)


def unit_failure_rate(n_cn: int, m_mn: int,
                      f_cn: float = hw.FAIL_CN,
                      f_mn: float = hw.FAIL_MN) -> float:
    """Weighted per-node failure rate of a disaggregated unit (Eq. 2)."""
    return (f_cn * n_cn + f_mn * m_mn) / (n_cn + m_mn)


def expected_backups(n_units: int, n_cn: int, m_mn: int,
                     scheme: str = "disagg") -> float:
    """Mean backup nodes/day for a fleet of serving units."""
    if scheme == "disagg":
        return n_units * (n_cn * hw.FAIL_CN + m_mn * hw.FAIL_MN)
    return n_units * n_cn * hw.FAIL_GPU_SERVER


def recovery_cost_s(kind: str) -> float:
    """Time to restore service after a failure (migration / re-route).

    CN failure: migrate the primary task to a backup node (restore model
    replica + warm-up). MN failure with surviving replicas: rebuild the
    MemAccess routing table only (fast). Monolithic: full server migration.
    """
    return {"cn": 120.0, "mn": 5.0, "mono": 180.0}[kind]
