"""Query scheduling (paper §IV-C): sequential vs interleaved processing.

`Batcher` implements the paper's ingress behavior: large queries split
into sub-batches, small queries fused into one batch (Fig. 3a). The two
MN scheduling policies are consumed by serving/simulator.py:

interleaved: each MN serves packets FCFS independently — packets of
             different queries interleave; every in-flight query finishes
             late (head-of-line blocking across queries).
sequential:  the global task manager runs one query's packets on all MNs
             in lock step; the next query starts only when the previous
             query's embedding ops complete on every MN.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

INTERLEAVED = "interleaved"
SEQUENTIAL = "sequential"


@dataclass
class Query:
    qid: int
    arrival: float
    size: int                     # candidate items to rank
    # filled by the pipeline
    batch_id: int = -1
    done: float = -1.0


@dataclass
class Batch:
    bid: int
    queries: List[Query]
    formed_at: float
    size: int
    # (query, rows contributed) in row order — lets an engine slice each
    # query's payload rows out of the fused batch (split queries appear in
    # several batches; their contributions are consumed FIFO)
    parts: List[Tuple[Query, int]] = field(default_factory=list)
    # owning model under fleet serving (0 for single-model streams)
    model: int = 0


class Batcher:
    """Split/fuse incoming queries into fixed-size batches.

    Under fleet serving each model gets its own ingress Batcher; `model`
    tags the emitted batches and `bid_start`/`bid_step` stride the batch
    id space so ids stay globally unique across per-model batchers (the
    defaults reproduce the single-batcher id sequence exactly).
    """

    def __init__(self, batch_size: int, max_wait_s: float = 0.005,
                 model: int = 0, bid_start: int = 0, bid_step: int = 1):
        self.batch_size = batch_size
        self.max_wait = max_wait_s
        self.model = model
        self._pending: List[Tuple[Query, int]] = []   # (query, remaining)
        self._pending_since: Optional[float] = None
        self._next_bid = bid_start
        self._bid_step = bid_step

    def offer(self, q: Query, now: float) -> List[Batch]:
        """Add a query; return any batches that became full."""
        remaining = q.size
        out = []
        self._pending.append((q, remaining))
        if self._pending_since is None:
            self._pending_since = now
        while self._pending_total() >= self.batch_size:
            out.append(self._form(now))
        return out

    def flush(self, now: float) -> List[Batch]:
        """Emit a partial batch if max_wait elapsed. Compares against
        next_deadline() so `flush(next_deadline())` always fires (the
        subtraction form can miss by one ulp)."""
        deadline = self.next_deadline()
        if deadline is not None and now >= deadline:
            return [self._form(now)]
        return []

    def next_deadline(self) -> Optional[float]:
        if self._pending and self._pending_since is not None:
            return self._pending_since + self.max_wait
        return None

    def _pending_total(self) -> int:
        return sum(r for _, r in self._pending)

    def _form(self, now: float) -> Batch:
        take = self.batch_size
        members: List[Query] = []
        parts: List[Tuple[Query, int]] = []
        kept: List[Tuple[Query, int]] = []
        used = 0
        for q, rem in self._pending:
            if take <= 0:
                kept.append((q, rem))
                continue
            grab = min(rem, take)
            take -= grab
            used += grab
            members.append(q)
            parts.append((q, grab))
            if rem - grab > 0:
                kept.append((q, rem - grab))
        self._pending = kept
        # a kept remainder is fresh work: restart its flush clock at the
        # forming instant, or a long-waiting head query would leave the
        # remainder's deadline already in the past and drain loops would
        # emit degenerate partial batches instead of waiting max_wait_s
        self._pending_since = now if kept else None
        b = Batch(self._next_bid, members, now, used, parts,
                  model=self.model)
        self._next_bid += self._bid_step
        return b
