"""DisaggRec core: the paper's contributions as composable modules.

C1 near-memory reduction ........ core.sharding (+ kernels/embedding_bag)
C2 embedding management ......... core.embedding_manager
C3 sequential query processing .. core.scheduler (+ serving.simulator)
C4 failure-aware allocation ..... core.allocator, core.failure
C5/C6 TCO + heterogeneity ....... core.tco, core.hardware
"""
from repro.core import (allocator, embedding_manager, failure, hardware,
                        scheduler, serving_unit, sharding, tco)  # noqa: F401
