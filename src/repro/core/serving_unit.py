"""Serving-unit performance/cost model (paper §IV-A, §V).

A serving unit is {n CNs, m MNs} (disaggregated) or n monolithic servers.
The analytic model produces stage latencies, peak and latency-bounded
throughput (hill-climbing pressure test, §III-C), power and capex — the
inputs QPS_{M,S} / Power_{M,S} to the failure-aware allocator (§IV-D).

Stage model (per query of `q` samples):
  G_P  preprocess  : hash ops on CN/host CPUs
  comm (indices)   : CN -> MNs scatter over back-end NICs / UPI
  G_S  SparseNet   : table scans at MN memory bandwidth (near-memory
                     reduction: only pooled Fsum returns)
  comm (Fsum)      : MNs -> CN gather
  G_D  DenseNet    : MLPs+interaction on CN GPUs

Queries pipeline across stages; latency-bounded QPS sweeps (batch, rate)
like the paper's pressure test, with an M/D/1-style queueing estimate
validated by the discrete-event simulator (serving/simulator.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.configs import counting
from repro.configs.base import ModelConfig
from repro.core import hardware as hw
from repro.core.hardware import NODE_TYPES, NodeType


@dataclass(frozen=True)
class UnitSpec:
    """{n CNs, m MNs} or (n monolithic servers, m=0).

    `mn_types` makes the MN pool heterogeneous: one node-type name per
    MN (length m), e.g. ("ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn").  When
    omitted every MN is `mn_type`, reproducing the homogeneous model
    bit-for-bit.
    """
    n: int
    cn_type: str
    m: int = 0
    mn_type: str = "ddr_mn"
    scheme: str = "disagg"        # disagg | distributed | su_naive | su_numa
    mn_types: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if self.mn_types is not None:
            object.__setattr__(self, "mn_types", tuple(self.mn_types))
            if len(self.mn_types) != self.m:
                raise ValueError(
                    f"mn_types has {len(self.mn_types)} entries for m={self.m}")

    @property
    def cn(self) -> NodeType:
        return NODE_TYPES[self.cn_type]

    @property
    def mn(self) -> NodeType:
        return NODE_TYPES[self.mn_type]

    def mn_node_types(self) -> Tuple[NodeType, ...]:
        names = self.mn_types or (self.mn_type,) * self.m
        return tuple(NODE_TYPES[t] for t in names)

    def capex(self) -> float:
        return (self.n * self.cn.capex
                + sum(mn.capex for mn in self.mn_node_types()))

    def power(self) -> float:
        return (self.n * self.cn.power
                + sum(mn.power for mn in self.mn_node_types()))

    def nodes(self) -> int:
        return self.n + self.m

    def mem_capacity(self) -> float:
        return (self.n * self.cn.mem_capacity
                + sum(mn.mem_capacity for mn in self.mn_node_types()))


@dataclass
class StageTimes:
    t_pre: float
    t_comm_in: float
    t_sparse: float
    t_comm_out: float
    t_dense: float

    def total(self) -> float:
        return (self.t_pre + self.t_comm_in + self.t_sparse
                + self.t_comm_out + self.t_dense)

    def bottleneck(self) -> float:
        return max(self.t_pre, self.t_comm_in + self.t_comm_out,
                   self.t_sparse, self.t_dense)


class ServingUnitModel:
    def __init__(self, model: ModelConfig, unit: UnitSpec,
                 routing_imbalance: float = 1.0):
        assert model.family == "dlrm"
        self.model = model
        self.unit = unit
        self.imbalance = max(1.0, routing_imbalance)
        r = model.dlrm
        self.sparse_bytes = counting.dlrm_sparse_bytes(model)
        self.dense_flops = counting.dlrm_dense_flops(model)
        self.idx_bytes = r.num_tables * r.avg_pooling * 4
        self.fsum_bytes = r.num_tables * r.embed_dim * 4
        self.hash_ops = r.num_tables * r.avg_pooling
        self.size_bytes = counting.dlrm_size_bytes(model)

    # ------------------------------------------------------------ checks
    def fits(self) -> bool:
        return self.unit.mem_capacity() >= self.size_bytes

    def _sparse_bw_latency(self) -> float:
        """Aggregate bandwidth serving one batch's embedding scan."""
        u = self.unit
        if u.scheme == "su_naive":
            per_socket = 1.0 / (0.5 / hw.NUMA_LOCAL_BW + 0.5 / hw.NUMA_REMOTE_BW)
            return 2 * per_socket
        if u.scheme == "su_numa":
            return 2 * hw.LOCAL_MEM_BW
        if u.scheme == "distributed":
            return u.n * u.cn.mem_bw
        return sum(mn.mem_bw for mn in u.mn_node_types())

    def _cn_cores(self) -> int:
        cn = self.unit.cn
        cores = (hw.ICELAKE_CORES if "icelake" in cn.cpus
                 else hw.COOPERLAKE_CORES) // 2        # half: G_P thread
        if self.unit.scheme in ("su_naive", "su_numa"):
            cores *= len(cn.cpus)
        return cores

    # ------------------------------------------------------- stage times
    def stage_times(self, batch: int) -> StageTimes:
        """Latency of ONE batch through ONE CN's pipeline (MNs shared)."""
        u = self.unit
        t_pre = batch * self.hash_ops / (self._cn_cores() * hw.CPU_PREPROC_RATE)
        t_sparse = batch * self.sparse_bytes * self.imbalance / self._sparse_bw_latency()
        if u.scheme == "su_naive":
            t_comm_in = t_comm_out = 0.0
        else:
            comm_bw = hw.UPI_BW if u.scheme == "su_numa" else hw.NIC_BW
            t_comm_in = batch * self.idx_bytes / comm_bw
            t_comm_out = batch * self.fsum_bytes / comm_bw
        gpus = max(u.cn.gpus, 1)
        t_dense = batch * self.dense_flops / (gpus * hw.A100_EFF_FLOPS)
        return StageTimes(t_pre, t_comm_in, t_sparse, t_comm_out, t_dense)

    # -------------------------------------------------------- throughput
    def capacities(self) -> Dict[str, float]:
        """Aggregate per-resource capacity (samples/s): n CN streams run
        concurrently, the MN pool (or server memory) is shared."""
        u = self.unit
        n = 1 if u.scheme in ("su_naive", "su_numa") else u.n
        cap = {
            "pre": n * self._cn_cores() * hw.CPU_PREPROC_RATE / self.hash_ops,
            "sparse": self._sparse_bw_latency()
                      / (self.sparse_bytes * self.imbalance),
            "dense": n * max(u.cn.gpus, 1) * hw.A100_EFF_FLOPS
                     / max(self.dense_flops, 1),
        }
        if u.scheme != "su_naive":
            comm_bw = hw.UPI_BW if u.scheme == "su_numa" else hw.NIC_BW
            cap["comm"] = n * comm_bw / (self.idx_bytes + self.fsum_bytes)
        return cap

    def peak_qps(self, batch: int = 256) -> float:
        """Pipelined peak (samples/s) over all CN streams."""
        return min(self.capacities().values())

    def latency(self, batch: int, rate: float) -> float:
        """Mean query latency at arrival rate `rate` (samples/s):
        M/D/1-ish wait on the bottleneck resource + pipeline traversal."""
        st = self.stage_times(batch)
        cap = self.peak_qps(batch)
        rho = min(rate / cap, 0.9999)
        wait = rho / (2.0 * (1.0 - rho)) * (batch / cap)
        batching_delay = 0.5 * batch / max(rate, 1e-9)
        return min(batching_delay, 0.05) + wait + st.total()

    def p95_latency(self, batch: int, rate: float) -> float:
        # heavy-tailed query sizes push p95 ~3x the mean wait (calibrated
        # against the DES); pipeline time is deterministic.
        st = self.stage_times(batch)
        cap = self.peak_qps(batch)
        rho = min(rate / cap, 0.9999)
        wait95 = 3.0 * rho / (2.0 * (1.0 - rho)) * (batch / cap)
        batching_delay = 0.5 * batch / max(rate, 1e-9)
        return min(batching_delay, 0.05) + wait95 + st.total()

    def latency_bounded_qps(self, sla: float = 0.1,
                            batches=(32, 64, 128, 256, 512, 1024, 2048),
                            ) -> Tuple[float, int]:
        """Paper's hill-climbing pressure test: sweep batch sizes; for each,
        binary-search the max rate with p95 <= SLA; return the best."""
        best, best_b = 0.0, 0
        for b in batches:
            if self.stage_times(b).total() > sla:
                continue
            lo, hi = 0.0, self.peak_qps(b)
            for _ in range(40):
                mid = 0.5 * (lo + hi)
                if self.p95_latency(b, mid) <= sla:
                    lo = mid
                else:
                    hi = mid
            if lo > best:
                best, best_b = lo, b
        return best, best_b


def sequential_vs_interleaved_gain() -> float:
    """Documented paper claim (Fig. 8b): sequential scheduling sustains
    ~28% higher latency-bounded throughput; the DES reproduces this."""
    return 0.28
