"""DisaggRec's communication pattern as JAX collectives (C1).

`disagg_embedding_lookup` is the production-path embedding op: tables are
table-sharded over the ``model`` mesh axis (shards = memory nodes, laid
out by the greedy allocator), every shard pools **locally** (near-memory
reduction — optionally via the Pallas embedding_bag kernel), and only the
pooled Fsum crosses the interconnect via one all-gather. The indices
scatter is implicit: index tensors are replicated over the model axis
(they are tiny: P*4 bytes per bag vs P*D*4 gathered rows — the paper's
core traffic argument).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import embedding_manager as em
from repro.distributed import sharding as shd


def permutation_from_assignment(shards: List[List[int]], n_tables: int):
    """Flatten per-shard table lists into a permutation + inverse."""
    perm = [t for sh in shards for t in sh]
    assert sorted(perm) == list(range(n_tables)), "not a permutation"
    inv = np.empty(n_tables, np.int32)
    for pos, t in enumerate(perm):
        inv[t] = pos
    return np.asarray(perm, np.int32), inv


def disagg_embedding_lookup(tables, idx, mesh=None, axis: str = "model",
                            use_kernel: bool = False):
    """tables: (T, R, D) sharded on T over `axis`; idx: (B, T, P) int32
    (-1 padded). Returns pooled (B, T, D), gathered over `axis`.

    Without a mesh this is the reference single-host path.
    """
    from repro.models.dlrm import embedding_bag_ref

    def pool(tbl, ix):
        if use_kernel:
            from repro.kernels import ops as kops
            return kops.embedding_bag(tbl, ix)
        return embedding_bag_ref(tbl, ix)

    if mesh is None or axis not in mesh.shape or mesh.shape[axis] == 1:
        return pool(tables, idx)

    n_shards = mesh.shape[axis]
    T = tables.shape[0]
    assert T % n_shards == 0, (T, n_shards)
    from repro.models.layers import batch_pspec_entry
    bspec = batch_pspec_entry(idx.shape[0], mesh)

    def local_fn(tbl, ix):
        # tbl: (T_loc, R, D); ix: (B_loc, T, P) -> slice own tables
        shard = jax.lax.axis_index(axis)
        t_loc = tbl.shape[0]
        ix_loc = jax.lax.dynamic_slice_in_dim(ix, shard * t_loc, t_loc, 1)
        pooled = pool(tbl, ix_loc)                     # (B_loc, T_loc, D)
        # Fsum all-gather: only pooled vectors cross the network
        return jax.lax.all_gather(pooled, axis, axis=1, tiled=True)

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis, None, None), P(bspec, None, None)),
        out_specs=P(bspec, None, None),
        check_rep=False,
    )(tables, idx)


def greedy_table_layout(model_cfg, m: int, n_tasks: int = 1,
                        heterogeneous_seed: Optional[int] = None):
    """Run the paper's greedy allocation+routing for a DLRM config and
    return (perm, inv_perm, alloc, routing) for `m` shards."""
    r = model_cfg.dlrm
    rng = np.random.RandomState(heterogeneous_seed or 0)
    tables = []
    for t in range(r.num_tables):
        rows = r.rows_per_table
        if heterogeneous_seed is not None:
            rows = int(r.rows_per_table * float(rng.lognormal(0.0, 0.5)))
        tables.append(em.TableInfo(t, rows, r.embed_dim,
                                   r.avg_pooling, 4))
    cap = sum(t.size_bytes for t in tables)
    caps = [cap // m + cap // (4 * m)] * m     # capacity for ~1.25 replicas
    alloc = em.allocate_greedy(tables, caps)
    routing = em.route_greedy(tables, alloc, n_tasks, m)
    shards = em.shard_assignment(alloc, routing, r.num_tables, m)
    # balance shard cardinality for the stacked-array layout (pad by moving
    # tables from over-full shards — routing stays balanced by bytes)
    want = r.num_tables // m
    overflow = []
    for sh in shards:
        while len(sh) > want:
            overflow.append(sh.pop())
    for sh in shards:
        while len(sh) < want:
            sh.append(overflow.pop())
    perm, inv = permutation_from_assignment(shards, r.num_tables)
    return perm, inv, alloc, routing
