"""Failure-aware resource allocation (paper §IV-D, Eq. 1-3).

    Minimize  N_peak * Capex_S + sum_t P(t) * Rate_E           (1)
    s.t.      N(t) >= (1+R%) * load(t)/QPS_{M,S}
                    + (F_CN%*n + F_MN%*m)/(n+m) * load_peak/QPS (2)
              P(t) >= Power_{M,S} * N(t)                        (3)

QPS_{M,S} and Power_{M,S} come from offline characterization
(core/serving_unit.py or measured). Loads are diurnal (Fig. 2b).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.core import hardware as hw
from repro.core.serving_unit import ServingUnitModel, UnitSpec


def diurnal_load(peak: float, steps: int = 96) -> List[float]:
    """24h load curve (Fig. 2b): trough ~40% of peak, peak at 6pm."""
    out = []
    for i in range(steps):
        t = i / steps * 24.0
        out.append(peak * (0.7 + 0.3 * math.sin(2 * math.pi * (t - 12.0) / 24.0)))
    return out


@dataclass
class AllocationPlan:
    unit: UnitSpec
    qps_per_unit: float
    n_units: List[int]            # N(t) per step
    n_peak: int
    capex: float
    opex: float                   # energy over the evaluation horizon
    tco: float
    failure_units: float          # over-provision attributable to failures
    idle_units: float             # mean (N_peak - N(t)) gap


def allocate(unit: UnitSpec, qps_per_unit: float, power_per_unit: float,
             peak_load: float, horizon_days: float = 365.0 * hw.LIFETIME_YEARS,
             r_margin: float = hw.LOAD_VARIANCE_R,
             f_cn: float = hw.FAIL_CN, f_mn: float = hw.FAIL_MN,
             steps: int = 96) -> AllocationPlan:
    if qps_per_unit <= 0:
        raise ValueError("unit cannot serve the model (QPS=0)")
    loads = diurnal_load(peak_load, steps)
    n, m = unit.n, (unit.m if unit.scheme == "disagg" else 0)
    if unit.scheme == "disagg":
        f_rate = (f_cn * n + f_mn * m) / (n + m)
    else:
        # a monolithic server is lost when EITHER its compute or its
        # memory fails — the margin must cover both part failure rates
        f_rate = f_cn + f_mn
    fail_extra = f_rate * peak_load / qps_per_unit

    n_units = [math.ceil((1 + r_margin) * L / qps_per_unit + fail_extra)
               for L in loads]
    n_peak = max(n_units)

    step_s = 24 * 3600.0 / steps
    day_energy = sum(power_per_unit * nu * step_s for nu in n_units)  # J/day
    opex = day_energy * horizon_days * hw.ELECTRICITY_RATE
    capex = n_peak * unit.capex()
    mean_n = sum(n_units) / len(n_units)
    return AllocationPlan(
        unit=unit, qps_per_unit=qps_per_unit, n_units=n_units,
        n_peak=n_peak, capex=capex, opex=opex, tco=capex + opex,
        failure_units=fail_extra, idle_units=n_peak - mean_n,
    )


def allocate_from_model(model, unit: UnitSpec, peak_load: float,
                        sla: float = 0.1, **kw) -> AllocationPlan:
    sm = ServingUnitModel(model, unit)
    if not sm.fits():
        raise ValueError(f"{unit} cannot hold {model.name}")
    qps, _ = sm.latency_bounded_qps(sla=sla)
    return allocate(unit, qps, unit.power(), peak_load, **kw)


def best_unit(model, candidates: Sequence[UnitSpec], peak_load: float,
              sla: float = 0.1) -> Tuple[AllocationPlan, List[AllocationPlan]]:
    """Paper's design-space exploration (Fig. 12): pick min-TCO unit."""
    plans = []
    for u in candidates:
        try:
            plans.append(allocate_from_model(model, u, peak_load, sla=sla))
        except ValueError:
            continue
    if not plans:
        raise ValueError("no feasible unit for model")
    best = min(plans, key=lambda p: p.tco)
    return best, plans
