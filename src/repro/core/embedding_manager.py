"""Intelligent embedding management (paper §IV-B, Fig. 7).

Greedy **allocation**: compute nReplicas from aggregate MN capacity, then
place each table's replicas on the nReplicas MNs with the most available
capacity. Greedy **MemAccess routing**: for every (task, table), route to
the replica-holding MN with the least accumulated access bytes
(access bytes = avg pooling factor x embedding row bytes, profiled from
historical queries). The random baseline (Fig. 7d) picks both uniformly.

Failure handling (§IV-A): losing an MN re-routes to surviving replicas;
losing all replicas of any table triggers a re-initialization with backup
MNs.
"""
from __future__ import annotations

import dataclasses
import random as _random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class TableInfo:
    tid: int
    rows: int
    dim: int
    avg_pooling: float
    dtype_bytes: int = 4

    @property
    def size_bytes(self) -> int:
        return self.rows * self.dim * self.dtype_bytes

    @property
    def access_bytes(self) -> float:
        """Expected bytes touched per sample (pooling x row bytes)."""
        return self.avg_pooling * self.dim * self.dtype_bytes


@dataclass
class Allocation:
    replicas: Dict[int, List[int]]           # table id -> MN ids
    mn_used: List[int]                       # bytes allocated per MN
    n_replicas: int


@dataclass
class RoutingTable:
    # (task id, table id) -> destination MN id  (paper Fig. 7c tuple)
    routes: Dict[Tuple[int, int], int]
    mn_access: List[float]                   # accumulated access bytes/sample


def compute_n_replicas(tables: Sequence[TableInfo], capacities: Sequence[int]) -> int:
    total = sum(t.size_bytes for t in tables)
    cap = sum(capacities)
    if total == 0:
        return len(capacities)
    return max(1, min(len(capacities), int(cap // total)))


def allocate_greedy(tables: Sequence[TableInfo], capacities: Sequence[int],
                    n_replicas: Optional[int] = None) -> Allocation:
    m = len(capacities)
    nrep = n_replicas or compute_n_replicas(tables, capacities)
    used = [0] * m
    replicas: Dict[int, List[int]] = {}
    # large tables first: classic greedy bin balance
    for t in sorted(tables, key=lambda t: -t.size_bytes):
        avail = sorted(range(m), key=lambda i: capacities[i] - used[i],
                       reverse=True)[:nrep]
        for i in avail:
            used[i] += t.size_bytes
        replicas[t.tid] = sorted(avail)
    return Allocation(replicas=replicas, mn_used=used, n_replicas=nrep)


def allocate_random(tables: Sequence[TableInfo], capacities: Sequence[int],
                    n_replicas: Optional[int] = None, seed: int = 0) -> Allocation:
    rng = _random.Random(seed)
    m = len(capacities)
    nrep = n_replicas or compute_n_replicas(tables, capacities)
    used = [0] * m
    replicas: Dict[int, List[int]] = {}
    for t in tables:
        picks = rng.sample(range(m), nrep)
        for i in picks:
            used[i] += t.size_bytes
        replicas[t.tid] = sorted(picks)
    return Allocation(replicas=replicas, mn_used=used, n_replicas=nrep)


def route_greedy(tables: Sequence[TableInfo], alloc: Allocation,
                 n_tasks: int, m: int,
                 exclude: Sequence[int] = ()) -> RoutingTable:
    acc = [0.0] * m
    routes: Dict[Tuple[int, int], int] = {}
    dead = set(exclude)
    # heaviest access streams first for tighter balance
    order = sorted(tables, key=lambda t: -t.access_bytes)
    for task in range(n_tasks):
        for t in order:
            cands = [i for i in alloc.replicas[t.tid] if i not in dead]
            if not cands:
                raise LookupError(f"table {t.tid}: all replicas failed")
            dest = min(cands, key=lambda i: acc[i])
            acc[dest] += t.access_bytes
            routes[(task, t.tid)] = dest
    return RoutingTable(routes=routes, mn_access=acc)


def route_random(tables: Sequence[TableInfo], alloc: Allocation,
                 n_tasks: int, m: int, seed: int = 0,
                 exclude: Sequence[int] = ()) -> RoutingTable:
    rng = _random.Random(seed)
    acc = [0.0] * m
    routes: Dict[Tuple[int, int], int] = {}
    dead = set(exclude)
    for task in range(n_tasks):
        for t in tables:
            cands = [i for i in alloc.replicas[t.tid] if i not in dead]
            if not cands:
                raise LookupError(f"table {t.tid}: all replicas failed")
            dest = rng.choice(cands)
            acc[dest] += t.access_bytes
            routes[(task, t.tid)] = dest
    return RoutingTable(routes=routes, mn_access=acc)


def imbalance(values: Sequence[float]) -> float:
    """max/mean load ratio (1.0 = perfectly balanced)."""
    vals = [v for v in values if v > 0] or [0.0]
    mean = sum(vals) / len(vals)
    return max(vals) / mean if mean else 1.0


def rebuild_after_failure(tables: Sequence[TableInfo], alloc: Allocation,
                          n_tasks: int, m: int,
                          failed: Sequence[int],
                          backup_capacity: int = 0):
    """MN failure handling (paper Fig. 7b).

    Returns (routing, reinitialized: bool, alloc). If every table still has
    a live replica we only re-run greedy routing over survivors; otherwise
    the serving unit re-initializes: backup MNs join and allocation is
    recomputed from scratch.
    """
    dead = set(failed)
    lost = [t for t in tables
            if all(r in dead for r in alloc.replicas[t.tid])]
    if not lost:
        routing = route_greedy(tables, alloc, n_tasks, m, exclude=failed)
        return routing, False, alloc
    # re-initialize with backups replacing dead MNs; survivors must absorb
    # the full replica set, so size their capacity for it (the old per-MN
    # usage is too small once the pool shrinks)
    live = max(1, m - len(dead))
    total = sum(t.size_bytes for t in tables)
    need = (alloc.n_replicas * total) // live + max(
        (t.size_bytes for t in tables), default=0)
    caps = [0 if i in dead else max(backup_capacity, need)
            for i in range(m)]
    new_alloc = allocate_greedy(tables, caps,
                                n_replicas=min(alloc.n_replicas, live))
    routing = route_greedy(tables, new_alloc, n_tasks, m, exclude=failed)
    return routing, True, new_alloc


def shard_assignment(alloc: Allocation, routing: RoutingTable,
                     n_tables: int, m: int, task: int = 0) -> List[List[int]]:
    """Per-MN table lists for the JAX table-sharded embedding op: the MN a
    task's lookups route to is the shard that owns the table for that task."""
    shards: List[List[int]] = [[] for _ in range(m)]
    for tid in range(n_tables):
        shards[routing.routes[(task, tid)]].append(tid)
    return shards
