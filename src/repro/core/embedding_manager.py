"""Intelligent embedding management (paper §IV-B, Fig. 7).

Greedy **allocation**: compute nReplicas from aggregate MN capacity, then
place each table's replicas on the nReplicas MNs with the most available
capacity. Greedy **MemAccess routing**: for every (task, table), route to
the replica-holding MN with the least accumulated access bytes
(access bytes = avg pooling factor x embedding row bytes, profiled from
historical queries). The random baseline (Fig. 7d) picks both uniformly.

Failure handling (§IV-A): losing an MN re-routes to surviving replicas;
losing all replicas of any table triggers a re-initialization with backup
MNs.

Elastic resize (§III, Fig. 2b/11): `allocate_incremental` re-allocates a
grown/shrunk pool while keeping every surviving placement in place, and
`plan_migration` diffs two allocations into the minimal set of shard
copies that must cross the fabric — only tables whose placement changed
move.
"""
from __future__ import annotations

import dataclasses
import random as _random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple


@dataclass(frozen=True)
class TableInfo:
    tid: int
    rows: int
    dim: int
    avg_pooling: float
    dtype_bytes: int = 4

    @property
    def size_bytes(self) -> int:
        return self.rows * self.dim * self.dtype_bytes

    @property
    def access_bytes(self) -> float:
        """Expected bytes touched per sample (pooling x row bytes)."""
        return self.avg_pooling * self.dim * self.dtype_bytes


@dataclass
class Allocation:
    replicas: Dict[int, List[int]]           # table id -> MN ids
    mn_used: List[int]                       # bytes allocated per MN
    n_replicas: int


@dataclass
class RoutingTable:
    # (task id, table id) -> destination MN id  (paper Fig. 7c tuple)
    routes: Dict[Tuple[int, int], int]
    mn_access: List[float]                   # accumulated access bytes/sample


class HotnessCounter:
    """Measured per-table access stream (paper §IV-B: profiled hotness).

    The engine bumps one counter per *valid* embedding lookup it serves,
    so ``measured_access_bytes`` replaces the allocator's assumed
    ``avg_pooling``-derived access profile with what the live workload
    actually touched — hot tables then prefer DDR (where the CN row
    cache can capture their traffic) and cold capacity tables prefer
    NMP, measured rather than assumed.  The same classification
    (``hot_tables``: above-median access density) feeds cache admission
    priorities.

    ``owners`` (per-tid group id, e.g. the owning model of a fleet)
    scopes the median cut per group: without it, one model's heavy
    traffic raises the global median and silently demotes every other
    model's genuinely-hot tables to cold — the classic shared-pool
    attribution bug. ``owners=None`` is the single-group (single-model)
    behavior, unchanged.
    """

    def __init__(self, n_tables: int,
                 owners: Optional[Sequence[int]] = None):
        self.lookups = [0.0] * n_tables
        if owners is not None and len(owners) != n_tables:
            raise ValueError(f"{len(owners)} owners for {n_tables} tables")
        self.owners = list(owners) if owners is not None else None

    def update(self, tids: Sequence[int], counts: Sequence[float]) -> None:
        for t, c in zip(tids, counts):
            self.lookups[t] += float(c)

    @property
    def total(self) -> float:
        return sum(self.lookups)

    def measured_access_bytes(self, tables: Sequence[TableInfo]
                              ) -> Optional[List[float]]:
        """Per-tid observed access bytes (lookups x row bytes), indexed
        by tid; None before any lookup was observed (cold start — the
        caller falls back to the assumed profile)."""
        if not self.total:
            return None
        out = [0.0] * len(self.lookups)
        for t in tables:
            out[t.tid] = self.lookups[t.tid] * t.dim * t.dtype_bytes
        return out

    def owner_totals(self, tables: Sequence[TableInfo]) -> Dict[int, float]:
        """Measured access bytes summed per owner group (0 for all tables
        when no ``owners`` were given) — the cache-budget rebalance signal."""
        out: Dict[int, float] = {}
        for t in tables:
            o = self.owners[t.tid] if self.owners is not None else 0
            out[o] = out.get(o, 0.0) + self.lookups[t.tid] * t.dim * t.dtype_bytes
        return out

    def hot_tables(self, tables: Sequence[TableInfo]) -> Optional[Set[int]]:
        """Tables with above-median measured access density (the same
        cut ``allocate_heterogeneous`` uses); None on cold start.

        With ``owners`` the median is taken within each owner group, so
        hotness is relative to the table's own model's traffic."""
        ab = self.measured_access_bytes(tables)
        if ab is None:
            return None
        hot: Set[int] = set()
        for group in _owner_groups(tables, self.owners):
            dens = sorted(ab[t.tid] / max(t.size_bytes, 1) for t in group)
            cut = dens[len(dens) // 2] if dens else 0.0
            hot |= {t.tid for t in group
                    if ab[t.tid] / max(t.size_bytes, 1) > cut}
        return hot


def _owner_groups(tables: Sequence[TableInfo],
                  owners: Optional[Sequence[int]]) -> List[List[TableInfo]]:
    """Partition tables by owner id (one group when owners is None),
    in ascending owner order for determinism."""
    if owners is None:
        return [list(tables)]
    by: Dict[int, List[TableInfo]] = {}
    for t in tables:
        by.setdefault(owners[t.tid], []).append(t)
    return [by[o] for o in sorted(by)]


def compute_n_replicas(tables: Sequence[TableInfo], capacities: Sequence[int]) -> int:
    total = sum(t.size_bytes for t in tables)
    cap = sum(capacities)
    if total == 0:
        return len(capacities)
    return max(1, min(len(capacities), int(cap // total)))


def allocate_greedy(tables: Sequence[TableInfo], capacities: Sequence[int],
                    n_replicas: Optional[int] = None) -> Allocation:
    m = len(capacities)
    nrep = n_replicas or compute_n_replicas(tables, capacities)
    used = [0] * m
    replicas: Dict[int, List[int]] = {}
    # large tables first: classic greedy bin balance
    for t in sorted(tables, key=lambda t: -t.size_bytes):
        avail = sorted(range(m), key=lambda i: capacities[i] - used[i],
                       reverse=True)[:nrep]
        for i in avail:
            used[i] += t.size_bytes
        replicas[t.tid] = sorted(avail)
    return Allocation(replicas=replicas, mn_used=used, n_replicas=nrep)


def allocate_random(tables: Sequence[TableInfo], capacities: Sequence[int],
                    n_replicas: Optional[int] = None, seed: int = 0) -> Allocation:
    rng = _random.Random(seed)
    m = len(capacities)
    nrep = n_replicas or compute_n_replicas(tables, capacities)
    used = [0] * m
    replicas: Dict[int, List[int]] = {}
    for t in tables:
        picks = rng.sample(range(m), nrep)
        for i in picks:
            used[i] += t.size_bytes
        replicas[t.tid] = sorted(picks)
    return Allocation(replicas=replicas, mn_used=used, n_replicas=nrep)


def route_greedy(tables: Sequence[TableInfo], alloc: Allocation,
                 n_tasks: int, m: int,
                 exclude: Sequence[int] = (),
                 mn_weights: Optional[Sequence[float]] = ()) -> RoutingTable:
    """Greedy MemAccess routing; `mn_weights` makes it node-type-aware.

    A weight is the relative cost of one access byte on that MN (e.g.
    base_bw / mn_bw, so a 4x-bandwidth NMP node weighs 0.25): the greedy
    pick minimizes accumulated *cost*, steering traffic toward the
    faster replica while `mn_access` keeps reporting raw bytes. Uniform
    (or omitted) weights reproduce the homogeneous behavior exactly.
    """
    w = list(mn_weights) if mn_weights else [1.0] * m
    acc = [0.0] * m                          # raw access bytes (reported)
    cost = [0.0] * m                         # weighted bytes (decision)
    routes: Dict[Tuple[int, int], int] = {}
    dead = set(exclude)
    # heaviest access streams first for tighter balance
    order = sorted(tables, key=lambda t: -t.access_bytes)
    for task in range(n_tasks):
        for t in order:
            cands = [i for i in alloc.replicas[t.tid] if i not in dead]
            if not cands:
                raise LookupError(f"table {t.tid}: all replicas failed")
            dest = min(cands, key=lambda i: cost[i])
            acc[dest] += t.access_bytes
            cost[dest] += t.access_bytes * w[dest]
            routes[(task, t.tid)] = dest
    return RoutingTable(routes=routes, mn_access=acc)


def allocate_heterogeneous(tables: Sequence[TableInfo],
                           capacities: Sequence[int],
                           mn_types: Sequence[str],
                           n_replicas: Optional[int] = None,
                           access_bytes: Optional[Sequence[float]] = None,
                           table_groups: Optional[Sequence[int]] = None
                           ) -> Allocation:
    """Node-type-aware placement for a mixed DDR/NMP pool (paper §NMP).

    Policy: *hot* tables — high access density (access bytes per byte of
    capacity) — prefer commodity DDR MNs, where re-streaming rows is
    cheap and NMP capacity is not wasted on small tables; *capacity*
    tables (the bulk of the pool, below-median density) prefer NMP MNs,
    where their dominant row traffic is pooled on-node and never crosses
    the fabric. Replicas alternate classes, so with n_replicas >= 2
    every table keeps one copy in each class: a class-wide issue cannot
    lose a table, and node-type-aware routing can arbitrage bandwidth
    between the two copies. Homogeneous pools fall back to the plain
    greedy allocator unchanged.

    ``access_bytes`` (indexed by tid, e.g. from ``HotnessCounter.
    measured_access_bytes``) replaces each table's assumed
    ``avg_pooling``-derived access profile with measured traffic, so
    the hot/cold classification follows the live workload.

    ``table_groups`` (per-tid owner id, e.g. the owning model of a
    fleet) scopes the hot/cold median cut within each group, exactly
    mirroring ``HotnessCounter.hot_tables``: a fleet's heavy model must
    not push every other model's tables below the global median and off
    DDR. One group (or None) reproduces the historical classification.
    """
    m = len(capacities)
    if len(mn_types) != m:
        raise ValueError(f"{len(mn_types)} MN types for {m} capacities")
    nmp_ids = [i for i, t in enumerate(mn_types) if "nmp" in t]
    ddr_ids = [i for i, t in enumerate(mn_types) if "nmp" not in t]
    if not nmp_ids or not ddr_ids:
        return allocate_greedy(tables, capacities, n_replicas)
    classes = {"nmp": nmp_ids, "ddr": ddr_ids}
    # clamp like allocate_greedy's avail[:nrep]: never more replicas
    # than there are MNs to hold them
    nrep = min(n_replicas or compute_n_replicas(tables, capacities), m)

    def _ab(t: TableInfo) -> float:
        return (access_bytes[t.tid] if access_bytes is not None
                else t.access_bytes)

    cuts: Dict[int, float] = {}
    for group in _owner_groups(tables, table_groups):
        dens = sorted(_ab(t) / max(t.size_bytes, 1) for t in group)
        cut = dens[len(dens) // 2] if dens else 0.0
        for t in group:
            cuts[t.tid] = cut
    used = [0] * m
    replicas: Dict[int, List[int]] = {}
    for t in sorted(tables, key=lambda t: -t.size_bytes):
        hot = _ab(t) / max(t.size_bytes, 1) > cuts[t.tid]
        pref = "ddr" if hot else "nmp"
        other = "nmp" if pref == "ddr" else "ddr"
        chosen: List[int] = []
        for r in range(nrep):
            cls = pref if r % 2 == 0 else other
            pool = [i for i in classes[cls] if i not in chosen]
            if not pool:                 # class exhausted: spill anywhere
                pool = [i for i in range(m) if i not in chosen]
            dest = max(pool, key=lambda i: capacities[i] - used[i])
            chosen.append(dest)
            used[dest] += t.size_bytes
        replicas[t.tid] = sorted(chosen)
    return Allocation(replicas=replicas, mn_used=used, n_replicas=nrep)


def allocate_fleet(tables: Sequence[TableInfo],
                   capacities: Sequence[int],
                   mn_types: Sequence[str],
                   owners: Sequence[int],
                   n_replicas: Optional[int] = None,
                   access_bytes: Optional[Sequence[float]] = None
                   ) -> Allocation:
    """Shared-table placement for a multi-model fleet on one MN pool.

    All models' tables (global tid space, ``owners[tid]`` = owning
    model) are placed together on the single pool — hot-on-DDR /
    capacity-on-NMP with the hot/cold median taken *within each model*,
    replicas class-preserving across models.  A fleet of one is exactly
    ``allocate_heterogeneous``.
    """
    if len(owners) != len(tables):
        raise ValueError(f"{len(owners)} owners for {len(tables)} tables")
    return allocate_heterogeneous(tables, capacities, mn_types,
                                  n_replicas=n_replicas,
                                  access_bytes=access_bytes,
                                  table_groups=owners)


def allocate_incremental(tables: Sequence[TableInfo],
                         capacities: Sequence[int],
                         mn_types: Sequence[str],
                         prev: Allocation,
                         n_replicas: Optional[int] = None,
                         exclude: Sequence[int] = ()) -> Allocation:
    """Minimal-movement re-allocation for an elastic pool resize.

    Every replica that still lands on a live MN of the new pool stays
    put; only replicas stranded on departed/excluded MNs are re-placed,
    and tables short of `n_replicas` (a grown pool may afford more)
    gain copies.  New copies follow the same node-type class policy as
    `allocate_heterogeneous`: in a mixed pool a table's replica set
    should keep spanning classes, so a top-up targets the class the
    surviving copies miss; within the class the most-available MN wins.
    A homogeneous pool degenerates to plain most-available placement.
    """
    m = len(capacities)
    if len(mn_types) != m:
        raise ValueError(f"{len(mn_types)} MN types for {m} capacities")
    dead = set(exclude)
    live = [i for i in range(m) if i not in dead]
    if not live:
        raise ValueError("resize leaves no live MN")
    nrep = min(n_replicas or prev.n_replicas, len(live))
    classes = {"nmp": [i for i in live if "nmp" in mn_types[i]],
               "ddr": [i for i in live if "nmp" not in mn_types[i]]}
    hetero = bool(classes["nmp"]) and bool(classes["ddr"])
    used = [0] * m
    replicas: Dict[int, List[int]] = {}
    order = sorted(tables, key=lambda t: -t.size_bytes)
    # first pass: keep every surviving placement (zero movement)
    for t in order:
        keep = [i for i in prev.replicas.get(t.tid, ())
                if i < m and i not in dead][:nrep]
        for i in keep:
            used[i] += t.size_bytes
        replicas[t.tid] = keep
    # second pass: top up stranded / newly-affordable replicas
    for t in order:
        chosen = replicas[t.tid]
        while len(chosen) < nrep:
            pool = [i for i in live if i not in chosen]
            if hetero:
                have = {("nmp" if "nmp" in mn_types[i] else "ddr")
                        for i in chosen}
                missing = [c for c in ("ddr", "nmp") if c not in have]
                if missing:
                    cls_pool = [i for c in missing for i in classes[c]
                                if i not in chosen]
                    pool = cls_pool or pool
            if not pool:
                break                        # nrep > live pool: clamp
            dest = max(pool, key=lambda i: capacities[i] - used[i])
            chosen.append(dest)
            used[dest] += t.size_bytes
        replicas[t.tid] = sorted(chosen)
    # third pass: rebalance.  A joining MN starts empty, and routing only
    # targets replica holders — without movement a grown pool would never
    # absorb load.  Shift replicas from the fullest to the emptiest MN
    # (class-preserving, so the placement policy survives) while a single
    # move still narrows the spread; each move strictly decreases
    # sum(used^2), so this terminates.
    groups = [classes["nmp"], classes["ddr"]] if hetero else [live]
    for group in groups:
        if len(group) < 2:
            continue
        while True:
            lo = min(group, key=lambda i: (used[i], i))
            hi = max(group, key=lambda i: (used[i], i))
            gap = used[hi] - used[lo]
            cands = [t for t in order
                     if hi in replicas[t.tid] and lo not in replicas[t.tid]
                     and t.size_bytes < gap]
            if not cands:
                break
            t = min(cands, key=lambda t: (abs(gap - 2 * t.size_bytes),
                                          t.tid))
            replicas[t.tid] = sorted(
                [i for i in replicas[t.tid] if i != hi] + [lo])
            used[hi] -= t.size_bytes
            used[lo] += t.size_bytes
    return Allocation(replicas=replicas, mn_used=used, n_replicas=nrep)


PARAM_STORE = -1          # migration source when no replica can stream


@dataclass
class MigrationPlan:
    """Incremental shard migration between two allocations.

    `moves` is one entry per embedding-table copy that must be created:
    (table id, source MN, destination MN).  The source is a surviving
    replica when one exists, else a departing replica being drained,
    else `PARAM_STORE` (re-streamed from the parameter store).  Dropped
    replicas are free — no bytes cross the fabric to delete a copy.
    """
    moves: List[Tuple[int, int, int]]
    dropped: List[Tuple[int, int]]           # (table id, MN) copies freed
    bytes_moved: int

    @property
    def n_moves(self) -> int:
        return len(self.moves)


def plan_migration(old: Allocation, new: Allocation,
                   tables: Sequence[TableInfo]) -> MigrationPlan:
    """Diff two allocations into the minimal copy set (elastic resize).

    Only tables whose placement changed appear in the plan; a table
    whose replica set is identical in both allocations moves nothing.
    """
    size = {t.tid: t.size_bytes for t in tables}
    moves: List[Tuple[int, int, int]] = []
    dropped: List[Tuple[int, int]] = []
    bytes_moved = 0
    for tid, new_reps in new.replicas.items():
        old_reps = list(old.replicas.get(tid, ()))
        added = [j for j in new_reps if j not in old_reps]
        removed = [j for j in old_reps if j not in new_reps]
        survivors = [j for j in old_reps if j in new_reps]
        for k, dst in enumerate(added):
            if survivors:
                src = survivors[k % len(survivors)]
            elif removed:                    # drain the departing copy
                src = removed[k % len(removed)]
            else:
                src = PARAM_STORE
            moves.append((tid, src, dst))
            bytes_moved += size.get(tid, 0)
        dropped += [(tid, j) for j in removed]
    return MigrationPlan(moves=moves, dropped=dropped,
                         bytes_moved=bytes_moved)


def route_random(tables: Sequence[TableInfo], alloc: Allocation,
                 n_tasks: int, m: int, seed: int = 0,
                 exclude: Sequence[int] = ()) -> RoutingTable:
    rng = _random.Random(seed)
    acc = [0.0] * m
    routes: Dict[Tuple[int, int], int] = {}
    dead = set(exclude)
    for task in range(n_tasks):
        for t in tables:
            cands = [i for i in alloc.replicas[t.tid] if i not in dead]
            if not cands:
                raise LookupError(f"table {t.tid}: all replicas failed")
            dest = rng.choice(cands)
            acc[dest] += t.access_bytes
            routes[(task, t.tid)] = dest
    return RoutingTable(routes=routes, mn_access=acc)


def imbalance(values: Sequence[float]) -> float:
    """max/mean load ratio (1.0 = perfectly balanced)."""
    vals = [v for v in values if v > 0] or [0.0]
    mean = sum(vals) / len(vals)
    return max(vals) / mean if mean else 1.0


def rebuild_after_failure(tables: Sequence[TableInfo], alloc: Allocation,
                          n_tasks: int, m: int,
                          failed: Sequence[int],
                          backup_capacity: int = 0):
    """MN failure handling (paper Fig. 7b).

    Returns (routing, reinitialized: bool, alloc). If every table still has
    a live replica we only re-run greedy routing over survivors; otherwise
    the serving unit re-initializes: backup MNs join and allocation is
    recomputed from scratch.
    """
    dead = set(failed)
    lost = [t for t in tables
            if all(r in dead for r in alloc.replicas[t.tid])]
    if not lost:
        routing = route_greedy(tables, alloc, n_tasks, m, exclude=failed)
        return routing, False, alloc
    # re-initialize with backups replacing dead MNs; survivors must absorb
    # the full replica set, so size their capacity for it (the old per-MN
    # usage is too small once the pool shrinks)
    live = max(1, m - len(dead))
    total = sum(t.size_bytes for t in tables)
    need = (alloc.n_replicas * total) // live + max(
        (t.size_bytes for t in tables), default=0)
    caps = [0 if i in dead else max(backup_capacity, need)
            for i in range(m)]
    new_alloc = allocate_greedy(tables, caps,
                                n_replicas=min(alloc.n_replicas, live))
    routing = route_greedy(tables, new_alloc, n_tasks, m, exclude=failed)
    return routing, True, new_alloc


def shard_assignment(alloc: Allocation, routing: RoutingTable,
                     n_tables: int, m: int, task: int = 0) -> List[List[int]]:
    """Per-MN table lists for the JAX table-sharded embedding op: the MN a
    task's lookups route to is the shard that owns the table for that task."""
    shards: List[List[int]] = [[] for _ in range(m)]
    for tid in range(n_tables):
        shards[routing.routes[(task, tid)]].append(tid)
    return shards
