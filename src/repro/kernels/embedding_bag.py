"""Fused embedding-bag (gather + pooling) Pallas kernel.

Near-memory reduction on TPU: the table lives in HBM; the grid walks
(bag, pooling-slot) and the BlockSpec index_map — driven by the
scalar-prefetched index array — streams exactly the needed (1, D) rows
into VMEM, double-buffered by the Pallas pipeline. Accumulation happens
in the revisited VMEM output block, so raw rows never cross back to HBM:
only the pooled Fsum is written out — the paper's NMP-DIMM insight,
VMEM-local.

Padding indices are negative: their loads are clamped to row 0 and the
accumulate is predicated off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, table_blk, out_blk):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        out_blk[...] = jnp.zeros_like(out_blk)

    @pl.when(idx_ref[b, p] >= 0)
    def _acc():
        out_blk[...] += table_blk[...].astype(out_blk.dtype)


def embedding_bag_1table(table: jax.Array, idx: jax.Array,
                         interpret: bool = True) -> jax.Array:
    """table: (R, D); idx: (B, P) int32, -1 padded -> pooled (B, D)."""
    R, D = table.shape
    B, P = idx.shape

    def table_map(b, p, idx_ref):
        # clamp padding to row 0; the accumulate is masked in the kernel
        return jnp.maximum(idx_ref[b, p], 0), 0

    def out_map(b, p, idx_ref):
        return b, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, P),
        in_specs=[pl.BlockSpec((1, D), table_map)],
        out_specs=pl.BlockSpec((1, D), out_map),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(idx, table)


def embedding_bag(tables: jax.Array, idx: jax.Array,
                  interpret: bool = True) -> jax.Array:
    """tables: (T, R, D); idx: (B, T, P) -> pooled (B, T, D)."""
    f = functools.partial(embedding_bag_1table, interpret=interpret)
    out = jax.vmap(f, in_axes=(0, 1), out_axes=1)(tables,
                                                  idx)  # (B, T, D)
    return out.astype(tables.dtype)


# --------------------------------------------------------- fused multi-table
def _fused_kernel(idx_ref, off_ref, table_blk, out_blk):
    b = pl.program_id(0)
    t = pl.program_id(1)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        out_blk[...] = jnp.zeros_like(out_blk)

    @pl.when(idx_ref[b, t, p] >= 0)
    def _acc():
        out_blk[...] += table_blk[...].astype(out_blk.dtype)


def embedding_bag_fused_flat(flat_table: jax.Array, offsets: jax.Array,
                             idx: jax.Array,
                             interpret: bool = True) -> jax.Array:
    """One Pallas call pooling every table of a (flattened) shard.

    flat_table: (sum_t R_t, D) — all tables stacked row-wise, so tables of
    different row counts coexist in one shard buffer.
    offsets:    (T,) int32 — scalar-prefetched row offset of each table in
    flat_table; with idx, it drives the BlockSpec index_map so the pipeline
    streams exactly one (1, D) row per (bag, table, slot) grid step.
    idx:        (B, T, P) int32, table-local rows, -1 padded.

    Returns pooled (B, T, D) fp32. Grid order (B, T, P) makes P innermost:
    each (b, t) output block is revisited P times and accumulated in VMEM —
    raw rows never return to HBM, only the pooled Fsum (the NMP insight,
    now amortizing ONE kernel launch across the whole shard instead of one
    vmapped call per table).
    """
    _, D = flat_table.shape
    B, T, P = idx.shape

    def table_map(b, t, p, idx_ref, off_ref):
        # clamp padding to the table's row 0; accumulate is masked off
        return off_ref[t] + jnp.maximum(idx_ref[b, t, p], 0), 0

    def out_map(b, t, p, idx_ref, off_ref):
        return b, t, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, T, P),
        in_specs=[pl.BlockSpec((1, D), table_map)],
        out_specs=pl.BlockSpec((1, 1, D), out_map),
    )
    return pl.pallas_call(
        _fused_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        interpret=interpret,
    )(idx, offsets, flat_table)


def embedding_bag_fused(tables: jax.Array, idx: jax.Array,
                        interpret: bool = True) -> jax.Array:
    """tables: (T, R, D); idx: (B, T, P) -> pooled (B, T, D) in one call."""
    T, R, D = tables.shape
    offsets = jnp.arange(T, dtype=jnp.int32) * R
    out = embedding_bag_fused_flat(tables.reshape(T * R, D), offsets, idx,
                                   interpret=interpret)
    return out.astype(tables.dtype)


# ------------------------------------------------------ near-memory pooling
def _nmp_kernel(idx_ref, off_ref, table_ref, out_blk, *, pool: int):
    t = pl.program_id(0)
    b = pl.program_id(1)

    def body(p, acc):
        r = off_ref[t] + jnp.maximum(idx_ref[b, t, p], 0)
        row = table_ref[pl.ds(r, 1), :].astype(jnp.float32)
        # exact skip for padding: select the OLD accumulator, never add 0.0
        # (keeps -0.0 rows bitwise and matches the fused kernel's predicate)
        return jnp.where(idx_ref[b, t, p] >= 0, acc + row[None], acc)

    acc = jax.lax.fori_loop(0, pool, body,
                            jnp.zeros(out_blk.shape, jnp.float32))
    out_blk[...] = acc


def embedding_bag_nmp_flat(flat_table: jax.Array, offsets: jax.Array,
                           idx: jax.Array,
                           interpret: bool = True) -> jax.Array:
    """On-MN pooling kernel for an NMP memory node (paper §NMP, Fig. 14).

    Same contract as ``embedding_bag_fused_flat`` — flat_table
    (sum_t R_t, D) with scalar-prefetched per-table ``offsets`` and
    table-local ``idx`` (B, T, P), -1 padded — but a different execution
    shape that mirrors the NMP-DIMM: the grid walks (table, bag) — one
    step per *pooled output* — and the whole bag reduces inside the
    kernel body with a sequential ``fori_loop`` over pooling slots,
    accumulating in a local register/VMEM accumulator.  Rows are fetched
    with dynamic slices from the resident shard buffer (the DIMM-rank
    fetch; on real NMP hardware each fetch stays inside the rank), and
    only the D-dim pooled Fsum is ever written out — the memory node
    ships ``tables x D`` bytes to the CN instead of ``rows x D``.

    Slots accumulate in ascending order, the same order the fused
    CN-side bag revisits its output block, so fp32 results are bitwise
    identical to ``embedding_bag_fused_flat`` and to
    ``kernels.ref.embedding_bag_seq_ref`` (tests pin this).
    """
    Rtot, D = flat_table.shape
    B, T, P = idx.shape

    def table_map(t, b, idx_ref, off_ref):
        return 0, 0                     # shard buffer resident on the node

    def out_map(t, b, idx_ref, off_ref):
        return b, t, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(T, B),
        in_specs=[pl.BlockSpec((Rtot, D), table_map)],
        out_specs=pl.BlockSpec((1, 1, D), out_map),
    )
    return pl.pallas_call(
        functools.partial(_nmp_kernel, pool=P),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        interpret=interpret,
    )(idx, offsets, flat_table)


def embedding_bag_nmp(tables: jax.Array, idx: jax.Array,
                      interpret: bool = True) -> jax.Array:
    """tables: (T, R, D); idx: (B, T, P) -> pooled (B, T, D) on-node."""
    T, R, D = tables.shape
    offsets = jnp.arange(T, dtype=jnp.int32) * R
    out = embedding_bag_nmp_flat(tables.reshape(T * R, D), offsets, idx,
                                 interpret=interpret)
    return out.astype(tables.dtype)
