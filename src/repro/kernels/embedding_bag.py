"""Fused embedding-bag (gather + pooling) Pallas kernel.

Near-memory reduction on TPU: the table lives in HBM; the grid walks
(bag, pooling-slot) and the BlockSpec index_map — driven by the
scalar-prefetched index array — streams exactly the needed (1, D) rows
into VMEM, double-buffered by the Pallas pipeline. Accumulation happens
in the revisited VMEM output block, so raw rows never cross back to HBM:
only the pooled Fsum is written out — the paper's NMP-DIMM insight,
VMEM-local.

Padding indices are negative: their loads are clamped to row 0 and the
accumulate is predicated off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, table_blk, out_blk):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        out_blk[...] = jnp.zeros_like(out_blk)

    @pl.when(idx_ref[b, p] >= 0)
    def _acc():
        out_blk[...] += table_blk[...].astype(out_blk.dtype)


def embedding_bag_1table(table: jax.Array, idx: jax.Array,
                         interpret: bool = True) -> jax.Array:
    """table: (R, D); idx: (B, P) int32, -1 padded -> pooled (B, D)."""
    R, D = table.shape
    B, P = idx.shape

    def table_map(b, p, idx_ref):
        # clamp padding to row 0; the accumulate is masked in the kernel
        return jnp.maximum(idx_ref[b, p], 0), 0

    def out_map(b, p, idx_ref):
        return b, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, P),
        in_specs=[pl.BlockSpec((1, D), table_map)],
        out_specs=pl.BlockSpec((1, D), out_map),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(idx, table)


def embedding_bag(tables: jax.Array, idx: jax.Array,
                  interpret: bool = True) -> jax.Array:
    """tables: (T, R, D); idx: (B, T, P) -> pooled (B, T, D)."""
    f = functools.partial(embedding_bag_1table, interpret=interpret)
    out = jax.vmap(f, in_axes=(0, 1), out_axes=1)(tables,
                                                  idx)  # (B, T, D)
    return out.astype(tables.dtype)
