"""Causal flash-attention forward Pallas kernel (train/prefill fast path).

Grid (B*H, nq, nk): online-softmax accumulation in VMEM scratch; KV blocks
stream HBM->VMEM; fully-masked blocks are skipped (pl.when) — the compile
-time-visible version of the causal-skip optimization. GQA is handled in
the k/v index_map (q head -> kv head), so KV is never materialized per
q-head.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_blk, k_blk, v_blk, o_blk, m_scr, l_scr, acc_scr,
            *, qb, kb, nk, causal, scale):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = i * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    k_pos = j * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    live = (not causal) or (j * kb <= i * qb + qb - 1)

    @pl.when(live)
    def _compute():
        q = q_blk[0].astype(jnp.float32)            # (qb, D)
        k = k_blk[0].astype(jnp.float32)            # (kb, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_scr[...]                          # (qb, 1)
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        m_scr[...] = m_new
        v = v_blk[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _flush():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-37)
        o_blk[0] = out.astype(o_blk.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool = True):
    """q: (B, H, S, D); k/v: (B, Hkv, T, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = H // Hkv
    qb = min(q_block, S)
    kb = min(kv_block, T)
    assert S % qb == 0 and T % kb == 0
    nq, nk = S // qb, T // kb
    scale = 1.0 / math.sqrt(D)

    q3 = q.reshape(B * H, S, D)

    def qmap(bh, i, j):
        return bh, i, 0

    def kvmap(bh, i, j):
        b, h = bh // H, bh % H
        return b * Hkv + h // G, j, 0

    k3 = k.reshape(B * Hkv, T, D)
    v3 = v.reshape(B * Hkv, T, D)

    kern = functools.partial(_kernel, qb=qb, kb=kb, nk=nk,
                             causal=causal, scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, D), qmap),
            pl.BlockSpec((1, kb, D), kvmap),
            pl.BlockSpec((1, kb, D), kvmap),
        ],
        out_specs=pl.BlockSpec((1, qb, D), qmap),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, D), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(B, H, S, D)
