"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in interpret mode (the Pallas body
executes as traced JAX); on TPU pass interpret=False (the default flips
on TPU backends).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import embedding_bag as _eb
from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(tables, idx, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _eb.embedding_bag(tables, idx, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_fused(tables, idx, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _eb.embedding_bag_fused(tables, idx, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_fused_flat(flat_table, offsets, idx, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _eb.embedding_bag_fused_flat(flat_table, offsets, idx,
                                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_nmp(tables, idx, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _eb.embedding_bag_nmp(tables, idx, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_nmp_flat(flat_table, offsets, idx, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _eb.embedding_bag_nmp_flat(flat_table, offsets, idx,
                                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(q, k, v, causal: bool = True, q_block: int = 128,
                    kv_block: int = 128, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _fa.flash_attention(q, k, v, causal=causal, q_block=q_block,
                               kv_block=kv_block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("kv_offset", "kv_block",
                                             "interpret"))
def flash_decode_partial(q, k_cache, v_cache, pos, kv_offset: int = 0,
                         kv_block: int = 256, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _fd.flash_decode_partial(q, k_cache, v_cache, pos,
                                    kv_offset=kv_offset,
                                    kv_block=kv_block, interpret=interpret)
