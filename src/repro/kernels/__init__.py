"""Pallas TPU kernels for the perf-critical compute layers.

embedding_bag : fused gather+pool — the TPU-native analogue of the
                paper's NMP-DIMM near-memory reduction (rows stream
                HBM->VMEM via scalar-prefetch-driven BlockSpecs and are
                reduced in VMEM; the gathered matrix never exists in HBM).
flash_attention : causal blocked attention for train/prefill.
flash_decode  : KV-block decode attention emitting (o, l, m) partials —
                the kernel under the sequence-sharded cache's Fsum
                combine.

All kernels are validated on CPU in interpret mode against ref.py.
"""
