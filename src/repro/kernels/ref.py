"""Pure-jnp oracles for every kernel (the test ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(tables, idx):
    """tables: (T, R, D); idx: (B, T, P) int32 (-1 pad) -> (B, T, D)."""
    from repro.models.dlrm import embedding_bag_ref as _ref
    return _ref(tables, idx)


def embedding_bag_seq_ref(tables, idx):
    """Order-exact oracle: accumulates pooling slots in ascending order,
    the same order the Pallas kernels revisit the output block — so fp32
    results match the kernels bitwise (jnp.sum may reassociate)."""
    valid = (idx >= 0)[..., None]                    # (B, T, P, 1)
    safe = jnp.maximum(idx, 0)
    rows = jax.vmap(lambda tb, ix: jnp.take(tb, ix, axis=0),
                    in_axes=(0, 1), out_axes=1)(tables, safe)  # (B,T,P,D)
    rows = jnp.where(valid, rows.astype(jnp.float32), 0.0)
    acc = jnp.zeros(rows.shape[:2] + rows.shape[3:], jnp.float32)
    for p in range(idx.shape[-1]):
        acc = acc + rows[:, :, p]
    return acc


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,H,S,D); k/v: (B,Hkv,T,D) -> (B,H,S,D) full softmax."""
    B, H, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, S, D)
    s = jnp.einsum("bhgsd,bhtd->bhgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        qp = jnp.arange(S)[:, None]
        kp = jnp.arange(T)[None, :]
        s = jnp.where(qp >= kp, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


def flash_decode_ref(q, k_cache, v_cache, pos, kv_offset: int = 0):
    """Partial decode attention (unnormalized o, l, m) — mirrors
    layers.decode_attention_local."""
    from repro.models.layers import decode_attention_local
    return decode_attention_local(q, k_cache, v_cache, pos,
                                  kv_offset=kv_offset)


def decode_attention_full_ref(q, k_cache, v_cache, pos):
    """Normalized single-shard decode attention output."""
    from repro.models.layers import combine_partials, decode_attention_local
    o, l, m = decode_attention_local(q, k_cache, v_cache, pos)
    return combine_partials(o, l, m, None)
