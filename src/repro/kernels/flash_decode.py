"""Decode attention Pallas kernel over a (local) KV-cache slice.

Emits per-shard PARTIALS (o, l, m) — the Fsum payload that crosses the
network in DisaggRec's near-memory-reduction scheme; the cross-shard
combine (layers.combine_partials) runs outside. The current position is
scalar-prefetched so future cache slots are masked without host sync.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_blk, k_blk, v_blk, o_blk, l_blk, m_blk,
            m_scr, l_scr, acc_scr, *, kb, nk, kv_offset, scale):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]
    blk_start = kv_offset + j * kb

    @pl.when(blk_start <= pos)
    def _compute():
        q = q_blk[0, 0].astype(jnp.float32)          # (G, D)
        k = k_blk[0, :, 0].astype(jnp.float32)       # (kb, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, kb)
        t = blk_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(t <= pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        m_scr[...] = m_new
        v = v_blk[0, :, 0].astype(jnp.float32)       # (kb, D)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _flush():
        o_blk[0, 0] = acc_scr[...].astype(o_blk.dtype)
        l_blk[0, 0] = l_scr[..., 0].astype(l_blk.dtype)
        m_blk[0, 0] = m_scr[..., 0].astype(m_blk.dtype)


def flash_decode_partial(q, k_cache, v_cache, pos, *, kv_offset: int = 0,
                         kv_block: int = 256, interpret: bool = True):
    """q: (B, H, D); caches: (B, T, Hkv, D); pos: scalar int32.

    Returns partials (o (B,H,D) f32 UNNORMALIZED, l (B,H) f32, m (B,H)
    f32) for combine_partials.
    """
    B, H, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    kb = min(kv_block, T)
    assert T % kb == 0
    nk = T // kb
    scale = 1.0 / math.sqrt(D)

    q4 = q.reshape(B, Hkv, G, D)

    def qmap(b, h, j, pos_ref):
        return b, h, 0, 0

    def kvmap(b, h, j, pos_ref):
        return b, j, h, 0

    def outmap(b, h, j, pos_ref):
        return b, h, 0, 0

    def lmmap(b, h, j, pos_ref):
        return b, h, 0

    kern = functools.partial(_kernel, kb=kb, nk=nk, kv_offset=kv_offset,
                             scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), qmap),
            pl.BlockSpec((1, kb, 1, D), kvmap),
            pl.BlockSpec((1, kb, 1, D), kvmap),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), outmap),
            pl.BlockSpec((1, 1, G), lmmap),
            pl.BlockSpec((1, 1, G), lmmap),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    o, l, m = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), q4, k_cache, v_cache)
    return (o.reshape(B, H, D), l.reshape(B, H), m.reshape(B, H))
