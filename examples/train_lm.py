"""End-to-end driver: train the ~100M-param assigned arch (smollm-135m)
for a few hundred steps on synthetic token streams, with checkpointing
and fault-tolerant restart.

Full-size run:     PYTHONPATH=src python examples/train_lm.py --steps 300
Quick smoke (CI):  PYTHONPATH=src python examples/train_lm.py --reduced --steps 40
"""
import argparse

from repro import configs
from repro.data.queries import ShardedLoader, lm_batch
from repro.models import registry
from repro.train.optimizer import OptConfig
from repro.train.train_loop import TrainLoopConfig, run_train_loop


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--ckpt", default="/tmp/repro_smollm_ckpt")
    args = p.parse_args()

    cfg = (configs.get_reduced("smollm-135m") if args.reduced
           else configs.get_config("smollm-135m"))
    model = registry.build(cfg)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    loader = ShardedLoader(
        lambda r: lm_batch(cfg.vocab_size, args.batch, args.seq, r))
    loop = TrainLoopConfig(steps=args.steps, log_every=10,
                           checkpoint_every=100, checkpoint_dir=args.ckpt)
    _, _, hist = run_train_loop(model, OptConfig(lr=3e-4), loader, loop)
    print(f"loss: {hist[0][1]:.3f} -> {hist[-1][1]:.3f}")


if __name__ == "__main__":
    main()
