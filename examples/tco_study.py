"""TCO evolution study (paper Figs. 10/13/14): monolithic vs
disaggregated vs NMP-provisioned clusters across RM1/RM2 V0-V5.

Run:  PYTHONPATH=src python examples/tco_study.py
"""
from repro import configs
from repro.core import allocator, tco

PEAK_LOAD = 2e5


def study(fam: str):
    print(f"— {fam.upper()} V0..V5 (peak load {PEAK_LOAD:.0f} samples/s) —")
    header = f"{'gen':6s} {'mono $M':>9s} {'disagg $M':>10s} {'saving':>8s} {'+NMP $M':>9s} {'saving':>8s}"
    print(header)
    for v in range(6):
        m = configs.get_generation(fam, v)
        try:
            bm, _ = allocator.best_unit(m, tco.monolithic_candidates()
                                        + tco.monolithic_nmp_candidates(),
                                        PEAK_LOAD)
            bd, _ = allocator.best_unit(m, tco.disagg_candidates(), PEAK_LOAD)
            bn, _ = allocator.best_unit(m, tco.disagg_candidates()
                                        + tco.disagg_candidates(mn_type="nmp_mn"),
                                        PEAK_LOAD)
        except ValueError as e:
            print(f"  v{v}: infeasible ({e})")
            continue
        s1 = 1 - bd.tco / bm.tco
        s2 = 1 - bn.tco / bm.tco
        print(f"  v{v:2d}  {bm.tco/1e6:9.2f} {bd.tco/1e6:10.2f} "
              f"{100*s1:7.1f}% {bn.tco/1e6:9.2f} {100*s2:7.1f}%")


if __name__ == "__main__":
    study("rm1")
    study("rm2")
    print("paper claims: disagg up to 49.3% (RM1); with NMP pools the "
          "disaggregated cluster saves 21-43.6% over 3 years")
