"""Quickstart: the paper's full pipeline at laptop scale in ~a minute.

1. Build a reduced RM1 (DLRM) model.
2. Run the greedy embedding allocation + MemAccess routing (C2).
3. Train it for a few steps on synthetic click logs.
4. Serve queries with sequential (lock-step) batching (C3).
5. Size a fleet with the failure-aware allocator and compare the TCO of
   monolithic vs disaggregated serving units (C4/C5).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro import configs
from repro.core import allocator, embedding_manager as em, tco
from repro.core.serving_unit import ServingUnitModel, UnitSpec
from repro.data.queries import (QueryDist, ShardedLoader, dlrm_batch,
                                dlrm_request_stream)
from repro.models import registry
from repro.serving.engine import DLRMServingEngine, Request
from repro.train.optimizer import OptConfig
from repro.train.train_loop import TrainLoopConfig, run_train_loop


def main():
    cfg = configs.get_reduced("rm1")
    model = registry.build(cfg)

    # --- C2: greedy embedding management over 4 "memory nodes"
    rng = np.random.RandomState(0)
    tables = [em.TableInfo(i, int(rng.lognormal(8, 1.0)) + 16, 16,
                           float(rng.lognormal(2, 0.7)) + 1)
              for i in range(cfg.dlrm.num_tables)]
    caps = [int(2.5 * sum(t.size_bytes for t in tables) / 4)] * 4
    alloc = em.allocate_greedy(tables, caps)
    routing = em.route_greedy(tables, alloc, n_tasks=2, m=4)
    print(f"[C2] nReplicas={alloc.n_replicas} "
          f"alloc imbalance={em.imbalance(alloc.mn_used):.3f} "
          f"routing imbalance={em.imbalance(routing.mn_access):.3f}")

    # --- train a few steps
    loader = ShardedLoader(lambda r: dlrm_batch(cfg, 32, r))
    _, _, hist = run_train_loop(
        model, OptConfig(kind="adagrad", lr=0.05), loader,
        TrainLoopConfig(steps=30, log_every=10))
    print(f"[train] BCE {hist[0][1]:.4f} -> {hist[-1][1]:.4f}")

    # --- serve with sequential query processing
    params = model.init(0)
    engine = DLRMServingEngine(model, params, batch_size=64)
    # the one sanctioned way to build an engine workload: a seeded
    # stream from dlrm_request_stream (gap_s=0 -> all arrive at t=0,
    # matching the historical hand-rolled batch)
    reqs = [Request(*r) for r in
            dlrm_request_stream(cfg, 16, seed=0, gap_s=0.0,
                                dist=QueryDist(mean_size=12,
                                               max_size=128))]
    results = engine.serve(reqs)
    print(f"[serve] {len(results)} queries, "
          f"{sum(r.outputs.size for r in results)} samples scored")

    # --- C4/C5: fleet sizing + TCO, full-size RM1.V0
    m0 = configs.get_generation("rm1", 0)
    best_mono, _ = allocator.best_unit(m0, tco.monolithic_candidates(), 2e5)
    best_dis, _ = allocator.best_unit(m0, tco.disagg_candidates(), 2e5)
    print(f"[TCO] monolithic ${best_mono.tco/1e6:.2f}M vs "
          f"disaggregated ${best_dis.tco/1e6:.2f}M "
          f"(saving {100 * (1 - best_dis.tco / best_mono.tco):.1f}%)")


if __name__ == "__main__":
    main()
