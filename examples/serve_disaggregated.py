"""Disaggregated serving scenario (C1+C3) + failure handling.

Runs the discrete-event cluster simulator for a {2 CN, 2 MN} serving
unit under both scheduling policies (paper Fig. 8), then injects MN/CN
failures and shows the recovery path (re-routing vs re-initialization),
serves a real-JAX DLRM through the multi-unit ClusterEngine — killing an
MN mid-stream to show live replica re-routing — and finally follows a
diurnal autoscaling schedule that grows/shrinks both pools while the
stream is in flight (paper Fig. 2b/11).

Run:  PYTHONPATH=src python examples/serve_disaggregated.py
"""
import numpy as np

from repro import configs
from repro.core import embedding_manager as em
from repro.core.scheduler import INTERLEAVED, SEQUENTIAL
from repro.core.serving_unit import ServingUnitModel, UnitSpec
from repro.data.queries import QueryDist, dlrm_request_stream
from repro.models.dlrm import DLRMModel
from repro.serving.autoscaler import Autoscaler, AutoscalerConfig
from repro.serving.cluster import ClusterConfig, ClusterEngine
from repro.serving.engine import Request
from repro.serving.simulator import ClusterSim, SimConfig


def main():
    m = configs.get_generation("rm1", 0)
    unit = UnitSpec(2, "cn_1g", 2, "ddr_mn")
    um = ServingUnitModel(m, unit)

    print("— Fig. 8: scheduling policy @250ms SLA —")
    res = {}
    for policy in (SEQUENTIAL, INTERLEAVED):
        sim = ClusterSim(um, SimConfig(policy=policy, batch_size=128,
                                       duration_s=8.0, warmup_s=2.0, seed=1))
        q = sim.latency_bounded_qps(sla=0.25, iters=8)
        res[policy] = q
        print(f"  {policy:12s}: {q:7.1f} qps")
    print(f"  sequential gain: "
          f"{100 * (res[SEQUENTIAL] / res[INTERLEAVED] - 1):.1f}% "
          f"(paper: ~28%)")

    print("— failure injection —")
    sim = ClusterSim(um, SimConfig(policy=SEQUENTIAL, batch_size=128,
                                   duration_s=8.0, warmup_s=2.0,
                                   inject_failures=True, seed=11))
    st = sim.run(res[SEQUENTIAL] * 0.8)
    print(f"  {st.failures} failures; p95 {st.p95 * 1e3:.1f}ms, "
          f"throughput {st.throughput_qps:.1f} qps")

    print("— MN failure: routing rebuild (C2) —")
    rng = np.random.RandomState(0)
    tables = [em.TableInfo(i, int(rng.lognormal(10, 1.0)) + 1, 128,
                           float(rng.lognormal(3, 0.8)) + 1)
              for i in range(256)]
    caps = [int(2.5 * sum(t.size_bytes for t in tables) / 4)] * 4
    alloc = em.allocate_greedy(tables, caps)
    routing, reinit, _ = em.rebuild_after_failure(tables, alloc, 2, 4, [1])
    print(f"  lost MN 1 -> reinit={reinit}; surviving-MN access imbalance "
          f"{em.imbalance([a for i, a in enumerate(routing.mn_access) if i != 1]):.3f}")

    print("— real-JAX ClusterEngine: {2 CN, 4 MN}, MN 1 dies mid-stream —")
    cfg = configs.get_reduced("rm1")
    model = DLRMModel(cfg)
    params = model.init(0)
    engine = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=32, n_replicas=2))
    reqs = [Request(*t) for t in dlrm_request_stream(
        cfg, 40, seed=1, dist=QueryDist(mean_size=8.0, max_size=64))]
    results, st = engine.serve(reqs, failures=[(0.04, 1)])
    print(f"  completed {st.completed}/{len(reqs)} queries, "
          f"{len(reqs) - st.completed} dropped; p95 {st.p95 * 1e3:.2f}ms")
    print(f"  MN failure at t=40ms -> reroutes={st.reroutes} "
          f"(replica fast path), reinit={st.reinits}; "
          f"surviving-MN access imbalance {st.imbalance:.3f}")
    v = engine.validate_latency_model()
    print(f"  latency accounting vs analytic unit model: "
          f"ratio {v['ratio']:.2f}")

    print("— heterogeneous pool: 2 DDR + 2 NMP memory nodes (Fig. 14) —")
    het = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=32, n_replicas=2,
        mn_types=["ddr_mn", "ddr_mn", "nmp_mn", "nmp_mn"]))
    res_h, st_h = het.serve(reqs)
    same = all(np.array_equal(a.outputs, b.outputs)
               for a, b in zip(sorted(results, key=lambda r: r.rid),
                               sorted(res_h, key=lambda r: r.rid)))
    mem, gat = sum(st_h.mn_access_bytes), sum(st_h.mn_gather_bytes)
    print(f"  scores bitwise-identical to the DDR pool: {same}")
    nb = max(het.batches_seen, 1)
    for j, t in enumerate(st_h.mn_types):
        print(f"  MN{j} [{t:6s}] scanned {st_h.mn_access_bytes[j] / 1e3:8.1f}KB "
              f"shipped {st_h.mn_gather_bytes[j] / 1e3:8.1f}KB "
              f"mean modeled G_S {het.mn_stage_s[j] / nb * 1e6:.2f}us/batch")
    print(f"  fabric traffic {gat / 1e6:.2f}MB vs {mem / 1e6:.2f}MB raw "
          f"({100 * (1 - gat / mem):.1f}% gather bytes saved on NMP shards)")

    print("— elastic autoscaling: diurnal resize schedule (Fig. 2b/11) —")
    span = 0.002 * len(reqs)
    toy = Autoscaler(AutoscalerConfig(        # {2 CN, 4 MN} is the peak
        qps_per_cn=0.5, qps_per_mn=0.25, min_cn=1, min_mn=2,
        max_cn=2, max_mn=4))
    events = toy.plan(peak_load=0.95, duration_s=span, steps=8)
    el = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=32, n_replicas=2))
    res_e, st_e = el.serve(reqs, resizes=events)
    same = all(np.array_equal(a.outputs, b.outputs)
               for a, b in zip(sorted(results, key=lambda r: r.rid),
                               sorted(res_e, key=lambda r: r.rid)))
    sched = " -> ".join(f"{{{e.n_cn},{e.m_mn}}}@{e.time_s * 1e3:.0f}ms"
                        for e in events)
    print(f"  schedule: {sched}")
    print(f"  {st_e.resizes} resizes applied, "
          f"{st_e.migration_bytes / 1e3:.1f}KB shard migration drained "
          f"to survivors; pool now {{{el.n_cn} CN, {el.m_mn} MN}}")
    print(f"  scores bitwise-identical to the fixed {{2 CN, 4 MN}} "
          f"pool: {same}")

    print("— skew-aware CN hot-row cache (Zipf alpha=1.05, Gupta et al.) —")
    sreqs = [Request(*t) for t in dlrm_request_stream(
        cfg, 40, seed=1, dist=QueryDist(mean_size=8.0, max_size=64,
                                        alpha=1.05))]
    base = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=32, n_replicas=2))
    res_b, st_b = base.serve(sreqs)
    cached = ClusterEngine(model, params, ClusterConfig(
        n_cn=2, m_mn=4, batch_size=32, n_replicas=2, cache_mb=16))
    res_k, st_k = cached.serve(sreqs, failures=[(0.04, 1)])
    same = all(np.array_equal(a.outputs, b.outputs)
               for a, b in zip(sorted(res_b, key=lambda r: r.rid),
                               sorted(res_k, key=lambda r: r.rid)))
    probes = st_k.cache_hits + st_k.cache_misses
    print(f"  {100 * st_k.cache_hits / max(probes, 1):.1f}% hit rate -> "
          f"{st_k.cache_bytes_saved / 1e6:.2f}MB gather bytes stayed on "
          f"the CN ({sum(st_b.mn_gather_bytes) / 1e6:.2f}MB uncached)")
    print(f"  MN 1 died mid-stream: {st_k.cache_invalidations} rows "
          f"invalidated (the tables whose serving copy moved), scores "
          f"still bitwise-identical to the uncached clean run: {same}")


if __name__ == "__main__":
    main()
