"""Disaggregated serving scenario (C1+C3) + failure handling.

Runs the discrete-event cluster simulator for a {2 CN, 2 MN} serving
unit under both scheduling policies (paper Fig. 8), then injects MN/CN
failures and shows the recovery path (re-routing vs re-initialization),
and finally walks the declarative scenario library
(``examples/scenarios/*.json``, built by ``serving.scenario.preset``)
through the real-JAX ClusterEngine's single front door
(``run_scenario``): a failover storm with timed recoveries, a diurnal
elastic day (paper Fig. 2b/11), a skew-drift stream feeding the CN
hot-row cache, a heterogeneous DDR+NMP pool (Fig. 14), and a Poisson
flash crowd held under its p99 SLA by the feedback SLAController —
each bitwise-identical to its event-free baseline.

Run:  PYTHONPATH=src python examples/serve_disaggregated.py
"""
import dataclasses

import numpy as np

from repro import configs
from repro.core import embedding_manager as em
from repro.core.scheduler import INTERLEAVED, SEQUENTIAL
from repro.core.serving_unit import ServingUnitModel, UnitSpec
from repro.models.dlrm import DLRMModel
from repro.serving.scenario import FailMN, RecoverMN, preset, run_scenario
from repro.serving.simulator import ClusterSim, SimConfig


def main():
    m = configs.get_generation("rm1", 0)
    unit = UnitSpec(2, "cn_1g", 2, "ddr_mn")
    um = ServingUnitModel(m, unit)

    print("— Fig. 8: scheduling policy @250ms SLA —")
    res = {}
    for policy in (SEQUENTIAL, INTERLEAVED):
        sim = ClusterSim(um, SimConfig(policy=policy, batch_size=128,
                                       duration_s=8.0, warmup_s=2.0, seed=1))
        q = sim.latency_bounded_qps(sla=0.25, iters=8)
        res[policy] = q
        print(f"  {policy:12s}: {q:7.1f} qps")
    print(f"  sequential gain: "
          f"{100 * (res[SEQUENTIAL] / res[INTERLEAVED] - 1):.1f}% "
          f"(paper: ~28%)")

    print("— failure injection —")
    sim = ClusterSim(um, SimConfig(policy=SEQUENTIAL, batch_size=128,
                                   duration_s=8.0, warmup_s=2.0,
                                   inject_failures=True, seed=11))
    st = sim.run(res[SEQUENTIAL] * 0.8)
    print(f"  {st.failures} failures; p95 {st.p95 * 1e3:.1f}ms, "
          f"throughput {st.throughput_qps:.1f} qps")

    print("— MN failure: routing rebuild (C2) —")
    rng = np.random.RandomState(0)
    tables = [em.TableInfo(i, int(rng.lognormal(10, 1.0)) + 1, 128,
                           float(rng.lognormal(3, 0.8)) + 1)
              for i in range(256)]
    caps = [int(2.5 * sum(t.size_bytes for t in tables) / 4)] * 4
    alloc = em.allocate_greedy(tables, caps)
    routing, reinit, _ = em.rebuild_after_failure(tables, alloc, 2, 4, [1])
    print(f"  lost MN 1 -> reinit={reinit}; surviving-MN access imbalance "
          f"{em.imbalance([a for i, a in enumerate(routing.mn_access) if i != 1]):.3f}")

    # one model/params pair shared by every scenario below, so the
    # cross-scenario bitwise claims compare like with like
    cfg = configs.get_reduced("rm1")
    model = DLRMModel(cfg)
    params = model.init(0)

    print("— scenario: failover storm (timed failures AND recoveries) —")
    spec = preset("failover_storm")
    rep = run_scenario(spec, model=model, params=params)
    clean = run_scenario(dataclasses.replace(spec, events=()),
                         model=model, params=params)
    print(f"  completed {rep.completed}/{rep.total}; "
          f"p95 {rep.stats.p95 * 1e3:.2f}ms; "
          f"failures={rep.stats.failures} recoveries={rep.stats.recoveries} "
          f"reroutes={rep.stats.reroutes}")
    for rec in rep.stats.events:
        print(f"  @{rec.time_s * 1e3:5.1f}ms {rec.event.kind:<11s} "
              f"mn={getattr(rec.event, 'mn', '-')} -> dead={list(rec.dead)}")
    print(f"  scores bitwise-identical to the event-free run: "
          f"{rep.bitwise_equal(clean)}")
    v = rep.latency_model
    print(f"  latency accounting vs analytic unit model: "
          f"ratio {v['ratio']:.2f}")

    print("— scenario: diurnal elastic day (Fig. 2b/11) —")
    spec = preset("diurnal_elastic")
    rep = run_scenario(spec, model=model, params=params)
    fixed = run_scenario(dataclasses.replace(spec, events=()),
                         model=model, params=params)
    sched = " -> ".join(
        f"{{{r.event.n_cn},{r.event.m_mn}}}@{r.time_s * 1e3:.0f}ms"
        for r in rep.stats.events)
    print(f"  schedule: {sched}")
    print(f"  {rep.stats.resizes} resizes applied, "
          f"{rep.stats.migration_bytes / 1e3:.1f}KB shard migration "
          f"drained to survivors; pool now "
          f"{{{rep.final_n_cn} CN, {rep.final_m_mn} MN}}")
    print(f"  scores bitwise-identical to the fixed "
          f"{{{fixed.final_n_cn} CN, {fixed.final_m_mn} MN}} pool: "
          f"{rep.bitwise_equal(fixed)}")

    print("— scenario: skew drift + CN hot-row cache (Gupta et al.) —")
    spec = preset("skew_drift")
    rep = run_scenario(spec, model=model, params=params)
    for ph in rep.phases:
        print(f"  phase {ph.index} @{ph.t_start * 1e3:3.0f}ms "
              f"alpha={ph.alpha:<4g} gap={ph.gap_s * 1e3:g}ms: "
              f"{ph.completed}/{ph.requests} completed, "
              f"p95 {ph.p95 * 1e3:.2f}ms")
    st_k = rep.stats
    probes = st_k.cache_hits + st_k.cache_misses
    print(f"  {100 * st_k.cache_hits / max(probes, 1):.1f}% hit rate as "
          f"the stream drifts uniform -> alpha=1.2 "
          f"({st_k.cache_bytes_saved / 1e3:.1f}KB gather bytes stayed "
          f"on the CN)")

    print("— scenario: mixed DDR+NMP pool, fail/recover/grow (Fig. 14) —")
    spec = preset("mixed_ddr_nmp")
    rep = run_scenario(spec, model=model, params=params)
    base = run_scenario(dataclasses.replace(
        spec, events=tuple(e for e in spec.events
                           if isinstance(e, (FailMN, RecoverMN)))),
        model=model, params=params)
    st_h = rep.stats
    mem = sum(st_h.mn_access_bytes) + st_h.retired_access_bytes
    gat = sum(st_h.mn_gather_bytes) + st_h.retired_gather_bytes
    for j, t in enumerate(st_h.mn_types):
        print(f"  MN{j} [{t:6s}] scanned "
              f"{st_h.mn_access_bytes[j] / 1e3:8.1f}KB "
              f"shipped {st_h.mn_gather_bytes[j] / 1e3:8.1f}KB")
    print(f"  fabric traffic {gat / 1e6:.2f}MB vs {mem / 1e6:.2f}MB raw "
          f"({100 * (1 - gat / mem):.1f}% gather bytes saved on NMP "
          f"shards); pool grew to {{{rep.final_n_cn} CN, "
          f"{rep.final_m_mn} MN}} mid-stream")
    print(f"  scores bitwise-identical to the un-grown pool: "
          f"{rep.bitwise_equal(base)}")

    print("— scenario: flash crowd + SLA feedback controller —")
    spec = preset("flash_crowd")
    rep = run_scenario(spec, model=model, params=params)
    off = run_scenario(dataclasses.replace(spec, sla_p99_s=None),
                       model=model, params=params)
    st_s = rep.stats
    peak_cn = max(r.n_cn for r in st_s.events)
    peak_mn = max(r.m_mn for r in st_s.events)
    print(f"  Poisson arrivals spike ~6x past the {{1 CN, 2 MN}} floor; "
          f"measured p99 feeds SLAController(sla={spec.sla_p99_s * 1e6:g}us)")
    print(f"  {st_s.sla_actions} live resize actions; pool peaked at "
          f"{{{peak_cn} CN, {peak_mn} MN}}, back to "
          f"{{{rep.final_n_cn} CN, {rep.final_m_mn} MN}} after the crowd")
    print(f"  p99 {st_s.p99 * 1e6:.0f}us controlled vs "
          f"{off.stats.p99 * 1e6:.0f}us uncontrolled "
          f"({off.stats.p99 / st_s.p99:.2f}x); queue wait p99 "
          f"{st_s.queue_wait_p99 * 1e6:.1f}us")


if __name__ == "__main__":
    main()
